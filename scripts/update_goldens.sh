#!/usr/bin/env bash
# Refresh the committed golden snapshots under rust/tests/goldens/.
#
# Goldens are COMMITTED to the repo and required in CI
# (HARP_REQUIRE_GOLDENS=1, no bootstrap step), so they catch cross-run
# regressions, not just intra-run nondeterminism. When an intentional
# model change moves the numbers, run this script and commit the diff —
# the review of that diff IS the review of the numeric change.
#
# Covers every snapshot in tests/golden_figures.rs: table1, the
# workload table, fig6–fig10 (+ the MoE fig6 variant), the contention-on
# evaluations, the allocation-policy ablation (fig_alloc_ablation), and
# the serving saturation-knee figures (fig_serving_knee and the
# per-class fig_serving_knee_class), and the disaggregated-serving
# comparison (fig_serving_disagg).
#
# Usage:
#   scripts/update_goldens.sh          # regenerate every golden
#   git diff rust/tests/goldens/       # inspect what moved, then commit
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== regenerating goldens (HARP_UPDATE_GOLDENS=1) =="
HARP_UPDATE_GOLDENS=1 HARP_THREADS="${HARP_THREADS:-4}" \
    cargo test -q --release --test golden_figures

echo
echo "== goldens now on disk =="
ls -l rust/tests/goldens/*.txt

if git status --porcelain rust/tests/goldens | grep -q .; then
    echo
    echo "goldens changed — review with 'git diff rust/tests/goldens/' and commit."
else
    echo
    echo "goldens unchanged — nothing to commit."
fi
