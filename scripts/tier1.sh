#!/usr/bin/env bash
# Tier-1 gate in one command: release build, full test suite, and a
# smoke invocation of the CLI figure drivers at a tiny mapper budget.
#
# Knobs:
#   HARP_THREADS        worker threads (default: core count, capped at 16)
#   HARP_TIER1_SAMPLES  mapper samples for the figures smoke run (default 8)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier1: float-sort lint =="
# NaN-hostile float sorting panics at runtime; total_cmp is total and
# panic-free. Ban partial_cmp in library code (comment lines, which may
# discuss the old pattern, are exempt).
if grep -rnH 'partial_cmp' rust/src --include='*.rs' | grep -vE ':[0-9]+:\s*//'; then
    echo "tier1 FAIL: partial_cmp in rust/src — use total_cmp for float ordering"
    exit 1
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

BIN=target/release/harp
SAMPLES="${HARP_TIER1_SAMPLES:-8}"

echo "== tier1: CLI smoke =="
"$BIN" taxonomy > /dev/null
"$BIN" classify neupim > /dev/null
"$BIN" roofline > /dev/null
"$BIN" topology list > /dev/null
"$BIN" topology hier+xdepth > /dev/null
"$BIN" topology --file examples/topologies/fig4h_compound.json > /dev/null
# Workload front-end: registry listing, built-in + file cascades, and
# the loud-error path when a workload file is combined with --model.
"$BIN" workload list > /dev/null
"$BIN" workload moe_decode > /dev/null
"$BIN" workload --file examples/workloads/moe_decode.json > /dev/null
"$BIN" eval --workload examples/workloads/moe_decode.json --machine hier+xnode \
    --samples 20 --json > /dev/null
"$BIN" eval --model gqa_decode --machine leaf+xnode --samples 20 --json > /dev/null
if "$BIN" eval --workload examples/workloads/moe_decode.json --model bert \
    --machine leaf+homo --samples 20 > /dev/null 2>&1; then
    echo "tier1 FAIL: --workload FILE + --model should be a loud error"; exit 1
fi
"$BIN" eval --workload bert --machine leaf+xnode --samples 20 --json > /dev/null
"$BIN" eval --workload llama2 --samples 20 --json \
    --topology examples/topologies/fig4h_compound.json > /dev/null
# Contention model: booked evaluation on the shared-LLB machines, via
# the taxonomy generator and an explicit topology file with pinned
# capacity shares.
"$BIN" eval --workload llama2 --machine hier+xnode --samples 20 \
    --contention on --json > /dev/null
"$BIN" eval --workload llama2 --samples 20 --contention on --json \
    --topology examples/topologies/hier_xnode_shared_llb.json > /dev/null
# Allocation-policy engine: the schedule-aware search end-to-end, and
# the loud-error paths (unknown policy; --alloc alongside --config).
"$BIN" eval --workload llama2 --machine hier+xnode --samples 20 \
    --alloc search --json > /dev/null
if "$BIN" eval --workload bert --machine leaf+xnode --alloc bogus \
    --samples 20 > /dev/null 2>&1; then
    echo "tier1 FAIL: unknown --alloc policy should be a loud error"; exit 1
fi
# Persistent mapping cache: a cold run spills it, a warm run serves
# from it with byte-identical --json output, and --mapping-cache
# alongside --config is a loud conflict (the config's "mapping_cache"
# key owns that knob).
rm -f target/tier1-mapping-cache.json
"$BIN" eval --workload llama2 --machine hier+xnode --samples 20 \
    --alloc search --mapping-cache target/tier1-mapping-cache.json \
    --json > target/tier1-mapcache-cold.json
test -s target/tier1-mapping-cache.json
"$BIN" eval --workload llama2 --machine hier+xnode --samples 20 \
    --alloc search --mapping-cache target/tier1-mapping-cache.json \
    --json > target/tier1-mapcache-warm.json
if ! cmp -s target/tier1-mapcache-cold.json target/tier1-mapcache-warm.json; then
    echo "tier1 FAIL: warm mapping-cache run must be byte-identical"; exit 1
fi
printf '{"workload":"bert","machine":"leaf+homo","samples":20}' \
    > target/tier1-eval-cfg.json
if "$BIN" eval --config target/tier1-eval-cfg.json \
    --mapping-cache target/tier1-mapping-cache.json > /dev/null 2>&1; then
    echo "tier1 FAIL: --mapping-cache alongside --config should be a loud error"
    exit 1
fi
# Binary cache spill (the fast path for million-point sweeps): a .bin
# extension selects it, cold/warm --json output stays byte-identical,
# and the loud-error paths hold — a knob contradicting the extension, a
# dead knob without a cache, and a corrupt binary file.
rm -f target/tier1-mapping-cache.bin
"$BIN" eval --workload llama2 --machine hier+xnode --samples 20 \
    --alloc search --mapping-cache target/tier1-mapping-cache.bin \
    --json > target/tier1-bincache-cold.json
test -s target/tier1-mapping-cache.bin
"$BIN" eval --workload llama2 --machine hier+xnode --samples 20 \
    --alloc search --mapping-cache target/tier1-mapping-cache.bin \
    --cache-format binary --json > target/tier1-bincache-warm.json
if ! cmp -s target/tier1-bincache-cold.json target/tier1-bincache-warm.json; then
    echo "tier1 FAIL: warm binary mapping-cache run must be byte-identical"; exit 1
fi
if ! cmp -s target/tier1-mapcache-cold.json target/tier1-bincache-cold.json; then
    echo "tier1 FAIL: JSON and binary caches must serve identical results"; exit 1
fi
if "$BIN" eval --workload llama2 --machine hier+xnode --samples 20 \
    --alloc search --mapping-cache target/tier1-mapping-cache.bin \
    --cache-format json > /dev/null 2>&1; then
    echo "tier1 FAIL: --cache-format contradicting the extension should be loud"
    exit 1
fi
if "$BIN" eval --workload bert --machine leaf+homo --samples 20 \
    --cache-format binary > /dev/null 2>&1; then
    echo "tier1 FAIL: --cache-format without --mapping-cache should be loud"; exit 1
fi
printf 'harp_bin corrupted' > target/tier1-corrupt-cache.bin
if "$BIN" eval --workload llama2 --machine hier+xnode --samples 20 \
    --alloc search --mapping-cache target/tier1-corrupt-cache.bin \
    > /dev/null 2>&1; then
    echo "tier1 FAIL: a corrupt binary cache should be a loud error"; exit 1
fi
# NDJSON sweep streaming: every emitted line is a standalone JSON object.
"$BIN" sweep --workload bert --samples 5 --threads "${HARP_THREADS:-4}" --json \
    > target/tier1-sweep.ndjson
test -s target/tier1-sweep.ndjson
rm -f target/tier1-mapping-cache-figs.json
"$BIN" figures --samples "$SAMPLES" --threads "${HARP_THREADS:-4}" \
    --cache target/tier1-eval-cache.json \
    --mapping-cache target/tier1-mapping-cache-figs.json > /dev/null
# Second figures run must be served from the disk-spilled caches (the
# coarse per-evaluation cache AND the fine-grained mapping cache).
"$BIN" figures --samples "$SAMPLES" --threads "${HARP_THREADS:-4}" \
    --cache target/tier1-eval-cache.json \
    --mapping-cache target/tier1-mapping-cache-figs.json > /dev/null
# And a third pair through the binary spills for BOTH cache layers.
rm -f target/tier1-eval-cache.bin target/tier1-mapping-cache-figs.bin
"$BIN" figures --samples "$SAMPLES" --threads "${HARP_THREADS:-4}" \
    --cache target/tier1-eval-cache.bin \
    --mapping-cache target/tier1-mapping-cache-figs.bin > /dev/null
test -s target/tier1-eval-cache.bin
"$BIN" figures --samples "$SAMPLES" --threads "${HARP_THREADS:-4}" \
    --cache target/tier1-eval-cache.bin \
    --mapping-cache target/tier1-mapping-cache-figs.bin > /dev/null

# Serving simulator: a text run, the NDJSON stream, the loud-error
# paths (--config conflict, unknown process), and the byte-identity
# acceptance gate — one fixed invocation across HARP_THREADS=1 and 4
# plus a repeat run must all agree byte-for-byte.
"$BIN" serve --arrivals poisson --seed 7 --requests 8 --samples "$SAMPLES" \
    > /dev/null
"$BIN" serve --arrivals bursty --seed 3 --requests 6 --samples "$SAMPLES" \
    --json > target/tier1-serve.ndjson
test -s target/tier1-serve.ndjson
printf '{"workload":"bert","machine":"hier+xnode","samples":8,"arrivals":{"process":"poisson","requests":6}}' \
    > target/tier1-serve-cfg.json
"$BIN" serve --config target/tier1-serve-cfg.json > /dev/null
if "$BIN" serve --config target/tier1-serve-cfg.json --load 4 > /dev/null 2>&1; then
    echo "tier1 FAIL: a stream knob alongside serve --config should be loud"; exit 1
fi
if "$BIN" eval --config target/tier1-serve-cfg.json > /dev/null 2>&1; then
    echo "tier1 FAIL: eval should reject a config with an 'arrivals' key"; exit 1
fi
if "$BIN" serve --arrivals sinusoid > /dev/null 2>&1; then
    echo "tier1 FAIL: an unknown arrival process should be a loud error"; exit 1
fi
HARP_THREADS=1 "$BIN" serve --arrivals poisson --seed 7 --requests 8 \
    --samples "$SAMPLES" > target/tier1-serve-t1.txt
HARP_THREADS=4 "$BIN" serve --arrivals poisson --seed 7 --requests 8 \
    --samples "$SAMPLES" > target/tier1-serve-t4.txt
HARP_THREADS=4 "$BIN" serve --arrivals poisson --seed 7 --requests 8 \
    --samples "$SAMPLES" > target/tier1-serve-t4b.txt
if ! cmp -s target/tier1-serve-t1.txt target/tier1-serve-t4.txt; then
    echo "tier1 FAIL: serve output must be byte-identical across HARP_THREADS"; exit 1
fi
if ! cmp -s target/tier1-serve-t4.txt target/tier1-serve-t4b.txt; then
    echo "tier1 FAIL: serve output must be byte-identical across runs"; exit 1
fi
# Class-aware admission + paged KV booking: a uniform interactive mix
# with default engine knobs must be byte-identical to the legacy
# invocation (byte-stable defaults), while the full knob set (mixed
# classes, batch SLO, paged booking, pressure placement) must be
# byte-identical across HARP_THREADS.
"$BIN" serve --arrivals poisson --seed 7 --requests 8 --samples "$SAMPLES" \
    --class-mix interactive > target/tier1-serve-uniform.txt
if ! cmp -s target/tier1-serve-t4.txt target/tier1-serve-uniform.txt; then
    echo "tier1 FAIL: uniform interactive class mix must not move the report"; exit 1
fi
HARP_THREADS=1 "$BIN" serve --arrivals poisson --seed 7 --requests 8 \
    --samples "$SAMPLES" --class-mix interactive:1,batch:3 \
    --kv-page-words 4096 --slo-ttft-batch 5e6 --placement pressure \
    > target/tier1-serve-classed-t1.txt
HARP_THREADS=4 "$BIN" serve --arrivals poisson --seed 7 --requests 8 \
    --samples "$SAMPLES" --class-mix interactive:1,batch:3 \
    --kv-page-words 4096 --slo-ttft-batch 5e6 --placement pressure \
    > target/tier1-serve-classed-t4.txt
if ! cmp -s target/tier1-serve-classed-t1.txt target/tier1-serve-classed-t4.txt; then
    echo "tier1 FAIL: classed/paged serve must be byte-identical across HARP_THREADS"
    exit 1
fi
grep -q 'class interactive' target/tier1-serve-classed-t1.txt
grep -q 'class batch' target/tier1-serve-classed-t1.txt
grep -q 'kv pages 4096 words each' target/tier1-serve-classed-t1.txt
if "$BIN" serve --class-mix gold > /dev/null 2>&1; then
    echo "tier1 FAIL: an unknown request class should be a loud error"; exit 1
fi
# Disaggregated prefill/decode serving: the split runs on a two-type
# machine with byte-identical repeats, and the loud-error paths hold —
# an unknown role, a single-type machine, and --disagg alongside
# --config.
"$BIN" serve --arrivals poisson --seed 7 --requests 8 --samples "$SAMPLES" \
    --machine hier+xnode --disagg prefill=high,decode=low \
    > target/tier1-serve-disagg-a.txt
"$BIN" serve --arrivals poisson --seed 7 --requests 8 --samples "$SAMPLES" \
    --machine hier+xnode --disagg prefill=high,decode=low \
    > target/tier1-serve-disagg-b.txt
if ! cmp -s target/tier1-serve-disagg-a.txt target/tier1-serve-disagg-b.txt; then
    echo "tier1 FAIL: disagg serve must be byte-identical across runs"; exit 1
fi
grep -q 'disagg prefill=high,decode=low' target/tier1-serve-disagg-a.txt
if "$BIN" serve --disagg prefill=gold,decode=low > /dev/null 2>&1; then
    echo "tier1 FAIL: an unknown disagg role should be a loud error"; exit 1
fi
if "$BIN" serve --machine leaf+homo --disagg prefill=high,decode=low \
    > /dev/null 2>&1; then
    echo "tier1 FAIL: disagg on a single-type machine should be a loud error"
    exit 1
fi
if "$BIN" serve --config target/tier1-serve-cfg.json \
    --disagg prefill=high,decode=low > /dev/null 2>&1; then
    echo "tier1 FAIL: --disagg alongside serve --config should be loud"; exit 1
fi

echo "== tier1: bench smoke (compile + one iteration) =="
# Every bench target compiles and runs exactly once, so bench drift
# breaks the gate instead of rotting silently. HARP_BENCH_SMOKE skips
# the statistical sampling; numbers printed here are meaningless.
HARP_BENCH_SMOKE=1 cargo bench --bench perf_hotpath > /dev/null

echo "tier1 OK"
