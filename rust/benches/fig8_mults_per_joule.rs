//! Fig 8: multiplications per joule (energy efficiency).

mod common;

use harp::coordinator::figures;

fn main() {
    common::banner("fig8_mults_per_joule", "Fig 8 — mults/J normalized to leaf+homogeneous");
    let ev = common::evaluator();
    figures::fig8_mults_per_joule(&ev).emit("fig8_mults_per_joule");
}
