//! Fig 1: roofline partitioning across sub-accelerators.

mod common;

use harp::coordinator::figures;

fn main() {
    common::banner("fig1_roofline", "Fig 1 — compute roof + bandwidth split");
    figures::fig1_roofline().emit("fig1_roofline");
    // The structural claims of Fig 1, asserted:
    let fig = figures::fig1_roofline();
    let homo = &fig.series[0];
    let high = &fig.series[1];
    let low = &fig.series[2];
    assert!(high.get("AI=1024").unwrap() > low.get("AI=1024").unwrap(), "high roof above low");
    assert!(low.get("AI=1").unwrap() > high.get("AI=1").unwrap(), "low-reuse unit gets more bw");
    assert!(homo.get("AI=1024").unwrap() >= high.get("AI=1024").unwrap(), "undivided roof");
    println!("fig1 structural checks PASS");
}
