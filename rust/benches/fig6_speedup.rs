//! Fig 6: speedup of configurations (a-d) vs leaf+homogeneous at the
//! 2048/512 b-per-cycle bandwidth sweep, plus the BERT utilisation zoom.

mod common;

use harp::coordinator::figures;

fn main() {
    common::banner("fig6_speedup", "Fig 6 — speedup normalized to leaf+homogeneous");
    let ev = common::evaluator();
    let (fig, zoom) = figures::fig6_speedup(&ev);
    fig.emit("fig6_speedup");
    zoom.emit("fig6_zoom_utilization");
}
