//! Table I: classification of existing works, plus Tables II/III.

mod common;

use harp::coordinator::figures;

fn main() {
    common::banner("table1_taxonomy", "Table I — existing works under the HARP taxonomy");
    println!("{}", figures::table1());
    println!("{}", figures::table2_table3());
}
