//! Shared bench plumbing: evaluation budget from the environment.

use harp::coordinator::experiment::EvalOptions;
use harp::coordinator::figures::Evaluator;

/// Mapper samples per unique shape (override: HARP_BENCH_SAMPLES).
pub fn bench_samples() -> usize {
    std::env::var("HARP_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400)
}

pub fn evaluator() -> Evaluator {
    let mut opts = EvalOptions::default();
    opts.samples = bench_samples();
    Evaluator::new(opts)
}

pub fn banner(name: &str, paper: &str) {
    println!("==============================================================");
    println!("HARP bench: {name}");
    println!("reproduces: {paper}");
    println!("mapper samples/shape: {}", bench_samples());
    println!("==============================================================\n");
}
