//! Fig 9: on-chip energy split between high- and low-reuse units.

mod common;

use harp::coordinator::figures;

fn main() {
    common::banner("fig9_subaccel_energy", "Fig 9 — on-chip energy by sub-accelerator role");
    let ev = common::evaluator();
    figures::fig9_subaccel_energy(&ev).emit("fig9_subaccel_energy");
}
