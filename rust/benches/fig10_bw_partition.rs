//! Fig 10: bandwidth-partitioning sensitivity (75/25 vs naive 50/50).

mod common;

use harp::coordinator::figures;

fn main() {
    common::banner("fig10_bw_partition", "Fig 10 — 75/25 vs 50/50 DRAM bandwidth split");
    let ev = common::evaluator();
    figures::fig10_bw_partition(&ev).emit("fig10_bw_partition");
}
