//! Fig 7: energy broken down across memory-hierarchy levels.

mod common;

use harp::coordinator::figures;

fn main() {
    common::banner("fig7_energy", "Fig 7 — energy by memory level per configuration");
    let ev = common::evaluator();
    for (i, fig) in figures::fig7_energy(&ev).into_iter().enumerate() {
        fig.emit(&format!("fig7_energy_{i}"));
    }
}
