//! §Perf: timing benchmarks for the framework's hot paths.
//!
//! - nest analysis (called O(10⁴-10⁵) times per mapper run)
//! - map-space search for one op (serial vs batched-parallel)
//! - whole-cascade blackbox mapping (parallel)
//! - DAG scheduling
//! - one full figure-grade evaluation
//! - incremental (`replay_delta`) vs full (`replay`) schedule replay
//!   under a local-search-style single-op move sequence
//! - a fig6-style multi-config sweep, serial vs the shared thread pool
//! - result serialization: tree-build-then-write vs the streaming
//!   `JsonStreamWriter` on a ≥100k-row synthetic sweep document
//!
//! Results feed EXPERIMENTS.md §Perf (before/after iteration log).

mod common;

use harp::arch::partition::{HardwareParams, MachineConfig};
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::coordinator::figures::{self, Evaluator};
use harp::hhp::scheduler::{schedule, ScheduleOptions};
use harp::mapper::blackbox::BlackboxMapper;
use harp::mapper::search::{search_best, search_best_threaded, SearchBudget};
use harp::mapping::loopnest::Mapping;
use harp::model::nest::analyze;
use harp::util::benchkit::{bench_fn, bench_smoke};
use harp::util::threadpool::default_threads;
use harp::workload::einsum::{Dim, Phase, TensorOp};
use harp::workload::intensity::Classifier;
use harp::workload::transformer;
use std::time::{Duration, Instant};

fn main() {
    common::banner("perf_hotpath", "framework hot-path throughput (§Perf)");
    let budget = Duration::from_millis(600);
    // HARP_BENCH_SMOKE=1 (CI): every target runs once at a tiny mapper
    // budget — a compile-and-execute drift gate, not a measurement.
    let smoke = bench_smoke();
    let mapper_samples = if smoke { 20 } else { 400 };

    // --- nest analysis ---------------------------------------------------
    let machine = MachineConfig::build(
        &HarpClass::from_id("leaf+xnode").unwrap(),
        &HardwareParams::default(),
    )
    .unwrap();
    let spec = machine.sub_accels[0].spec.clone();
    let op = TensorOp::gemm("ffn1", Phase::Encoder, 3000, 12288, 49152);
    let mut m = Mapping::trivial(spec.levels.len(), &op);
    m.spatial_row = (Dim::M, spec.rows.min(3000));
    m.spatial_col = (Dim::N, spec.cols);
    m.temporal[3] = [1, 24, 192, 12288];
    let t = bench_fn("nest_analysis (GPT3 ffn1 mapping)", budget, 5000, || {
        let _ = std::hint::black_box(analyze(&op, &spec, &m));
    });
    println!("  → {:.2} M analyses/s\n", 1e9 / t.median_ns / 1e6);

    // --- single-op search --------------------------------------------------
    let sb = SearchBudget { samples: mapper_samples, seed: 1 };
    let serial = bench_fn("mapper search_best (400 samples, serial)", budget, 200, || {
        let _ = std::hint::black_box(search_best_threaded(&op, &spec, &sb, 1));
    });
    let par = bench_fn(
        &format!("mapper search_best (400 samples, {} threads)", default_threads()),
        budget,
        200,
        || {
            let _ = std::hint::black_box(search_best(&op, &spec, &sb));
        },
    );
    println!("  → single-op search speedup: {:.2}×\n", serial.median_ns / par.median_ns);

    // --- whole-cascade mapping ----------------------------------------------
    let cascade = transformer::decoder_cascade(&transformer::gpt3());
    let classifier = Classifier::new(machine.params.tipping_ai());
    let assignment = harp::hhp::allocator::allocate(&cascade, &machine, &classifier);
    let mapper =
        BlackboxMapper::with_budget(SearchBudget { samples: mapper_samples.min(200), seed: 1 });
    bench_fn("blackbox map_cascade (GPT3, 45 ops)", budget, 50, || {
        let _ = std::hint::black_box(mapper.map_cascade(&cascade, &machine, &assignment));
    });

    // --- scheduler -----------------------------------------------------------
    let mapped = mapper.map_cascade(&cascade, &machine, &assignment);
    bench_fn("scheduler (GPT3 DAG)", budget, 5000, || {
        let _ = std::hint::black_box(schedule(
            &cascade,
            &machine,
            &mapped,
            &ScheduleOptions { dynamic_bw: true },
        ));
    });

    // --- scheduler dependency queries: per-call scans vs CascadeAdj ---------
    // The scheduler's hot loops (critical-path priorities, ready-set
    // updates) used to call `Cascade::predecessors`/`successors`, each an
    // O(E) scan allocating a fresh Vec — O(V·E) per schedule. They now
    // index a `CascadeAdj` built once. The "before" below reimplements
    // the old per-call-scan priority pass for comparison on a dense
    // 400-op DAG (~30k edges).
    let mut big = harp::workload::cascade::Cascade::new("dense");
    let mut rng = harp::util::rng::Rng::new(0xAD7A);
    for i in 0..400 {
        big.push(TensorOp::gemm(&format!("n{i}"), Phase::Encoder, 8, 8, 8));
    }
    for i in 0..400 {
        for j in (i + 1)..400 {
            if rng.next_f64() < 0.4 {
                big.dep(i, j);
            }
        }
    }
    let lats: Vec<f64> = (0..400).map(|i| (i % 17 + 1) as f64).collect();
    let scan_priorities = |g: &harp::workload::cascade::Cascade| -> Vec<f64> {
        let order = g.topo_order().expect("valid DAG");
        let mut prio = vec![0.0f64; g.ops.len()];
        for &i in order.iter().rev() {
            let down =
                g.successors(i).into_iter().map(|s| prio[s]).fold(0.0f64, f64::max);
            prio[i] = lats[i] + down;
        }
        prio
    };
    let adj_priorities = |g: &harp::workload::cascade::Cascade| -> Vec<f64> {
        let adj = harp::workload::cascade::CascadeAdj::new(g);
        let order = g.topo_order_with(&adj).expect("valid DAG");
        let mut prio = vec![0.0f64; g.ops.len()];
        for &i in order.iter().rev() {
            let down = adj.succs[i].iter().map(|&s| prio[s]).fold(0.0f64, f64::max);
            prio[i] = lats[i] + down;
        }
        prio
    };
    assert_eq!(scan_priorities(&big), adj_priorities(&big));
    let before = bench_fn("priorities, per-call edge scans (400 ops)", budget, 200, || {
        let _ = std::hint::black_box(scan_priorities(&big));
    });
    let after = bench_fn("priorities, CascadeAdj (400 ops)", budget, 200, || {
        let _ = std::hint::black_box(adj_priorities(&big));
    });
    println!(
        "  → scheduler priority pass speedup: {:.1}× (identical output asserted)\n",
        before.median_ns / after.median_ns
    );

    // --- full evaluation -------------------------------------------------------
    let opts = EvalOptions { samples: mapper_samples.min(200), ..EvalOptions::default() };
    bench_fn("full evaluation (GPT3 × hier+xdepth)", Duration::from_secs(2), 20, || {
        let _ = std::hint::black_box(evaluate_cascade_on_config(
            &HarpClass::from_id("hier+xdepth").unwrap(),
            &HardwareParams::default(),
            &cascade,
            &opts,
        ));
    });

    // --- allocation-policy search: cost vs makespan gain ---------------------
    // The acceptance metric of the allocation engine: what the
    // schedule-aware `search` policy pays over `greedy` (cost-matrix
    // mapping of every op on every eligible unit + scheduler-replay
    // local search) and what it buys (makespan). Replays reuse one
    // `ScheduleOracle`, so the probe cost is the event loop alone —
    // the before/after of the `replay()` entry point.
    {
        use harp::hhp::allocator::AllocPolicy;
        let mut greedy_opts =
            EvalOptions { samples: mapper_samples.min(200), ..EvalOptions::default() };
        let mut search_opts = greedy_opts.clone();
        search_opts.alloc = AllocPolicy::Search;
        let class = HarpClass::from_id("hier+xnode").unwrap();
        let run = |opts: &EvalOptions| {
            let t0 = Instant::now();
            let r = evaluate_cascade_on_config(
                &class,
                &HardwareParams::default(),
                &cascade,
                opts,
            )
            .unwrap();
            (t0.elapsed().as_secs_f64(), r.stats.latency_cycles)
        };
        greedy_opts.threads = default_threads();
        search_opts.threads = default_threads();
        let (t_greedy, m_greedy) = run(&greedy_opts);
        let (t_search, m_search) = run(&search_opts);
        assert!(
            m_search <= m_greedy * (1.0 + 1e-9),
            "search must never schedule worse than greedy"
        );
        println!(
            "alloc search (GPT3 × hier+xnode): greedy {t_greedy:.2}s @ {m_greedy:.4e} cyc, \
             search {t_search:.2}s @ {m_search:.4e} cyc → {:.2}× search cost, {:.3}× makespan",
            t_search / t_greedy,
            m_search / m_greedy
        );
    }

    // --- incremental vs full schedule replay ---------------------------------
    // The acceptance metric of the incremental-replay rewrite: the
    // allocation search probes hundreds of single-op moves against one
    // `ScheduleOracle`, and `replay_delta` must amortise each probe to
    // the dirty suffix of the recorded timeline instead of
    // re-simulating every op. The DAG is the shape a search run spends
    // most of its probes on late in a walk — a heavy critical-path
    // spine plus hundreds of cheap leaves — with the moves landing on
    // late-anchored leaves, so the reusable prefix covers most of the
    // timeline. Makespan bits are asserted equal between the two entry
    // points on EVERY move; under HARP_BENCH_SMOKE=1 this section runs
    // as that structural bit-identity gate, not a measurement.
    {
        use harp::hhp::scheduler::ScheduleOracle;
        use harp::model::stats::OpStats;

        const SPINE: usize = 40;
        const LEAVES: usize = 460;
        let n = SPINE + LEAVES;
        let mut g = harp::workload::cascade::Cascade::new("spine+leaves");
        for i in 0..n {
            g.push(TensorOp::gemm(&format!("p{i}"), Phase::Encoder, 8, 8, 8));
        }
        for i in 1..SPINE {
            g.dep(i - 1, i);
        }
        for j in 0..LEAVES {
            g.dep(j % (SPINE - 2), SPINE + j); // leaves anchored along the spine
        }
        let machine = MachineConfig::build(
            &HarpClass::from_id("hier+xnode").unwrap(),
            &HardwareParams::default(),
        )
        .unwrap();
        let nsub = machine.sub_accels.len();
        assert!(nsub >= 2, "the move sequence needs two units to toggle between");
        // Synthetic per-(op, unit) costs: the spine dominates every
        // leaf's priority by three orders of magnitude, so a leaf move
        // never propagates into the spine's priorities — the probes
        // stay on the incremental path by construction (asserted via
        // replay_counts below). Leaf cost depends on the unit so every
        // move genuinely changes the moved op's latency.
        let costs: Vec<Vec<OpStats>> = (0..n)
            .map(|i| {
                (0..nsub)
                    .map(|u| {
                        let mut s = OpStats::new_empty();
                        s.cycles =
                            if i < SPINE { 1000.0 } else { (3 + i % 7 + u) as f64 };
                        s.compute_cycles = s.cycles;
                        s
                    })
                    .collect()
            })
            .collect();
        let stats_view = |a: &[usize]| -> Vec<&OpStats> {
            a.iter().enumerate().map(|(i, &u)| &costs[i][u]).collect()
        };
        let opts = ScheduleOptions { dynamic_bw: false };
        let mut full = ScheduleOracle::new(&g, &machine, &opts);
        let mut inc = ScheduleOracle::new(&g, &machine, &opts);
        let mut a: Vec<usize> = (0..n).map(|i| usize::from(i >= SPINE)).collect();
        let v = stats_view(&a);
        assert_eq!(full.replay(&a, &v).to_bits(), inc.replay_delta(&a, &v).to_bits());
        // Only leaves that become ready in the last ~10% of the spine:
        // their old ready time bounds the replayed-prefix length.
        let targets: Vec<usize> = (0..LEAVES)
            .filter(|j| j % (SPINE - 2) >= SPINE - 4)
            .map(|j| SPINE + j)
            .collect();
        assert!(!targets.is_empty());
        let moves = if smoke { 40 } else { 400 };
        let mut rng = harp::util::rng::Rng::new(0xDE17A5);
        let (mut t_full, mut t_inc) = (Duration::ZERO, Duration::ZERO);
        for _ in 0..moves {
            let leaf = targets[rng.next_below(targets.len())];
            a[leaf] = 1 - a[leaf];
            let v = stats_view(&a);
            let t0 = Instant::now();
            let m_full = full.replay(&a, &v);
            t_full += t0.elapsed();
            let t1 = Instant::now();
            let m_inc = inc.replay_delta(&a, &v);
            t_inc += t1.elapsed();
            assert_eq!(
                m_full.to_bits(),
                m_inc.to_bits(),
                "incremental replay diverged from full replay"
            );
        }
        assert_eq!(
            inc.replay_counts(),
            (1, moves),
            "every probe after the first must take the incremental path"
        );
        let speedup = t_full.as_secs_f64() / t_inc.as_secs_f64();
        println!(
            "incremental replay ({n}-op spine+leaves, {moves} single-leaf moves): \
             full {:.2} ms, incremental {:.2} ms → {speedup:.1}× \
             (≥5× required, 10× target; makespan bits equal on every move)",
            t_full.as_secs_f64() * 1e3,
            t_inc.as_secs_f64() * 1e3
        );
        if !smoke {
            assert!(
                speedup >= 5.0,
                "incremental replay speedup {speedup:.1}× is below the required 5×"
            );
        }
    }

    // --- parallel sweep throughput (fig6-style) ------------------------------
    // The acceptance metric of the parallel-sweep work: one full fig6
    // sweep (all workloads × taxonomy points × both bandwidths) with the
    // engine pinned to one worker vs the shared pool. A fresh Evaluator
    // per run keeps the cross-run cache from flattering either side; the
    // outputs are byte-identical by construction (asserted).
    let sweep_samples = if smoke { 8 } else { 150 };
    let sweep = |threads: usize| -> (f64, String) {
        let mut o = EvalOptions { samples: sweep_samples, ..EvalOptions::default() };
        o.threads = threads;
        let ev = Evaluator::new(o);
        let t0 = Instant::now();
        let (fig, zoom) = figures::fig6_speedup(&ev);
        (t0.elapsed().as_secs_f64(), format!("{}{}", fig.render(), zoom.render()))
    };
    let threads = default_threads();
    let (t_serial, out_serial) = sweep(1);
    let (t_par, out_par) = sweep(threads);
    assert_eq!(out_serial, out_par, "sweep output must be byte-identical across thread counts");
    println!(
        "fig6-style sweep: serial {t_serial:.2}s, {threads} threads {t_par:.2}s → {:.2}× speedup (byte-identical output)",
        t_serial / t_par
    );

    // --- result serialization: tree build vs streaming ------------------------
    // The acceptance metric of the streaming-serialization rewrite: a
    // synthetic sweep document the shape a million-point DSE run emits
    // (many series × many rows), serialized the old way — build the
    // full `Json` tree, render one monolithic `String` — vs streamed
    // row by row through `JsonStreamWriter`. The bytes are asserted
    // identical on every run (the structural gate smoke mode keeps);
    // outside smoke the streamed path must be ≥5× the tree path's
    // throughput, and the writer's reused scratch buffer must settle
    // (`scratch_growths` is the peak-allocation proxy: it stays a
    // small constant while the row count scales).
    {
        use harp::util::benchkit::{Figure, Series};
        use harp::util::json::{JsonStreamWriter, JsonStyle};

        let (nseries, nrows) = if smoke { (4, 500) } else { (12, 10_000) };
        let mut fig = Figure::new("synthetic sweep", "latency (cycles)");
        for s in 0..nseries {
            let mut series = Series::new(&format!("machine-{s} bw={}", 2048 >> (s % 3)));
            for r in 0..nrows {
                // Cycle counts: integral f64s, the sweep rows' real shape.
                series.push(
                    &format!("wl{:03}|pt{r:06}", r % 140),
                    (r * 137 + s * 7 + 3) as f64,
                );
            }
            fig.add(series);
        }
        let total_rows = nseries * nrows;

        // Byte identity between the two pipelines, asserted always.
        let tree_bytes = fig.to_json().to_string_compact();
        let mut w = JsonStreamWriter::new(Vec::new(), JsonStyle::Compact);
        fig.write_json(&mut w).unwrap();
        let growths = w.scratch_growths();
        let streamed = w.finish().unwrap();
        assert_eq!(
            tree_bytes.as_bytes(),
            &streamed[..],
            "streamed document diverged from the tree-built bytes"
        );
        assert!(
            growths <= 16,
            "scratch buffer grew {growths} times over {total_rows} rows — \
             the reused row buffer is not settling"
        );

        let iters = if smoke { 1 } else { 5 };
        let t0 = Instant::now();
        for _ in 0..iters {
            let _ = std::hint::black_box(fig.to_json().to_string_compact());
        }
        let t_tree = t0.elapsed();
        // The streamed side reuses one sink across iterations — the
        // deployment shape, where a single `BufWriter` carries the
        // whole document and no per-row buffer survives a row.
        let mut sink: Vec<u8> = Vec::new();
        let t1 = Instant::now();
        for _ in 0..iters {
            sink.clear();
            let mut w = JsonStreamWriter::new(&mut sink, JsonStyle::Compact);
            fig.write_json(&mut w).unwrap();
            w.finish().unwrap();
            std::hint::black_box(&sink);
        }
        let t_stream = t1.elapsed();
        let speedup = t_tree.as_secs_f64() / t_stream.as_secs_f64();
        println!(
            "serialization ({total_rows} rows × {iters} iters): tree {:.2} ms, \
             streamed {:.2} ms → {speedup:.1}× ({growths} scratch growth(s); \
             byte-identical output asserted)",
            t_tree.as_secs_f64() * 1e3,
            t_stream.as_secs_f64() * 1e3
        );
        if !smoke {
            assert!(
                speedup >= 5.0,
                "streaming serialization speedup {speedup:.1}× is below the required 5×"
            );
        }
    }

    // --- serving simulator throughput ----------------------------------------
    // The continuous-batching engine over a synthetic cost table (no
    // calibration — this times the step loop + per-step schedule
    // replays, not the mapper). Byte identity between two runs is the
    // structural gate smoke mode keeps: the engine is single-threaded
    // and seeded, so the report must never wobble.
    {
        use harp::runtime::serve::{
            build_serving_machine, simulate, FamilyCosts, ServeConfig, ServingCosts,
        };
        use harp::workload::arrivals::{synthesize, ArrivalKind, RequestFamily, StreamParams};

        let costs = ServingCosts::from_parts(
            RequestFamily::ALL
                .iter()
                .map(|&f| {
                    (
                        f,
                        FamilyCosts {
                            prefill_per_token: 50.0,
                            decode_per_token: 200.0,
                            base_kv: f.base_context() as f64,
                            d_model: f.d_model(),
                        },
                    )
                })
                .collect(),
        );
        let machine = build_serving_machine(
            &HarpClass::from_id("hier+xnode").unwrap(),
            2048.0,
            harp::arch::topology::ContentionMode::Off,
        )
        .unwrap();
        let stream = synthesize(&StreamParams {
            kind: ArrivalKind::Poisson,
            mix: RequestFamily::ALL.iter().map(|&f| (f, 1.0)).collect(),
            classes: vec![],
            load: 4.0,
            requests: 64,
            seed: 7,
        })
        .unwrap();
        let cfg = ServeConfig::default();
        let a = simulate(&stream, &machine, &costs, true, 4.0, &cfg).unwrap();
        let b = simulate(&stream, &machine, &costs, true, 4.0, &cfg).unwrap();
        assert_eq!(
            a.report.render(),
            b.report.render(),
            "serving report must be byte-identical across runs"
        );
        let t = bench_fn("serving simulate (64-req Poisson stream)", budget, 50, || {
            let _ = std::hint::black_box(
                simulate(&stream, &machine, &costs, true, 4.0, &cfg).unwrap(),
            );
        });
        println!(
            "  → {:.1} serve runs/s ({} completed, {} evictions; byte-identical report asserted)\n",
            1e9 / t.median_ns,
            a.report.completed,
            a.report.evictions
        );
    }
}
