//! Machine topology: the memory tree with sub-accelerators attached at
//! arbitrary nodes and depths.
//!
//! This is the machine's source of truth. A machine is a tree of storage
//! nodes rooted at DRAM; each sub-accelerator (a PE array plus its
//! register file) attaches to one node at any depth. Flattening an
//! accelerator's path to the root yields the innermost-first
//! [`ArchSpec`] level list the cost model consumes, so the tree widens
//! the design space without touching the per-op analysis.
//!
//! Three structural markers carry the HARP taxonomy (paper §IV):
//!
//! - **attach depth** — compute at ≥2 distinct depths ⇒ hierarchical
//!   placement;
//! - **accelerator type** (`ty`) — which units are instances of the same
//!   architecture. Heterogeneity exists between *distinct* types; the
//!   hierarchical+homogeneous point is the same type at two depths;
//! - **FSM groups** — units sharing a sequencer (B100 SM, RaPiD) are
//!   intra-node heterogeneous regardless of where their storage lives;
//! - **passthrough group nodes** — Symphony-style clusters: a grouping
//!   boundary that contributes no storage level but scopes the "repeated
//!   heterogeneous mix" test for clustered cross-node points.
//!
//! [`MachineTopology::classify`] derives the taxonomy point from these
//! markers alone; the partition generator's round-trip invariant
//! (generate → classify → same class) is tested for every point.
//!
//! DRAM bandwidth is partitioned per tree edge: every accelerator owns
//! an exclusive share (`dram_share`), and a node may pin an explicit
//! aggregate share for its whole subtree ([`MemoryNode::dram_share`]).
//! Without pinned edges the shares nest proportionally, and the
//! scheduler's dynamic re-grant reduces exactly to the flat
//! share-weighted formula (see [`MachineTopology::dram_shares`]).
//!
//! ## Shared-node contention
//!
//! Several units may *use* one storage node — attached at it directly,
//! or attached anywhere in its subtree so their root path passes through
//! it (hier+xnode's shared low LLB, clustered Symphony groups). Under
//! [`ContentionMode::Off`] every user sees the full node — capacity
//! double-booking, the pre-contention model. Under
//! [`ContentionMode::Booked`] each user books an exclusive slice:
//! pinned per-attachment ([`AccelNode::capacity_share`], words,
//! validated to sum ≤ the node capacity) or proportional to PE count
//! over what the pins leave free. Shared *edge* bandwidth (a node's
//! uplink feeding ≥2 users) is likewise split by DRAM-share weight, and
//! the scheduler re-grants idle users' slices along the tree exactly
//! like the DRAM re-grant ([`MachineTopology::shared_edge_bw`]).

use super::energy;
use super::level::{LevelKind, StorageLevel};
use super::partition::Role;
use super::spec::{ArchSpec, MappingConstraints};
use crate::util::json::Json;
use crate::workload::einsum::Dim;
use std::collections::BTreeSet;

/// How co-attached units treat shared tree nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ContentionMode {
    /// Every unit sees the full capacity and edge bandwidth of each node
    /// on its path — shared nodes are double-booked (the historical
    /// model; bit-identical to the pre-contention scheduler).
    #[default]
    Off,
    /// Units book exclusive capacity slices of shared nodes and contend
    /// for shared edge bandwidth while simultaneously busy.
    Booked,
}

impl ContentionMode {
    pub fn name(self) -> &'static str {
        match self {
            ContentionMode::Off => "off",
            ContentionMode::Booked => "on",
        }
    }

    /// Parse the CLI/config spelling (`off` | `on`, with `booked` as an
    /// alias for `on`).
    pub fn parse(s: &str) -> Result<ContentionMode, String> {
        match s {
            "off" => Ok(ContentionMode::Off),
            "on" | "booked" => Ok(ContentionMode::Booked),
            other => Err(format!("unknown contention mode '{other}' (off | on)")),
        }
    }
}

/// One storage node of the memory tree.
#[derive(Debug, Clone)]
pub struct MemoryNode {
    pub id: usize,
    pub kind: LevelKind,
    /// Instance label (distinct nodes of one kind need distinct labels).
    pub label: String,
    /// Capacity in words; `u64::MAX` for the unbounded root.
    pub size_words: u64,
    pub energy_pj_per_word: f64,
    /// Words per cycle the parent delivers down the edge to this node.
    /// For the root this is the machine's total DRAM bandwidth.
    pub bw_words_per_cycle: f64,
    /// Pinned aggregate DRAM-bandwidth share for this subtree, words per
    /// cycle. `None` (the default) lets the subtree's share float to the
    /// sum of its accelerators' shares.
    pub dram_share: Option<f64>,
    pub parent: Option<usize>,
    pub children: Vec<usize>,
    /// Grouping-only node (cluster boundary): no storage level.
    pub passthrough: bool,
}

/// One sub-accelerator attachment.
#[derive(Debug, Clone)]
pub struct AccelNode {
    pub label: String,
    /// Architectural type: units with equal `ty` are instances of the
    /// same sub-accelerator design (the taxonomy's homogeneity notion).
    pub ty: String,
    pub role: Role,
    pub rows: u64,
    pub cols: u64,
    pub rf_bytes_per_pe: u64,
    /// Node this unit's array hangs off.
    pub attach: usize,
    /// Words per cycle the attach node delivers to the array.
    pub attach_bw: f64,
    /// Exclusive share of the root (DRAM) bandwidth, words per cycle.
    pub dram_share: f64,
    /// Pinned capacity booking in words, applied at every *shared*
    /// bounded node on this unit's root path under
    /// [`ContentionMode::Booked`] (clamped to the node capacity; inert
    /// on nodes this unit has to itself). `None` books proportionally
    /// to PE count out of what the pinned units leave free.
    ///
    /// One word count per attachment: a unit whose path crosses SEVERAL
    /// shared bounded nodes of different sizes cannot express per-node
    /// pins — leave such units unpinned (proportional booking adapts to
    /// each node) rather than pinning a value sized for only one of
    /// them.
    pub capacity_share: Option<u64>,
    pub mac_energy_pj: f64,
    /// Units sharing a sequencer/FSM (intra-node heterogeneity marker).
    pub fsm_group: Option<usize>,
    pub constraints: MappingConstraints,
}

impl AccelNode {
    pub fn peak_macs(&self) -> u64 {
        self.rows * self.cols
    }
}

/// The machine as a memory tree. `nodes[0]` is always the root, and
/// every node's parent precedes it (pre-order ids) — both builders below
/// and the JSON parser maintain this.
#[derive(Debug, Clone, Default)]
pub struct MachineTopology {
    pub name: String,
    pub nodes: Vec<MemoryNode>,
    pub accels: Vec<AccelNode>,
}

impl MachineTopology {
    /// Start a tree with an unbounded DRAM root delivering
    /// `dram_bw_words` downward.
    pub fn new(name: &str, dram_bw_words: f64) -> MachineTopology {
        MachineTopology {
            name: name.into(),
            nodes: vec![MemoryNode {
                id: 0,
                kind: LevelKind::DRAM,
                label: "dram".into(),
                size_words: u64::MAX,
                energy_pj_per_word: energy::DRAM_PJ,
                bw_words_per_cycle: dram_bw_words,
                dram_share: None,
                parent: None,
                children: Vec::new(),
                passthrough: false,
            }],
            accels: Vec::new(),
        }
    }

    pub fn root(&self) -> usize {
        0
    }

    pub fn total_dram_bw(&self) -> f64 {
        self.nodes[0].bw_words_per_cycle
    }

    /// Add a storage node under `parent`. `uplink_bw` is the bandwidth
    /// the parent delivers to this node; energy defaults to the SRAM
    /// capacity fit when not given.
    pub fn add_node(
        &mut self,
        parent: usize,
        kind: LevelKind,
        label: &str,
        size_words: u64,
        uplink_bw: f64,
        energy_pj_per_word: Option<f64>,
    ) -> usize {
        let id = self.nodes.len();
        assert!(parent < id, "parent must precede child (pre-order ids)");
        self.nodes.push(MemoryNode {
            id,
            kind,
            label: label.into(),
            size_words,
            energy_pj_per_word: energy_pj_per_word
                .unwrap_or_else(|| energy::sram_pj(size_words)),
            bw_words_per_cycle: uplink_bw,
            dram_share: None,
            parent: Some(parent),
            children: Vec::new(),
            passthrough: false,
        });
        self.nodes[parent].children.push(id);
        id
    }

    /// Add a passthrough grouping node (cluster boundary) under `parent`.
    pub fn add_group(&mut self, parent: usize, label: &str) -> usize {
        let id = self.add_node(parent, LevelKind::named("GROUP"), label, 0, 0.0, Some(0.0));
        self.nodes[id].passthrough = true;
        id
    }

    /// Attach a sub-accelerator; returns its index.
    pub fn add_accel(&mut self, accel: AccelNode) -> usize {
        assert!(accel.attach < self.nodes.len(), "attach node exists");
        self.accels.push(accel);
        self.accels.len() - 1
    }

    /// Depth of a node: storage hops below the root, with passthrough
    /// group nodes contributing nothing.
    pub fn depth(&self, node: usize) -> usize {
        let mut d = 0;
        let mut cur = node;
        while let Some(p) = self.nodes[cur].parent {
            if !self.nodes[cur].passthrough {
                d += 1;
            }
            cur = p;
        }
        d
    }

    /// One accelerator's root path: the non-passthrough storage nodes
    /// from its attach node up to (and including) the root.
    pub fn accel_path(&self, idx: usize) -> Vec<usize> {
        let mut path = Vec::new();
        let mut cur = Some(self.accels[idx].attach);
        while let Some(i) = cur {
            if !self.nodes[i].passthrough {
                path.push(i);
            }
            cur = self.nodes[i].parent;
        }
        path
    }

    /// For every node, the accelerators whose root path passes through
    /// it (its *users*). A node with ≥2 users is shared: its capacity is
    /// double-booked unless contention is on.
    pub fn node_users(&self) -> Vec<Vec<usize>> {
        let mut users = vec![Vec::new(); self.nodes.len()];
        for a in 0..self.accels.len() {
            for n in self.accel_path(a) {
                users[n].push(a);
            }
        }
        users
    }

    /// Capacity slices of node `n` under [`ContentionMode::Booked`], as
    /// `(accel, words)` in user-index order. Pinned users book exactly
    /// their `capacity_share` (clamped to the node size); the rest split
    /// the remaining words proportionally to PE count, each guaranteed
    /// ≥ 1 word, summing exactly to the remainder. Unshared or unbounded
    /// nodes grant every user the full capacity.
    pub fn booked_capacities(&self, n: usize, users: &[usize]) -> Vec<(usize, u64)> {
        let size = self.nodes[n].size_words;
        if users.len() < 2 || size == u64::MAX {
            return users.iter().map(|&u| (u, size)).collect();
        }
        let pinned: u64 = users
            .iter()
            .filter_map(|&u| self.accels[u].capacity_share)
            .map(|s| s.min(size))
            .sum();
        let unpinned: Vec<usize> = users
            .iter()
            .copied()
            .filter(|&u| self.accels[u].capacity_share.is_none())
            .collect();
        let mut left = size.saturating_sub(pinned);
        let mut pes_left: u128 =
            unpinned.iter().map(|&u| self.accels[u].peak_macs() as u128).sum();
        let mut out = Vec::with_capacity(users.len());
        let mut k = 0usize;
        for &u in users {
            let words = match self.accels[u].capacity_share {
                Some(s) => s.min(size),
                None => {
                    // Sequential proportional split of what's left: exact
                    // sum, deterministic, and ≥1 word per unit as long as
                    // validate() held (remainder ≥ unpinned count).
                    let after = (unpinned.len() - 1 - k) as u64;
                    let pes = self.accels[u].peak_macs() as u128;
                    let take = if k + 1 == unpinned.len() {
                        left
                    } else {
                        let raw = (left as u128 * pes / pes_left.max(1)) as u64;
                        raw.max(1).min(left.saturating_sub(after))
                    };
                    left -= take;
                    pes_left -= pes;
                    k += 1;
                    take
                }
            };
            out.push((u, words));
        }
        out
    }

    /// Booked capacity of node `n` for accelerator `a` (see
    /// [`MachineTopology::booked_capacities`]).
    pub fn booked_capacity(&self, n: usize, a: usize) -> u64 {
        let users = self.node_users();
        self.booked_capacities(n, &users[n])
            .into_iter()
            .find(|&(u, _)| u == a)
            .map(|(_, w)| w)
            .unwrap_or(self.nodes[n].size_words)
    }

    /// Accelerator `a`'s grant of the edge feeding node `n` (bandwidth
    /// `n.bw_words_per_cycle`), when exactly the units with
    /// `busy[x] == true` contend: the edge splits over its busy users in
    /// proportion to their DRAM shares, idle users forfeiting to the
    /// busy — the per-edge analogue of [`MachineTopology::dram_shares`].
    /// An unshared edge goes to its sole user whole.
    pub fn shared_edge_bw(&self, n: usize, a: usize, users: &[usize], busy: &[bool]) -> f64 {
        let bw = self.nodes[n].bw_words_per_cycle;
        if users.len() < 2 {
            return bw;
        }
        let total: f64 = users.iter().map(|&u| self.accels[u].dram_share).sum();
        let busy_sum: f64 =
            users.iter().filter(|&&u| busy[u]).map(|&u| self.accels[u].dram_share).sum();
        // Static partition when the busy set is degenerate (no busy user
        // recorded — callers normally include `a` itself).
        let denom = if busy_sum > 0.0 { busy_sum } else { total };
        bw * self.accels[a].dram_share / denom
    }

    /// Structural validity of the tree and its attachments.
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() || self.nodes[0].parent.is_some() {
            return Err("topology needs a parentless root at index 0".into());
        }
        for n in &self.nodes {
            match n.parent {
                None if n.id != 0 => return Err(format!("node {} has no parent", n.label)),
                Some(p) if p >= n.id => {
                    return Err(format!("node {} precedes its parent", n.label))
                }
                _ => {}
            }
            if n.id != 0 && !n.passthrough {
                if n.size_words == 0 {
                    return Err(format!("storage node {} has zero capacity", n.label));
                }
                if n.bw_words_per_cycle <= 0.0 {
                    return Err(format!("storage node {} has no uplink bandwidth", n.label));
                }
            }
        }
        if self.accels.is_empty() {
            return Err("topology has no sub-accelerators".into());
        }
        // Labels key user-facing reports (node_contention, describe):
        // distinct nodes need distinct labels, or consumers matching by
        // name silently read the wrong node.
        let mut labels: Vec<&str> = self.nodes.iter().map(|n| n.label.as_str()).collect();
        labels.sort_unstable();
        if let Some(w) = labels.windows(2).find(|w| w[0] == w[1]) {
            return Err(format!(
                "duplicate node label '{}' — give each node a distinct 'label'",
                w[0]
            ));
        }
        let total = self.total_dram_bw();
        for n in &self.nodes {
            if let Some(share) = n.dram_share {
                // A zero/negative pinned share would starve the subtree
                // under dynamic re-granting (0 w/cyc ⇒ infinite latency)
                // — reject at parse time instead.
                if share <= 0.0 {
                    return Err(format!(
                        "node {}: pinned DRAM share must be positive",
                        n.label
                    ));
                }
                if share > total * (1.0 + 1e-9) {
                    return Err(format!(
                        "node {}: pinned DRAM share {share:.3} exceeds the root's {total:.3}",
                        n.label
                    ));
                }
            }
        }
        let mut share_sum = 0.0;
        for a in &self.accels {
            if a.attach >= self.nodes.len() {
                return Err(format!("accel {} attaches to a missing node", a.label));
            }
            if self.nodes[a.attach].passthrough {
                return Err(format!("accel {} attaches to a grouping node", a.label));
            }
            if a.rows == 0 || a.cols == 0 {
                return Err(format!("accel {} has an empty PE array", a.label));
            }
            if a.dram_share <= 0.0 || a.attach_bw <= 0.0 {
                return Err(format!("accel {} needs positive bandwidth shares", a.label));
            }
            share_sum += a.dram_share;
        }
        if share_sum > total * (1.0 + 1e-9) {
            return Err(format!(
                "accelerator DRAM shares sum to {share_sum:.3} w/cyc, above the root's {total:.3}"
            ));
        }
        for a in &self.accels {
            if a.capacity_share == Some(0) {
                return Err(format!(
                    "accel {}: pinned capacity share must be positive",
                    a.label
                ));
            }
        }
        // Capacity booking feasibility: at every shared bounded node the
        // pinned shares must fit, and must leave ≥1 word per unpinned
        // user (otherwise booking would hand out empty buffers and no
        // mapping could ever validate).
        for (n, users) in self.node_users().iter().enumerate() {
            let size = self.nodes[n].size_words;
            if users.len() < 2 || size == u64::MAX {
                continue;
            }
            let mut pinned: u64 = 0;
            let mut unpinned = 0u64;
            for &u in users {
                match self.accels[u].capacity_share {
                    Some(s) => {
                        if s > size {
                            return Err(format!(
                                "accel {}: capacity share {s} exceeds shared node {}'s \
                                 {size} words",
                                self.accels[u].label, self.nodes[n].label
                            ));
                        }
                        pinned = pinned.saturating_add(s);
                    }
                    None => unpinned += 1,
                }
            }
            if pinned > size {
                return Err(format!(
                    "node {}: pinned capacity shares sum to {pinned} words, above its {size}",
                    self.nodes[n].label
                ));
            }
            if size - pinned < unpinned {
                return Err(format!(
                    "node {}: pinned capacity shares leave {} word(s) for {} unpinned \
                     co-attached unit(s)",
                    self.nodes[n].label,
                    size - pinned,
                    unpinned
                ));
            }
        }
        Ok(())
    }

    /// Flatten one accelerator's path to the root into the
    /// innermost-first [`ArchSpec`] level list the cost model consumes.
    ///
    /// Level `i`'s bandwidth is what it delivers to level `i-1`: the
    /// attach node delivers `attach_bw` to the array, every higher node
    /// delivers the uplink bandwidth of the node below it, and the root
    /// delivers this unit's exclusive `dram_share`. Equivalent to
    /// [`MachineTopology::flatten_with`] at [`ContentionMode::Off`].
    pub fn flatten(&self, idx: usize) -> ArchSpec {
        self.flatten_with(idx, ContentionMode::Off)
    }

    /// Flatten under a contention mode. [`ContentionMode::Off`] hands
    /// every unit the full capacity and bandwidth of each node on its
    /// path (the historical double-booking).
    /// [`ContentionMode::Booked`] instead hands the unit its *booked*
    /// slice of every shared node's capacity
    /// ([`MachineTopology::booked_capacities`]) and its static
    /// DRAM-share-weighted fraction of every shared intermediate edge's
    /// bandwidth; exclusive nodes and edges, the attach port, and the
    /// outermost `dram_share` level are unchanged.
    pub fn flatten_with(&self, idx: usize, mode: ContentionMode) -> ArchSpec {
        let a = &self.accels[idx];
        let pes = a.rows * a.cols;
        let mut levels = vec![ArchSpec::rf_level(a.rf_bytes_per_pe, pes)];
        let path = self.accel_path(idx);
        let users = match mode {
            ContentionMode::Off => Vec::new(),
            ContentionMode::Booked => self.node_users(),
        };
        let all_busy = vec![true; self.accels.len()];
        let mut below_bw = a.attach_bw;
        let outer = path.len() - 1;
        for (j, &i) in path.iter().enumerate() {
            let n = &self.nodes[i];
            let bw = if j == outer {
                // The outermost boundary crosses the edge feeding the
                // node just below the root. Historically it carries the
                // unit's exclusive dram_share; under Booked, when that
                // edge is SHARED, co-attached units' shares must not
                // double-book it — cap at the share-weighted edge split
                // (a no-op on every generated machine, whose node
                // uplinks equal the units' DRAM shares by construction).
                match mode {
                    ContentionMode::Off => a.dram_share,
                    ContentionMode::Booked if outer >= 1
                        && users[path[outer - 1]].len() >= 2 =>
                    {
                        a.dram_share.min(self.shared_edge_bw(
                            path[outer - 1],
                            idx,
                            &users[path[outer - 1]],
                            &all_busy,
                        ))
                    }
                    ContentionMode::Booked => a.dram_share,
                }
            } else if j == 0 {
                a.attach_bw
            } else {
                below_bw
            };
            let size = match mode {
                ContentionMode::Off => n.size_words,
                ContentionMode::Booked => self
                    .booked_capacities(i, &users[i])
                    .into_iter()
                    .find(|&(u, _)| u == idx)
                    .map(|(_, w)| w)
                    .unwrap_or(n.size_words),
            };
            levels.push(StorageLevel::new(n.kind, size, bw, n.energy_pj_per_word));
            below_bw = match mode {
                ContentionMode::Off => n.bw_words_per_cycle,
                // The edge feeding this node serves every unit whose
                // path passes through it: the static partition assumes
                // all of them busy.
                ContentionMode::Booked => self.shared_edge_bw(i, idx, &users[i], &all_busy),
            };
        }
        ArchSpec {
            name: a.label.clone(),
            rows: a.rows,
            cols: a.cols,
            levels,
            mac_energy_pj: a.mac_energy_pj,
            constraints: a.constraints.clone(),
        }
    }

    /// Flatten every accelerator, in attachment order.
    pub fn flatten_all(&self) -> Vec<ArchSpec> {
        (0..self.accels.len()).map(|i| self.flatten(i)).collect()
    }

    /// Flatten every accelerator under a contention mode.
    pub fn flatten_all_with(&self, mode: ContentionMode) -> Vec<ArchSpec> {
        (0..self.accels.len()).map(|i| self.flatten_with(i, mode)).collect()
    }

    /// Does any node pin an explicit subtree bandwidth share?
    pub fn custom_edge_shares(&self) -> bool {
        self.nodes.iter().any(|n| n.dram_share.is_some())
    }

    /// Distribute the root bandwidth over the busy accelerators along
    /// the tree: at each node, the grant splits over busy subtrees and
    /// busy locally-attached units in proportion to their shares (a
    /// subtree's share is its pinned [`MemoryNode::dram_share`], or the
    /// sum of its busy units' shares when unpinned). Idle subtrees
    /// forfeit their share to their siblings — the NeuPIM-style re-grant
    /// generalised from a 2-way split to the whole tree.
    pub fn dram_shares(&self, busy: &[bool], total: f64) -> Vec<f64> {
        assert_eq!(busy.len(), self.accels.len());
        let n = self.nodes.len();
        // Busy share mass per subtree (reverse pre-order = children first).
        let mut mass = vec![0.0f64; n];
        for (i, a) in self.accels.iter().enumerate() {
            if busy[i] {
                mass[a.attach] += a.dram_share;
            }
        }
        for id in (1..n).rev() {
            let p = self.nodes[id].parent.expect("non-root has parent");
            mass[p] += mass[id];
        }
        // Weight a subtree bids at its parent: pinned share if busy.
        let weight = |id: usize| -> f64 {
            if mass[id] <= 0.0 {
                0.0
            } else {
                self.nodes[id].dram_share.unwrap_or(mass[id])
            }
        };
        let mut grant = vec![0.0f64; n];
        grant[0] = total;
        let mut out = vec![0.0f64; self.accels.len()];
        for id in 0..n {
            let g = grant[id];
            if g <= 0.0 {
                continue;
            }
            let mut wsum: f64 = self.nodes[id].children.iter().map(|&c| weight(c)).sum();
            for (i, a) in self.accels.iter().enumerate() {
                if busy[i] && a.attach == id {
                    wsum += a.dram_share;
                }
            }
            if wsum <= 0.0 {
                continue;
            }
            let scale = g / wsum;
            for &c in &self.nodes[id].children {
                grant[c] = weight(c) * scale;
            }
            for (i, a) in self.accels.iter().enumerate() {
                if busy[i] && a.attach == id {
                    out[i] = a.dram_share * scale;
                }
            }
        }
        out
    }

    // ---- Classification ---------------------------------------------------

    /// Derive the HARP taxonomy point from the tree structure alone:
    /// attach depths give the placement axis; type/FSM/cluster markers
    /// give the heterogeneity axis. The partition generator's invariant
    /// is `classify(generate(class)) == class` for every taxonomy point.
    pub fn classify(&self) -> Result<super::taxonomy::HarpClass, String> {
        use super::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
        if self.accels.is_empty() {
            return Err("cannot classify an empty machine".into());
        }
        let depths: Vec<usize> = self.accels.iter().map(|a| self.depth(a.attach)).collect();
        let distinct: BTreeSet<usize> = depths.iter().copied().collect();
        let placement = if distinct.len() >= 2 {
            ComputePlacement::Hierarchical
        } else {
            ComputePlacement::LeafOnly
        };

        // Types in first-appearance order, with their depth sets.
        let mut tys: Vec<&str> = Vec::new();
        for a in &self.accels {
            if !tys.contains(&a.ty.as_str()) {
                tys.push(&a.ty);
            }
        }
        let depth_set = |ty: &str| -> BTreeSet<usize> {
            self.accels
                .iter()
                .zip(&depths)
                .filter(|(a, _)| a.ty == ty)
                .map(|(_, &d)| d)
                .collect()
        };
        let share_fsm = |x: &str, y: &str| -> bool {
            self.accels.iter().filter(|a| a.ty == x).any(|a| {
                a.fsm_group.is_some()
                    && self
                        .accels
                        .iter()
                        .any(|b| b.ty == y && b.fsm_group == a.fsm_group)
            })
        };

        let clustered = self.has_repeated_clusters();
        let (mut intra, mut xnode, mut xdepth) = (false, false, false);
        for (i, &x) in tys.iter().enumerate() {
            for &y in &tys[i + 1..] {
                if share_fsm(x, y) {
                    intra = true;
                } else if depth_set(x).intersection(&depth_set(y)).next().is_some() {
                    xnode = true;
                } else {
                    xdepth = true;
                }
            }
        }

        let mut sources: Vec<HeterogeneityLoc> = Vec::new();
        if intra {
            sources.push(HeterogeneityLoc::IntraNode);
        }
        if xnode {
            sources.push(HeterogeneityLoc::CrossNode { clustered });
        }
        if xdepth {
            sources.push(HeterogeneityLoc::CrossDepth);
        }
        let heterogeneity = match sources.len() {
            0 => HeterogeneityLoc::Homogeneous,
            1 => sources.pop().unwrap(),
            _ => HeterogeneityLoc::Compound(sources),
        };
        let class = HarpClass::new(placement, heterogeneity);
        class.validate()?;
        Ok(class)
    }

    /// Symphony-style clustering: ≥2 sibling subtrees under the root
    /// whose accelerator-type multisets are equal and heterogeneous
    /// (≥2 distinct types).
    fn has_repeated_clusters(&self) -> bool {
        let mut multisets: Vec<Vec<&str>> = Vec::new();
        for &child in &self.nodes[0].children {
            let mut tys: Vec<&str> = self
                .accels
                .iter()
                .filter(|a| self.subtree_contains(child, a.attach))
                .map(|a| a.ty.as_str())
                .collect();
            tys.sort_unstable();
            if tys.iter().collect::<BTreeSet<_>>().len() >= 2 {
                multisets.push(tys);
            }
        }
        for (i, m) in multisets.iter().enumerate() {
            if multisets[i + 1..].contains(m) {
                return true;
            }
        }
        false
    }

    fn subtree_contains(&self, ancestor: usize, node: usize) -> bool {
        let mut cur = Some(node);
        while let Some(i) = cur {
            if i == ancestor {
                return true;
            }
            cur = self.nodes[i].parent;
        }
        false
    }

    // ---- Rendering ---------------------------------------------------------

    /// ASCII rendering of the tree (the `harp topology` output).
    pub fn describe(&self) -> String {
        let mut s = format!(
            "machine tree '{}': {} storage node(s), {} sub-accelerator(s), DRAM {:.0} w/cyc\n",
            self.name,
            self.nodes.iter().filter(|n| !n.passthrough).count(),
            self.accels.len(),
            self.total_dram_bw()
        );
        self.render_node(0, "", &mut s);
        s
    }

    fn render_node(&self, id: usize, prefix: &str, out: &mut String) {
        let n = &self.nodes[id];
        if n.parent.is_none() {
            out.push_str(&format!("{} [∞, {:.0} w/cyc total]\n", n.kind.name(), n.bw_words_per_cycle));
        }
        let accels: Vec<usize> = (0..self.accels.len())
            .filter(|&i| self.accels[i].attach == id)
            .collect();
        let total_rows = n.children.len() + accels.len();
        let mut row = 0usize;
        for &c in &n.children {
            row += 1;
            let last = row == total_rows;
            let (tee, bar) = if last { ("└─ ", "   ") } else { ("├─ ", "│  ") };
            let ch = &self.nodes[c];
            if ch.passthrough {
                out.push_str(&format!("{prefix}{tee}[{}]\n", ch.label));
            } else {
                let pin = match ch.dram_share {
                    Some(v) => format!(", pinned {v:.0} w/cyc"),
                    None => String::new(),
                };
                out.push_str(&format!(
                    "{prefix}{tee}{} {} [{} w, ↑{:.0} w/cyc{pin}]\n",
                    ch.kind.name(),
                    ch.label,
                    ch.size_words,
                    ch.bw_words_per_cycle
                ));
            }
            self.render_node(c, &format!("{prefix}{bar}"), out);
        }
        for &i in &accels {
            row += 1;
            let tee = if row == total_rows { "└─ " } else { "├─ " };
            let a = &self.accels[i];
            let mut fsm = match a.fsm_group {
                Some(g) => format!(", fsm {g}"),
                None => String::new(),
            };
            if let Some(w) = a.capacity_share {
                fsm.push_str(&format!(", books {w} w"));
            }
            out.push_str(&format!(
                "{prefix}{tee}◆ {} ({}, ty {}, {}×{} PEs, DRAM share {:.0} w/cyc{fsm})\n",
                a.label,
                a.role.name(),
                a.ty,
                a.rows,
                a.cols,
                a.dram_share
            ));
        }
    }

    // ---- JSON --------------------------------------------------------------

    /// Parse a machine description (the `--topology FILE` input; schema
    /// documented in the README). Defaults: label = level name, energy
    /// from the SRAM capacity fit, attach bandwidth `√PEs·16`, DRAM
    /// shares proportional to PE count for units that omit theirs.
    pub fn from_json(j: &Json) -> Result<MachineTopology, String> {
        let name = j.get("name").and_then(|v| v.as_str()).unwrap_or("custom").to_string();
        let root = j.get("root").ok_or("topology needs a 'root' node")?;
        let root_bw = root
            .get("bw_words_per_cycle")
            .and_then(|v| v.as_f64())
            .ok_or("root needs 'bw_words_per_cycle' (total DRAM bandwidth)")?;
        let mut t = MachineTopology::new(&name, root_bw);
        if let Some(kind) = root.get("level").and_then(|v| v.as_str()) {
            t.nodes[0].kind = LevelKind::named(kind);
        }
        t.parse_children(root, 0)?;
        t.parse_accels(root, 0)?;
        // Fill missing DRAM shares proportionally to PE count out of the
        // bandwidth explicit shares leave unclaimed.
        let missing: Vec<usize> =
            (0..t.accels.len()).filter(|&i| t.accels[i].dram_share <= 0.0).collect();
        if !missing.is_empty() {
            let claimed: f64 = t.accels.iter().map(|a| a.dram_share.max(0.0)).sum();
            let pes: u64 = missing.iter().map(|&i| t.accels[i].peak_macs()).sum();
            let pool = root_bw - claimed;
            if pool <= 0.0 {
                return Err("explicit DRAM shares leave no bandwidth for the rest".into());
            }
            for &i in &missing {
                t.accels[i].dram_share = pool * t.accels[i].peak_macs() as f64 / pes as f64;
            }
        }
        t.validate()?;
        Ok(t)
    }

    fn parse_children(&mut self, j: &Json, parent: usize) -> Result<(), String> {
        let Some(children) = j.get("children").and_then(|v| v.as_arr()) else {
            return Ok(());
        };
        for c in children {
            let id = if c.get("group").and_then(|v| v.as_bool()).unwrap_or(false) {
                let label = c.get("label").and_then(|v| v.as_str()).unwrap_or("group");
                self.add_group(parent, label)
            } else {
                let kind = c
                    .get("level")
                    .and_then(|v| v.as_str())
                    .ok_or("storage node needs a 'level' name")?;
                let size = c
                    .get("size_words")
                    .and_then(|v| v.as_u64())
                    .ok_or_else(|| format!("node '{kind}' needs 'size_words'"))?;
                let bw = c
                    .get("bw_words_per_cycle")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("node '{kind}' needs 'bw_words_per_cycle'"))?;
                let label = c.get("label").and_then(|v| v.as_str()).unwrap_or(kind).to_string();
                if c.get("capacity_share_words").is_some() {
                    // Capacity booking is a property of an attachment,
                    // not of a storage node — reject rather than
                    // silently ignore a share on a non-attachment edge.
                    return Err(format!(
                        "node '{label}': 'capacity_share_words' applies to accels \
                         (attachments), not storage nodes"
                    ));
                }
                let e = c.get("energy_pj_per_word").and_then(|v| v.as_f64());
                let id = self.add_node(parent, LevelKind::named(kind), &label, size, bw, e);
                if let Some(share) = c.get("dram_share_words").and_then(|v| v.as_f64()) {
                    self.nodes[id].dram_share = Some(share);
                }
                id
            };
            self.parse_children(c, id)?;
            self.parse_accels(c, id)?;
        }
        Ok(())
    }

    fn parse_accels(&mut self, j: &Json, node: usize) -> Result<(), String> {
        let Some(accels) = j.get("accels").and_then(|v| v.as_arr()) else {
            return Ok(());
        };
        for a in accels {
            let label = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("accel needs a 'name'")?
                .to_string();
            let rows = a
                .get("rows")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| format!("accel '{label}' needs 'rows'"))?;
            let cols = a
                .get("cols")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| format!("accel '{label}' needs 'cols'"))?;
            let role = match a.get("role").and_then(|v| v.as_str()).unwrap_or("unified") {
                "high" => Role::High,
                "low" => Role::Low,
                "unified" => Role::Unified,
                other => return Err(format!("accel '{label}': unknown role '{other}'")),
            };
            let ty = a.get("type").and_then(|v| v.as_str()).unwrap_or(&label).to_string();
            let rf = a.get("rf_bytes_per_pe").and_then(|v| v.as_u64()).unwrap_or(64);
            let pes = rows * cols;
            let attach_bw = a
                .get("attach_bw_words")
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| ArchSpec::default_attach_bw(pes));
            let dram_share =
                a.get("dram_share_words").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let capacity_share = match a.get("capacity_share_words") {
                None => None,
                Some(v) => match v.as_f64() {
                    Some(w) if w.is_finite() && w > 0.0 => Some(v.as_u64().ok_or_else(
                        || format!("accel '{label}': 'capacity_share_words' must be an integer"),
                    )?),
                    _ => {
                        return Err(format!(
                            "accel '{label}': 'capacity_share_words' must be a positive \
                             integer word count"
                        ))
                    }
                },
            };
            let mac = a
                .get("mac_energy_pj")
                .and_then(|v| v.as_f64())
                .unwrap_or(energy::MAC_PJ);
            let fsm_group = a.get("fsm").and_then(|v| v.as_usize());
            let mut constraints = MappingConstraints::default();
            if let Some(d) = a.get("forced_col_dim").and_then(|v| v.as_str()) {
                constraints.forced_col_dim = Some(match d {
                    "B" => Dim::B,
                    "M" => Dim::M,
                    "N" => Dim::N,
                    "K" => Dim::K,
                    other => {
                        return Err(format!("accel '{label}': unknown dim '{other}'"))
                    }
                });
            }
            if let Some(b) = a.get("no_dram_psum").and_then(|v| v.as_bool()) {
                constraints.no_dram_psum = b;
            }
            self.add_accel(AccelNode {
                label,
                ty,
                role,
                rows,
                cols,
                rf_bytes_per_pe: rf,
                attach: node,
                attach_bw,
                dram_share,
                capacity_share,
                mac_energy_pj: mac,
                fsm_group,
                constraints,
            });
        }
        Ok(())
    }

    /// Serialize back to the `--topology` JSON schema (inverse of
    /// [`MachineTopology::from_json`] up to defaulted fields).
    pub fn to_json(&self) -> Json {
        Json::obj().with("name", self.name.as_str()).with("root", self.node_json(0))
    }

    fn node_json(&self, id: usize) -> Json {
        let n = &self.nodes[id];
        let mut j = if n.passthrough {
            Json::obj().with("group", true).with("label", n.label.as_str())
        } else if n.parent.is_none() {
            Json::obj()
                .with("level", n.kind.name())
                .with("bw_words_per_cycle", n.bw_words_per_cycle)
        } else {
            let mut j = Json::obj()
                .with("level", n.kind.name())
                .with("label", n.label.as_str())
                .with("size_words", n.size_words)
                .with("bw_words_per_cycle", n.bw_words_per_cycle)
                .with("energy_pj_per_word", n.energy_pj_per_word);
            if let Some(share) = n.dram_share {
                j = j.with("dram_share_words", share);
            }
            j
        };
        if !n.children.is_empty() {
            let kids: Vec<Json> = n.children.iter().map(|&c| self.node_json(c)).collect();
            j = j.with("children", Json::Arr(kids));
        }
        let accels: Vec<Json> = self
            .accels
            .iter()
            .filter(|a| a.attach == id)
            .map(|a| {
                let role = match a.role {
                    Role::High => "high",
                    Role::Low => "low",
                    Role::Unified => "unified",
                };
                let mut j = Json::obj()
                    .with("name", a.label.as_str())
                    .with("type", a.ty.as_str())
                    .with("role", role)
                    .with("rows", a.rows)
                    .with("cols", a.cols)
                    .with("rf_bytes_per_pe", a.rf_bytes_per_pe)
                    .with("attach_bw_words", a.attach_bw)
                    .with("dram_share_words", a.dram_share)
                    .with("mac_energy_pj", a.mac_energy_pj);
                if let Some(w) = a.capacity_share {
                    j = j.with("capacity_share_words", w);
                }
                if let Some(g) = a.fsm_group {
                    j = j.with("fsm", g);
                }
                if let Some(d) = a.constraints.forced_col_dim {
                    j = j.with("forced_col_dim", d.name());
                }
                if a.constraints.no_dram_psum {
                    j = j.with("no_dram_psum", true);
                }
                j
            })
            .collect();
        if !accels.is_empty() {
            j = j.with("accels", Json::Arr(accels));
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::taxonomy::{ComputePlacement, HeterogeneityLoc};

    /// Hand-built leaf+xnode tree matching `ArchSpec::leaf` numbers.
    fn two_unit_tree() -> MachineTopology {
        let mut t = MachineTopology::new("t", 256.0);
        let llb_hi = t.add_node(0, LevelKind::LLB, "llb.hi", 3 << 20, 64.0, None);
        let l1_hi = t.add_node(llb_hi, LevelKind::L1, "l1.hi", 128 << 10, 819.2, None);
        let llb_lo = t.add_node(0, LevelKind::LLB, "llb.lo", 1 << 20, 192.0, None);
        let l1_lo = t.add_node(llb_lo, LevelKind::L1, "l1.lo", 128 << 10, 204.8, None);
        for (label, ty, role, rows, cols, attach, bw) in [
            ("high", "hi-array", Role::High, 128u64, 256u64, l1_hi, 2896.309),
            ("low", "lo-array", Role::Low, 64, 128, l1_lo, 1448.154),
        ] {
            t.add_accel(AccelNode {
                label: label.into(),
                ty: ty.into(),
                role,
                rows,
                cols,
                rf_bytes_per_pe: 64,
                attach,
                attach_bw: bw,
                dram_share: if role == Role::High { 64.0 } else { 192.0 },
                capacity_share: None,
                mac_energy_pj: crate::arch::energy::MAC_PJ,
                fsm_group: None,
                constraints: MappingConstraints::default(),
            });
        }
        t
    }

    #[test]
    fn flatten_matches_chain() {
        let t = two_unit_tree();
        t.validate().unwrap();
        let hi = t.flatten(0);
        assert_eq!(hi.levels.len(), 4);
        assert_eq!(hi.levels[0].kind, LevelKind::RF);
        assert_eq!(hi.levels[1].kind, LevelKind::L1);
        assert_eq!(hi.levels[1].size_words, 128 << 10);
        assert!((hi.levels[1].bw_words_per_cycle - 2896.309).abs() < 1e-9);
        assert_eq!(hi.levels[2].size_words, 3 << 20);
        assert!((hi.levels[2].bw_words_per_cycle - 819.2).abs() < 1e-9); // L1 uplink
        assert_eq!(hi.levels[3].kind, LevelKind::DRAM);
        assert!((hi.levels[3].bw_words_per_cycle - 64.0).abs() < 1e-9); // exclusive share
        assert_eq!(hi.levels[0].size_words, 64 * 128 * 256);
    }

    #[test]
    fn classify_two_unit_cross_node() {
        let t = two_unit_tree();
        let c = t.classify().unwrap();
        assert_eq!(c.placement, ComputePlacement::LeafOnly);
        assert_eq!(c.heterogeneity, HeterogeneityLoc::CrossNode { clustered: false });
    }

    #[test]
    fn classify_fsm_group_is_intra_node() {
        let mut t = two_unit_tree();
        t.accels[0].fsm_group = Some(0);
        t.accels[1].fsm_group = Some(0);
        assert_eq!(t.classify().unwrap().heterogeneity, HeterogeneityLoc::IntraNode);
    }

    #[test]
    fn classify_same_type_is_homogeneous() {
        let mut t = two_unit_tree();
        t.accels[1].ty = "hi-array".into();
        assert_eq!(t.classify().unwrap().heterogeneity, HeterogeneityLoc::Homogeneous);
    }

    #[test]
    fn classify_disjoint_depths_is_cross_depth() {
        let mut t = two_unit_tree();
        // Move the low unit up to its LLB node: depths {2} vs {1}.
        t.accels[1].attach = 3;
        let c = t.classify().unwrap();
        assert_eq!(c.placement, ComputePlacement::Hierarchical);
        assert_eq!(c.heterogeneity, HeterogeneityLoc::CrossDepth);
    }

    #[test]
    fn passthrough_groups_mark_clusters_without_levels() {
        let mut t = MachineTopology::new("sym", 256.0);
        for cl in 0..2 {
            let g = t.add_group(0, &format!("cluster{cl}"));
            let llb_hi =
                t.add_node(g, LevelKind::LLB, &format!("llb.hi.c{cl}"), 1 << 20, 32.0, None);
            let l1 =
                t.add_node(llb_hi, LevelKind::L1, &format!("l1.hi.c{cl}"), 64 << 10, 400.0, None);
            let llb_lo =
                t.add_node(g, LevelKind::LLB, &format!("llb.lo.c{cl}"), 1 << 20, 96.0, None);
            let l1_lo =
                t.add_node(llb_lo, LevelKind::L1, &format!("l1.lo.c{cl}"), 64 << 10, 100.0, None);
            for (label, ty, role, attach, share) in [
                (format!("hi.c{cl}"), "hi", Role::High, l1, 32.0),
                (format!("lo.c{cl}"), "lo", Role::Low, l1_lo, 96.0),
            ] {
                t.add_accel(AccelNode {
                    label,
                    ty: ty.into(),
                    role,
                    rows: 64,
                    cols: 64,
                    rf_bytes_per_pe: 64,
                    attach,
                    attach_bw: 512.0,
                    dram_share: share,
                    capacity_share: None,
                    mac_energy_pj: crate::arch::energy::MAC_PJ,
                    fsm_group: None,
                    constraints: MappingConstraints::default(),
                });
            }
        }
        t.validate().unwrap();
        // Group nodes contribute no storage level…
        let spec = t.flatten(0);
        assert_eq!(spec.levels.len(), 4); // RF, L1, LLB, DRAM — no GROUP
        // …but scope the clustered cross-node classification.
        let c = t.classify().unwrap();
        assert_eq!(c.heterogeneity, HeterogeneityLoc::CrossNode { clustered: true });
        assert_eq!(c.placement, ComputePlacement::LeafOnly);
        // All accels attach at the same tree depth despite the groups.
        assert_eq!(t.depth(t.accels[0].attach), t.depth(t.accels[3].attach));
    }

    #[test]
    fn dram_shares_regrant_idle_subtrees() {
        let t = two_unit_tree();
        let total = 256.0;
        // Both busy: static shares.
        let both = t.dram_shares(&[true, true], total);
        assert!((both[0] - 64.0).abs() < 1e-9);
        assert!((both[1] - 192.0).abs() < 1e-9);
        // Only the low unit busy: it inherits the whole root bandwidth.
        let solo = t.dram_shares(&[false, true], total);
        assert_eq!(solo[0], 0.0);
        assert!((solo[1] - 256.0).abs() < 1e-9);
    }

    #[test]
    fn pinned_edge_share_caps_a_subtree() {
        let mut t = two_unit_tree();
        // Pin the high subtree to a quarter of the root bandwidth even
        // though its unit's own share asks for 64/256.
        t.nodes[1].dram_share = Some(32.0);
        assert!(t.custom_edge_shares());
        let both = t.dram_shares(&[true, true], 256.0);
        // hi bids 32 against lo's 192: 32/224 and 192/224 of 256.
        assert!((both[0] - 256.0 * 32.0 / 224.0).abs() < 1e-9);
        assert!((both[1] - 256.0 * 192.0 / 224.0).abs() < 1e-9);
    }

    #[test]
    fn json_round_trip() {
        let t = two_unit_tree();
        let j = t.to_json();
        let back = MachineTopology::from_json(&j).unwrap();
        assert_eq!(back.nodes.len(), t.nodes.len());
        assert_eq!(back.accels.len(), t.accels.len());
        for (a, b) in t.accels.iter().zip(&back.accels) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.ty, b.ty);
            assert_eq!(a.attach, b.attach);
            assert_eq!(a.dram_share, b.dram_share);
        }
        for (a, b) in t.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.size_words, b.size_words);
            assert_eq!(a.parent, b.parent);
        }
        assert_eq!(back.classify().unwrap(), t.classify().unwrap());
    }

    #[test]
    fn json_defaults_fill_shares() {
        let doc = r#"{
          "name": "mini",
          "root": { "bw_words_per_cycle": 100,
            "children": [
              { "level": "LLB", "size_words": 4096, "bw_words_per_cycle": 100,
                "accels": [
                  { "name": "a", "rows": 4, "cols": 4 },
                  { "name": "b", "rows": 4, "cols": 12 } ] } ] } }"#;
        let t = MachineTopology::from_json(&Json::parse(doc).unwrap()).unwrap();
        assert_eq!(t.accels.len(), 2);
        // Shares proportional to PE count: 16 vs 48 PEs → 25 vs 75.
        assert!((t.accels[0].dram_share - 25.0).abs() < 1e-9);
        assert!((t.accels[1].dram_share - 75.0).abs() < 1e-9);
        // Both attach at the LLB: a 3-level flattened spec.
        assert_eq!(t.flatten(0).levels.len(), 3);
    }

    #[test]
    fn degenerate_pinned_shares_rejected() {
        let mut t = two_unit_tree();
        t.nodes[1].dram_share = Some(0.0);
        assert!(t.validate().unwrap_err().contains("positive"));
        t.nodes[1].dram_share = Some(-4.0);
        assert!(t.validate().is_err());
        t.nodes[1].dram_share = Some(1e6); // above the 256 w/cyc root
        assert!(t.validate().unwrap_err().contains("exceeds"));
        t.nodes[1].dram_share = Some(32.0);
        t.validate().unwrap();
    }

    /// Two units co-attached at one LLB node (the shared-node shape the
    /// contention model is about).
    fn co_attached_tree(shares: [Option<u64>; 2]) -> MachineTopology {
        let mut t = MachineTopology::new("co", 256.0);
        let llb = t.add_node(0, LevelKind::LLB, "llb.shared", 4096, 128.0, None);
        for (i, (pes, share)) in [(16u64, shares[0]), (48u64, shares[1])].iter().enumerate() {
            t.add_accel(AccelNode {
                label: format!("u{i}"),
                ty: format!("ty{i}"),
                role: Role::Unified,
                rows: 4,
                cols: pes / 4,
                rf_bytes_per_pe: 64,
                attach: llb,
                attach_bw: 64.0,
                dram_share: 128.0,
                capacity_share: *share,
                mac_energy_pj: crate::arch::energy::MAC_PJ,
                fsm_group: None,
                constraints: MappingConstraints::default(),
            });
        }
        t
    }

    #[test]
    fn booked_capacity_splits_shared_nodes_proportionally() {
        let t = co_attached_tree([None, None]);
        t.validate().unwrap();
        let users = t.node_users();
        assert_eq!(users[1], vec![0, 1]);
        let booked = t.booked_capacities(1, &users[1]);
        // 16 vs 48 PEs → 1024 vs 3072 of the 4096-word LLB, summing
        // exactly to the capacity.
        assert_eq!(booked, vec![(0, 1024), (1, 3072)]);
        assert_eq!(booked.iter().map(|&(_, w)| w).sum::<u64>(), 4096);
        // Unshared nodes (and the unbounded root) stay whole.
        assert_eq!(t.booked_capacity(0, 0), u64::MAX);
    }

    #[test]
    fn pinned_capacity_shares_book_exactly() {
        let t = co_attached_tree([Some(512), None]);
        t.validate().unwrap();
        assert_eq!(t.booked_capacity(1, 0), 512);
        // The unpinned sibling takes everything the pin leaves.
        assert_eq!(t.booked_capacity(1, 1), 4096 - 512);
    }

    #[test]
    fn flatten_booked_hands_out_slices_but_off_is_unchanged() {
        let t = co_attached_tree([None, None]);
        let off = t.flatten_with(0, ContentionMode::Off);
        assert_eq!(off.levels[1].size_words, 4096); // full node
        for (a, b) in off.levels.iter().zip(&t.flatten(0).levels) {
            assert_eq!(a.size_words, b.size_words);
            assert_eq!(a.bw_words_per_cycle, b.bw_words_per_cycle);
        }
        let booked = t.flatten_with(0, ContentionMode::Booked);
        assert_eq!(booked.levels[1].size_words, 1024); // booked slice
        assert_eq!(t.flatten_with(1, ContentionMode::Booked).levels[1].size_words, 3072);
        // The attach port stays exclusive…
        assert_eq!(booked.levels[1].bw_words_per_cycle, off.levels[1].bw_words_per_cycle);
        // …but the SHARED LLB uplink (128 w/cyc) cannot be double-booked
        // by two 128 w/cyc DRAM shares: the outermost boundary caps at
        // the share-weighted edge split, 128 · 128/256 = 64 per unit.
        assert_eq!(off.levels[2].bw_words_per_cycle, 128.0);
        assert!((booked.levels[2].bw_words_per_cycle - 64.0).abs() < 1e-9);
        let sum: f64 = (0..2)
            .map(|i| t.flatten_with(i, ContentionMode::Booked).levels[2].bw_words_per_cycle)
            .sum();
        assert!(sum <= 128.0 + 1e-9, "booked root boundaries oversubscribe the shared uplink");
    }

    #[test]
    fn flatten_booked_is_identity_on_share_free_trees() {
        // No node in the two-unit tree is shared: Booked == Off exactly.
        let t = two_unit_tree();
        for i in 0..t.accels.len() {
            let off = t.flatten_with(i, ContentionMode::Off);
            let on = t.flatten_with(i, ContentionMode::Booked);
            assert_eq!(off.levels.len(), on.levels.len());
            for (a, b) in off.levels.iter().zip(&on.levels) {
                assert_eq!(a.size_words, b.size_words);
                assert_eq!(a.bw_words_per_cycle, b.bw_words_per_cycle);
                assert_eq!(a.energy_pj_per_word, b.energy_pj_per_word);
            }
        }
    }

    /// Deep sharing: a mid-level node used by a leaf-attached unit and a
    /// directly-attached sibling — the shared *edge* (the node's uplink)
    /// shows up in the leaf unit's intermediate levels.
    fn deep_shared_tree() -> MachineTopology {
        let mut t = MachineTopology::new("deep", 256.0);
        let llb = t.add_node(0, LevelKind::LLB, "llb", 1 << 20, 256.0, None);
        let l2 = t.add_node(llb, LevelKind::named("L2"), "l2.shared", 65536, 96.0, None);
        let l1 = t.add_node(l2, LevelKind::L1, "l1.deep", 8192, 256.0, None);
        for (label, attach, share) in [("deep", l1, 64.0), ("near", l2, 192.0)] {
            t.add_accel(AccelNode {
                label: label.into(),
                ty: label.into(),
                role: Role::Unified,
                rows: 8,
                cols: 8,
                rf_bytes_per_pe: 64,
                attach,
                attach_bw: 128.0,
                dram_share: share,
                capacity_share: None,
                mac_energy_pj: crate::arch::energy::MAC_PJ,
                fsm_group: None,
                constraints: MappingConstraints::default(),
            });
        }
        t.validate().unwrap();
        t
    }

    #[test]
    fn shared_intermediate_edge_splits_statically_and_regrants() {
        let t = deep_shared_tree();
        let users = t.node_users();
        // l2 (node 2) is shared by both units; l1 (node 3) is private.
        assert_eq!(users[2], vec![0, 1]);
        assert_eq!(users[3], vec![0]);
        // Static partition (all busy): the l2 uplink (96 w/cyc) splits
        // 64:192 → 24 vs 72.
        let both = [true, true];
        assert!((t.shared_edge_bw(2, 0, &users[2], &both) - 24.0).abs() < 1e-9);
        assert!((t.shared_edge_bw(2, 1, &users[2], &both) - 72.0).abs() < 1e-9);
        // Idle sibling forfeits: the deep unit inherits the whole edge.
        let solo = [true, false];
        assert!((t.shared_edge_bw(2, 0, &users[2], &solo) - 96.0).abs() < 1e-9);
        // An unshared edge goes to its sole user whole.
        assert!((t.shared_edge_bw(3, 0, &users[3], &both) - 256.0).abs() < 1e-9);
        // The booked flatten bakes the static split into the deep unit's
        // L2 level bandwidth (level 2 = L2, fed by the l2 uplink… no:
        // level 3 = LLB is fed by the l2 uplink edge).
        let off = t.flatten_with(0, ContentionMode::Off);
        let on = t.flatten_with(0, ContentionMode::Booked);
        assert_eq!(off.levels[3].bw_words_per_cycle, 96.0);
        assert!((on.levels[3].bw_words_per_cycle - 24.0).abs() < 1e-9);
        // Shared L2 capacity is booked 50:50 (equal PE counts).
        assert_eq!(on.levels[2].size_words, 32768);
        assert_eq!(off.levels[2].size_words, 65536);
    }

    #[test]
    fn oversubscribed_capacity_shares_rejected() {
        let mut t = co_attached_tree([Some(4096), Some(1)]);
        assert!(t.validate().unwrap_err().contains("capacity shares sum"));
        t.accels[0].capacity_share = Some(8192); // single pin above the node
        assert!(t.validate().unwrap_err().contains("exceeds"));
        t.accels[0].capacity_share = Some(0);
        assert!(t.validate().unwrap_err().contains("positive"));
        // Pins must leave ≥1 word per unpinned co-attached unit.
        t.accels[0].capacity_share = Some(4096);
        t.accels[1].capacity_share = None;
        assert!(t.validate().unwrap_err().contains("unpinned"));
        t.accels[0].capacity_share = Some(2048);
        t.validate().unwrap();
    }

    #[test]
    fn duplicate_node_labels_rejected() {
        // Two shared LLBs that both default their label to the level
        // name would collide in the contention report — rejected.
        let doc = r#"{"name":"m","root":{"bw_words_per_cycle":256,"children":[
            {"level":"LLB","size_words":4096,"bw_words_per_cycle":64,
             "accels":[{"name":"a","rows":4,"cols":4}]},
            {"level":"LLB","size_words":4096,"bw_words_per_cycle":64,
             "accels":[{"name":"b","rows":4,"cols":4}]}]}}"#;
        let err = MachineTopology::from_json(&Json::parse(doc).unwrap()).unwrap_err();
        assert!(err.contains("duplicate node label"), "{err}");
    }

    #[test]
    fn capacity_share_json_round_trips_and_rejects_malformed() {
        let t = co_attached_tree([Some(512), None]);
        t.validate().unwrap();
        let back = MachineTopology::from_json(&t.to_json()).unwrap();
        assert_eq!(back.accels[0].capacity_share, Some(512));
        assert_eq!(back.accels[1].capacity_share, None);
        // Malformed shares are parse errors, not silent defaults.
        for (patch, what) in [
            (r#""capacity_share_words": -4"#, "negative"),
            (r#""capacity_share_words": 0"#, "zero"),
            (r#""capacity_share_words": 1.5"#, "fractional"),
            (r#""capacity_share_words": "big""#, "non-numeric"),
        ] {
            let doc = format!(
                r#"{{"name":"m","root":{{"bw_words_per_cycle":100,"children":[
                    {{"level":"LLB","size_words":4096,"bw_words_per_cycle":100,
                      "accels":[{{"name":"a","rows":4,"cols":4,{patch}}},
                                {{"name":"b","rows":4,"cols":4}}]}}]}}}}"#
            );
            let j = Json::parse(&doc).unwrap();
            assert!(MachineTopology::from_json(&j).is_err(), "{what} share accepted");
        }
        // A capacity share on a storage node (a non-attachment edge) is
        // rejected too.
        let doc = r#"{"name":"m","root":{"bw_words_per_cycle":100,"children":[
            {"level":"LLB","size_words":4096,"bw_words_per_cycle":100,
             "capacity_share_words": 64,
             "accels":[{"name":"a","rows":4,"cols":4}]}]}}"#;
        let err = MachineTopology::from_json(&Json::parse(doc).unwrap()).unwrap_err();
        assert!(err.contains("not storage nodes"), "{err}");
    }

    #[test]
    fn invalid_topologies_rejected() {
        let mut t = MachineTopology::new("bad", 256.0);
        assert!(t.validate().is_err()); // no accels
        let n = t.add_node(0, LevelKind::LLB, "llb", 1024, 64.0, None);
        t.add_accel(AccelNode {
            label: "a".into(),
            ty: "a".into(),
            role: Role::Unified,
            rows: 4,
            cols: 4,
            rf_bytes_per_pe: 64,
            attach: n,
            attach_bw: 64.0,
            dram_share: 300.0, // above the root's 256
            capacity_share: None,
            mac_energy_pj: 0.2,
            fsm_group: None,
            constraints: MappingConstraints::default(),
        });
        assert!(t.validate().is_err());
        t.accels[0].dram_share = 64.0;
        t.validate().unwrap();
    }
}
