//! Resource partitioning: taxonomy point + Table III budget → machines.
//!
//! Implements the paper's policies (§V-D):
//! - PEs (compute roof) split `roof_ratio : 1` between high- and
//!   low-reuse sub-accelerators (Table III: 4:1);
//! - LLB capacity split in the ratio of compute roof — high-reuse ops
//!   want on-chip space, low-reuse ops hit peak intensity with little;
//! - DRAM bandwidth split by `bw_frac_low` (default 0.75 to the
//!   low-reuse side for decoder workloads — Fig 10 sweeps this);
//! - hierarchical points attach the low-reuse unit at the LLB (no
//!   private L1), which is where its energy advantage comes from;
//! - intra-node points share the FSM: both arrays get the same column
//!   count and must parallelise the same dimension across columns.

use super::spec::{ArchSpec, MappingConstraints};
use super::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
use crate::workload::einsum::Dim;
use crate::workload::intensity::ReuseClass;

/// Table III hardware parameters.
#[derive(Debug, Clone)]
pub struct HardwareParams {
    /// Total number of MACs across all sub-accelerators (Table III: 40960).
    pub total_macs: u64,
    /// Word width in bits (Table III: 8).
    pub datawidth_bits: u64,
    /// Shared DRAM bandwidth in bits per cycle (sweep: 2048, 512).
    pub dram_bw_bits: f64,
    /// LLB capacity in bytes (4 MB).
    pub llb_bytes: u64,
    /// L1 capacity per array in bytes (0.125 MB).
    pub l1_bytes: u64,
    /// Register file bytes per PE (64 B).
    pub rf_bytes_per_pe: u64,
    /// High : low compute-roof ratio (4:1).
    pub roof_ratio: f64,
    /// Fraction of DRAM bandwidth granted to the low-reuse side in
    /// heterogeneous configurations.
    pub bw_frac_low: f64,
    /// LLB port bandwidth in words per cycle (on-chip, shared budget).
    pub llb_bw_words: f64,
}

impl Default for HardwareParams {
    fn default() -> HardwareParams {
        HardwareParams {
            total_macs: 40960,
            datawidth_bits: 8,
            dram_bw_bits: 2048.0,
            llb_bytes: 4 << 20,
            l1_bytes: 128 << 10,
            rf_bytes_per_pe: 64,
            roof_ratio: 4.0,
            bw_frac_low: 0.75,
            llb_bw_words: 1024.0,
        }
    }
}

impl HardwareParams {
    /// DRAM bandwidth in words per cycle.
    pub fn dram_bw_words(&self) -> f64 {
        self.dram_bw_bits / self.datawidth_bits as f64
    }

    /// Roofline tipping point of the unpartitioned machine (MACs/word).
    pub fn tipping_ai(&self) -> f64 {
        self.total_macs as f64 / self.dram_bw_words()
    }
}

/// Role a sub-accelerator plays in the HHP.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Runs high-reuse operations.
    High,
    /// Runs low-reuse operations.
    Low,
    /// Homogeneous machine: runs everything.
    Unified,
}

impl Role {
    pub const ALL: [Role; 3] = [Role::High, Role::Low, Role::Unified];

    pub fn accepts(self, class: ReuseClass) -> bool {
        match self {
            Role::Unified => true,
            Role::High => class == ReuseClass::High,
            Role::Low => class == ReuseClass::Low,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Role::High => "high-reuse",
            Role::Low => "low-reuse",
            Role::Unified => "unified",
        }
    }
}

/// One sub-accelerator instance within a machine.
#[derive(Debug, Clone)]
pub struct SubAccel {
    pub id: usize,
    pub role: Role,
    pub spec: ArchSpec,
}

/// A fully-partitioned machine: the realisation of one taxonomy point.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    pub class: HarpClass,
    pub params: HardwareParams,
    pub sub_accels: Vec<SubAccel>,
}

/// Pick a near-square `rows × cols = macs` factorisation (cols ≥ rows).
pub fn array_shape(macs: u64) -> (u64, u64) {
    let mut best = (1, macs);
    let mut r = 1;
    while r * r <= macs {
        if macs % r == 0 {
            best = (r, macs / r);
        }
        r += 1;
    }
    best
}

impl MachineConfig {
    /// Build the machine for a taxonomy point under `params`.
    pub fn build(class: &HarpClass, params: &HardwareParams) -> Result<MachineConfig, String> {
        class.validate()?;
        let p = params.clone();
        let dram_w = p.dram_bw_words();
        let frac_high_roof = p.roof_ratio / (p.roof_ratio + 1.0);
        let high_macs = ((p.total_macs as f64) * frac_high_roof).round() as u64;
        let low_macs = p.total_macs - high_macs;
        // LLB capacity split ∝ compute roof (§V-D).
        let llb_high = ((p.llb_bytes as f64) * frac_high_roof) as u64;
        let llb_low = p.llb_bytes - llb_high;
        // Bandwidth splits.
        let bw_low = dram_w * p.bw_frac_low;
        let bw_high = dram_w - bw_low;
        let llbbw_high = p.llb_bw_words * frac_high_roof;
        let llbbw_low = p.llb_bw_words - llbbw_high;

        let mut subs: Vec<SubAccel> = Vec::new();
        let push = |role: Role, spec: ArchSpec, subs: &mut Vec<SubAccel>| {
            let id = subs.len();
            subs.push(SubAccel { id, role, spec });
        };

        match (&class.placement, &class.heterogeneity) {
            // (a) leaf + homogeneous: one machine, undivided resources.
            (ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous) => {
                let (r, c) = array_shape(p.total_macs);
                let spec = ArchSpec::leaf(
                    "unified",
                    r,
                    c,
                    p.rf_bytes_per_pe,
                    p.l1_bytes,
                    p.llb_bytes,
                    p.llb_bw_words,
                    dram_w,
                );
                push(Role::Unified, spec, &mut subs);
            }
            // (b) leaf + cross-node: two leaf sub-accelerators, disjoint
            // nodes, independent FSMs — no shared mapping constraints.
            // The hierarchical unclustered variant attaches the low-reuse
            // unit at the LLB (compute at two depths, different types at
            // different nodes).
            (placement, HeterogeneityLoc::CrossNode { clustered: false }) => {
                let (rh, ch) = array_shape(high_macs);
                let (rl, cl) = array_shape(low_macs);
                push(
                    Role::High,
                    ArchSpec::leaf("high", rh, ch, p.rf_bytes_per_pe, p.l1_bytes, llb_high, llbbw_high, bw_high),
                    &mut subs,
                );
                let low = if *placement == ComputePlacement::Hierarchical {
                    ArchSpec::near_llb("low", rl, cl, p.rf_bytes_per_pe, llb_low, llbbw_low, bw_low)
                } else {
                    ArchSpec::leaf("low", rl, cl, p.rf_bytes_per_pe, p.l1_bytes, llb_low, llbbw_low, bw_low)
                };
                push(Role::Low, low, &mut subs);
            }
            // (f) hierarchical + clustered cross-node (Symphony-like):
            // the heterogeneous mix repeats per cluster. Two clusters,
            // each holding half of each sub-accelerator; per-cluster
            // arrays are smaller, which costs spatial utilisation on
            // large ops — the modelling consequence of clustering.
            (ComputePlacement::Hierarchical, HeterogeneityLoc::CrossNode { clustered: true })
            | (ComputePlacement::LeafOnly, HeterogeneityLoc::CrossNode { clustered: true }) => {
                for cluster in 0..2u64 {
                    let (rh, ch) = array_shape(high_macs / 2);
                    let (rl, cl) = array_shape(low_macs / 2);
                    push(
                        Role::High,
                        ArchSpec::leaf(
                            &format!("high.c{cluster}"),
                            rh,
                            ch,
                            p.rf_bytes_per_pe,
                            p.l1_bytes / 2,
                            llb_high / 2,
                            llbbw_high / 2.0,
                            bw_high / 2.0,
                        ),
                        &mut subs,
                    );
                    push(
                        Role::Low,
                        ArchSpec::leaf(
                            &format!("low.c{cluster}"),
                            rl,
                            cl,
                            p.rf_bytes_per_pe,
                            p.l1_bytes / 2,
                            llb_low / 2,
                            llbbw_low / 2.0,
                            bw_low / 2.0,
                        ),
                        &mut subs,
                    );
                }
            }
            // (c) leaf + intra-node: shared FSM. Arrays share the column
            // count; the mapper must parallelise the same dimension
            // across columns on both (forced to N).
            (ComputePlacement::LeafOnly, HeterogeneityLoc::IntraNode)
            | (ComputePlacement::Hierarchical, HeterogeneityLoc::IntraNode) => {
                // Common columns: the widest divisor of the high-reuse
                // PE count that the low-reuse budget can still fill with
                // at least one full row (otherwise the shared-FSM column
                // constraint would inflate the low unit past its share).
                let (_, near_square_cols) = array_shape(high_macs);
                let cols = (1..=near_square_cols.min(low_macs))
                    .rev()
                    .find(|c| high_macs % c == 0)
                    .unwrap_or(1);
                let rows_h = high_macs / cols;
                let rows_l = (low_macs / cols).max(1);
                let shared = MappingConstraints {
                    forced_col_dim: Some(Dim::N),
                    forced_col_factor: None,
                    no_dram_psum: false,
                };
                let mut hi = ArchSpec::leaf(
                    "high",
                    rows_h,
                    cols,
                    p.rf_bytes_per_pe,
                    p.l1_bytes,
                    llb_high,
                    llbbw_high,
                    bw_high,
                );
                hi.constraints = shared.clone();
                let low_is_hier = class.placement == ComputePlacement::Hierarchical;
                let mut lo = if low_is_hier {
                    ArchSpec::near_llb(
                        "low",
                        rows_l,
                        cols,
                        p.rf_bytes_per_pe,
                        llb_low,
                        llbbw_low,
                        bw_low,
                    )
                } else {
                    ArchSpec::leaf(
                        "low",
                        rows_l,
                        cols,
                        p.rf_bytes_per_pe,
                        p.l1_bytes,
                        llb_low,
                        llbbw_low,
                        bw_low,
                    )
                };
                lo.constraints = shared;
                push(Role::High, hi, &mut subs);
                push(Role::Low, lo, &mut subs);
            }
            // (d) hierarchical + cross-depth: NPU at the leaves,
            // bandwidth-oriented unit attached to the LLB (NeuPIM-like).
            (ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth) => {
                let (rh, ch) = array_shape(high_macs);
                // The near-memory unit is wide and shallow (vector-like):
                // few rows, many columns — built for streaming, not reuse.
                let rl = (low_macs as f64).sqrt() as u64 / 2;
                let rl = rl.max(1);
                let cl = low_macs / rl;
                push(
                    Role::High,
                    ArchSpec::leaf("npu", rh, ch, p.rf_bytes_per_pe, p.l1_bytes, llb_high, llbbw_high, bw_high),
                    &mut subs,
                );
                push(
                    Role::Low,
                    ArchSpec::near_llb("near-llb", rl, cl, p.rf_bytes_per_pe, llb_low, llbbw_low, bw_low),
                    &mut subs,
                );
            }
            // (e) hierarchical + homogeneous: the SAME sub-accelerator
            // architecture replicated at two levels (no prior work —
            // derived from the taxonomy). Leaf instance + LLB instance
            // with identical aspect ratio.
            (ComputePlacement::Hierarchical, HeterogeneityLoc::Homogeneous) => {
                let (rh, ch) = array_shape(high_macs);
                let (rl, cl) = array_shape(low_macs);
                push(
                    Role::High,
                    ArchSpec::leaf("leaf", rh, ch, p.rf_bytes_per_pe, p.l1_bytes, llb_high, llbbw_high, bw_high),
                    &mut subs,
                );
                push(
                    Role::Low,
                    ArchSpec::near_llb("llb-level", rl, cl, p.rf_bytes_per_pe, llb_low, llbbw_low, bw_low),
                    &mut subs,
                );
            }
            // (h) compound: cross-node at the leaves + cross-depth.
            // Three sub-accelerators: big leaf (high), small leaf (low),
            // near-LLB streamer (low). Low-side resources split evenly
            // between the two low units.
            (placement, HeterogeneityLoc::Compound(_)) => {
                let _ = placement;
                let (rh, ch) = array_shape(high_macs);
                let (rl1, cl1) = array_shape(low_macs / 2);
                let (rl2, cl2) = array_shape(low_macs - low_macs / 2);
                push(
                    Role::High,
                    ArchSpec::leaf("high", rh, ch, p.rf_bytes_per_pe, p.l1_bytes, llb_high, llbbw_high, bw_high),
                    &mut subs,
                );
                push(
                    Role::Low,
                    ArchSpec::leaf(
                        "low-leaf",
                        rl1,
                        cl1,
                        p.rf_bytes_per_pe,
                        p.l1_bytes,
                        llb_low / 2,
                        llbbw_low / 2.0,
                        bw_low / 2.0,
                    ),
                    &mut subs,
                );
                push(
                    Role::Low,
                    ArchSpec::near_llb(
                        "low-nearllb",
                        rl2,
                        cl2,
                        p.rf_bytes_per_pe,
                        llb_low / 2,
                        llbbw_low / 2.0,
                        bw_low / 2.0,
                    ),
                    &mut subs,
                );
            }
            (ComputePlacement::LeafOnly, HeterogeneityLoc::CrossDepth) => {
                unreachable!("rejected by validate()")
            }
        }

        Ok(MachineConfig { class: class.clone(), params: p, sub_accels: subs })
    }

    /// Total PEs across sub-accelerators (invariant: == params.total_macs,
    /// up to the intra-node column-rounding remainder).
    pub fn total_pes(&self) -> u64 {
        self.sub_accels.iter().map(|s| s.spec.peak_macs()).sum()
    }

    pub fn is_heterogeneous(&self) -> bool {
        self.sub_accels.len() > 1
    }

    /// Sub-accelerators that accept a reuse class.
    pub fn accelerators_for(&self, class: ReuseClass) -> Vec<usize> {
        self.sub_accels
            .iter()
            .filter(|s| s.role.accepts(class))
            .map(|s| s.id)
            .collect()
    }

    pub fn describe(&self) -> String {
        let mut s = format!(
            "machine [{}]  total {} PEs, DRAM {:.0} w/cyc, tipping AI {:.0}\n",
            self.class,
            self.total_pes(),
            self.params.dram_bw_words(),
            self.params.tipping_ai()
        );
        for sub in &self.sub_accels {
            s.push_str(&format!("  [{}] {}\n", sub.role.name(), sub.spec.describe()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::level::LevelKind;

    fn params() -> HardwareParams {
        HardwareParams::default()
    }

    #[test]
    fn array_shape_near_square() {
        assert_eq!(array_shape(40960), (160, 256));
        assert_eq!(array_shape(32768), (128, 256));
        assert_eq!(array_shape(8192), (64, 128));
        assert_eq!(array_shape(7), (1, 7));
    }

    #[test]
    fn homogeneous_is_undivided() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous);
        let m = MachineConfig::build(&c, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 1);
        assert_eq!(m.total_pes(), 40960);
        assert_eq!(m.sub_accels[0].spec.dram().bw_words_per_cycle, 256.0);
        assert_eq!(m.sub_accels[0].spec.level(LevelKind::Llb).unwrap().size_words, 4 << 20);
    }

    #[test]
    fn cross_node_splits_match_policy() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node());
        let m = MachineConfig::build(&c, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 2);
        let hi = &m.sub_accels[0].spec;
        let lo = &m.sub_accels[1].spec;
        assert_eq!(hi.peak_macs(), 32768);
        assert_eq!(lo.peak_macs(), 8192);
        // LLB ∝ roof, BW 25/75.
        assert_eq!(hi.level(LevelKind::Llb).unwrap().size_words, (4 << 20) * 4 / 5);
        assert!((hi.dram().bw_words_per_cycle - 64.0).abs() < 1e-9);
        assert!((lo.dram().bw_words_per_cycle - 192.0).abs() < 1e-9);
    }

    #[test]
    fn intra_node_shares_columns() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::IntraNode);
        let m = MachineConfig::build(&c, &params()).unwrap();
        let hi = &m.sub_accels[0].spec;
        let lo = &m.sub_accels[1].spec;
        assert_eq!(hi.cols, lo.cols);
        assert!(hi.constraints.forced_col_dim.is_some());
        assert!(lo.constraints.forced_col_dim.is_some());
    }

    #[test]
    fn cross_depth_low_has_no_l1() {
        let c = HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth);
        let m = MachineConfig::build(&c, &params()).unwrap();
        let lo = &m.sub_accels[1].spec;
        assert!(lo.level(LevelKind::L1).is_none());
        let hi = &m.sub_accels[0].spec;
        assert!(hi.level(LevelKind::L1).is_some());
    }

    #[test]
    fn invalid_point_rejected() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::CrossDepth);
        assert!(MachineConfig::build(&c, &params()).is_err());
    }

    #[test]
    fn total_pes_conserved_within_rounding() {
        for (_, class) in HarpClass::eval_points() {
            let m = MachineConfig::build(&class, &params()).unwrap();
            let total = m.total_pes();
            assert!(
                total >= 40960 * 95 / 100 && total <= 40960,
                "{class}: {total} PEs"
            );
        }
    }

    #[test]
    fn compound_has_three_units() {
        let c = HarpClass::new(
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::cross_node(),
                HeterogeneityLoc::CrossDepth,
            ]),
        );
        let m = MachineConfig::build(&c, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 3);
        assert_eq!(m.accelerators_for(ReuseClass::Low).len(), 2);
    }

    #[test]
    fn clustered_cross_node_builds_four() {
        let c = HarpClass::new(
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::CrossNode { clustered: true },
        );
        let m = MachineConfig::build(&c, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 4);
    }
}
