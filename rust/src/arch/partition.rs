//! Resource partitioning: a topology *generator* that turns any HARP
//! taxonomy point plus a Table III hardware budget into a machine tree.
//!
//! Implements the paper's policies (§V-D):
//! - PEs (compute roof) split `roof_ratio : 1` between high- and
//!   low-reuse sub-accelerators (Table III: 4:1);
//! - LLB capacity split in the ratio of compute roof — high-reuse ops
//!   want on-chip space, low-reuse ops hit peak intensity with little;
//! - DRAM bandwidth split by `bw_frac_low` (default 0.75 to the
//!   low-reuse side for decoder workloads — Fig 10 sweeps this), carried
//!   as per-edge shares of the memory tree;
//! - hierarchical points attach compute directly at the LLB (no private
//!   L1), which is where the energy advantage comes from;
//! - intra-node points share the FSM: both arrays get the same column
//!   count, must parallelise the same dimension across columns, and are
//!   tagged with one FSM group in the tree;
//! - clustered points (Symphony-style) repeat the heterogeneous mix
//!   under passthrough cluster nodes with halved resources;
//! - compound points compose the above: one low-side unit per
//!   heterogeneity source, with distinct architectural types so the
//!   classification recovers every source.
//!
//! The invariant tested for every taxonomy point is the round trip
//! `MachineTopology::classify(generate(class, params)) == class`.

use super::spec::{ArchSpec, MappingConstraints};
use super::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
use super::topology::{AccelNode, ContentionMode, MachineTopology};
use crate::arch::energy;
use crate::workload::einsum::Dim;
use crate::workload::intensity::ReuseClass;

/// Table III hardware parameters.
#[derive(Debug, Clone)]
pub struct HardwareParams {
    /// Total number of MACs across all sub-accelerators (Table III: 40960).
    pub total_macs: u64,
    /// Word width in bits (Table III: 8).
    pub datawidth_bits: u64,
    /// Shared DRAM bandwidth in bits per cycle (sweep: 2048, 512).
    pub dram_bw_bits: f64,
    /// LLB capacity in bytes (4 MB).
    pub llb_bytes: u64,
    /// L1 capacity per array in bytes (0.125 MB).
    pub l1_bytes: u64,
    /// Register file bytes per PE (64 B).
    pub rf_bytes_per_pe: u64,
    /// High : low compute-roof ratio (4:1).
    pub roof_ratio: f64,
    /// Fraction of DRAM bandwidth granted to the low-reuse side in
    /// heterogeneous configurations.
    pub bw_frac_low: f64,
    /// LLB port bandwidth in words per cycle (on-chip, shared budget).
    pub llb_bw_words: f64,
}

impl Default for HardwareParams {
    fn default() -> HardwareParams {
        HardwareParams {
            total_macs: 40960,
            datawidth_bits: 8,
            dram_bw_bits: 2048.0,
            llb_bytes: 4 << 20,
            l1_bytes: 128 << 10,
            rf_bytes_per_pe: 64,
            roof_ratio: 4.0,
            bw_frac_low: 0.75,
            llb_bw_words: 1024.0,
        }
    }
}

impl HardwareParams {
    /// DRAM bandwidth in words per cycle.
    pub fn dram_bw_words(&self) -> f64 {
        self.dram_bw_bits / self.datawidth_bits as f64
    }

    /// Roofline tipping point of the unpartitioned machine (MACs/word).
    pub fn tipping_ai(&self) -> f64 {
        self.total_macs as f64 / self.dram_bw_words()
    }
}

/// Role a sub-accelerator plays in the HHP.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Runs high-reuse operations.
    High,
    /// Runs low-reuse operations.
    Low,
    /// Homogeneous machine: runs everything.
    Unified,
}

impl Role {
    pub const ALL: [Role; 3] = [Role::High, Role::Low, Role::Unified];

    pub fn accepts(self, class: ReuseClass) -> bool {
        match self {
            Role::Unified => true,
            Role::High => class == ReuseClass::High,
            Role::Low => class == ReuseClass::Low,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Role::High => "high-reuse",
            Role::Low => "low-reuse",
            Role::Unified => "unified",
        }
    }
}

/// One sub-accelerator instance within a machine: the flattened view of
/// one tree attachment (same index as `MachineConfig::topology.accels`).
#[derive(Debug, Clone)]
pub struct SubAccel {
    pub id: usize,
    pub role: Role,
    pub spec: ArchSpec,
}

/// A fully-partitioned machine: the memory tree realising one taxonomy
/// point, plus the flattened per-unit view the cost model consumes.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    pub class: HarpClass,
    pub params: HardwareParams,
    pub topology: MachineTopology,
    pub sub_accels: Vec<SubAccel>,
    /// Shared-node contention mode the `sub_accels` specs were
    /// flattened under. The scheduler reads this to decide whether to
    /// arbitrate shared-edge bandwidth, so the flag and the specs can
    /// never disagree — change it only via
    /// [`MachineConfig::with_contention`].
    pub contention: ContentionMode,
}

/// Pick a near-square `rows × cols = macs` factorisation (cols ≥ rows).
pub fn array_shape(macs: u64) -> (u64, u64) {
    let mut best = (1, macs);
    let mut r = 1;
    while r * r <= macs {
        if macs % r == 0 {
            best = (r, macs / r);
        }
        r += 1;
    }
    best
}

/// The per-side resource shares every heterogeneous generator draws from.
struct Shares {
    dram_w: f64,
    high_macs: u64,
    low_macs: u64,
    llb_high: u64,
    llb_low: u64,
    bw_high: f64,
    bw_low: f64,
    llbbw_high: f64,
    llbbw_low: f64,
}

impl Shares {
    fn new(p: &HardwareParams) -> Shares {
        let dram_w = p.dram_bw_words();
        let frac_high_roof = p.roof_ratio / (p.roof_ratio + 1.0);
        let high_macs = ((p.total_macs as f64) * frac_high_roof).round() as u64;
        let low_macs = p.total_macs - high_macs;
        // LLB capacity split ∝ compute roof (§V-D).
        let llb_high = ((p.llb_bytes as f64) * frac_high_roof) as u64;
        let llb_low = p.llb_bytes - llb_high;
        // Bandwidth splits.
        let bw_low = dram_w * p.bw_frac_low;
        let bw_high = dram_w - bw_low;
        let llbbw_high = p.llb_bw_words * frac_high_roof;
        let llbbw_low = p.llb_bw_words - llbbw_high;
        Shares {
            dram_w,
            high_macs,
            low_macs,
            llb_high,
            llb_low,
            bw_high,
            bw_low,
            llbbw_high,
            llbbw_low,
        }
    }
}

/// Leaf-attached unit: a private `LLB → L1 → array` chain under `parent`.
#[allow(clippy::too_many_arguments)]
fn leaf_unit(
    t: &mut MachineTopology,
    parent: usize,
    label: &str,
    ty: &str,
    role: Role,
    rows: u64,
    cols: u64,
    rf_bytes_per_pe: u64,
    l1_bytes: u64,
    llb_bytes: u64,
    llb_bw: f64,
    dram_bw: f64,
    fsm_group: Option<usize>,
    constraints: MappingConstraints,
) -> usize {
    use crate::arch::level::LevelKind;
    let llb =
        t.add_node(parent, LevelKind::LLB, &format!("llb.{label}"), llb_bytes, dram_bw, None);
    let l1 = t.add_node(llb, LevelKind::L1, &format!("l1.{label}"), l1_bytes, llb_bw, None);
    let attach_bw = ArchSpec::default_attach_bw(rows * cols);
    attach_unit(
        t, l1, label, ty, role, rows, cols, rf_bytes_per_pe, attach_bw, dram_bw,
        fsm_group, constraints,
    )
}

/// LLB-attached unit (near-memory, no private L1) under `parent`.
#[allow(clippy::too_many_arguments)]
fn llb_unit(
    t: &mut MachineTopology,
    parent: usize,
    label: &str,
    ty: &str,
    role: Role,
    rows: u64,
    cols: u64,
    rf_bytes_per_pe: u64,
    llb_bytes: u64,
    llb_bw: f64,
    dram_bw: f64,
    fsm_group: Option<usize>,
    constraints: MappingConstraints,
) -> usize {
    use crate::arch::level::LevelKind;
    let llb =
        t.add_node(parent, LevelKind::LLB, &format!("llb.{label}"), llb_bytes, dram_bw, None);
    attach_unit(
        t, llb, label, ty, role, rows, cols, rf_bytes_per_pe, llb_bw, dram_bw,
        fsm_group, constraints,
    )
}

/// Attach a unit at an *existing* node (used when several units share a
/// subtree, e.g. the hierarchical cross-node low side).
#[allow(clippy::too_many_arguments)]
fn attach_unit(
    t: &mut MachineTopology,
    node: usize,
    label: &str,
    ty: &str,
    role: Role,
    rows: u64,
    cols: u64,
    rf_bytes_per_pe: u64,
    attach_bw: f64,
    dram_bw: f64,
    fsm_group: Option<usize>,
    constraints: MappingConstraints,
) -> usize {
    t.add_accel(AccelNode {
        label: label.into(),
        ty: ty.into(),
        role,
        rows,
        cols,
        rf_bytes_per_pe,
        attach: node,
        attach_bw,
        dram_share: dram_bw,
        capacity_share: None,
        mac_energy_pj: energy::MAC_PJ,
        fsm_group,
        constraints,
    })
}

/// Shared-FSM column coupling for an intra-node pair: the widest divisor
/// of the high-reuse PE count the low-reuse budget can still fill with
/// at least one full row (otherwise the shared-FSM column constraint
/// would inflate the low unit past its share).
fn intra_cols(high_macs: u64, low_macs: u64) -> (u64, u64, u64) {
    let (_, near_square_cols) = array_shape(high_macs);
    let cols = (1..=near_square_cols.min(low_macs))
        .rev()
        .find(|c| high_macs % c == 0)
        .unwrap_or(1);
    (cols, high_macs / cols, (low_macs / cols).max(1))
}

fn shared_fsm_constraints() -> MappingConstraints {
    MappingConstraints {
        forced_col_dim: Some(Dim::N),
        forced_col_factor: None,
        no_dram_psum: false,
    }
}

/// Generate the memory tree for a taxonomy point under `params`.
pub fn generate_topology(
    class: &HarpClass,
    p: &HardwareParams,
) -> Result<MachineTopology, String> {
    use crate::arch::level::LevelKind;
    class.validate()?;
    let s = Shares::new(p);
    let mut t = MachineTopology::new(&class.id(), s.dram_w);
    let root = t.root();
    let none = MappingConstraints::default;

    match (&class.placement, &class.heterogeneity) {
        // (a) leaf + homogeneous: one machine, undivided resources.
        (ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous) => {
            let (r, c) = array_shape(p.total_macs);
            leaf_unit(
                &mut t, root, "unified", "array", Role::Unified, r, c, p.rf_bytes_per_pe,
                p.l1_bytes, p.llb_bytes, p.llb_bw_words, s.dram_w, None, none(),
            );
        }
        // (e) hierarchical + homogeneous: the SAME architecture
        // replicated at two levels (no prior work — derived from the
        // taxonomy): a leaf instance plus an LLB-attached instance.
        (ComputePlacement::Hierarchical, HeterogeneityLoc::Homogeneous) => {
            let (rh, ch) = array_shape(s.high_macs);
            let (rl, cl) = array_shape(s.low_macs);
            leaf_unit(
                &mut t, root, "leaf", "array", Role::High, rh, ch, p.rf_bytes_per_pe,
                p.l1_bytes, s.llb_high, s.llbbw_high, s.bw_high, None, none(),
            );
            llb_unit(
                &mut t, root, "llb-level", "array", Role::Low, rl, cl, p.rf_bytes_per_pe,
                s.llb_low, s.llbbw_low, s.bw_low, None, none(),
            );
        }
        // (b) leaf + cross-node: two leaf units in disjoint subtrees,
        // independent FSMs — no shared mapping constraints.
        (ComputePlacement::LeafOnly, HeterogeneityLoc::CrossNode { clustered: false }) => {
            let (rh, ch) = array_shape(s.high_macs);
            let (rl, cl) = array_shape(s.low_macs);
            leaf_unit(
                &mut t, root, "high", "hi-array", Role::High, rh, ch, p.rf_bytes_per_pe,
                p.l1_bytes, s.llb_high, s.llbbw_high, s.bw_high, None, none(),
            );
            leaf_unit(
                &mut t, root, "low", "lo-array", Role::Low, rl, cl, p.rf_bytes_per_pe,
                p.l1_bytes, s.llb_low, s.llbbw_low, s.bw_low, None, none(),
            );
        }
        // Hierarchical cross-node: the leaf mix of (b) plus a second
        // low-type instance attached directly at the low-side LLB, so
        // compute spans two depths while the heterogeneity stays at the
        // leaves (the low type exists at both depths; the high/low pair
        // still meets at leaf depth ⇒ cross-node, not cross-depth).
        (ComputePlacement::Hierarchical, HeterogeneityLoc::CrossNode { clustered: false }) => {
            let (rh, ch) = array_shape(s.high_macs);
            leaf_unit(
                &mut t, root, "high", "hi-array", Role::High, rh, ch, p.rf_bytes_per_pe,
                p.l1_bytes, s.llb_high, s.llbbw_high, s.bw_high, None, none(),
            );
            let lm = s.low_macs / 2;
            let (rl, cl) = array_shape(lm);
            let (rl2, cl2) = array_shape(s.low_macs - lm);
            let llb_lo =
                t.add_node(root, LevelKind::LLB, "llb.low", s.llb_low, s.bw_low, None);
            let l1_lo = t.add_node(
                llb_lo, LevelKind::L1, "l1.low", p.l1_bytes, s.llbbw_low / 2.0, None,
            );
            let pes = rl * cl;
            attach_unit(
                &mut t, l1_lo, "low-leaf", "lo-array", Role::Low, rl, cl,
                p.rf_bytes_per_pe, ArchSpec::default_attach_bw(pes), s.bw_low / 2.0, None, none(),
            );
            attach_unit(
                &mut t, llb_lo, "low-llb", "lo-array", Role::Low, rl2, cl2,
                p.rf_bytes_per_pe, s.llbbw_low / 2.0, s.bw_low - s.bw_low / 2.0, None,
                none(),
            );
        }
        // (c) leaf/hierarchical + intra-node: shared FSM. Arrays share
        // the column count and the column-parallel dimension; the tree
        // tags both with one FSM group.
        (placement, HeterogeneityLoc::IntraNode) => {
            let (cols, rows_h, rows_l) = intra_cols(s.high_macs, s.low_macs);
            let shared = shared_fsm_constraints();
            leaf_unit(
                &mut t, root, "high", "hi-array", Role::High, rows_h, cols,
                p.rf_bytes_per_pe, p.l1_bytes, s.llb_high, s.llbbw_high, s.bw_high,
                Some(0), shared.clone(),
            );
            if *placement == ComputePlacement::Hierarchical {
                llb_unit(
                    &mut t, root, "low", "lo-array", Role::Low, rows_l, cols,
                    p.rf_bytes_per_pe, s.llb_low, s.llbbw_low, s.bw_low, Some(0), shared,
                );
            } else {
                leaf_unit(
                    &mut t, root, "low", "lo-array", Role::Low, rows_l, cols,
                    p.rf_bytes_per_pe, p.l1_bytes, s.llb_low, s.llbbw_low, s.bw_low,
                    Some(0), shared,
                );
            }
        }
        // (f) clustered cross-node (Symphony-like): the heterogeneous
        // mix repeats under two cluster nodes with halved resources;
        // per-cluster arrays are smaller, which costs spatial
        // utilisation on large ops — the modelling consequence of
        // clustering. The hierarchical variant adds a per-cluster
        // LLB-attached low instance (compute at two depths).
        (placement, HeterogeneityLoc::CrossNode { clustered: true }) => {
            let hier = *placement == ComputePlacement::Hierarchical;
            for cluster in 0..2u64 {
                let g = t.add_group(root, &format!("cluster{cluster}"));
                let (rh, ch) = array_shape(s.high_macs / 2);
                leaf_unit(
                    &mut t, g, &format!("high.c{cluster}"), "hi-array", Role::High, rh, ch,
                    p.rf_bytes_per_pe, p.l1_bytes / 2, s.llb_high / 2, s.llbbw_high / 2.0,
                    s.bw_high / 2.0, None, none(),
                );
                let lm = s.low_macs / 2;
                if hier {
                    let (rl, cl) = array_shape(lm / 2);
                    let (rl2, cl2) = array_shape(lm - lm / 2);
                    let llb_lo = t.add_node(
                        g, LevelKind::LLB, &format!("llb.low.c{cluster}"), s.llb_low / 2,
                        s.bw_low / 2.0, None,
                    );
                    let l1_lo = t.add_node(
                        llb_lo, LevelKind::L1, &format!("l1.low.c{cluster}"),
                        p.l1_bytes / 2, s.llbbw_low / 4.0, None,
                    );
                    let pes = rl * cl;
                    attach_unit(
                        &mut t, l1_lo, &format!("low-leaf.c{cluster}"), "lo-array",
                        Role::Low, rl, cl, p.rf_bytes_per_pe, ArchSpec::default_attach_bw(pes),
                        s.bw_low / 4.0, None, none(),
                    );
                    attach_unit(
                        &mut t, llb_lo, &format!("low-llb.c{cluster}"), "lo-array",
                        Role::Low, rl2, cl2, p.rf_bytes_per_pe, s.llbbw_low / 4.0,
                        s.bw_low / 2.0 - s.bw_low / 4.0, None, none(),
                    );
                } else {
                    let (rl, cl) = array_shape(lm);
                    leaf_unit(
                        &mut t, g, &format!("low.c{cluster}"), "lo-array", Role::Low, rl,
                        cl, p.rf_bytes_per_pe, p.l1_bytes / 2, s.llb_low / 2,
                        s.llbbw_low / 2.0, s.bw_low / 2.0, None, none(),
                    );
                }
            }
        }
        // (d) hierarchical + cross-depth: NPU at the leaves, a
        // bandwidth-oriented streamer attached at the LLB (NeuPIM-like):
        // wide and shallow — built for streaming, not reuse.
        (ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth) => {
            let (rh, ch) = array_shape(s.high_macs);
            let rl = ((s.low_macs as f64).sqrt() as u64 / 2).max(1);
            let cl = s.low_macs / rl;
            leaf_unit(
                &mut t, root, "npu", "npu-array", Role::High, rh, ch, p.rf_bytes_per_pe,
                p.l1_bytes, s.llb_high, s.llbbw_high, s.bw_high, None, none(),
            );
            llb_unit(
                &mut t, root, "near-llb", "streamer", Role::Low, rl, cl,
                p.rf_bytes_per_pe, s.llb_low, s.llbbw_low, s.bw_low, None, none(),
            );
        }
        // (h) compound: one low-side unit per heterogeneity source, each
        // with a distinct architectural type so classification recovers
        // every source. Low-side resources split evenly across the low
        // units; a clustered source repeats the whole mix per cluster.
        (placement, HeterogeneityLoc::Compound(parts)) => {
            let has_intra = parts.contains(&HeterogeneityLoc::IntraNode);
            let clustered = parts
                .iter()
                .any(|x| matches!(x, HeterogeneityLoc::CrossNode { clustered: true }));
            let has_xnode = clustered
                || parts
                    .iter()
                    .any(|x| matches!(x, HeterogeneityLoc::CrossNode { clustered: false }));
            let has_xdepth = parts.contains(&HeterogeneityLoc::CrossDepth);
            let hier = *placement == ComputePlacement::Hierarchical;
            let nclusters: u64 = if clustered { 2 } else { 1 };
            for cluster in 0..nclusters {
                let parent = if clustered {
                    t.add_group(root, &format!("cluster{cluster}"))
                } else {
                    root
                };
                let sfx = if clustered { format!(".c{cluster}") } else { String::new() };
                compound_cluster(
                    &mut t, parent, p, &s, nclusters, cluster as usize, &sfx, has_intra,
                    has_xnode, has_xdepth, hier,
                );
            }
        }
        (ComputePlacement::LeafOnly, HeterogeneityLoc::CrossDepth) => {
            unreachable!("rejected by validate()")
        }
    }
    Ok(t)
}

/// One compound cluster's unit list. With `nclusters == 1` this is the
/// whole machine.
#[allow(clippy::too_many_arguments)]
fn compound_cluster(
    t: &mut MachineTopology,
    parent: usize,
    p: &HardwareParams,
    s: &Shares,
    nclusters: u64,
    cluster: usize,
    sfx: &str,
    has_intra: bool,
    has_xnode: bool,
    has_xdepth: bool,
    hier: bool,
) {
    let none = MappingConstraints::default;
    let nc = nclusters;
    let high_macs = s.high_macs / nc;
    let low_macs = s.low_macs / nc;
    let l1 = p.l1_bytes / nc;
    let llb_high = s.llb_high / nc;
    let llb_low = s.llb_low / nc;
    let ncf = nc as f64;
    let (bw_high, bw_low) = (s.bw_high / ncf, s.bw_low / ncf);
    let (llbbw_high, llbbw_low) = (s.llbbw_high / ncf, s.llbbw_low / ncf);

    // The low-side unit list: (label, ty, attaches-at-LLB), one entry
    // per heterogeneity source, each with a distinct type.
    let mut lows: Vec<(&str, &str, bool)> = Vec::new();
    if has_intra {
        lows.push(("low-fsm", "lo-fsm-array", false));
    }
    if has_xnode {
        lows.push(("low-leaf", "lo-array", false));
    }
    if has_xdepth {
        lows.push(("low-nearllb", "streamer", true));
    }
    let nlow = lows.len() as u64;

    // When the placement is hierarchical but no cross-depth source
    // supplies the second level, the high type itself is replicated at
    // the LLB: same type at two depths adds hierarchy without adding a
    // heterogeneity source.
    let split_high = hier && !has_xdepth;
    let fsm = if has_intra { Some(cluster) } else { None };
    let hi_constraints =
        if has_intra { shared_fsm_constraints() } else { MappingConstraints::default() };
    let hm = if split_high { high_macs / 2 } else { high_macs };
    let (cols_shared, rows_h, rows_fsm) = if has_intra {
        intra_cols(hm, low_macs / nlow.max(1))
    } else {
        let (rh, ch) = array_shape(hm);
        (ch, rh, 1)
    };
    let hbw_div = if split_high { 2.0 } else { 1.0 };
    leaf_unit(
        t, parent, &format!("high{sfx}"), "hi-array", Role::High, rows_h, cols_shared,
        p.rf_bytes_per_pe, l1, if split_high { llb_high / 2 } else { llb_high },
        llbbw_high / hbw_div, bw_high / hbw_div, fsm, hi_constraints,
    );
    if split_high {
        let (rh2, ch2) = array_shape(high_macs - hm);
        llb_unit(
            t, parent, &format!("high-llb{sfx}"), "hi-array", Role::High, rh2, ch2,
            p.rf_bytes_per_pe, llb_high - llb_high / 2, llbbw_high / 2.0,
            bw_high - bw_high / 2.0, None, none(),
        );
    }

    for (i, (label, ty, at_llb)) in lows.iter().enumerate() {
        let macs = if i as u64 + 1 == nlow {
            low_macs - (nlow - 1) * (low_macs / nlow)
        } else {
            low_macs / nlow
        };
        let nlf = nlow as f64;
        let (llb_sz, llb_bw, dram_bw) = (llb_low / nlow, llbbw_low / nlf, bw_low / nlf);
        let label = format!("{label}{sfx}");
        if *at_llb {
            let rl = ((macs as f64).sqrt() as u64 / 2).max(1);
            let cl = macs / rl;
            llb_unit(
                t, parent, &label, ty, Role::Low, rl, cl, p.rf_bytes_per_pe, llb_sz,
                llb_bw, dram_bw, None, none(),
            );
        } else if *ty == "lo-fsm-array" {
            leaf_unit(
                t, parent, &label, ty, Role::Low, rows_fsm, cols_shared,
                p.rf_bytes_per_pe, l1, llb_sz, llb_bw, dram_bw, fsm,
                shared_fsm_constraints(),
            );
        } else {
            let (rl, cl) = array_shape(macs);
            leaf_unit(
                t, parent, &label, ty, Role::Low, rl, cl, p.rf_bytes_per_pe, l1, llb_sz,
                llb_bw, dram_bw, None, none(),
            );
        }
    }
}

/// Precomputed shared-node lookup tables (`node_users` + per-unit root
/// paths) for repeated contended-bandwidth queries. Derived from the
/// topology; rebuild after any structural change.
pub struct ContentionCtx {
    users: Vec<Vec<usize>>,
    paths: Vec<Vec<usize>>,
}

/// Flatten every attachment of `topology` under `mode` into the
/// per-unit view the cost model consumes — the ONE place the tree and
/// the flattened specs are tied together, shared by every
/// `MachineConfig` constructor so they can never drift.
///
/// Every flattened unit must have at least one PE: a zero-PE unit would
/// make the allocator's roof-weighted load ratios NaN (and its `min_by`
/// ordering meaningless). Topology files already reject empty arrays at
/// `validate()`, but the *generator* can produce one when the hardware
/// budget is too small to split (e.g. `total_macs: 1` on a
/// heterogeneous point rounds the low side to zero) — so the check
/// lives here, on the constructor path every machine passes through.
fn sub_accels_for(
    topology: &MachineTopology,
    mode: ContentionMode,
) -> Result<Vec<SubAccel>, String> {
    let sub_accels: Vec<SubAccel> = topology
        .flatten_all_with(mode)
        .into_iter()
        .enumerate()
        .map(|(id, spec)| SubAccel { id, role: topology.accels[id].role, spec })
        .collect();
    for s in &sub_accels {
        if s.spec.peak_macs() == 0 {
            return Err(format!(
                "sub-accelerator '{}' has zero PEs — the hardware budget is too small \
                 to partition at this taxonomy point",
                s.spec.name
            ));
        }
    }
    Ok(sub_accels)
}

impl MachineConfig {
    /// Build the machine for a taxonomy point under `params`: generate
    /// the memory tree, then flatten every attachment into the per-unit
    /// specs the cost model consumes.
    pub fn build(class: &HarpClass, params: &HardwareParams) -> Result<MachineConfig, String> {
        let topology = generate_topology(class, params)?;
        let sub_accels = sub_accels_for(&topology, ContentionMode::Off)?;
        Ok(MachineConfig {
            class: class.clone(),
            params: params.clone(),
            topology,
            sub_accels,
            contention: ContentionMode::Off,
        })
    }

    /// Re-flatten the machine under `mode`: the per-unit specs pick up
    /// their booked capacity slices and statically-partitioned shared
    /// edge bandwidths (or revert to the historical full-node view for
    /// [`ContentionMode::Off`]). Everything else — tree, class, params —
    /// is unchanged, so a `with_contention(Off)` round trip is exact.
    pub fn with_contention(mut self, mode: ContentionMode) -> Result<MachineConfig, String> {
        if mode == self.contention {
            return Ok(self);
        }
        self.topology.validate()?;
        self.sub_accels = sub_accels_for(&self.topology, mode)?;
        self.contention = mode;
        Ok(self)
    }

    /// Build from an explicit memory tree (the `--topology FILE` path).
    /// The taxonomy point is *derived* from the tree, and the synthetic
    /// `HardwareParams` summarise its aggregates (total PEs, root
    /// bandwidth) so downstream classification thresholds keep working.
    pub fn from_topology(topology: MachineTopology) -> Result<MachineConfig, String> {
        use crate::arch::level::LevelKind;
        topology.validate()?;
        let class = topology.classify()?;
        let defaults = HardwareParams::default();
        let params = HardwareParams {
            total_macs: topology.accels.iter().map(|a| a.peak_macs()).sum(),
            dram_bw_bits: topology.total_dram_bw() * defaults.datawidth_bits as f64,
            llb_bytes: topology
                .nodes
                .iter()
                .filter(|n| !n.passthrough && n.parent.is_some() && n.kind == LevelKind::LLB)
                .map(|n| n.size_words)
                .sum::<u64>()
                .max(1),
            ..defaults
        };
        let sub_accels = sub_accels_for(&topology, ContentionMode::Off)?;
        Ok(MachineConfig {
            class,
            params,
            topology,
            sub_accels,
            contention: ContentionMode::Off,
        })
    }

    /// Re-derive the taxonomy point from the tree structure (the
    /// generate → classify round-trip invariant).
    pub fn classify(&self) -> Result<HarpClass, String> {
        self.topology.classify()
    }

    /// Effective DRAM bandwidth for unit `s` when exactly the units with
    /// `busy[x] == true` contend (callers include `s` itself): idle
    /// units' shares are re-granted along the tree. Trees whose edge
    /// shares nest proportionally (every generated machine) reduce to
    /// the flat share-weighted formula, which we use directly so results
    /// are bit-stable against the pre-tree scheduler; pinned per-edge
    /// shares take the recursive path. That path walks the whole tree
    /// and allocates per call — acceptable because it only runs for
    /// explicitly pinned `--topology` machines, and the scheduler issues
    /// O(units) such queries per completion event, not per candidate op.
    pub fn dynamic_dram_bw(&self, s: usize, busy: &[bool]) -> f64 {
        let total = self.params.dram_bw_words();
        if self.topology.custom_edge_shares() {
            return self.topology.dram_shares(busy, total)[s];
        }
        let busy_now: f64 = (0..self.sub_accels.len())
            .filter(|&x| busy[x])
            .map(|x| self.sub_accels[x].spec.dram().bw_words_per_cycle)
            .sum();
        self.sub_accels[s].spec.dram().bw_words_per_cycle * (total / busy_now)
    }

    /// Precompute the shared-node lookup tables
    /// ([`MachineConfig::contended_boundary_bw_with`] queries them per
    /// dispatch — built once per schedule, like `CascadeAdj`, so the
    /// scheduler's hot loop allocates no per-call user tables).
    pub fn contention_ctx(&self) -> ContentionCtx {
        ContentionCtx {
            users: self.topology.node_users(),
            paths: (0..self.topology.accels.len())
                .map(|i| self.topology.accel_path(i))
                .collect(),
        }
    }

    /// Effective bandwidth at every boundary of unit `s`'s flattened
    /// spec when exactly the units with `busy[x] == true` contend
    /// (entry `j` feeds boundary `j`, between levels `j` and `j+1`).
    /// Convenience wrapper over
    /// [`MachineConfig::contended_boundary_bw_with`] that rebuilds the
    /// lookup tables; repeated callers (the scheduler) should hold a
    /// [`ContentionCtx`] instead.
    pub fn contended_boundary_bw(&self, s: usize, busy: &[bool]) -> Vec<f64> {
        self.contended_boundary_bw_with(&self.contention_ctx(), s, busy)
    }

    /// Per-boundary bandwidth grants for unit `s` under the busy set:
    ///
    /// - the attach port (boundary 0) is exclusive;
    /// - each intermediate boundary crosses the uplink edge of a path
    ///   node — under [`ContentionMode::Booked`] a shared edge splits
    ///   over its busy users by DRAM-share weight with idle re-grant
    ///   ([`MachineTopology::shared_edge_bw`]); under
    ///   [`ContentionMode::Off`] it stays whole (the historical model);
    /// - the outermost boundary is the DRAM grant
    ///   ([`MachineConfig::dynamic_dram_bw`]); under Booked, when the
    ///   edge below the root is shared, the grant additionally caps at
    ///   that edge's busy-weighted split so co-attached units cannot
    ///   double-book it (mirroring the static flatten).
    ///
    /// With every user busy this reproduces the static spec bandwidths
    /// bit-identically — provided the DRAM shares fully subscribe the
    /// root, which holds for every generated machine and for
    /// `--topology` files that claim (or default-fill to) the whole
    /// root. Undersubscribed shares behave like idle siblings: the
    /// dynamic re-grant hands the unclaimed bandwidth to the busy
    /// units, the longstanding [`MachineConfig::dynamic_dram_bw`]
    /// semantic.
    pub fn contended_boundary_bw_with(
        &self,
        ctx: &ContentionCtx,
        s: usize,
        busy: &[bool],
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.contended_boundary_bw_into(ctx, s, busy, &mut out);
        out
    }

    /// [`MachineConfig::contended_boundary_bw_with`] into a reusable
    /// buffer — the scheduler's per-dispatch form (no allocation once
    /// the buffer has grown to the deepest unit's boundary count).
    pub fn contended_boundary_bw_into(
        &self,
        ctx: &ContentionCtx,
        s: usize,
        busy: &[bool],
        out: &mut Vec<f64>,
    ) {
        let spec = &self.sub_accels[s].spec;
        let nb = spec.levels.len() - 1;
        out.clear();
        out.extend((0..nb).map(|j| spec.levels[j + 1].bw_words_per_cycle));
        out[nb - 1] = self.dynamic_dram_bw(s, busy);
        if self.contention == ContentionMode::Booked {
            let path = &ctx.paths[s];
            // Boundary j (1 ≤ j < nb−1) crosses the edge feeding path
            // node j−1; its users are that node's users.
            for j in 1..nb.saturating_sub(1) {
                let n = path[j - 1];
                out[j] = self.topology.shared_edge_bw(n, s, &ctx.users[n], busy);
            }
            // Shared edge below the root: cap the DRAM grant.
            if nb >= 2 {
                let n = path[nb - 2];
                if ctx.users[n].len() >= 2 {
                    out[nb - 1] = out[nb - 1].min(self.topology.shared_edge_bw(
                        n,
                        s,
                        &ctx.users[n],
                        busy,
                    ));
                }
            }
        }
    }

    /// Total PEs across sub-accelerators (invariant: == params.total_macs,
    /// up to the intra-node column-rounding remainder).
    pub fn total_pes(&self) -> u64 {
        self.sub_accels.iter().map(|s| s.spec.peak_macs()).sum()
    }

    pub fn is_heterogeneous(&self) -> bool {
        self.sub_accels.len() > 1
    }

    /// Sub-accelerators that accept a reuse class.
    pub fn accelerators_for(&self, class: ReuseClass) -> Vec<usize> {
        self.sub_accels
            .iter()
            .filter(|s| s.role.accepts(class))
            .map(|s| s.id)
            .collect()
    }

    pub fn describe(&self) -> String {
        let mut s = format!(
            "machine [{}]  total {} PEs, DRAM {:.0} w/cyc, tipping AI {:.0}\n",
            self.class,
            self.total_pes(),
            self.params.dram_bw_words(),
            self.params.tipping_ai()
        );
        for sub in &self.sub_accels {
            s.push_str(&format!("  [{}] {}\n", sub.role.name(), sub.spec.describe()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::level::LevelKind;

    fn params() -> HardwareParams {
        HardwareParams::default()
    }

    #[test]
    fn array_shape_near_square() {
        assert_eq!(array_shape(40960), (160, 256));
        assert_eq!(array_shape(32768), (128, 256));
        assert_eq!(array_shape(8192), (64, 128));
        assert_eq!(array_shape(7), (1, 7));
    }

    #[test]
    fn homogeneous_is_undivided() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous);
        let m = MachineConfig::build(&c, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 1);
        assert_eq!(m.total_pes(), 40960);
        assert_eq!(m.sub_accels[0].spec.dram().bw_words_per_cycle, 256.0);
        assert_eq!(m.sub_accels[0].spec.level(LevelKind::LLB).unwrap().size_words, 4 << 20);
    }

    #[test]
    fn cross_node_splits_match_policy() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node());
        let m = MachineConfig::build(&c, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 2);
        let hi = &m.sub_accels[0].spec;
        let lo = &m.sub_accels[1].spec;
        assert_eq!(hi.peak_macs(), 32768);
        assert_eq!(lo.peak_macs(), 8192);
        // LLB ∝ roof, BW 25/75.
        assert_eq!(hi.level(LevelKind::LLB).unwrap().size_words, (4 << 20) * 4 / 5);
        assert!((hi.dram().bw_words_per_cycle - 64.0).abs() < 1e-9);
        assert!((lo.dram().bw_words_per_cycle - 192.0).abs() < 1e-9);
    }

    /// The flattened tree specs must be numerically identical to the
    /// direct `ArchSpec::leaf`/`near_llb` chains — the guarantee that
    /// moving the machine model onto the tree moved no golden figure.
    #[test]
    fn flattened_specs_match_flat_constructors() {
        let p = params();
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node());
        let m = MachineConfig::build(&c, &p).unwrap();
        let direct = ArchSpec::leaf(
            "high", 128, 256, p.rf_bytes_per_pe, p.l1_bytes, (4 << 20) * 4 / 5,
            1024.0 * 0.8, 64.0,
        );
        let flat = &m.sub_accels[0].spec;
        assert_eq!(flat.levels.len(), direct.levels.len());
        for (a, b) in flat.levels.iter().zip(&direct.levels) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.size_words, b.size_words);
            assert_eq!(a.bw_words_per_cycle, b.bw_words_per_cycle);
            assert_eq!(a.energy_pj_per_word, b.energy_pj_per_word);
        }
        // Near-LLB instance too (cross-depth low unit).
        let cd = HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth);
        let mcd = MachineConfig::build(&cd, &p).unwrap();
        let lo = &mcd.sub_accels[1].spec;
        let direct_lo = ArchSpec::near_llb(
            "near-llb", lo.rows, lo.cols, p.rf_bytes_per_pe,
            (4 << 20) - (4 << 20) * 4 / 5, 1024.0 - 1024.0 * 0.8, 192.0,
        );
        assert_eq!(lo.levels.len(), direct_lo.levels.len());
        for (a, b) in lo.levels.iter().zip(&direct_lo.levels) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.size_words, b.size_words);
            assert_eq!(a.bw_words_per_cycle, b.bw_words_per_cycle);
            assert_eq!(a.energy_pj_per_word, b.energy_pj_per_word);
        }
    }

    #[test]
    fn intra_node_shares_columns() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::IntraNode);
        let m = MachineConfig::build(&c, &params()).unwrap();
        let hi = &m.sub_accels[0].spec;
        let lo = &m.sub_accels[1].spec;
        assert_eq!(hi.cols, lo.cols);
        assert!(hi.constraints.forced_col_dim.is_some());
        assert!(lo.constraints.forced_col_dim.is_some());
        // The tree marks the shared sequencer.
        assert_eq!(m.topology.accels[0].fsm_group, m.topology.accels[1].fsm_group);
        assert!(m.topology.accels[0].fsm_group.is_some());
    }

    #[test]
    fn cross_depth_low_has_no_l1() {
        let c = HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth);
        let m = MachineConfig::build(&c, &params()).unwrap();
        let lo = &m.sub_accels[1].spec;
        assert!(lo.level(LevelKind::L1).is_none());
        let hi = &m.sub_accels[0].spec;
        assert!(hi.level(LevelKind::L1).is_some());
    }

    #[test]
    fn invalid_point_rejected() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::CrossDepth);
        assert!(MachineConfig::build(&c, &params()).is_err());
    }

    /// Regression for the latent allocator NaN: a hardware budget too
    /// small to split (the low side rounds to zero PEs) must be
    /// rejected at machine construction — previously it built a
    /// zero-PE unit whose load ratio was NaN and the allocator's
    /// `min_by` comparison panicked mid-evaluation.
    #[test]
    fn degenerate_budget_rejected_not_nan() {
        let tiny = HardwareParams { total_macs: 1, ..params() };
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node());
        let err = MachineConfig::build(&c, &tiny).unwrap_err();
        assert!(err.contains("zero PEs"), "{err}");
        // A budget of 1 still builds the homogeneous point (one unit).
        let homo = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous);
        assert!(MachineConfig::build(&homo, &tiny).is_ok());
    }

    #[test]
    fn total_pes_conserved_within_rounding() {
        for (_, class) in HarpClass::eval_points() {
            let m = MachineConfig::build(&class, &params()).unwrap();
            let total = m.total_pes();
            assert!(
                total >= 40960 * 95 / 100 && total <= 40960,
                "{class}: {total} PEs"
            );
        }
    }

    #[test]
    fn compound_has_three_units() {
        let c = HarpClass::new(
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::cross_node(),
                HeterogeneityLoc::CrossDepth,
            ]),
        );
        let m = MachineConfig::build(&c, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 3);
        assert_eq!(m.accelerators_for(ReuseClass::Low).len(), 2);
    }

    #[test]
    fn clustered_cross_node_unit_counts() {
        let leaf = HarpClass::new(
            ComputePlacement::LeafOnly,
            HeterogeneityLoc::CrossNode { clustered: true },
        );
        let m = MachineConfig::build(&leaf, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 4);
        // The hierarchical variant adds a per-cluster LLB-attached low
        // unit: the mix repeats at two depths.
        let hier = HarpClass::new(
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::CrossNode { clustered: true },
        );
        let mh = MachineConfig::build(&hier, &params()).unwrap();
        assert_eq!(mh.sub_accels.len(), 6);
    }

    #[test]
    fn hierarchical_cross_node_has_three_units_two_depths() {
        let c =
            HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node());
        let m = MachineConfig::build(&c, &params()).unwrap();
        assert_eq!(m.sub_accels.len(), 3);
        let depths: std::collections::BTreeSet<usize> =
            m.topology.accels.iter().map(|a| m.topology.depth(a.attach)).collect();
        assert_eq!(depths.len(), 2);
        // The two low units share one LLB node.
        assert_eq!(
            m.sub_accels[1].spec.level(LevelKind::LLB).unwrap().size_words,
            m.sub_accels[2].spec.level(LevelKind::LLB).unwrap().size_words
        );
    }

    /// The tentpole invariant: generate → classify returns the same
    /// taxonomy point, for every point the taxonomy can express.
    #[test]
    fn round_trip_every_taxonomy_point() {
        for class in HarpClass::all_points() {
            let m = MachineConfig::build(&class, &params()).unwrap();
            let back = m.classify().unwrap();
            assert_eq!(back, class, "round trip failed for {class}");
        }
    }

    /// The contention tentpole on the generated machines: hier+xnode's
    /// two low units share one LLB node; booking splits it exactly,
    /// leaves every exclusive resource alone, and round-trips back to
    /// the historical specs at `Off`.
    #[test]
    fn with_contention_books_shared_llb_and_round_trips() {
        let c =
            HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node());
        let m = MachineConfig::build(&c, &params()).unwrap();
        let llb_full = m.sub_accels[1].spec.level(LevelKind::LLB).unwrap().size_words;
        let booked = m.clone().with_contention(ContentionMode::Booked).unwrap();
        assert_eq!(booked.contention, ContentionMode::Booked);
        let lo1 = booked.sub_accels[1].spec.level(LevelKind::LLB).unwrap().size_words;
        let lo2 = booked.sub_accels[2].spec.level(LevelKind::LLB).unwrap().size_words;
        // The two equal-sized low units split the shared LLB, summing
        // exactly to the node capacity (no words lost to rounding).
        assert!(lo1 < llb_full && lo2 < llb_full);
        assert_eq!(lo1 + lo2, llb_full);
        assert!(lo1.abs_diff(lo2) <= 1);
        // The high unit has its LLB to itself: untouched.
        assert_eq!(
            booked.sub_accels[0].spec.level(LevelKind::LLB).unwrap().size_words,
            m.sub_accels[0].spec.level(LevelKind::LLB).unwrap().size_words
        );
        // DRAM shares (already exclusive) are untouched.
        for (a, b) in booked.sub_accels.iter().zip(&m.sub_accels) {
            assert_eq!(
                a.spec.dram().bw_words_per_cycle,
                b.spec.dram().bw_words_per_cycle
            );
        }
        // Off round trip restores the historical specs bit-identically.
        let back = booked.with_contention(ContentionMode::Off).unwrap();
        for (a, b) in back.sub_accels.iter().zip(&m.sub_accels) {
            assert_eq!(a.spec.levels.len(), b.spec.levels.len());
            for (x, y) in a.spec.levels.iter().zip(&b.spec.levels) {
                assert_eq!(x.size_words, y.size_words);
                assert_eq!(x.bw_words_per_cycle, y.bw_words_per_cycle);
            }
        }
    }

    #[test]
    fn contended_boundary_bw_matches_static_spec_under_full_load() {
        let c =
            HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node());
        let m = MachineConfig::build(&c, &params())
            .unwrap()
            .with_contention(ContentionMode::Booked)
            .unwrap();
        let all = vec![true; m.sub_accels.len()];
        for s in 0..m.sub_accels.len() {
            let bw = m.contended_boundary_bw(s, &all);
            let spec = &m.sub_accels[s].spec;
            assert_eq!(bw.len(), spec.levels.len() - 1);
            for (j, &b) in bw.iter().enumerate() {
                assert_eq!(
                    b,
                    spec.levels[j + 1].bw_words_per_cycle,
                    "unit {s} boundary {j} diverges from the static partition"
                );
            }
        }
        // A solo busy low unit re-inherits bandwidth up to the physical
        // uplink of its SHARED subtree edge (192 w/cyc — the low LLB's
        // fill rate), not the whole 256 w/cyc root: co-attached units'
        // grants can never oversubscribe the edge they share.
        let mut solo = vec![false; m.sub_accels.len()];
        solo[1] = true;
        let bw = m.contended_boundary_bw(1, &solo);
        assert!((bw.last().unwrap() - 192.0).abs() < 1e-6);
        // The high unit shares no below-root edge: its solo re-grant is
        // still the whole root.
        let mut solo_hi = vec![false; m.sub_accels.len()];
        solo_hi[0] = true;
        let bw = m.contended_boundary_bw(0, &solo_hi);
        assert!((bw.last().unwrap() - m.params.dram_bw_words()).abs() < 1e-6);
    }

    #[test]
    fn dynamic_bw_regrants_to_sole_busy_unit() {
        let c = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node());
        let m = MachineConfig::build(&c, &params()).unwrap();
        let both = m.dynamic_dram_bw(0, &[true, true]);
        assert!((both - 64.0).abs() < 1e-9);
        let solo = m.dynamic_dram_bw(1, &[false, true]);
        assert!((solo - 256.0).abs() < 1e-6);
    }
}
