//! Architecture layer: storage hierarchies, sub-accelerator specs, the
//! HARP taxonomy, energy tables, and the resource partitioner that turns
//! a taxonomy point + Table III hardware budget into concrete machines.

pub mod energy;
pub mod level;
pub mod partition;
pub mod spec;
pub mod taxonomy;

pub use level::{LevelKind, StorageLevel};
pub use partition::{HardwareParams, MachineConfig, SubAccel};
pub use spec::ArchSpec;
pub use taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
