//! Architecture layer: storage hierarchies, sub-accelerator specs, the
//! HARP taxonomy, energy tables, the machine memory tree ([`topology`]),
//! and the topology generator ([`partition`]) that turns a taxonomy
//! point + Table III hardware budget into a concrete machine tree.

pub mod energy;
pub mod level;
pub mod partition;
pub mod spec;
pub mod taxonomy;
pub mod topology;

pub use level::{LevelKind, StorageLevel};
pub use partition::{HardwareParams, MachineConfig, SubAccel};
pub use spec::ArchSpec;
pub use taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
pub use topology::{AccelNode, MachineTopology, MemoryNode};
