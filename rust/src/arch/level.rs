//! Storage levels of a memory hierarchy.
//!
//! Following the paper's tree view of the hierarchy (footnote 2): DRAM is
//! the root, the last-level buffer (LLB) an intermediate node, L1 the
//! per-array buffer, and the per-PE register file (RF) the leaf. A
//! sub-accelerator's `ArchSpec` holds an *innermost-first* list of these.
//!
//! A level's *kind* is an open, interned name rather than a closed enum:
//! the four canonical kinds (`RF`, `L1`, `LLB`, `DRAM`) cover the paper's
//! machines, and [`LevelKind::named`] mints additional kinds (`"L2"`,
//! `"HBM"`, …) for deeper custom hierarchies described by a `--topology`
//! JSON file. Identity is the name — two kinds compare equal iff their
//! names match — so levels survive a JSON round-trip exactly. A level's
//! *position* in the hierarchy is its index in the spec's level list (or
//! its depth in the machine tree), never something inferred from the
//! kind: the cost model walks levels by index.

use std::sync::Mutex;

/// Kind (identity) of a storage level: an interned name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LevelKind(&'static str);

/// Interned custom level names (canonical kinds never land here). Leaked
/// once per distinct name, bounded by the set of names a process sees.
static INTERNED: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());

impl LevelKind {
    /// Per-PE register file — the innermost level.
    pub const RF: LevelKind = LevelKind("RF");
    /// Per-array buffer.
    pub const L1: LevelKind = LevelKind("L1");
    /// Last-level buffer.
    pub const LLB: LevelKind = LevelKind("LLB");
    /// Off-chip memory — the outermost level (tree root).
    pub const DRAM: LevelKind = LevelKind("DRAM");

    /// The canonical four-level chain, innermost first. Custom kinds are
    /// not listed here; serialization appends them after these.
    pub const ALL: [LevelKind; 4] =
        [LevelKind::RF, LevelKind::L1, LevelKind::LLB, LevelKind::DRAM];

    pub fn name(self) -> &'static str {
        self.0
    }

    /// Position of a canonical kind in the RF→DRAM chain; `None` for
    /// custom kinds.
    pub fn canonical_depth(self) -> Option<usize> {
        LevelKind::ALL.iter().position(|k| *k == self)
    }

    /// A kind by name. Canonical names resolve to the canonical
    /// constants; any other name is interned (first use leaks one copy).
    pub fn named(name: &str) -> LevelKind {
        for k in LevelKind::ALL {
            if k.0 == name {
                return k;
            }
        }
        let mut pool = INTERNED.lock().unwrap();
        if let Some(s) = pool.iter().find(|s| **s == name) {
            return LevelKind(s);
        }
        let s: &'static str = Box::leak(name.to_string().into_boxed_str());
        pool.push(s);
        LevelKind(s)
    }
}

/// One storage level of a sub-accelerator.
#[derive(Debug, Clone)]
pub struct StorageLevel {
    pub kind: LevelKind,
    /// Capacity in words (datawidth = 8 bits ⇒ 1 word = 1 byte).
    /// `u64::MAX` for DRAM (unbounded).
    pub size_words: u64,
    /// Peak words per cycle this level can deliver to the level below
    /// (toward compute). For DRAM this is the partitioned share of the
    /// Table III sweep value.
    pub bw_words_per_cycle: f64,
    /// Access energy in pJ per word.
    pub energy_pj_per_word: f64,
}

impl StorageLevel {
    pub fn new(kind: LevelKind, size_words: u64, bw: f64, energy_pj: f64) -> StorageLevel {
        StorageLevel { kind, size_words, bw_words_per_cycle: bw, energy_pj_per_word: energy_pj }
    }

    pub fn is_unbounded(&self) -> bool {
        self.size_words == u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_unbounded() {
        let d = StorageLevel::new(LevelKind::DRAM, u64::MAX, 256.0, 160.0);
        assert!(d.is_unbounded());
        let l1 = StorageLevel::new(LevelKind::L1, 131072, 512.0, 2.0);
        assert!(!l1.is_unbounded());
    }

    #[test]
    fn canonical_names_resolve_to_constants() {
        assert_eq!(LevelKind::named("RF"), LevelKind::RF);
        assert_eq!(LevelKind::named("DRAM"), LevelKind::DRAM);
        assert_eq!(LevelKind::RF.canonical_depth(), Some(0));
        assert_eq!(LevelKind::DRAM.canonical_depth(), Some(3));
    }

    #[test]
    fn custom_kinds_intern_by_name() {
        let a = LevelKind::named("L2");
        let b = LevelKind::named("L2");
        assert_eq!(a, b);
        assert_eq!(a.name(), "L2");
        assert_eq!(a.canonical_depth(), None);
        assert_ne!(a, LevelKind::L1);
        // Interning is stable across lookups of other names.
        let c = LevelKind::named("HBM");
        assert_ne!(a, c);
        assert_eq!(LevelKind::named("L2"), a);
    }
}
