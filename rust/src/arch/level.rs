//! Storage levels of a memory hierarchy.
//!
//! Following the paper's tree view of the hierarchy (footnote 2): DRAM is
//! the root, the last-level buffer (LLB) the intermediate node, L1 the
//! per-array buffer, and the per-PE register file (RF) the leaf. A
//! sub-accelerator's `ArchSpec` holds an *innermost-first* list of these.

/// Kind of storage level. `Dram` is always outermost; `Rf` innermost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LevelKind {
    Rf,
    L1,
    Llb,
    Dram,
}

impl LevelKind {
    pub fn name(self) -> &'static str {
        match self {
            LevelKind::Rf => "RF",
            LevelKind::L1 => "L1",
            LevelKind::Llb => "LLB",
            LevelKind::Dram => "DRAM",
        }
    }

    pub const ALL: [LevelKind; 4] = [LevelKind::Rf, LevelKind::L1, LevelKind::Llb, LevelKind::Dram];
}

/// One storage level of a sub-accelerator.
#[derive(Debug, Clone)]
pub struct StorageLevel {
    pub kind: LevelKind,
    /// Capacity in words (datawidth = 8 bits ⇒ 1 word = 1 byte).
    /// `u64::MAX` for DRAM (unbounded).
    pub size_words: u64,
    /// Peak words per cycle this level can deliver to the level below
    /// (toward compute). For DRAM this is the partitioned share of the
    /// Table III sweep value.
    pub bw_words_per_cycle: f64,
    /// Access energy in pJ per word.
    pub energy_pj_per_word: f64,
}

impl StorageLevel {
    pub fn new(kind: LevelKind, size_words: u64, bw: f64, energy_pj: f64) -> StorageLevel {
        StorageLevel { kind, size_words, bw_words_per_cycle: bw, energy_pj_per_word: energy_pj }
    }

    pub fn is_unbounded(&self) -> bool {
        self.size_words == u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_unbounded() {
        let d = StorageLevel::new(LevelKind::Dram, u64::MAX, 256.0, 160.0);
        assert!(d.is_unbounded());
        let l1 = StorageLevel::new(LevelKind::L1, 131072, 512.0, 2.0);
        assert!(!l1.is_unbounded());
    }
}
