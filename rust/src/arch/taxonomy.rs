//! The HARP taxonomy (paper §IV).
//!
//! Two axes classify every hierarchical and/or heterogeneous processor:
//!
//! 1. **Compute placement** — leaf-only (compute only next to L1, the
//!    leaves of the memory tree) vs hierarchical (compute at multiple
//!    levels of the hierarchy).
//! 2. **Heterogeneity location** — homogeneous, intra-node (sub-
//!    accelerators under one FSM), cross-node (different nodes at the
//!    same level), cross-depth (different levels of the hierarchy), or
//!    compound (several of the above at once).
//!
//! `classify()` reproduces Table I; `HarpClass::validate()` encodes the
//! structural rules the paper states (e.g. cross-depth is the one
//! category with no leaf-only counterpart).

use std::fmt;

/// Axis 1: where compute sits in the memory tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ComputePlacement {
    LeafOnly,
    Hierarchical,
}

impl ComputePlacement {
    pub fn name(self) -> &'static str {
        match self {
            ComputePlacement::LeafOnly => "leaf-only",
            ComputePlacement::Hierarchical => "hierarchical",
        }
    }
}

/// Axis 2: where heterogeneity (if any) occurs.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum HeterogeneityLoc {
    /// No heterogeneity (e.g. TPUv1).
    Homogeneous,
    /// Sub-accelerators share a node and an FSM (tensor core + SM,
    /// RaPiD's MAC array + SFU row). Tightest coupling.
    IntraNode,
    /// Different sub-accelerators at different nodes of the same level
    /// (Herald, AESPA, TPUv4). `clustered` marks Symphony-style layouts
    /// where the heterogeneous mix repeats per cluster rather than
    /// occupying disjoint regions.
    CrossNode { clustered: bool },
    /// Sub-accelerators at different levels of the hierarchy
    /// (NeuPIM, Duplex). Coarsest coupling; implies hierarchical.
    CrossDepth,
    /// Multiple simultaneous sources of heterogeneity (paper Fig 4h).
    Compound(Vec<HeterogeneityLoc>),
}

impl HeterogeneityLoc {
    pub fn cross_node() -> HeterogeneityLoc {
        HeterogeneityLoc::CrossNode { clustered: false }
    }

    pub fn name(&self) -> String {
        match self {
            HeterogeneityLoc::Homogeneous => "homogeneous".into(),
            HeterogeneityLoc::IntraNode => "intra-node".into(),
            HeterogeneityLoc::CrossNode { clustered: false } => "cross-node".into(),
            HeterogeneityLoc::CrossNode { clustered: true } => "cross-node (clustered)".into(),
            HeterogeneityLoc::CrossDepth => "cross-depth".into(),
            HeterogeneityLoc::Compound(parts) => {
                let names: Vec<String> = parts.iter().map(|p| p.name()).collect();
                format!("compound [{}]", names.join(" + "))
            }
        }
    }
}

/// A point in the HARP taxonomy.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct HarpClass {
    pub placement: ComputePlacement,
    pub heterogeneity: HeterogeneityLoc,
}

impl fmt::Display for HarpClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}+{}", self.placement.name(), self.heterogeneity.name())
    }
}

impl HarpClass {
    pub fn new(placement: ComputePlacement, heterogeneity: HeterogeneityLoc) -> HarpClass {
        HarpClass { placement, heterogeneity }
    }

    /// Structural validity rules from the paper:
    /// - cross-depth heterogeneity requires compute at ≥2 levels, so it
    ///   cannot be leaf-only ("the only category that cannot have a
    ///   leaf-only counterpart");
    /// - a compound class must name ≥2 distinct sources, none of which
    ///   is itself compound or homogeneous;
    /// - a compound containing cross-depth must be hierarchical.
    pub fn validate(&self) -> Result<(), String> {
        fn check_part(p: &HeterogeneityLoc) -> Result<(), String> {
            match p {
                HeterogeneityLoc::Compound(_) => Err("nested compound".into()),
                HeterogeneityLoc::Homogeneous => Err("homogeneous inside compound".into()),
                _ => Ok(()),
            }
        }
        match (&self.placement, &self.heterogeneity) {
            (ComputePlacement::LeafOnly, HeterogeneityLoc::CrossDepth) => {
                Err("cross-depth heterogeneity requires a hierarchical placement".into())
            }
            (placement, HeterogeneityLoc::Compound(parts)) => {
                if parts.is_empty() {
                    return Err("compound with no heterogeneity sources".into());
                }
                if parts.len() == 1 {
                    return Err(
                        "compound needs ≥2 heterogeneity sources (one source is just that source)"
                            .into(),
                    );
                }
                for p in parts {
                    check_part(p)?;
                }
                // Full pairwise distinctness — `dedup_by` only catches
                // *adjacent* duplicates, so [xnode, xdepth, xnode] used
                // to slip through.
                for (i, a) in parts.iter().enumerate() {
                    if parts[i + 1..].contains(a) {
                        return Err(format!(
                            "compound sources must be distinct ('{}' appears twice)",
                            a.name()
                        ));
                    }
                }
                // Clustering is a property of THE cross-node axis, so a
                // compound cannot carry both flavours at once — and the
                // classifier emits sources in canonical order, so only
                // canonically-ordered compounds can round-trip.
                let clustered_and_not = parts
                    .iter()
                    .any(|x| matches!(x, HeterogeneityLoc::CrossNode { clustered: false }))
                    && parts
                        .iter()
                        .any(|x| matches!(x, HeterogeneityLoc::CrossNode { clustered: true }));
                if clustered_and_not {
                    return Err(
                        "compound cannot mix clustered and unclustered cross-node sources"
                            .into(),
                    );
                }
                fn rank(p: &HeterogeneityLoc) -> u8 {
                    match p {
                        HeterogeneityLoc::IntraNode => 0,
                        HeterogeneityLoc::CrossNode { .. } => 1,
                        HeterogeneityLoc::CrossDepth => 2,
                        _ => 3,
                    }
                }
                if parts.windows(2).any(|w| rank(&w[0]) >= rank(&w[1])) {
                    return Err(
                        "compound sources must be in canonical order \
                         (intra-node, cross-node, cross-depth)"
                            .into(),
                    );
                }
                if parts.contains(&HeterogeneityLoc::CrossDepth)
                    && *placement == ComputePlacement::LeafOnly
                {
                    return Err("compound containing cross-depth must be hierarchical".into());
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    /// Every valid taxonomy point the topology generator can realise:
    /// the full placement × heterogeneity grid (Table I), clustered
    /// variants, and the compound combinations with their sources in
    /// canonical order (intra-node, cross-node, cross-depth). This is
    /// the domain of the generate → classify round-trip invariant.
    pub fn all_points() -> Vec<HarpClass> {
        use ComputePlacement::*;
        use HeterogeneityLoc::*;
        let xn = || CrossNode { clustered: false };
        let xc = || CrossNode { clustered: true };
        vec![
            HarpClass::new(LeafOnly, Homogeneous),
            HarpClass::new(LeafOnly, IntraNode),
            HarpClass::new(LeafOnly, xn()),
            HarpClass::new(LeafOnly, xc()),
            HarpClass::new(Hierarchical, Homogeneous),
            HarpClass::new(Hierarchical, IntraNode),
            HarpClass::new(Hierarchical, xn()),
            HarpClass::new(Hierarchical, xc()),
            HarpClass::new(Hierarchical, CrossDepth),
            HarpClass::new(LeafOnly, Compound(vec![IntraNode, xn()])),
            HarpClass::new(LeafOnly, Compound(vec![IntraNode, xc()])),
            HarpClass::new(Hierarchical, Compound(vec![IntraNode, xn()])),
            HarpClass::new(Hierarchical, Compound(vec![IntraNode, CrossDepth])),
            HarpClass::new(Hierarchical, Compound(vec![xn(), CrossDepth])),
            HarpClass::new(Hierarchical, Compound(vec![xc(), CrossDepth])),
            HarpClass::new(Hierarchical, Compound(vec![IntraNode, xn(), CrossDepth])),
        ]
    }

    /// The four evaluation configurations of the paper (Fig 4 a-d).
    pub fn eval_points() -> Vec<(char, HarpClass)> {
        vec![
            ('a', HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous)),
            ('b', HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node())),
            ('c', HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::IntraNode)),
            ('d', HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth)),
        ]
    }

    /// Short machine-friendly id (used in figure labels / CLI).
    pub fn id(&self) -> String {
        let p = match self.placement {
            ComputePlacement::LeafOnly => "leaf",
            ComputePlacement::Hierarchical => "hier",
        };
        let h: String = match &self.heterogeneity {
            HeterogeneityLoc::Homogeneous => "homo".into(),
            HeterogeneityLoc::IntraNode => "intra".into(),
            HeterogeneityLoc::CrossNode { clustered: false } => "xnode".into(),
            HeterogeneityLoc::CrossNode { clustered: true } => "xnode-cl".into(),
            HeterogeneityLoc::CrossDepth => "xdepth".into(),
            // Unambiguous per variant so every listed id parses back:
            // e.g. "compound[intra,xnode]".
            HeterogeneityLoc::Compound(parts) => {
                let toks: Vec<&str> = parts
                    .iter()
                    .map(|p| match p {
                        HeterogeneityLoc::IntraNode => "intra",
                        HeterogeneityLoc::CrossNode { clustered: false } => "xnode",
                        HeterogeneityLoc::CrossNode { clustered: true } => "xnode-cl",
                        HeterogeneityLoc::CrossDepth => "xdepth",
                        _ => "?", // rejected by validate()
                    })
                    .collect();
                format!("compound[{}]", toks.join(","))
            }
        };
        format!("{p}+{h}")
    }

    /// Parse an id produced by [`HarpClass::id`]. The bare `compound`
    /// shorthand is the canonical Fig 4h point, `[xnode, xdepth]`.
    pub fn from_id(id: &str) -> Option<HarpClass> {
        let (p, h) = id.split_once('+')?;
        let placement = match p {
            "leaf" => ComputePlacement::LeafOnly,
            "hier" => ComputePlacement::Hierarchical,
            _ => return None,
        };
        let part = |tok: &str| -> Option<HeterogeneityLoc> {
            Some(match tok {
                "intra" => HeterogeneityLoc::IntraNode,
                "xnode" => HeterogeneityLoc::cross_node(),
                "xnode-cl" => HeterogeneityLoc::CrossNode { clustered: true },
                "xdepth" => HeterogeneityLoc::CrossDepth,
                _ => return None,
            })
        };
        let heterogeneity = match h {
            "homo" => HeterogeneityLoc::Homogeneous,
            "compound" => HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::cross_node(),
                HeterogeneityLoc::CrossDepth,
            ]),
            _ => {
                if let Some(inner) =
                    h.strip_prefix("compound[").and_then(|r| r.strip_suffix(']'))
                {
                    let parts: Option<Vec<HeterogeneityLoc>> =
                        inner.split(',').map(|t| part(t.trim())).collect();
                    HeterogeneityLoc::Compound(parts?)
                } else {
                    part(h)?
                }
            }
        };
        let class = HarpClass::new(placement, heterogeneity);
        class.validate().ok()?;
        Some(class)
    }
}

/// A prior-work entry for the Table I reproduction.
#[derive(Debug, Clone)]
pub struct PriorWork {
    pub name: &'static str,
    pub class: HarpClass,
    pub remark: &'static str,
}

/// The classification of existing works — paper Table I.
pub fn prior_works() -> Vec<PriorWork> {
    use ComputePlacement::*;
    use HeterogeneityLoc::*;
    let xn = HeterogeneityLoc::cross_node;
    vec![
        PriorWork { name: "TPUv1", class: HarpClass::new(LeafOnly, Homogeneous), remark: "fixed-dataflow systolic array" },
        PriorWork { name: "MAERI", class: HarpClass::new(LeafOnly, Homogeneous), remark: "flexible interconnect, homogeneous PEs" },
        PriorWork { name: "Eyeriss", class: HarpClass::new(LeafOnly, Homogeneous), remark: "row-stationary CNN accelerator" },
        PriorWork { name: "Flexagon", class: HarpClass::new(LeafOnly, Homogeneous), remark: "multi-dataflow sparse-sparse accelerator" },
        PriorWork { name: "Herald", class: HarpClass::new(LeafOnly, xn()), remark: "sub-accelerators for different CONV shapes" },
        PriorWork { name: "AESPA", class: HarpClass::new(LeafOnly, xn()), remark: "heterogeneous SpGEMM accelerator" },
        PriorWork { name: "TPUv4", class: HarpClass::new(LeafOnly, xn()), remark: "dense core + sparse embedding core" },
        PriorWork { name: "NVIDIA B100", class: HarpClass::new(LeafOnly, IntraNode), remark: "SM + tensor core share one program counter" },
        PriorWork { name: "VEGETA", class: HarpClass::new(LeafOnly, IntraNode), remark: "sparse/dense tile extensions in a CPU core" },
        PriorWork { name: "RaPiD", class: HarpClass::new(LeafOnly, IntraNode), remark: "MAC array + high-precision SFU row, one FSM" },
        PriorWork { name: "NeuPIM", class: HarpClass::new(Hierarchical, CrossDepth), remark: "NPU at leaves + processing-in-DRAM at root" },
        PriorWork { name: "Duplex", class: HarpClass::new(Hierarchical, CrossDepth), remark: "LLM device with near-DRAM compute" },
        PriorWork { name: "Symphony", class: HarpClass::new(Hierarchical, CrossNode { clustered: true }), remark: "clustered cross-node heterogeneity across levels" },
    ]
}

/// Classify by name (the `classify` CLI verb).
pub fn classify(name: &str) -> Option<PriorWork> {
    let lower = name.to_ascii_lowercase();
    prior_works().into_iter().find(|w| w.name.to_ascii_lowercase().contains(&lower))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_depth_requires_hierarchical() {
        let bad = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::CrossDepth);
        assert!(bad.validate().is_err());
        let good = HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth);
        assert!(good.validate().is_ok());
    }

    #[test]
    fn compound_rules() {
        let ok = HarpClass::new(
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::cross_node(),
                HeterogeneityLoc::CrossDepth,
            ]),
        );
        assert!(ok.validate().is_ok());
        let too_few = HarpClass::new(
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::Compound(vec![HeterogeneityLoc::CrossDepth]),
        );
        assert!(too_few.validate().is_err());
        let leaf_xdepth = HarpClass::new(
            ComputePlacement::LeafOnly,
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::cross_node(),
                HeterogeneityLoc::CrossDepth,
            ]),
        );
        assert!(leaf_xdepth.validate().is_err());
        let nested = HarpClass::new(
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::cross_node(),
                HeterogeneityLoc::Compound(vec![]),
            ]),
        );
        assert!(nested.validate().is_err());
    }

    /// Degenerate compound payloads are rejected with a clear error:
    /// empty, single-source, nested compound, homogeneous-inside, and
    /// (the actual historical bug) non-adjacent duplicate sources.
    #[test]
    fn degenerate_compounds_rejected() {
        let hier = ComputePlacement::Hierarchical;
        let make = |parts: Vec<HeterogeneityLoc>| {
            HarpClass::new(hier, HeterogeneityLoc::Compound(parts))
        };
        let empty = make(vec![]).validate().unwrap_err();
        assert!(empty.contains("no heterogeneity sources"), "{empty}");
        let single = make(vec![HeterogeneityLoc::CrossDepth]).validate().unwrap_err();
        assert!(single.contains("≥2"), "{single}");
        let nested = make(vec![
            HeterogeneityLoc::cross_node(),
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::cross_node(),
                HeterogeneityLoc::CrossDepth,
            ]),
        ])
        .validate()
        .unwrap_err();
        assert!(nested.contains("nested"), "{nested}");
        let homo = make(vec![HeterogeneityLoc::cross_node(), HeterogeneityLoc::Homogeneous])
            .validate()
            .unwrap_err();
        assert!(homo.contains("homogeneous"), "{homo}");
        // Non-adjacent duplicate — dedup_by missed this before.
        let dup = make(vec![
            HeterogeneityLoc::cross_node(),
            HeterogeneityLoc::CrossDepth,
            HeterogeneityLoc::cross_node(),
        ])
        .validate()
        .unwrap_err();
        assert!(dup.contains("distinct"), "{dup}");
        // Mixed cross-node flavours are not expressible by one machine.
        let mixed = make(vec![
            HeterogeneityLoc::cross_node(),
            HeterogeneityLoc::CrossNode { clustered: true },
        ])
        .validate()
        .unwrap_err();
        assert!(mixed.contains("mix"), "{mixed}");
        // Only canonically-ordered compounds can round-trip classify().
        let unordered = make(vec![
            HeterogeneityLoc::CrossDepth,
            HeterogeneityLoc::cross_node(),
        ])
        .validate()
        .unwrap_err();
        assert!(unordered.contains("canonical order"), "{unordered}");
    }

    #[test]
    fn all_points_are_valid_and_distinct() {
        let points = HarpClass::all_points();
        assert_eq!(points.len(), 16);
        for p in &points {
            p.validate().unwrap_or_else(|e| panic!("{p}: {e}"));
        }
        for (i, p) in points.iter().enumerate() {
            assert!(!points[i + 1..].contains(p), "duplicate point {p}");
        }
    }

    #[test]
    fn table_i_matches_paper() {
        let works = prior_works();
        let find = |n: &str| works.iter().find(|w| w.name == n).unwrap();
        assert_eq!(find("TPUv1").class.id(), "leaf+homo");
        assert_eq!(find("Herald").class.id(), "leaf+xnode");
        assert_eq!(find("NVIDIA B100").class.id(), "leaf+intra");
        assert_eq!(find("NeuPIM").class.id(), "hier+xdepth");
        assert_eq!(find("Symphony").class.id(), "hier+xnode-cl");
        for w in &works {
            w.class.validate().unwrap();
        }
    }

    #[test]
    fn id_round_trips() {
        for (_, c) in HarpClass::eval_points() {
            assert_eq!(HarpClass::from_id(&c.id()), Some(c));
        }
        assert!(HarpClass::from_id("leaf+xdepth").is_none()); // invalid point
        assert!(HarpClass::from_id("garbage").is_none());
    }

    /// Every id `harp topology list` prints must parse back to the same
    /// point — including each compound variant, which used to collapse
    /// to an ambiguous (and for leaf-only, unparseable) 'compound'.
    #[test]
    fn every_listed_point_id_round_trips() {
        for c in HarpClass::all_points() {
            let id = c.id();
            assert_eq!(HarpClass::from_id(&id).as_ref(), Some(&c), "{id}");
        }
        // Legacy shorthand stays aliased to the canonical Fig 4h point.
        assert_eq!(
            HarpClass::from_id("hier+compound").unwrap().id(),
            "hier+compound[xnode,xdepth]"
        );
        assert!(HarpClass::from_id("hier+compound[intra]").is_none()); // 1 source
        assert!(HarpClass::from_id("leaf+compound[intra,xdepth]").is_none());
    }

    #[test]
    fn eval_points_cover_both_axes() {
        let pts = HarpClass::eval_points();
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().any(|(_, c)| c.placement == ComputePlacement::Hierarchical));
        assert!(pts.iter().any(|(_, c)| c.heterogeneity == HeterogeneityLoc::Homogeneous));
    }

    #[test]
    fn classify_by_substring() {
        assert_eq!(classify("neupim").unwrap().name, "NeuPIM");
        assert!(classify("does-not-exist").is_none());
    }
}
