//! Concrete sub-accelerator specification.

use super::energy;
use super::level::{LevelKind, StorageLevel};
use crate::workload::einsum::Dim;

/// Mapping constraints imposed by the hardware organisation (paper §V-C).
///
/// These are how the taxonomy point shows up in the map space:
/// an intra-node pair shares an FSM, so the dimension parallelised
/// across the (common) column dimension must match its sibling's and the
/// column count is fixed; a cross-depth sub-accelerator has no such ties.
#[derive(Debug, Clone, Default)]
pub struct MappingConstraints {
    /// If set, the mapper must parallelise exactly this dimension across
    /// the array columns (shared-FSM / RaPiD-style coupling).
    pub forced_col_dim: Option<Dim>,
    /// If set, the spatial column factor must equal this value
    /// (intra-node siblings share the column count of the wider array).
    pub forced_col_factor: Option<u64>,
    /// Disallow temporal K tiling above the LLB (useful ablation knob;
    /// keeps partial sums on chip).
    pub no_dram_psum: bool,
}

/// One sub-accelerator: a PE array plus its private/shared storage
/// hierarchy, listed innermost (RF) to outermost (DRAM).
#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: String,
    /// PE array rows (each PE = 1 MAC/cycle).
    pub rows: u64,
    /// PE array columns.
    pub cols: u64,
    pub levels: Vec<StorageLevel>,
    pub mac_energy_pj: f64,
    pub constraints: MappingConstraints,
}

impl ArchSpec {
    /// Peak MACs per cycle.
    pub fn peak_macs(&self) -> u64 {
        self.rows * self.cols
    }

    /// RF storage level for a `pes`-wide array: per-PE capacity
    /// aggregated, 2 words/cycle/PE, flip-flop energy. Shared by the
    /// direct chain constructors and the tree flattening
    /// ([`crate::arch::topology::MachineTopology::flatten`]) so the two
    /// can never diverge — the goldens' byte-identity rests on it.
    pub fn rf_level(rf_bytes_per_pe: u64, pes: u64) -> StorageLevel {
        StorageLevel::new(
            LevelKind::RF,
            rf_bytes_per_pe * pes,
            pes as f64 * 2.0,
            energy::RF_PJ,
        )
    }

    /// Default bandwidth of the edge feeding a `pes`-wide array from its
    /// attach node (`√PEs · 16` — array-boundary scaling). Same sharing
    /// rationale as [`ArchSpec::rf_level`].
    pub fn default_attach_bw(pes: u64) -> f64 {
        (pes as f64).sqrt() * 16.0
    }

    /// Index of a level by kind.
    pub fn level_index(&self, kind: LevelKind) -> Option<usize> {
        self.levels.iter().position(|l| l.kind == kind)
    }

    pub fn level(&self, kind: LevelKind) -> Option<&StorageLevel> {
        self.level_index(kind).map(|i| &self.levels[i])
    }

    /// The DRAM level (outermost). Panics if the spec has no DRAM.
    pub fn dram(&self) -> &StorageLevel {
        self.levels.last().expect("spec has levels")
    }

    /// Roofline tipping point (MACs/word) of this sub-accelerator.
    pub fn tipping_ai(&self) -> f64 {
        self.peak_macs() as f64 / self.dram().bw_words_per_cycle
    }

    /// Standard four-level leaf sub-accelerator:
    /// RF(per-PE) → L1(per-array) → LLB share → DRAM share.
    pub fn leaf(
        name: &str,
        rows: u64,
        cols: u64,
        rf_bytes_per_pe: u64,
        l1_bytes: u64,
        llb_bytes: u64,
        llb_bw: f64,
        dram_bw: f64,
    ) -> ArchSpec {
        let pes = rows * cols;
        ArchSpec {
            name: name.into(),
            rows,
            cols,
            levels: vec![
                ArchSpec::rf_level(rf_bytes_per_pe, pes),
                StorageLevel::new(
                    LevelKind::L1,
                    l1_bytes,
                    ArchSpec::default_attach_bw(pes),
                    energy::sram_pj(l1_bytes),
                ),
                StorageLevel::new(LevelKind::LLB, llb_bytes, llb_bw, energy::sram_pj(llb_bytes)),
                StorageLevel::new(LevelKind::DRAM, u64::MAX, dram_bw, energy::DRAM_PJ),
            ],
            mac_energy_pj: energy::MAC_PJ,
            constraints: MappingConstraints::default(),
        }
    }

    /// Near-LLB sub-accelerator for hierarchical / cross-depth points:
    /// compute attached directly to the LLB, skipping the L1 level
    /// entirely (NeuPIM/Duplex-style, paper §V-B) — one fewer hop per
    /// word is where its energy advantage comes from.
    pub fn near_llb(
        name: &str,
        rows: u64,
        cols: u64,
        rf_bytes_per_pe: u64,
        llb_bytes: u64,
        llb_bw: f64,
        dram_bw: f64,
    ) -> ArchSpec {
        let pes = rows * cols;
        ArchSpec {
            name: name.into(),
            rows,
            cols,
            levels: vec![
                ArchSpec::rf_level(rf_bytes_per_pe, pes),
                StorageLevel::new(LevelKind::LLB, llb_bytes, llb_bw, energy::sram_pj(llb_bytes)),
                StorageLevel::new(LevelKind::DRAM, u64::MAX, dram_bw, energy::DRAM_PJ),
            ],
            mac_energy_pj: energy::MAC_PJ,
            constraints: MappingConstraints::default(),
        }
    }

    pub fn describe(&self) -> String {
        let lv: Vec<String> = self
            .levels
            .iter()
            .map(|l| {
                let size = if l.is_unbounded() {
                    "∞".to_string()
                } else {
                    format!("{}", l.size_words)
                };
                format!("{}[{} w, {:.0} w/cyc]", l.kind.name(), size, l.bw_words_per_cycle)
            })
            .collect();
        format!("{}: {}×{} PEs, {}", self.name, self.rows, self.cols, lv.join(" ← "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaf_spec_has_four_levels() {
        let s = ArchSpec::leaf("hi", 256, 128, 64, 131072, 4 << 20, 512.0, 256.0);
        assert_eq!(s.peak_macs(), 32768);
        assert_eq!(s.levels.len(), 4);
        assert_eq!(s.levels[0].kind, LevelKind::RF);
        assert_eq!(s.dram().kind, LevelKind::DRAM);
        assert!(s.tipping_ai() > 100.0);
    }

    #[test]
    fn near_llb_skips_l1() {
        let s = ArchSpec::near_llb("lo", 64, 128, 64, 1 << 20, 512.0, 192.0);
        assert_eq!(s.levels.len(), 3);
        assert!(s.level(LevelKind::L1).is_none());
        assert!(s.level(LevelKind::LLB).is_some());
    }

    #[test]
    fn rf_capacity_scales_with_pes() {
        let s = ArchSpec::leaf("x", 2, 2, 64, 1024, 4096, 8.0, 8.0);
        assert_eq!(s.level(LevelKind::RF).unwrap().size_words, 64 * 4);
    }
}
