//! Per-access energy model (Accelergy-style technology table).
//!
//! Absolute numbers are representative of a ~22nm node at 8-bit
//! datawidth; the paper's claims are all *relative* (breakdowns by level,
//! efficiency orderings across taxonomy points), which survive any
//! monotone-in-capacity SRAM table. Energies in pJ per word (= per byte).

/// Energy of one 8-bit MAC (pJ).
pub const MAC_PJ: f64 = 0.2;

/// Energy of one register-file word access (pJ). RFs are tiny (64 B) and
/// flip-flop based, but are touched on every MAC — calibrated so the
/// encoder workload's energy is RF-led while the (far more
/// DRAM-intensive) decoder workloads stay DRAM-led, the paper's Fig 7
/// split.
pub const RF_PJ: f64 = 0.2;

/// Energy of one DRAM word access (pJ). Dominates everything on-chip by
/// ~an order of magnitude — the root of the paper's decoder-energy story.
pub const DRAM_PJ: f64 = 160.0;

/// SRAM access energy scaling with capacity: `E ≈ a + b·sqrt(KB)`.
/// Square-root-of-capacity growth tracks wordline/bitline length, the
/// standard first-order CACTI fit.
pub fn sram_pj(size_bytes: u64) -> f64 {
    let kb = size_bytes as f64 / 1024.0;
    0.4 + 0.45 * kb.sqrt()
}

/// Interconnect energy per word per hierarchy hop (NoC between levels).
/// Charged on cross-level transfers; makes the cross-depth accelerator's
/// skipped level (paper §V-B) visible in the totals.
pub const HOP_PJ: f64 = 0.25;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_monotone_in_capacity() {
        let l1 = sram_pj(128 * 1024); // 0.125 MB
        let llb = sram_pj(4 * 1024 * 1024); // 4 MB
        assert!(l1 > RF_PJ);
        assert!(llb > l1);
        assert!(DRAM_PJ > llb * 3.0);
    }

    #[test]
    fn table_iii_magnitudes() {
        // L1 (128 KB) a few pJ, LLB (4 MB) tens of pJ — the usual ordering.
        assert!((2.0..8.0).contains(&sram_pj(128 * 1024)));
        assert!((8.0..60.0).contains(&sram_pj(4 * 1024 * 1024)));
    }
}
