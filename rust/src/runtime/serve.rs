//! Serving-traffic engine: continuous batching of arrival streams onto
//! the machine tree, with SLO-grade reporting.
//!
//! The simulator is step-based, mirroring iteration-level continuous
//! batching: each step every in-flight request contributes exactly one
//! op — its whole prefill, or one decode chunk — and the ops of a step
//! are list-scheduled onto the machine through [`ScheduleOracle`]
//! replay, so queueing delay on oversubscribed units is the *real*
//! scheduler's arbitration, not a closed-form approximation. Requests
//! admit FIFO under booked KV-cache capacity and the newest admission
//! is preempted (produced tokens kept) when decode growth overflows the
//! books.
//!
//! Per-op costs come from a one-off calibration pass: per (family,
//! taxonomy point, bandwidth) the real cost model evaluates a
//! prefill-layer probe and a one-token decode probe through the shared
//! [`Evaluator`] cache, and the engine linearises those into
//! per-token costs. The first decode chunk is exactly one token, so
//! TTFT is measured at real first-token granularity; later chunks batch
//! [`ServeConfig::decode_chunk`] tokens.
//!
//! Determinism: the simulation itself is single-threaded and seeded;
//! the only parallelism is the `Evaluator`'s calibration warm-up, whose
//! results are bit-identical across `HARP_THREADS` by the repo-wide
//! invariant. A fixed (stream, machine, costs) triple therefore yields
//! byte-identical reports everywhere.

use std::collections::{BTreeMap, VecDeque};

use crate::arch::partition::{HardwareParams, MachineConfig};
use crate::arch::taxonomy::HarpClass;
use crate::arch::topology::ContentionMode;
use crate::coordinator::figures::{EvalPoint, Evaluator};
use crate::hhp::allocator::eligible_units;
use crate::hhp::scheduler::{ScheduleOptions, ScheduleOracle};
use crate::model::stats::OpStats;
use crate::workload::arrivals::{Request, RequestFamily};
use crate::workload::cascade::Cascade;
use crate::workload::einsum::{Phase, TensorOp};
use crate::workload::intensity::ReuseClass;
use crate::workload::registry::WorkloadSpec;

/// Decode tokens per step after the first (one-token) chunk.
pub const DECODE_CHUNK_TOKENS: u64 = 8;

/// Default TTFT SLO in cycles.
pub const DEFAULT_SLO_TTFT: f64 = 2_000_000.0;

/// Modeled DRAM-resident KV capacity as a multiple of the machine's
/// aggregate on-chip buffering (an HBM:SRAM ratio stand-in — the specs
/// model DRAM as unbounded, but a serving admission policy needs a
/// finite book to push against).
const KV_DRAM_FACTOR: f64 = 64.0;

/// Engine knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TTFT SLO in cycles; completions under it count toward goodput.
    pub slo_ttft: f64,
    /// Decode tokens batched per step after the first chunk.
    pub decode_chunk: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { slo_ttft: DEFAULT_SLO_TTFT, decode_chunk: DECODE_CHUNK_TOKENS }
    }
}

/// Calibrated per-token costs for one family on one machine point.
#[derive(Debug, Clone)]
pub struct FamilyCosts {
    /// Prefill cycles per prompt token (one layer probe, linearised).
    pub prefill_per_token: f64,
    /// Decode cycles per generated token at `base_kv` context.
    pub decode_per_token: f64,
    /// KV length the decode probe was calibrated at.
    pub base_kv: f64,
    /// KV-cache words booked per context token.
    pub d_model: u64,
}

/// Calibrated cost table (one entry per request family).
#[derive(Debug, Clone)]
pub struct ServingCosts {
    per: BTreeMap<RequestFamily, FamilyCosts>,
}

impl ServingCosts {
    /// Assemble from explicit parts (tests and benches; production code
    /// goes through [`calibrate`]).
    pub fn from_parts(parts: Vec<(RequestFamily, FamilyCosts)>) -> ServingCosts {
        ServingCosts { per: parts.into_iter().collect() }
    }

    pub fn family(&self, f: RequestFamily) -> &FamilyCosts {
        self.per.get(&f).expect("family was calibrated")
    }

    fn prefill_cycles(&self, r: &Request) -> f64 {
        self.family(r.family).prefill_per_token * r.context as f64
    }

    /// Cost of a decode chunk of `tokens` at `kv` context: linear in
    /// tokens, and scaled for the KV-scan term — half the probe cost is
    /// treated as context-proportional, half as fixed.
    fn decode_chunk_cycles(&self, f: RequestFamily, tokens: u64, kv: u64) -> f64 {
        let fc = self.family(f);
        fc.decode_per_token * tokens as f64 * (0.5 + 0.5 * kv as f64 / fc.base_kv)
    }
}

/// One-layer prefill probe at the family's base context.
fn prefill_probe(f: RequestFamily) -> Cascade {
    let (d, ff, h) = (f.d_model(), f.d_ff_effective(), f.heads());
    let (c, dh) = (f.base_context(), d / h);
    let mut g = Cascade::new(&format!("serve_probe_prefill_{}", f.name()));
    let qkv = g.push(TensorOp::gemm("qkv", Phase::Prefill, c, d, 2 * d));
    let attn = g.push(TensorOp::bmm("attn", Phase::Prefill, h, c, dh, c));
    let out = g.push(TensorOp::gemm("attn_out", Phase::Prefill, c, d, d));
    let up = g.push(TensorOp::gemm("ffn_up", Phase::Prefill, c, d, ff));
    let down = g.push(TensorOp::gemm("ffn_down", Phase::Prefill, c, ff, d));
    g.dep(qkv, attn);
    g.dep(attn, out);
    g.dep(out, up);
    g.dep(up, down);
    g
}

/// One-token decode probe against a KV cache of the base context.
fn decode_probe(f: RequestFamily) -> Cascade {
    let (d, ff, h) = (f.d_model(), f.d_ff_effective(), f.heads());
    let (c, dh) = (f.base_context(), d / h);
    let mut g = Cascade::new(&format!("serve_probe_decode_{}", f.name()));
    let qkv = g.push(TensorOp::gemm("qkv", Phase::Decode, 1, d, 2 * d));
    let attn = g.push(TensorOp::bmm("attn", Phase::Decode, h, 1, dh, c));
    let out = g.push(TensorOp::gemm("attn_out", Phase::Decode, 1, d, d));
    let up = g.push(TensorOp::gemm("ffn_up", Phase::Decode, 1, d, ff));
    let down = g.push(TensorOp::gemm("ffn_down", Phase::Decode, 1, ff, d));
    g.dep(qkv, attn);
    g.dep(attn, out);
    g.dep(out, up);
    g.dep(up, down);
    g
}

/// Calibrate per-token costs for `families` on one (class, bandwidth)
/// point through the shared evaluator — probe results land in the same
/// memoised cache the figure drivers use, keyed by probe content
/// fingerprint, so repeat serves and the knee sweep pay for each probe
/// once.
pub fn calibrate(
    ev: &Evaluator,
    class: &HarpClass,
    dram_bw_bits: f64,
    families: &[RequestFamily],
) -> ServingCosts {
    let points: Vec<EvalPoint> = families
        .iter()
        .flat_map(|&f| {
            [prefill_probe(f), decode_probe(f)]
                .into_iter()
                .map(|c| (WorkloadSpec::Cascade(c), class.clone(), dram_bw_bits, None))
        })
        .collect();
    ev.warm(&points);
    let mut per = BTreeMap::new();
    for &f in families {
        let pre = ev.eval(&WorkloadSpec::Cascade(prefill_probe(f)), class, dram_bw_bits, None);
        let dec = ev.eval(&WorkloadSpec::Cascade(decode_probe(f)), class, dram_bw_bits, None);
        per.insert(
            f,
            FamilyCosts {
                prefill_per_token: pre.latency_cycles / f.base_context() as f64,
                decode_per_token: dec.latency_cycles,
                base_kv: f.base_context() as f64,
                d_model: f.d_model(),
            },
        );
    }
    ServingCosts { per }
}

/// Machine for a serve run: the taxonomy point's tree under default
/// hardware params at `dram_bw_bits`, flattened under `contention`.
pub fn build_serving_machine(
    class: &HarpClass,
    dram_bw_bits: f64,
    contention: ContentionMode,
) -> Result<MachineConfig, String> {
    let params = HardwareParams { dram_bw_bits, ..HardwareParams::default() };
    MachineConfig::build(class, &params)?.with_contention(contention)
}

/// Lifecycle record of one completed request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: usize,
    pub family: RequestFamily,
    pub arrival: f64,
    pub context: u64,
    pub output: u64,
    /// First admission time (cycles).
    pub admitted: f64,
    /// First decode token completion time (cycles).
    pub first_token: f64,
    /// Last decode token completion time (cycles).
    pub completed: f64,
    /// Times this request was preempted by the capacity books.
    pub evictions: u32,
}

impl RequestRecord {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Mean inter-token latency after the first token.
    pub fn per_token(&self) -> f64 {
        if self.output > 1 {
            (self.completed - self.first_token) / (self.output - 1) as f64
        } else {
            0.0
        }
    }
}

/// SLO summary of one serve run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Offered load (requests per Mcycle) the stream was generated at.
    pub offered_load: f64,
    pub requests: usize,
    pub completed: usize,
    /// Requests whose KV need exceeds machine capacity outright.
    pub rejected: usize,
    /// Total capacity preemptions across the run.
    pub evictions: usize,
    /// Simulated span in cycles (first arrival to last completion).
    pub span_cycles: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub mean_per_token: f64,
    /// Completions per Mcycle.
    pub throughput: f64,
    /// SLO-meeting completions per Mcycle.
    pub goodput: f64,
    pub slo_ttft: f64,
    /// KV book the admission policy pushed against (words).
    pub kv_capacity_words: f64,
}

impl ServeReport {
    /// Text summary (also the byte-identity surface for the
    /// determinism tests — keep formatting stable).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "serving summary  offered {:.3} req/Mcycle, span {:.0} cycles\n",
            self.offered_load, self.span_cycles
        ));
        s.push_str(&format!(
            "  requests {}  completed {}  rejected {}  evictions {}\n",
            self.requests, self.completed, self.rejected, self.evictions
        ));
        s.push_str(&format!(
            "  TTFT p50 {:.0}  p99 {:.0}  (SLO {:.0} cycles)\n",
            self.p50_ttft, self.p99_ttft, self.slo_ttft
        ));
        s.push_str(&format!("  per-token latency {:.1} cycles\n", self.mean_per_token));
        s.push_str(&format!(
            "  throughput {:.4} req/Mcycle  goodput {:.4} req/Mcycle\n",
            self.throughput, self.goodput
        ));
        s
    }
}

/// A serve run: per-request records (completion order) plus summary.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub records: Vec<RequestRecord>,
    pub report: ServeReport,
}

/// A request somewhere in the pipeline (waiting or in flight).
#[derive(Debug, Clone)]
struct Job {
    req: Request,
    /// Decode tokens already produced (kept across evictions).
    produced: u64,
    prefilled: bool,
    /// First admission time; NaN until first admitted.
    admitted: f64,
    /// First-token completion; NaN until produced.
    first_token: f64,
    evictions: u32,
    /// Unit the next op runs on.
    unit: usize,
    /// Admission sequence number — eviction preempts the newest.
    seq: usize,
}

impl Job {
    fn new(req: Request) -> Job {
        Job {
            req,
            produced: 0,
            prefilled: false,
            admitted: f64::NAN,
            first_token: f64::NAN,
            evictions: 0,
            unit: 0,
            seq: 0,
        }
    }

    /// Words this job books right now.
    fn booked_words(&self) -> f64 {
        (self.req.context + self.produced) as f64 * self.req.family.d_model() as f64
    }

    /// Words this job will book at completion.
    fn final_words(&self) -> f64 {
        (self.req.context + self.req.output) as f64 * self.req.family.d_model() as f64
    }
}

/// Aggregate KV book: `KV_DRAM_FACTOR` × the sum over units of their
/// largest bounded on-chip level.
pub fn kv_capacity_words(machine: &MachineConfig) -> f64 {
    let onchip: u64 = machine
        .sub_accels
        .iter()
        .map(|s| {
            s.spec
                .levels
                .iter()
                .filter(|l| !l.is_unbounded())
                .map(|l| l.size_words)
                .max()
                .unwrap_or(0)
        })
        .sum();
    onchip as f64 * KV_DRAM_FACTOR
}

/// Run the continuous-batching engine over an arrival-sorted stream.
///
/// `dynamic_bw` mirrors `EvalOptions::dynamic_bw` for the per-step
/// schedule replays; `offered_load` is carried into the report (it is a
/// property of the stream generator, not derivable from the requests
/// once bursts overlap).
pub fn simulate(
    requests: &[Request],
    machine: &MachineConfig,
    costs: &ServingCosts,
    dynamic_bw: bool,
    offered_load: f64,
    cfg: &ServeConfig,
) -> ServeResult {
    let capacity = kv_capacity_words(machine);
    let hi_units = eligible_units(machine, ReuseClass::High);
    let lo_units = eligible_units(machine, ReuseClass::Low);
    let sopts = ScheduleOptions { dynamic_bw };

    let mut waiting: VecDeque<Job> = VecDeque::new();
    let mut active: Vec<Job> = Vec::new();
    let mut records: Vec<RequestRecord> = Vec::new();
    let mut booked = 0.0f64;
    let mut rejected = 0usize;
    let mut evictions_total = 0usize;
    let mut next_arrival = 0usize;
    let mut admit_seq = 0usize;
    let (mut rr_hi, mut rr_lo) = (0usize, 0usize);
    let mut t = 0.0f64;

    loop {
        // Arrivals up to the clock enter the FIFO; a request that could
        // never fit even alone is rejected outright (otherwise it would
        // starve the queue behind it forever).
        while next_arrival < requests.len() && requests[next_arrival].arrival <= t {
            let r = requests[next_arrival].clone();
            next_arrival += 1;
            if Job::new(r.clone()).final_words() > capacity {
                rejected += 1;
                continue;
            }
            waiting.push_back(Job::new(r));
        }

        // FIFO admission under the books. An empty machine always
        // admits its queue head — progress over strict accounting.
        while let Some(front) = waiting.front() {
            if !active.is_empty() && booked + front.booked_words() > capacity {
                break;
            }
            let mut job = waiting.pop_front().unwrap();
            booked += job.booked_words();
            if job.admitted.is_nan() {
                job.admitted = t;
            }
            job.seq = admit_seq;
            admit_seq += 1;
            job.unit = if job.prefilled {
                rr_lo += 1;
                lo_units[(rr_lo - 1) % lo_units.len()]
            } else {
                rr_hi += 1;
                hi_units[(rr_hi - 1) % hi_units.len()]
            };
            active.push(job);
        }

        if active.is_empty() {
            // Admission drained: nothing in flight means nothing
            // waiting either. Jump to the next arrival or finish.
            if next_arrival < requests.len() {
                t = t.max(requests[next_arrival].arrival);
                continue;
            }
            break;
        }

        // One op per in-flight request: whole prefill, or one decode
        // chunk (the first chunk is exactly one token so TTFT is real).
        let mut cascade = Cascade::new("serve_step");
        let mut stats: Vec<OpStats> = Vec::with_capacity(active.len());
        let mut assignment: Vec<usize> = Vec::with_capacity(active.len());
        let mut step_tokens: Vec<u64> = Vec::with_capacity(active.len());
        for job in &active {
            let (op, cost, tokens) = if !job.prefilled {
                let d = job.req.family.d_model();
                (
                    TensorOp::gemm(
                        &format!("r{}.prefill", job.req.id),
                        Phase::Prefill,
                        job.req.context,
                        d,
                        d,
                    ),
                    costs.prefill_cycles(&job.req),
                    0,
                )
            } else {
                let tokens = if job.produced == 0 {
                    1
                } else {
                    cfg.decode_chunk.min(job.req.output - job.produced)
                };
                let f = job.req.family;
                let kv = job.req.context + job.produced;
                (
                    TensorOp::bmm(
                        &format!("r{}.decode{}", job.req.id, job.produced),
                        Phase::Decode,
                        f.heads(),
                        tokens,
                        f.d_model() / f.heads(),
                        kv,
                    ),
                    costs.decode_chunk_cycles(f, tokens, kv),
                    tokens,
                )
            };
            cascade.push(op);
            let mut st = OpStats::new_empty();
            st.cycles = cost;
            stats.push(st);
            assignment.push(job.unit);
            step_tokens.push(tokens);
        }

        let refs: Vec<&OpStats> = stats.iter().collect();
        let mut oracle = ScheduleOracle::new(&cascade, machine, &sopts);
        let makespan = oracle.replay(&assignment, &refs);
        let finish: Vec<f64> = oracle
            .queue_delays()
            .iter()
            .zip(oracle.latencies())
            .map(|(d, l)| t + d + l)
            .collect();

        // Advance every in-flight request by its step op.
        let mut still_active: Vec<Job> = Vec::with_capacity(active.len());
        for (i, mut job) in active.drain(..).enumerate() {
            let fin = finish[i];
            if !job.prefilled {
                job.prefilled = true;
                rr_lo += 1;
                job.unit = lo_units[(rr_lo - 1) % lo_units.len()];
                still_active.push(job);
                continue;
            }
            let tokens = step_tokens[i];
            if job.produced == 0 {
                job.first_token = fin;
            }
            job.produced += tokens;
            booked += tokens as f64 * job.req.family.d_model() as f64;
            if job.produced >= job.req.output {
                booked -= job.booked_words();
                records.push(RequestRecord {
                    id: job.req.id,
                    family: job.req.family,
                    arrival: job.req.arrival,
                    context: job.req.context,
                    output: job.req.output,
                    admitted: job.admitted,
                    first_token: job.first_token,
                    completed: fin,
                    evictions: job.evictions,
                });
            } else {
                still_active.push(job);
            }
        }
        active = still_active;

        // Decode growth may overflow the books: preempt the newest
        // admission (produced tokens kept) until they balance — but
        // never the last one, so the machine always drains.
        while booked > capacity && active.len() > 1 {
            let newest = active
                .iter()
                .enumerate()
                .max_by_key(|(_, j)| j.seq)
                .map(|(i, _)| i)
                .unwrap();
            let mut job = active.swap_remove(newest);
            booked -= job.booked_words();
            job.evictions += 1;
            evictions_total += 1;
            waiting.push_front(job);
        }

        t += makespan;
    }

    let span = records
        .iter()
        .map(|r| r.completed)
        .fold(t, f64::max)
        .max(1.0);
    let mut ttfts: Vec<f64> = records.iter().map(RequestRecord::ttft).collect();
    ttfts.sort_by(f64::total_cmp);
    let good = records.iter().filter(|r| r.ttft() <= cfg.slo_ttft).count();
    let per_token_sum: f64 = records.iter().map(RequestRecord::per_token).sum();
    let report = ServeReport {
        offered_load,
        requests: requests.len(),
        completed: records.len(),
        rejected,
        evictions: evictions_total,
        span_cycles: span,
        p50_ttft: percentile(&ttfts, 50.0),
        p99_ttft: percentile(&ttfts, 99.0),
        mean_per_token: if records.is_empty() { 0.0 } else { per_token_sum / records.len() as f64 },
        throughput: records.len() as f64 * 1.0e6 / span,
        goodput: good as f64 * 1.0e6 / span,
        slo_ttft: cfg.slo_ttft,
        kv_capacity_words: capacity,
    };
    ServeResult { records, report }
}

/// Nearest-rank percentile of an ascending-sorted slice (0.0 when
/// empty, so reports stay JSON-representable).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

/// Saturation knee of a goodput-vs-offered-load curve: the first grid
/// load where goodput falls below 90% of offered (the service stops
/// keeping up), or the last grid load when it never does.
pub fn saturation_knee(curve: &[(f64, f64)]) -> f64 {
    for &(load, goodput) in curve {
        if goodput < 0.9 * load {
            return load;
        }
    }
    curve.last().map(|&(l, _)| l).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::taxonomy::HarpClass;
    use crate::workload::arrivals::{synthesize, ArrivalKind, StreamParams};

    fn test_costs() -> ServingCosts {
        ServingCosts::from_parts(
            RequestFamily::ALL
                .iter()
                .map(|&f| {
                    (
                        f,
                        FamilyCosts {
                            prefill_per_token: 50.0,
                            decode_per_token: 200.0,
                            base_kv: f.base_context() as f64,
                            d_model: f.d_model(),
                        },
                    )
                })
                .collect(),
        )
    }

    fn machine() -> MachineConfig {
        build_serving_machine(&HarpClass::from_id("hier+xnode").unwrap(), 2048.0, ContentionMode::Off)
            .unwrap()
    }

    fn stream(load: f64, n: usize) -> Vec<crate::workload::arrivals::Request> {
        synthesize(&StreamParams {
            kind: ArrivalKind::Poisson,
            mix: RequestFamily::ALL.iter().map(|&f| (f, 1.0)).collect(),
            load,
            requests: n,
            seed: 7,
        })
        .unwrap()
    }

    #[test]
    fn every_unrejected_request_completes() {
        let reqs = stream(2.0, 30);
        let r = simulate(&reqs, &machine(), &test_costs(), true, 2.0, &ServeConfig::default());
        assert_eq!(r.report.completed + r.report.rejected, reqs.len());
        for rec in &r.records {
            assert!(rec.ttft() >= 0.0, "request {} has negative TTFT", rec.id);
            assert!(rec.completed >= rec.first_token);
            assert!(rec.admitted >= rec.arrival);
        }
    }

    #[test]
    fn report_is_bit_identical_across_runs() {
        let reqs = stream(2.0, 30);
        let m = machine();
        let a = simulate(&reqs, &m, &test_costs(), true, 2.0, &ServeConfig::default());
        let b = simulate(&reqs, &m, &test_costs(), true, 2.0, &ServeConfig::default());
        assert_eq!(a.report.render(), b.report.render());
        assert_eq!(a.report.p99_ttft.to_bits(), b.report.p99_ttft.to_bits());
        assert_eq!(a.report.goodput.to_bits(), b.report.goodput.to_bits());
    }

    #[test]
    fn goodput_never_exceeds_throughput() {
        let reqs = stream(4.0, 40);
        let r = simulate(&reqs, &machine(), &test_costs(), true, 4.0, &ServeConfig::default());
        assert!(r.report.goodput <= r.report.throughput + 1e-12);
        assert!(r.report.p50_ttft <= r.report.p99_ttft);
    }

    #[test]
    fn higher_load_does_not_lower_pressure() {
        // The same stream compressed 16× in time must show queueing
        // somewhere: the run finishes sooner in absolute terms, and
        // tail TTFT cannot dip below the uncontended median.
        let m = machine();
        let light = simulate(&stream(0.5, 30), &m, &test_costs(), true, 0.5, &ServeConfig::default());
        let heavy = simulate(&stream(8.0, 30), &m, &test_costs(), true, 8.0, &ServeConfig::default());
        assert!(
            heavy.report.span_cycles < light.report.span_cycles,
            "heavy span {} >= light span {}",
            heavy.report.span_cycles,
            light.report.span_cycles
        );
        assert!(
            heavy.report.p99_ttft >= light.report.p50_ttft,
            "heavy p99 {} < light p50 {}",
            heavy.report.p99_ttft,
            light.report.p50_ttft
        );
    }

    #[test]
    fn knee_detection() {
        assert_eq!(saturation_knee(&[(1.0, 1.0), (2.0, 1.9), (4.0, 2.0)]), 4.0);
        assert_eq!(saturation_knee(&[(1.0, 0.5), (2.0, 0.5)]), 1.0);
        assert_eq!(saturation_knee(&[]), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 99.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn tiny_capacity_evicts_but_completes() {
        // Force the books to overflow by shrinking requests onto a
        // stream that overlaps heavily: everyone still finishes, and
        // the eviction counter moves only when capacity binds.
        let reqs = stream(8.0, 20);
        let r = simulate(&reqs, &machine(), &test_costs(), true, 8.0, &ServeConfig::default());
        assert_eq!(r.report.completed + r.report.rejected, reqs.len());
    }
}
