//! Serving-traffic engine: continuous batching of arrival streams onto
//! the machine tree, with SLO-grade reporting.
//!
//! The simulator is step-based, mirroring iteration-level continuous
//! batching: each step every in-flight request contributes exactly one
//! op — its whole prefill, one decode chunk, or (under paged booking) a
//! KV re-fetch after a partial spill — and the ops of a step are
//! list-scheduled onto the machine through [`ScheduleOracle`] replay,
//! so queueing delay on oversubscribed units is the *real* scheduler's
//! arbitration, not a closed-form approximation.
//!
//! Admission is Herald-style class-aware: the wait queue is ordered by
//! (latency class, arrival) so every `interactive` request admits ahead
//! of any `batch` request, each class carrying its own TTFT SLO. With
//! the default single-class stream this degrades exactly to the
//! historical FIFO. KV capacity is booked either whole-request (the
//! default, byte-identical to the historical books) or in fixed-size
//! pages ([`ServeConfig::kv_page_words`]): decode growth books pages
//! incrementally, and preemption spills page by page from the newest
//! admission of the lowest class — a partially spilled request stays
//! resident and pays a measured re-prefill (KV re-fetch) op before it
//! decodes again.
//!
//! Serving can be role-disaggregated ([`ServeConfig::disagg`]): prefill
//! ops pin to one sub-accelerator pool and decode chunks to another, and
//! when the pools actually differ each request pays an explicit KV
//! hand-off — a transfer op costed as words over the narrower of the two
//! units' DRAM shares in the machine tree, with the KV booked against
//! *both* pools while it is in flight. When both roles resolve to the
//! same pool the engine is bit-identical to the co-located default.
//!
//! Per-op costs come from a one-off calibration pass: per (family,
//! taxonomy point, bandwidth) the real cost model evaluates a
//! prefill-layer probe and a one-token decode probe through the shared
//! [`Evaluator`] cache, and the engine linearises those into
//! per-token costs. The first decode chunk is exactly one token, so
//! TTFT is measured at real first-token granularity; later chunks batch
//! [`ServeConfig::decode_chunk`] tokens.
//!
//! Determinism: the simulation itself is single-threaded and seeded;
//! the only parallelism is the `Evaluator`'s calibration warm-up, whose
//! results are bit-identical across `HARP_THREADS` by the repo-wide
//! invariant. A fixed (stream, machine, costs, knobs) tuple therefore
//! yields byte-identical reports everywhere — and the default knobs
//! (single class, whole-request booking, round-robin placement) are
//! contractually byte-identical to the pre-class/pre-page engine.

use std::collections::{BTreeMap, VecDeque};

use crate::arch::partition::{HardwareParams, MachineConfig};
use crate::arch::taxonomy::HarpClass;
use crate::arch::topology::ContentionMode;
use crate::coordinator::figures::{EvalPoint, Evaluator};
use crate::hhp::allocator::{eligible_units, pressure_ordered, strictly_better};
use crate::hhp::scheduler::{ScheduleOptions, ScheduleOracle};
use crate::model::stats::OpStats;
use crate::workload::arrivals::{Request, RequestClass, RequestFamily};
use crate::workload::cascade::Cascade;
use crate::workload::einsum::{Phase, TensorOp};
use crate::workload::intensity::ReuseClass;
use crate::workload::registry::WorkloadSpec;

/// Decode tokens per step after the first (one-token) chunk.
pub const DECODE_CHUNK_TOKENS: u64 = 8;

/// Default TTFT SLO in cycles.
pub const DEFAULT_SLO_TTFT: f64 = 2_000_000.0;

/// Modeled DRAM-resident KV capacity as a multiple of the machine's
/// aggregate on-chip buffering (an HBM:SRAM ratio stand-in — the specs
/// model DRAM as unbounded, but a serving admission policy needs a
/// finite book to push against).
const KV_DRAM_FACTOR: f64 = 64.0;

/// How hi/lo placement picks among the eligible units each time a
/// request (re-)enters a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Blind rotation over the eligible set (the historical,
    /// byte-stable default).
    #[default]
    RoundRobin,
    /// Rotate over [`pressure_ordered`] units: each step's schedule
    /// replay feeds its queue-delay/latency ratios back per unit
    /// (decayed ×0.5 per step), and placement skips units more than 2×
    /// as congested as the least-loaded one.
    Pressure,
    /// [`PlacementPolicy::Pressure`], plus a pressure-fed refinement of
    /// each step's op→unit assignment: the exported pressure signal
    /// orders extra [`ScheduleOracle::replay_delta`] probes within each
    /// op's phase pool, and only moves that strictly improve the true
    /// replayed step makespan are kept — the serving-side twin of
    /// [`search_allocation_pressured`](crate::hhp::allocator::search_allocation_pressured),
    /// so a step never schedules worse than its unrefined placement.
    PressureSearch,
}

impl PlacementPolicy {
    pub fn name(self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round_robin",
            PlacementPolicy::Pressure => "pressure",
            PlacementPolicy::PressureSearch => "pressure_search",
        }
    }

    pub fn parse(s: &str) -> Result<PlacementPolicy, String> {
        match s.to_ascii_lowercase().as_str() {
            "round_robin" | "round-robin" | "rr" => Ok(PlacementPolicy::RoundRobin),
            "pressure" => Ok(PlacementPolicy::Pressure),
            "pressure_search" | "pressure-search" => Ok(PlacementPolicy::PressureSearch),
            other => Err(format!(
                "unknown placement policy '{other}' (known: round_robin, pressure, \
                 pressure_search)"
            )),
        }
    }

    /// Whether the engine maintains the decayed per-unit pressure
    /// signal for this policy.
    pub fn uses_pressure(self) -> bool {
        !matches!(self, PlacementPolicy::RoundRobin)
    }
}

/// Role-disaggregated serving: pin prefill ops to one sub-accelerator
/// pool and decode chunks (plus KV re-fetches) to another, selected by
/// reuse role. Pools resolve through the same eligibility rule the
/// allocator uses ([`eligible_units`]), so `prefill=high,decode=low` on
/// a heterogeneous point reproduces the co-located engine's routing
/// with the KV hand-off made explicit, and a machine whose units all
/// accept both roles degrades bit-identically to co-located serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DisaggConfig {
    /// Pool serving whole-prompt prefill ops.
    pub prefill: ReuseClass,
    /// Pool serving decode chunks and KV re-fetches.
    pub decode: ReuseClass,
}

impl DisaggConfig {
    /// Parse the `--disagg` / `"disagg"` spelling:
    /// `prefill=<role>,decode=<role>` with roles `high` | `low`
    /// (aliases `hi`/`high-reuse`, `lo`/`low-reuse`).
    pub fn parse(s: &str) -> Result<DisaggConfig, String> {
        let mut prefill = None;
        let mut decode = None;
        for part in s.split(',') {
            let (k, v) = part.split_once('=').ok_or_else(|| {
                format!(
                    "disagg spec '{part}' must look like phase=role \
                     (e.g. prefill=high,decode=low)"
                )
            })?;
            let role = match v.trim().to_ascii_lowercase().as_str() {
                "high" | "hi" | "high-reuse" => ReuseClass::High,
                "low" | "lo" | "low-reuse" => ReuseClass::Low,
                other => {
                    return Err(format!("unknown disagg role '{other}' (known: high, low)"))
                }
            };
            match k.trim() {
                "prefill" if prefill.is_none() => prefill = Some(role),
                "decode" if decode.is_none() => decode = Some(role),
                "prefill" | "decode" => {
                    return Err(format!("duplicate disagg phase '{}'", k.trim()))
                }
                other => {
                    return Err(format!(
                        "unknown disagg phase '{other}' (known: prefill, decode)"
                    ))
                }
            }
        }
        match (prefill, decode) {
            (Some(p), Some(d)) => Ok(DisaggConfig { prefill: p, decode: d }),
            _ => Err(format!(
                "disagg spec '{s}' must name both phases: prefill=<role>,decode=<role>"
            )),
        }
    }

    /// Canonical `prefill=<role>,decode=<role>` form (render and JSON).
    pub fn label(&self) -> String {
        let short = |c: ReuseClass| match c {
            ReuseClass::High => "high",
            ReuseClass::Low => "low",
        };
        format!("prefill={},decode={}", short(self.prefill), short(self.decode))
    }
}

/// Engine knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TTFT SLO in cycles for `interactive` requests (and the fallback
    /// for `batch` when no per-class SLO is set); completions under
    /// their class SLO count toward goodput.
    pub slo_ttft: f64,
    /// TTFT SLO for `batch` requests; `None` inherits `slo_ttft`.
    pub slo_ttft_batch: Option<f64>,
    /// Decode tokens batched per step after the first chunk.
    pub decode_chunk: u64,
    /// KV booking granularity in words. `0` (the default) books each
    /// request's exact KV need — byte-identical to the historical
    /// whole-request books. A positive value books in fixed pages:
    /// growth allocates pages incrementally, preemption spills page by
    /// page, and spilled pages cost a measured re-prefill on return.
    pub kv_page_words: u64,
    /// Unit-placement policy for prefill/decode ops.
    pub placement: PlacementPolicy,
    /// Role-disaggregated prefill/decode pools. `None` (the default)
    /// keeps the co-located engine byte-identically; `Some` pins each
    /// phase to its pool and charges the KV hand-off between them.
    pub disagg: Option<DisaggConfig>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            slo_ttft: DEFAULT_SLO_TTFT,
            slo_ttft_batch: None,
            decode_chunk: DECODE_CHUNK_TOKENS,
            kv_page_words: 0,
            placement: PlacementPolicy::RoundRobin,
            disagg: None,
        }
    }
}

impl ServeConfig {
    /// TTFT SLO applying to `class`.
    pub fn slo_for(&self, class: RequestClass) -> f64 {
        match class {
            RequestClass::Interactive => self.slo_ttft,
            RequestClass::Batch => self.slo_ttft_batch.unwrap_or(self.slo_ttft),
        }
    }
}

/// Calibrated per-token costs for one family on one machine point.
#[derive(Debug, Clone)]
pub struct FamilyCosts {
    /// Prefill cycles per prompt token (one layer probe, linearised).
    pub prefill_per_token: f64,
    /// Decode cycles per generated token at `base_kv` context.
    pub decode_per_token: f64,
    /// KV length the decode probe was calibrated at.
    pub base_kv: f64,
    /// KV-cache words booked per context token.
    pub d_model: u64,
}

/// Calibrated cost table (one entry per request family).
#[derive(Debug, Clone)]
pub struct ServingCosts {
    per: BTreeMap<RequestFamily, FamilyCosts>,
}

impl ServingCosts {
    /// Assemble from explicit parts (tests and benches; production code
    /// goes through [`calibrate`]).
    pub fn from_parts(parts: Vec<(RequestFamily, FamilyCosts)>) -> ServingCosts {
        ServingCosts { per: parts.into_iter().collect() }
    }

    pub fn family(&self, f: RequestFamily) -> &FamilyCosts {
        self.per.get(&f).expect("family was calibrated")
    }

    fn prefill_cycles(&self, r: &Request) -> f64 {
        self.family(r.family).prefill_per_token * r.context as f64
    }

    /// Cost of a decode chunk of `tokens` at `kv` context: linear in
    /// tokens, and scaled for the KV-scan term — half the probe cost is
    /// treated as context-proportional, half as fixed.
    fn decode_chunk_cycles(&self, f: RequestFamily, tokens: u64, kv: u64) -> f64 {
        let fc = self.family(f);
        fc.decode_per_token * tokens as f64 * (0.5 + 0.5 * kv as f64 / fc.base_kv)
    }
}

/// One-layer prefill probe at the family's base context.
fn prefill_probe(f: RequestFamily) -> Cascade {
    let (d, ff, h) = (f.d_model(), f.d_ff_effective(), f.heads());
    let (c, dh) = (f.base_context(), d / h);
    let mut g = Cascade::new(&format!("serve_probe_prefill_{}", f.name()));
    let qkv = g.push(TensorOp::gemm("qkv", Phase::Prefill, c, d, 2 * d));
    let attn = g.push(TensorOp::bmm("attn", Phase::Prefill, h, c, dh, c));
    let out = g.push(TensorOp::gemm("attn_out", Phase::Prefill, c, d, d));
    let up = g.push(TensorOp::gemm("ffn_up", Phase::Prefill, c, d, ff));
    let down = g.push(TensorOp::gemm("ffn_down", Phase::Prefill, c, ff, d));
    g.dep(qkv, attn);
    g.dep(attn, out);
    g.dep(out, up);
    g.dep(up, down);
    g
}

/// One-token decode probe against a KV cache of the base context.
fn decode_probe(f: RequestFamily) -> Cascade {
    let (d, ff, h) = (f.d_model(), f.d_ff_effective(), f.heads());
    let (c, dh) = (f.base_context(), d / h);
    let mut g = Cascade::new(&format!("serve_probe_decode_{}", f.name()));
    let qkv = g.push(TensorOp::gemm("qkv", Phase::Decode, 1, d, 2 * d));
    let attn = g.push(TensorOp::bmm("attn", Phase::Decode, h, 1, dh, c));
    let out = g.push(TensorOp::gemm("attn_out", Phase::Decode, 1, d, d));
    let up = g.push(TensorOp::gemm("ffn_up", Phase::Decode, 1, d, ff));
    let down = g.push(TensorOp::gemm("ffn_down", Phase::Decode, 1, ff, d));
    g.dep(qkv, attn);
    g.dep(attn, out);
    g.dep(out, up);
    g.dep(up, down);
    g
}

/// Calibrate per-token costs for `families` on one (class, bandwidth)
/// point through the shared evaluator — probe results land in the same
/// memoised cache the figure drivers use, keyed by probe content
/// fingerprint, so repeat serves and the knee sweep pay for each probe
/// once.
pub fn calibrate(
    ev: &Evaluator,
    class: &HarpClass,
    dram_bw_bits: f64,
    families: &[RequestFamily],
) -> ServingCosts {
    let points: Vec<EvalPoint> = families
        .iter()
        .flat_map(|&f| {
            [prefill_probe(f), decode_probe(f)]
                .into_iter()
                .map(|c| (WorkloadSpec::Cascade(c), class.clone(), dram_bw_bits, None))
        })
        .collect();
    ev.warm(&points);
    let mut per = BTreeMap::new();
    for &f in families {
        let pre = ev.eval(&WorkloadSpec::Cascade(prefill_probe(f)), class, dram_bw_bits, None);
        let dec = ev.eval(&WorkloadSpec::Cascade(decode_probe(f)), class, dram_bw_bits, None);
        per.insert(
            f,
            FamilyCosts {
                prefill_per_token: pre.latency_cycles / f.base_context() as f64,
                decode_per_token: dec.latency_cycles,
                base_kv: f.base_context() as f64,
                d_model: f.d_model(),
            },
        );
    }
    ServingCosts { per }
}

/// Machine for a serve run: the taxonomy point's tree under default
/// hardware params at `dram_bw_bits`, flattened under `contention`.
pub fn build_serving_machine(
    class: &HarpClass,
    dram_bw_bits: f64,
    contention: ContentionMode,
) -> Result<MachineConfig, String> {
    let params = HardwareParams { dram_bw_bits, ..HardwareParams::default() };
    MachineConfig::build(class, &params)?.with_contention(contention)
}

/// Lifecycle record of one completed request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: usize,
    pub family: RequestFamily,
    pub class: RequestClass,
    pub arrival: f64,
    pub context: u64,
    pub output: u64,
    /// First admission time (cycles).
    pub admitted: f64,
    /// First decode token completion time (cycles).
    pub first_token: f64,
    /// Last decode token completion time (cycles).
    pub completed: f64,
    /// Times this request was preempted by the capacity books.
    pub evictions: u32,
    /// Peak pages booked at once (0 under whole-request booking).
    pub peak_pages: u64,
}

impl RequestRecord {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Mean inter-token latency after the first token. Defensive: the
    /// parse layer rejects `output == 0`, and this still never divides
    /// by zero or leaks a non-finite value into report means.
    pub fn per_token(&self) -> f64 {
        if self.output > 1 {
            let v = (self.completed - self.first_token) / (self.output - 1) as f64;
            if v.is_finite() { v } else { 0.0 }
        } else {
            0.0
        }
    }
}

/// Per-class slice of a serve run (only populated when the stream
/// actually carries a non-default class, so default reports are
/// byte-stable).
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: RequestClass,
    /// Stream requests of this class (including rejected ones).
    pub requests: usize,
    pub completed: usize,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    /// Class-SLO-meeting completions of this class per Mcycle.
    pub goodput: f64,
    /// The TTFT SLO this class was held to.
    pub slo_ttft: f64,
}

/// SLO summary of one serve run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Offered load (requests per Mcycle) the stream was generated at.
    pub offered_load: f64,
    pub requests: usize,
    pub completed: usize,
    /// Requests whose KV need exceeds machine capacity outright.
    pub rejected: usize,
    /// Total capacity preemptions across the run.
    pub evictions: usize,
    /// Simulated span in cycles (first arrival to last completion).
    pub span_cycles: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub mean_per_token: f64,
    /// Completions per Mcycle.
    pub throughput: f64,
    /// SLO-meeting completions per Mcycle (each against its class SLO).
    pub goodput: f64,
    pub slo_ttft: f64,
    /// KV book the admission policy pushed against (words).
    pub kv_capacity_words: f64,
    /// Booking granularity (0 = whole-request).
    pub kv_page_words: u64,
    /// Tokens re-prefetched after page spills across the run.
    pub reprefill_tokens: u64,
    /// Per-class breakouts; empty for single-class default streams.
    pub class_breakdown: Vec<ClassReport>,
    /// Canonical disagg spec when role-disaggregation was requested
    /// (`None` for co-located runs — the render/JSON gate).
    pub disagg: Option<String>,
    /// Prefill→decode KV hand-offs charged across the run.
    pub kv_transfers: usize,
    /// Total KV words moved between the pools across the run.
    pub kv_transfer_words: u64,
}

impl ServeReport {
    /// Text summary (also the byte-identity surface for the
    /// determinism tests — keep formatting stable; the class and page
    /// lines only appear when those features are in play, so default
    /// renders are byte-identical to the classless engine's).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "serving summary  offered {:.3} req/Mcycle, span {:.0} cycles\n",
            self.offered_load, self.span_cycles
        ));
        s.push_str(&format!(
            "  requests {}  completed {}  rejected {}  evictions {}\n",
            self.requests, self.completed, self.rejected, self.evictions
        ));
        s.push_str(&format!(
            "  TTFT p50 {:.0}  p99 {:.0}  (SLO {:.0} cycles)\n",
            self.p50_ttft, self.p99_ttft, self.slo_ttft
        ));
        s.push_str(&format!("  per-token latency {:.1} cycles\n", self.mean_per_token));
        s.push_str(&format!(
            "  throughput {:.4} req/Mcycle  goodput {:.4} req/Mcycle\n",
            self.throughput, self.goodput
        ));
        for c in &self.class_breakdown {
            s.push_str(&format!(
                "  class {:<11}  requests {}  completed {}  TTFT p50 {:.0}  p99 {:.0}  \
                 goodput {:.4} req/Mcycle  (SLO {:.0})\n",
                c.class.name(),
                c.requests,
                c.completed,
                c.p50_ttft,
                c.p99_ttft,
                c.goodput,
                c.slo_ttft
            ));
        }
        if self.kv_page_words > 0 {
            s.push_str(&format!(
                "  kv pages {} words each  re-prefill {} tokens\n",
                self.kv_page_words, self.reprefill_tokens
            ));
        }
        if let Some(d) = &self.disagg {
            s.push_str(&format!(
                "  disagg {}  kv transfer {} hand-offs  {} words\n",
                d, self.kv_transfers, self.kv_transfer_words
            ));
        }
        s
    }
}

/// A serve run: per-request records (completion order) plus summary.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub records: Vec<RequestRecord>,
    pub report: ServeReport,
    /// Final decayed per-unit pressure signal (Σ queue-delay/latency
    /// per unit, ×0.5 per step). All zeros under `round_robin`
    /// placement, which does not maintain it. Exported so serving
    /// pressure can feed the allocation search
    /// ([`search_allocation_pressured`](crate::hhp::allocator::search_allocation_pressured)).
    pub unit_pressure: Vec<f64>,
}

/// A request somewhere in the pipeline (waiting or in flight).
#[derive(Debug, Clone)]
struct Job {
    req: Request,
    /// Decode tokens already produced (kept across evictions).
    produced: u64,
    prefilled: bool,
    /// First admission time; NaN until first admitted.
    admitted: f64,
    /// First-token completion; NaN until produced.
    first_token: f64,
    evictions: u32,
    /// Unit the next op runs on.
    unit: usize,
    /// Admission sequence number — eviction preempts the newest.
    seq: usize,
    /// Pages currently booked (paged mode only; 0 under whole-request).
    pages: u64,
    /// Spilled KV words awaiting re-prefill (paged mode only).
    debt_words: u64,
    /// High-water page booking for the record.
    peak_pages: u64,
    /// Unit the prefill ran on, while the KV hand-off to the decode
    /// pool is still in flight (disagg only). While `Some`, the job's
    /// booking counts against *both* pools.
    transfer_from: Option<usize>,
}

impl Job {
    fn new(req: Request) -> Job {
        Job {
            req,
            produced: 0,
            prefilled: false,
            admitted: f64::NAN,
            first_token: f64::NAN,
            evictions: 0,
            unit: 0,
            seq: 0,
            pages: 0,
            debt_words: 0,
            peak_pages: 0,
            transfer_from: None,
        }
    }

    /// KV words this job's resident cache holds right now.
    fn kv_words(&self) -> u64 {
        (self.req.context + self.produced) * self.req.family.d_model()
    }

    /// Words this job books right now under whole-request booking.
    fn booked_words(&self) -> f64 {
        (self.req.context + self.produced) as f64 * self.req.family.d_model() as f64
    }

    /// Words this job will book at completion (whole-request booking).
    fn final_words(&self) -> f64 {
        (self.req.context + self.req.output) as f64 * self.req.family.d_model() as f64
    }

    /// Pages needed to hold the current KV at `page` words per page.
    fn need_pages(&self, page: u64) -> u64 {
        div_ceil_u64(self.kv_words(), page)
    }

    /// Words currently on the books for this job.
    fn booked_now(&self, page: u64) -> f64 {
        if page == 0 { self.booked_words() } else { (self.pages * page) as f64 }
    }

    /// Words an admission of this job would book.
    fn admit_words(&self, page: u64) -> f64 {
        if page == 0 {
            self.booked_words()
        } else {
            (self.need_pages(page) * page) as f64
        }
    }

    /// Words this job will book at completion under the active
    /// granularity — the outright-rejection bound.
    fn final_booked(&self, page: u64) -> f64 {
        if page == 0 {
            self.final_words()
        } else {
            let words = (self.req.context + self.req.output) * self.req.family.d_model();
            (div_ceil_u64(words, page) * page) as f64
        }
    }
}

fn div_ceil_u64(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// Aggregate KV book: `KV_DRAM_FACTOR` × the sum over units of their
/// largest bounded on-chip level.
pub fn kv_capacity_words(machine: &MachineConfig) -> f64 {
    let onchip: u64 = machine
        .sub_accels
        .iter()
        .map(|s| {
            s.spec
                .levels
                .iter()
                .filter(|l| !l.is_unbounded())
                .map(|l| l.size_words)
                .max()
                .unwrap_or(0)
        })
        .sum();
    onchip as f64 * KV_DRAM_FACTOR
}

/// Insert into the class-aware wait queue, ordered by (class rank,
/// request id). For a single-class stream this is provably identical
/// to the historical FIFO (arrivals append in id order; evictions land
/// ahead of everything waiting because an active job's id is always
/// below every waiting id).
fn enqueue(waiting: &mut VecDeque<Job>, job: Job) {
    let key = (job.req.class.rank(), job.req.id);
    let pos = waiting.partition_point(|j| (j.req.class.rank(), j.req.id) <= key);
    waiting.insert(pos, job);
}

/// Pick a unit for the next op: blind rotation, or rotation over the
/// pressure-ranked survivors. Free function (not a method) so callers
/// can borrow disjoint engine fields.
fn place(
    units: &[usize],
    ctr: &mut usize,
    placement: PlacementPolicy,
    pressure: &[f64],
) -> usize {
    let i = *ctr;
    *ctr += 1;
    match placement {
        PlacementPolicy::RoundRobin => units[i % units.len()],
        PlacementPolicy::Pressure | PlacementPolicy::PressureSearch => {
            let ranked = pressure_ordered(units, pressure);
            ranked[i % ranked.len()]
        }
    }
}

/// Top a paged job's booking up to its current KV need (covers decode
/// growth, re-booking after a KV re-fetch, and prefill completion after
/// a partial spill).
fn top_up_pages(job: &mut Job, booked: &mut f64, page: u64) {
    let need = job.need_pages(page);
    if need > job.pages {
        *booked += ((need - job.pages) * page) as f64;
        job.pages = need;
    }
    job.peak_pages = job.peak_pages.max(job.pages);
}

/// What a job's op this step was — drives the post-replay advance.
#[derive(Clone, Copy)]
enum StepKind {
    Prefill,
    /// KV hand-off of this many words from the prefill pool to the
    /// decode pool (disaggregated serving only).
    Transfer(u64),
    /// KV re-fetch of this many tokens after a page spill.
    Refetch(u64),
    /// Decode chunk of this many tokens.
    Decode(u64),
}

/// The continuous-batching state machine. `simulate` drives it to
/// completion; unit tests drive [`Engine::step`] directly to assert
/// per-step invariants (booking conservation, eviction bookkeeping)
/// under doctored capacities.
struct Engine<'a> {
    requests: &'a [Request],
    machine: &'a MachineConfig,
    costs: &'a ServingCosts,
    cfg: &'a ServeConfig,
    sopts: ScheduleOptions,
    capacity: f64,
    /// Units serving prefill ops (the high-reuse pool by default, or
    /// the disagg prefill role's pool).
    pre_units: Vec<usize>,
    /// Units serving decode chunks and KV re-fetches.
    dec_units: Vec<usize>,
    /// Disagg with pools that actually differ: prefill completion
    /// triggers an explicit KV hand-off, double-booked while in flight.
    transfer_split: bool,
    waiting: VecDeque<Job>,
    active: Vec<Job>,
    records: Vec<RequestRecord>,
    booked: f64,
    rejected: usize,
    evictions_total: usize,
    reprefill_tokens: u64,
    kv_transfers: usize,
    kv_transfer_words: u64,
    next_arrival: usize,
    admit_seq: usize,
    rr_pre: usize,
    rr_dec: usize,
    /// Decayed queue-delay/latency ratio per unit (pressure placement).
    unit_pressure: Vec<f64>,
    t: f64,
}

impl<'a> Engine<'a> {
    fn new(
        requests: &'a [Request],
        machine: &'a MachineConfig,
        costs: &'a ServingCosts,
        dynamic_bw: bool,
        cfg: &'a ServeConfig,
    ) -> Result<Engine<'a>, String> {
        let capacity = kv_capacity_words(machine);
        Engine::with_capacity(requests, machine, costs, dynamic_bw, cfg, capacity)
    }

    /// Like [`Engine::new`] but with an explicit KV book — the
    /// forced-pressure test entry point.
    fn with_capacity(
        requests: &'a [Request],
        machine: &'a MachineConfig,
        costs: &'a ServingCosts,
        dynamic_bw: bool,
        cfg: &'a ServeConfig,
        capacity: f64,
    ) -> Result<Engine<'a>, String> {
        if !capacity.is_finite() || capacity <= 0.0 {
            return Err(format!(
                "serving KV capacity is {capacity:.0} words — every on-chip level of \
                 every sub-accelerator is unbounded, so admission would silently \
                 reject 100% of requests; serve needs a machine with at least one \
                 bounded buffer level"
            ));
        }
        if cfg.decode_chunk == 0 {
            return Err("decode chunk must be at least 1 token".into());
        }
        if cfg.kv_page_words as f64 > capacity {
            return Err(format!(
                "kv page size {} words exceeds the machine's whole KV book \
                 ({capacity:.0} words) — not even one page could ever be booked, so \
                 admission would reject 100% of the stream (bar a lone-survivor \
                 bypass); shrink --kv-page-words or serve a machine with more \
                 buffering",
                cfg.kv_page_words
            ));
        }
        let (pre_units, dec_units) = match &cfg.disagg {
            Some(d) => {
                let mut tys: Vec<&str> =
                    machine.topology.accels.iter().map(|a| a.ty.as_str()).collect();
                tys.sort_unstable();
                tys.dedup();
                if tys.len() < 2 {
                    return Err(format!(
                        "--disagg needs a machine with at least two sub-accelerator \
                         types to split prefill from decode, but this one has only \
                         one ('{}') — the pools would be the same units, which is \
                         exactly the co-located engine",
                        tys.first().copied().unwrap_or("none")
                    ));
                }
                (eligible_units(machine, d.prefill), eligible_units(machine, d.decode))
            }
            None => {
                (eligible_units(machine, ReuseClass::High), eligible_units(machine, ReuseClass::Low))
            }
        };
        for r in requests {
            if r.context == 0 || r.output == 0 {
                return Err(format!(
                    "request {}: context and output must both be >= 1 token (got \
                     context {}, output {}) — zero-length requests would poison \
                     per-token latency",
                    r.id, r.context, r.output
                ));
            }
        }
        let transfer_split = cfg.disagg.is_some() && pre_units != dec_units;
        Ok(Engine {
            requests,
            machine,
            costs,
            cfg,
            sopts: ScheduleOptions { dynamic_bw },
            capacity,
            pre_units,
            dec_units,
            transfer_split,
            waiting: VecDeque::new(),
            active: Vec::new(),
            records: Vec::new(),
            booked: 0.0,
            rejected: 0,
            evictions_total: 0,
            reprefill_tokens: 0,
            kv_transfers: 0,
            kv_transfer_words: 0,
            next_arrival: 0,
            admit_seq: 0,
            rr_pre: 0,
            rr_dec: 0,
            unit_pressure: vec![0.0; machine.sub_accels.len()],
            t: 0.0,
        })
    }

    /// One engine iteration: ingest arrivals, admit, schedule a step,
    /// advance, preempt. Returns `false` once everything has drained.
    fn step(&mut self) -> bool {
        let page = self.cfg.kv_page_words;

        // Arrivals up to the clock enter the class-aware queue; a
        // request that could never fit even alone is rejected outright
        // (otherwise it would starve the queue behind it forever).
        while self.next_arrival < self.requests.len()
            && self.requests[self.next_arrival].arrival <= self.t
        {
            let r = self.requests[self.next_arrival].clone();
            self.next_arrival += 1;
            if Job::new(r.clone()).final_booked(page) > self.capacity {
                self.rejected += 1;
                continue;
            }
            enqueue(&mut self.waiting, Job::new(r));
        }

        // Class-ordered admission under the books. An empty machine
        // always admits its queue head — progress over strict
        // accounting.
        while let Some(front) = self.waiting.front() {
            if !self.active.is_empty() && self.booked + front.admit_words(page) > self.capacity
            {
                break;
            }
            let mut job = self.waiting.pop_front().unwrap();
            if page == 0 {
                self.booked += job.booked_words();
            } else {
                job.pages = job.need_pages(page);
                job.peak_pages = job.peak_pages.max(job.pages);
                self.booked += (job.pages * page) as f64;
            }
            if job.admitted.is_nan() {
                job.admitted = self.t;
            }
            job.seq = self.admit_seq;
            self.admit_seq += 1;
            job.unit = if job.prefilled {
                place(&self.dec_units, &mut self.rr_dec, self.cfg.placement, &self.unit_pressure)
            } else {
                place(&self.pre_units, &mut self.rr_pre, self.cfg.placement, &self.unit_pressure)
            };
            self.active.push(job);
        }

        if self.active.is_empty() {
            // Admission drained: nothing in flight means nothing
            // waiting either. Jump to the next arrival or finish.
            if self.next_arrival < self.requests.len() {
                self.t = self.t.max(self.requests[self.next_arrival].arrival);
                return true;
            }
            return false;
        }

        // One op per in-flight request: whole prefill, a KV re-fetch of
        // spilled pages, or one decode chunk (the first chunk is
        // exactly one token so TTFT is real).
        let mut cascade = Cascade::new("serve_step");
        let mut stats: Vec<OpStats> = Vec::with_capacity(self.active.len());
        let mut assignment: Vec<usize> = Vec::with_capacity(self.active.len());
        let mut kinds: Vec<StepKind> = Vec::with_capacity(self.active.len());
        for job in &self.active {
            let (op, cost, kind) = if !job.prefilled {
                let d = job.req.family.d_model();
                (
                    TensorOp::gemm(
                        &format!("r{}.prefill", job.req.id),
                        Phase::Prefill,
                        job.req.context,
                        d,
                        d,
                    ),
                    self.costs.prefill_cycles(&job.req),
                    StepKind::Prefill,
                )
            } else if let Some(from) = job.transfer_from {
                // KV hand-off between the prefill and decode pools:
                // the resident words cross the DRAM boundary, paced by
                // the narrower of the two units' DRAM shares in the
                // machine tree.
                let words = job.booked_now(page);
                let bw = self.machine.sub_accels[from]
                    .spec
                    .dram()
                    .bw_words_per_cycle
                    .min(self.machine.sub_accels[job.unit].spec.dram().bw_words_per_cycle);
                let d = job.req.family.d_model();
                (
                    TensorOp::gemm(
                        &format!("r{}.kvmove", job.req.id),
                        Phase::Decode,
                        1,
                        d,
                        d,
                    ),
                    words / bw.max(1e-9),
                    StepKind::Transfer(words as u64),
                )
            } else if page > 0 && job.debt_words > 0 {
                // Re-fetch spilled KV before decoding resumes: the
                // measured cost of a page-granular preemption.
                let d = job.req.family.d_model();
                let tokens = div_ceil_u64(job.debt_words, d);
                (
                    TensorOp::gemm(
                        &format!("r{}.refetch", job.req.id),
                        Phase::Prefill,
                        tokens,
                        d,
                        d,
                    ),
                    self.costs.family(job.req.family).prefill_per_token * tokens as f64,
                    StepKind::Refetch(tokens),
                )
            } else {
                let tokens = if job.produced == 0 {
                    1
                } else {
                    self.cfg.decode_chunk.min(job.req.output - job.produced)
                };
                let f = job.req.family;
                let kv = job.req.context + job.produced;
                (
                    TensorOp::bmm(
                        &format!("r{}.decode{}", job.req.id, job.produced),
                        Phase::Decode,
                        f.heads(),
                        tokens,
                        f.d_model() / f.heads(),
                        kv,
                    ),
                    self.costs.decode_chunk_cycles(f, tokens, kv),
                    StepKind::Decode(tokens),
                )
            };
            cascade.push(op);
            let mut st = OpStats::new_empty();
            st.cycles = cost;
            stats.push(st);
            assignment.push(job.unit);
            kinds.push(kind);
        }

        let refs: Vec<&OpStats> = stats.iter().collect();
        let mut oracle = ScheduleOracle::new(&cascade, self.machine, &self.sopts);
        let mut makespan = oracle.replay(&assignment, &refs);

        // Pressure-fed step search: the exported pressure signal orders
        // extra replay probes (hottest-unit ops first, coldest target
        // units first), and only moves that strictly improve the true
        // replayed step makespan are kept — so the refined step never
        // schedules worse than the rotation placement above. Transfer
        // ops stay put: their cost depends on the unit pair, so moving
        // one would break the replay's pure-stats contract.
        if self.cfg.placement == PlacementPolicy::PressureSearch && assignment.len() > 1 {
            let n = assignment.len();
            let budget = (4 * n).max(16);
            let mut moves = 0usize;
            let mut ranked: Vec<usize> = (0..n).collect();
            while moves < budget {
                ranked.sort_by(|&a, &b| {
                    let pa = self.unit_pressure[assignment[a]];
                    let pb = self.unit_pressure[assignment[b]];
                    pb.total_cmp(&pa).then(a.cmp(&b))
                });
                let mut improved = false;
                'outer: for &i in &ranked {
                    let pool: &[usize] = match kinds[i] {
                        StepKind::Prefill => &self.pre_units,
                        StepKind::Transfer(_) => continue,
                        _ => &self.dec_units,
                    };
                    if pool.len() < 2 {
                        continue;
                    }
                    let home = assignment[i];
                    let mut alts: Vec<usize> =
                        pool.iter().copied().filter(|&u| u != home).collect();
                    alts.sort_by(|&a, &b| {
                        self.unit_pressure[a]
                            .total_cmp(&self.unit_pressure[b])
                            .then(a.cmp(&b))
                    });
                    for u in alts {
                        assignment[i] = u;
                        let m = oracle.replay_delta(&assignment, &refs);
                        if strictly_better(m, makespan) {
                            makespan = m;
                            moves += 1;
                            improved = true;
                            break 'outer;
                        }
                        assignment[i] = home;
                    }
                }
                if !improved {
                    break;
                }
            }
            // The loop can end on a rejected (reverted) probe; one more
            // incremental replay restores the oracle's delay/latency
            // buffers to the accepted assignment (bit-identical
            // makespan, no-change fast path when nothing moved).
            makespan = oracle.replay_delta(&assignment, &refs);
            for (i, job) in self.active.iter_mut().enumerate() {
                job.unit = assignment[i];
            }
        }

        let finish: Vec<f64> = oracle
            .queue_delays()
            .iter()
            .zip(oracle.latencies())
            .map(|(d, l)| self.t + d + l)
            .collect();

        // Feed the replay's arbitration back into placement: each
        // unit's pressure is its decayed queue-delay/latency ratio.
        // Only maintained under the pressure policies, so the default
        // path does no extra float work.
        if self.cfg.placement.uses_pressure() {
            for p in self.unit_pressure.iter_mut() {
                *p *= 0.5;
            }
            oracle.accumulate_pressure(&assignment, &mut self.unit_pressure);
        }

        // Advance every in-flight request by its step op.
        let mut still_active: Vec<Job> = Vec::with_capacity(self.active.len());
        for (i, mut job) in std::mem::take(&mut self.active).into_iter().enumerate() {
            let fin = finish[i];
            match kinds[i] {
                StepKind::Prefill => {
                    job.prefilled = true;
                    let from = job.unit;
                    job.unit = place(
                        &self.dec_units,
                        &mut self.rr_dec,
                        self.cfg.placement,
                        &self.unit_pressure,
                    );
                    if page > 0 {
                        top_up_pages(&mut job, &mut self.booked, page);
                    }
                    if self.transfer_split && from != job.unit {
                        // The fresh KV must cross from the prefill pool
                        // to the decode pool: book it against both
                        // until the hand-off op completes.
                        job.transfer_from = Some(from);
                        self.booked += job.booked_now(page);
                    }
                    still_active.push(job);
                }
                StepKind::Transfer(words) => {
                    self.kv_transfers += 1;
                    self.kv_transfer_words += words;
                    // Hand-off done: release the prefill pool's copy.
                    self.booked -= job.booked_now(page);
                    job.transfer_from = None;
                    still_active.push(job);
                }
                StepKind::Refetch(tokens) => {
                    self.reprefill_tokens += tokens;
                    job.debt_words = 0;
                    top_up_pages(&mut job, &mut self.booked, page);
                    still_active.push(job);
                }
                StepKind::Decode(tokens) => {
                    if job.produced == 0 {
                        job.first_token = fin;
                    }
                    job.produced += tokens;
                    if page == 0 {
                        self.booked += tokens as f64 * job.req.family.d_model() as f64;
                    } else {
                        top_up_pages(&mut job, &mut self.booked, page);
                    }
                    if job.produced >= job.req.output {
                        self.booked -= job.booked_now(page);
                        self.records.push(RequestRecord {
                            id: job.req.id,
                            family: job.req.family,
                            class: job.req.class,
                            arrival: job.req.arrival,
                            context: job.req.context,
                            output: job.req.output,
                            admitted: job.admitted,
                            first_token: job.first_token,
                            completed: fin,
                            evictions: job.evictions,
                            peak_pages: job.peak_pages,
                        });
                    } else {
                        still_active.push(job);
                    }
                }
            }
        }
        self.active = still_active;

        // Growth may overflow the books: preempt the newest admission
        // of the lowest class (produced tokens kept) until they
        // balance — but never the last one, so the machine always
        // drains even when the lone survivor outgrows capacity. Under
        // paged booking the preemption is page-granular: spill one
        // page at a time, and only fully evict a request once its last
        // page is gone; a partially spilled request stays resident and
        // owes a re-fetch.
        while self.booked > self.capacity && self.active.len() > 1 {
            let victim = self
                .active
                .iter()
                .enumerate()
                .max_by_key(|(_, j)| (j.req.class.rank(), j.seq))
                .map(|(i, _)| i)
                .unwrap();
            if page == 0 {
                let mut job = self.active.swap_remove(victim);
                // A victim caught mid-hand-off frees both pool copies
                // (×1.0 is bitwise-exact for the co-located path).
                let mult = if job.transfer_from.is_some() { 2.0 } else { 1.0 };
                self.booked -= job.booked_words() * mult;
                job.transfer_from = None;
                job.evictions += 1;
                self.evictions_total += 1;
                enqueue(&mut self.waiting, job);
            } else {
                let job = &mut self.active[victim];
                job.pages -= 1;
                let mult = if job.transfer_from.is_some() { 2.0 } else { 1.0 };
                self.booked -= page as f64 * mult;
                if job.prefilled {
                    // Only resident KV needs re-fetching; an unprefilled
                    // job's prefill rebuilds its cache anyway.
                    job.debt_words = (job.debt_words + page).min(job.kv_words());
                }
                if job.pages == 0 {
                    let mut job = self.active.swap_remove(victim);
                    job.transfer_from = None;
                    job.evictions += 1;
                    self.evictions_total += 1;
                    enqueue(&mut self.waiting, job);
                }
            }
        }

        self.t += makespan;
        true
    }

    /// Assemble the report. Consumes the engine.
    fn finish(self, offered_load: f64) -> ServeResult {
        let records = self.records;
        let cfg = self.cfg;
        let span = records
            .iter()
            .map(|r| r.completed)
            .fold(self.t, f64::max)
            .max(1.0);
        let mut ttfts: Vec<f64> = records.iter().map(RequestRecord::ttft).collect();
        ttfts.sort_by(f64::total_cmp);
        let good = records.iter().filter(|r| r.ttft() <= cfg.slo_for(r.class)).count();
        let per_token_sum: f64 = records.iter().map(RequestRecord::per_token).sum();

        // Per-class breakouts only when the stream actually uses a
        // non-default class — default reports stay byte-stable.
        let mut class_breakdown = Vec::new();
        if self.requests.iter().any(|r| r.class != RequestClass::Interactive) {
            for class in RequestClass::ALL {
                let total = self.requests.iter().filter(|r| r.class == class).count();
                if total == 0 {
                    continue;
                }
                let recs: Vec<&RequestRecord> =
                    records.iter().filter(|r| r.class == class).collect();
                let mut tt: Vec<f64> = recs.iter().map(|r| r.ttft()).collect();
                tt.sort_by(f64::total_cmp);
                let slo = cfg.slo_for(class);
                let class_good = recs.iter().filter(|r| r.ttft() <= slo).count();
                class_breakdown.push(ClassReport {
                    class,
                    requests: total,
                    completed: recs.len(),
                    p50_ttft: percentile(&tt, 50.0),
                    p99_ttft: percentile(&tt, 99.0),
                    goodput: class_good as f64 * 1.0e6 / span,
                    slo_ttft: slo,
                });
            }
        }

        let report = ServeReport {
            offered_load,
            requests: self.requests.len(),
            completed: records.len(),
            rejected: self.rejected,
            evictions: self.evictions_total,
            span_cycles: span,
            p50_ttft: percentile(&ttfts, 50.0),
            p99_ttft: percentile(&ttfts, 99.0),
            mean_per_token: if records.is_empty() {
                0.0
            } else {
                per_token_sum / records.len() as f64
            },
            throughput: records.len() as f64 * 1.0e6 / span,
            goodput: good as f64 * 1.0e6 / span,
            slo_ttft: cfg.slo_ttft,
            kv_capacity_words: self.capacity,
            kv_page_words: cfg.kv_page_words,
            reprefill_tokens: self.reprefill_tokens,
            class_breakdown,
            disagg: cfg.disagg.as_ref().map(DisaggConfig::label),
            kv_transfers: self.kv_transfers,
            kv_transfer_words: self.kv_transfer_words,
        };
        ServeResult { records, report, unit_pressure: self.unit_pressure }
    }

    /// Bitwise booking conservation: the incremental book equals the
    /// sum over in-flight jobs of their current booking — counted twice
    /// while a KV hand-off is in flight, since the transfer holds both
    /// pools. Holds exactly (not just approximately) because every
    /// booked quantity is an integer-valued f64 below 2^53 (and the
    /// double-book is the exact sum b + b).
    #[cfg(test)]
    fn booked_matches_active(&self) -> bool {
        let page = self.cfg.kv_page_words;
        let sum: f64 = self
            .active
            .iter()
            .map(|j| {
                let b = j.booked_now(page);
                if j.transfer_from.is_some() { b + b } else { b }
            })
            .sum();
        sum.to_bits() == self.booked.to_bits()
    }
}

/// Run the continuous-batching engine over an arrival-sorted stream.
///
/// `dynamic_bw` mirrors `EvalOptions::dynamic_bw` for the per-step
/// schedule replays; `offered_load` is carried into the report (it is a
/// property of the stream generator, not derivable from the requests
/// once bursts overlap).
///
/// Errors loudly (instead of returning an empty-but-plausible report)
/// when the machine's KV book is zero — every on-chip level unbounded —
/// or when a request has a zero context/output length.
pub fn simulate(
    requests: &[Request],
    machine: &MachineConfig,
    costs: &ServingCosts,
    dynamic_bw: bool,
    offered_load: f64,
    cfg: &ServeConfig,
) -> Result<ServeResult, String> {
    let mut engine = Engine::new(requests, machine, costs, dynamic_bw, cfg)?;
    while engine.step() {}
    Ok(engine.finish(offered_load))
}

/// Nearest-rank percentile of an ascending-sorted slice (0.0 when
/// empty, so reports stay JSON-representable).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

/// Saturation knee of a goodput-vs-offered-load curve: the first grid
/// load where goodput falls below 90% of offered (the service stops
/// keeping up), or the last grid load when it never does.
///
/// The scalar form cannot distinguish "knee at the last grid load"
/// from "never saturates on the grid" — callers that care use
/// [`saturation_knee_checked`], which reports the two cases distinctly.
pub fn saturation_knee(curve: &[(f64, f64)]) -> f64 {
    saturation_knee_checked(curve).0
}

/// [`saturation_knee`] plus a saturation flag: `(knee, true)` when the
/// service actually fell below 90% of offered somewhere on the grid,
/// `(last_load, false)` when it kept up everywhere (the knee is then
/// only a lower bound — the curve never saturated on this grid).
pub fn saturation_knee_checked(curve: &[(f64, f64)]) -> (f64, bool) {
    for &(load, goodput) in curve {
        if goodput < 0.9 * load {
            return (load, true);
        }
    }
    (curve.last().map(|&(l, _)| l).unwrap_or(0.0), false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::taxonomy::HarpClass;
    use crate::workload::arrivals::{synthesize, ArrivalKind, StreamParams};

    fn test_costs() -> ServingCosts {
        ServingCosts::from_parts(
            RequestFamily::ALL
                .iter()
                .map(|&f| {
                    (
                        f,
                        FamilyCosts {
                            prefill_per_token: 50.0,
                            decode_per_token: 200.0,
                            base_kv: f.base_context() as f64,
                            d_model: f.d_model(),
                        },
                    )
                })
                .collect(),
        )
    }

    fn machine() -> MachineConfig {
        build_serving_machine(&HarpClass::from_id("hier+xnode").unwrap(), 2048.0, ContentionMode::Off)
            .unwrap()
    }

    fn stream(load: f64, n: usize) -> Vec<crate::workload::arrivals::Request> {
        synthesize(&StreamParams {
            kind: ArrivalKind::Poisson,
            mix: RequestFamily::ALL.iter().map(|&f| (f, 1.0)).collect(),
            classes: vec![],
            load,
            requests: n,
            seed: 7,
        })
        .unwrap()
    }

    /// A small hand-built llama2 request (context 64, output 32 —
    /// 393216 final KV words), for forced-pressure scenarios.
    fn req(id: usize, arrival: f64, class: RequestClass) -> Request {
        Request {
            id,
            arrival,
            family: RequestFamily::Llama2,
            context: 64,
            output: 32,
            class,
        }
    }

    /// Drive an engine to completion under a doctored capacity,
    /// asserting bitwise booking conservation after every step.
    fn run_pressured(reqs: &[Request], capacity: f64, cfg: &ServeConfig) -> ServeResult {
        let m = machine();
        let costs = test_costs();
        let mut e = Engine::with_capacity(reqs, &m, &costs, true, cfg, capacity).unwrap();
        while e.step() {
            assert!(e.booked_matches_active(), "booked diverged from Σ active bookings");
        }
        assert!(e.booked_matches_active());
        e.finish(0.0)
    }

    #[test]
    fn every_unrejected_request_completes() {
        let reqs = stream(2.0, 30);
        let r = simulate(&reqs, &machine(), &test_costs(), true, 2.0, &ServeConfig::default())
            .unwrap();
        assert_eq!(r.report.completed + r.report.rejected, reqs.len());
        for rec in &r.records {
            assert!(rec.ttft() >= 0.0, "request {} has negative TTFT", rec.id);
            assert!(rec.completed >= rec.first_token);
            assert!(rec.admitted >= rec.arrival);
        }
    }

    #[test]
    fn report_is_bit_identical_across_runs() {
        let reqs = stream(2.0, 30);
        let m = machine();
        let a = simulate(&reqs, &m, &test_costs(), true, 2.0, &ServeConfig::default()).unwrap();
        let b = simulate(&reqs, &m, &test_costs(), true, 2.0, &ServeConfig::default()).unwrap();
        assert_eq!(a.report.render(), b.report.render());
        assert_eq!(a.report.p99_ttft.to_bits(), b.report.p99_ttft.to_bits());
        assert_eq!(a.report.goodput.to_bits(), b.report.goodput.to_bits());
    }

    #[test]
    fn default_render_shape_is_pinned() {
        // The byte-stable-defaults contract: a classless, unpaged run
        // renders exactly the five historical lines — no class
        // breakdown, no page line.
        let reqs = stream(2.0, 10);
        let r = simulate(&reqs, &machine(), &test_costs(), true, 2.0, &ServeConfig::default())
            .unwrap();
        let text = r.report.render();
        assert_eq!(text.lines().count(), 5, "default render grew lines:\n{text}");
        assert!(!text.contains("class "), "default render leaked class lines:\n{text}");
        assert!(!text.contains("kv pages"), "default render leaked page line:\n{text}");
        assert!(r.report.class_breakdown.is_empty());
        assert_eq!(r.report.kv_page_words, 0);
        assert_eq!(r.report.reprefill_tokens, 0);
        assert!(r.records.iter().all(|rec| rec.peak_pages == 0));
    }

    #[test]
    fn goodput_never_exceeds_throughput() {
        let reqs = stream(4.0, 40);
        let r = simulate(&reqs, &machine(), &test_costs(), true, 4.0, &ServeConfig::default())
            .unwrap();
        assert!(r.report.goodput <= r.report.throughput + 1e-12);
        assert!(r.report.p50_ttft <= r.report.p99_ttft);
    }

    #[test]
    fn higher_load_does_not_lower_pressure() {
        // The same stream compressed 16× in time must show queueing
        // somewhere: the run finishes sooner in absolute terms, and
        // tail TTFT cannot dip below the uncontended median.
        let m = machine();
        let light = simulate(&stream(0.5, 30), &m, &test_costs(), true, 0.5, &ServeConfig::default())
            .unwrap();
        let heavy = simulate(&stream(8.0, 30), &m, &test_costs(), true, 8.0, &ServeConfig::default())
            .unwrap();
        assert!(
            heavy.report.span_cycles < light.report.span_cycles,
            "heavy span {} >= light span {}",
            heavy.report.span_cycles,
            light.report.span_cycles
        );
        assert!(
            heavy.report.p99_ttft >= light.report.p50_ttft,
            "heavy p99 {} < light p50 {}",
            heavy.report.p99_ttft,
            light.report.p50_ttft
        );
    }

    #[test]
    fn knee_detection() {
        assert_eq!(saturation_knee(&[(1.0, 1.0), (2.0, 1.9), (4.0, 2.0)]), 4.0);
        assert_eq!(saturation_knee(&[(1.0, 0.5), (2.0, 0.5)]), 1.0);
        assert_eq!(saturation_knee(&[]), 0.0);
    }

    #[test]
    fn knee_checked_separates_saturation_from_grid_end() {
        // A curve that genuinely saturates at the last grid load and
        // one that never saturates report the same scalar knee — the
        // checked form is what tells them apart.
        let saturates_at_end = [(1.0, 1.0), (2.0, 1.9), (4.0, 2.0)];
        let never_saturates = [(1.0, 1.0), (2.0, 2.0), (4.0, 4.0)];
        assert_eq!(saturation_knee(&saturates_at_end), saturation_knee(&never_saturates));
        assert_eq!(saturation_knee_checked(&saturates_at_end), (4.0, true));
        assert_eq!(saturation_knee_checked(&never_saturates), (4.0, false));
        // Mid-grid knee and empty grid.
        assert_eq!(saturation_knee_checked(&[(1.0, 0.5), (2.0, 0.5)]), (1.0, true));
        assert_eq!(saturation_knee_checked(&[]), (0.0, false));
        // The scalar form stays byte-compatible: it is the checked
        // knee, always.
        for curve in [&saturates_at_end[..], &never_saturates[..]] {
            assert_eq!(saturation_knee(curve), saturation_knee_checked(curve).0);
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 99.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn tiny_capacity_evicts_but_completes() {
        // Force the books to overflow by shrinking requests onto a
        // stream that overlaps heavily: everyone still finishes, and
        // the eviction counter moves only when capacity binds.
        let reqs = stream(8.0, 20);
        let r = simulate(&reqs, &machine(), &test_costs(), true, 8.0, &ServeConfig::default())
            .unwrap();
        assert_eq!(r.report.completed + r.report.rejected, reqs.len());
    }

    #[test]
    fn zero_capacity_machine_is_a_loud_error() {
        // Regression: a machine whose every on-chip level is unbounded
        // has a zero KV book; the pre-fix engine silently rejected 100%
        // of requests and reported an empty-but-plausible summary.
        let mut m = machine();
        for sa in &mut m.sub_accels {
            for level in &mut sa.spec.levels {
                level.size_words = u64::MAX;
            }
        }
        assert_eq!(kv_capacity_words(&m), 0.0);
        let err = simulate(&stream(2.0, 5), &m, &test_costs(), true, 2.0, &ServeConfig::default())
            .unwrap_err();
        assert!(err.contains("unbounded"), "{err}");
        assert!(err.contains("bounded buffer level"), "{err}");
    }

    #[test]
    fn zero_length_requests_are_a_loud_error() {
        // Defense in depth behind the trace loader's parse-time
        // rejection: the engine itself refuses zero-length requests
        // instead of dividing per-token latency by zero.
        let mut zero_out = vec![req(0, 0.0, RequestClass::Interactive)];
        zero_out[0].output = 0;
        let err = simulate(&zero_out, &machine(), &test_costs(), true, 2.0, &ServeConfig::default())
            .unwrap_err();
        assert!(err.contains("output 0"), "{err}");
        let mut zero_ctx = vec![req(0, 0.0, RequestClass::Interactive)];
        zero_ctx[0].context = 0;
        let err = simulate(&zero_ctx, &machine(), &test_costs(), true, 2.0, &ServeConfig::default())
            .unwrap_err();
        assert!(err.contains("context 0"), "{err}");
    }

    #[test]
    fn booking_conserves_under_whole_request_pressure() {
        // Two requests fit at admission but not at full growth, so the
        // run is forced through evictions; `run_pressured` asserts the
        // bitwise conservation invariant after every step.
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, i as f64 * 1000.0, RequestClass::Interactive)).collect();
        let r = run_pressured(&reqs, 600_000.0, &ServeConfig::default());
        assert_eq!(r.report.completed, 6);
        assert!(r.report.evictions > 0, "scenario never exercised eviction");
    }

    #[test]
    fn booking_conserves_under_paged_pressure() {
        // One-token pages (4096 words for llama2) under the same
        // squeeze: page-granular spills, re-fetch debt, and incremental
        // growth all keep the books bitwise-consistent, and the spills
        // show up as measured re-prefill tokens.
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, i as f64 * 1000.0, RequestClass::Interactive)).collect();
        let cfg = ServeConfig { kv_page_words: 4096, ..ServeConfig::default() };
        let r = run_pressured(&reqs, 600_000.0, &cfg);
        assert_eq!(r.report.completed, 6);
        assert!(r.report.evictions > 0, "scenario never exercised eviction");
        assert!(r.report.reprefill_tokens > 0, "paged spills never charged a re-fetch");
        assert!(r.records.iter().all(|rec| rec.peak_pages > 0));
        assert_eq!(r.report.kv_page_words, 4096);
        // Paged runs are deterministic too.
        let again = run_pressured(&reqs, 600_000.0, &cfg);
        assert_eq!(r.report.render(), again.report.render());
    }

    #[test]
    fn eviction_keeps_admitted_time_and_produced_tokens() {
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, i as f64 * 1000.0, RequestClass::Interactive)).collect();
        let m = machine();
        let costs = test_costs();
        let cfg = ServeConfig::default();
        let mut e = Engine::with_capacity(&reqs, &m, &costs, true, &cfg, 600_000.0).unwrap();
        // (id, original admitted, produced at eviction)
        let mut observed: Option<(usize, f64, u64)> = None;
        loop {
            let alive = e.step();
            if let Some((id, _, produced)) = observed {
                // Once readmitted, the job resumes from its kept tokens.
                if let Some(j) = e.active.iter().find(|j| j.req.id == id) {
                    assert!(j.produced >= produced, "produced tokens were lost on eviction");
                }
            } else if let Some(j) =
                e.waiting.iter().find(|j| j.evictions > 0 && j.produced > 0)
            {
                observed = Some((j.req.id, j.admitted, j.produced));
            }
            if !alive {
                break;
            }
        }
        let (id, admitted, produced) =
            observed.expect("scenario must evict a mid-decode request");
        assert!(produced > 0);
        let r = e.finish(0.0);
        let rec = r.records.iter().find(|rec| rec.id == id).unwrap();
        assert!(rec.evictions >= 1);
        assert_eq!(
            rec.admitted.to_bits(),
            admitted.to_bits(),
            "re-admission overwrote the original admitted time"
        );
        assert_eq!(rec.output, 32, "request did not finish its full output");
    }

    #[test]
    fn lone_survivor_over_capacity_still_drains() {
        // Shrink the book out from under a lone in-flight request: the
        // eviction loop must not spin (it never preempts the last job)
        // and the request must still complete.
        let reqs = vec![req(0, 0.0, RequestClass::Interactive)];
        let m = machine();
        let costs = test_costs();
        let cfg = ServeConfig::default();
        let mut e = Engine::with_capacity(&reqs, &m, &costs, true, &cfg, 500_000.0).unwrap();
        assert!(e.step(), "first step admits and prefills");
        // Mid-run the survivor's booking now exceeds the (shrunk) book.
        e.capacity = 1000.0;
        while e.step() {}
        assert!(e.booked.to_bits() == 0.0f64.to_bits());
        let r = e.finish(0.0);
        assert_eq!(r.report.completed, 1);
        assert_eq!(r.report.evictions, 0, "the lone survivor must never be preempted");
    }

    #[test]
    fn interactive_p99_beats_fifo_under_pressure() {
        // The pinned acceptance scenario: a KV-starved machine serving
        // an interleaved interactive/batch stream. Class-aware
        // admission must strictly improve interactive p99 TTFT over the
        // classless FIFO ordering of the *same* requests.
        let mixed: Vec<Request> = (0..12)
            .map(|i| {
                let class =
                    if i % 2 == 1 { RequestClass::Interactive } else { RequestClass::Batch };
                req(i, i as f64 * 500.0, class)
            })
            .collect();
        let fifo: Vec<Request> = mixed
            .iter()
            .cloned()
            .map(|mut r| {
                r.class = RequestClass::Interactive;
                r
            })
            .collect();
        let capacity = 600_000.0; // ~1.5 requests — admission queues hard
        let prio = run_pressured(&mixed, capacity, &ServeConfig::default());
        let base = run_pressured(&fifo, capacity, &ServeConfig::default());
        assert_eq!(prio.report.completed, 12);
        assert_eq!(base.report.completed, 12);
        let p99 = |res: &ServeResult| {
            let mut tt: Vec<f64> = res
                .records
                .iter()
                .filter(|r| r.id % 2 == 1)
                .map(|r| r.ttft())
                .collect();
            tt.sort_by(f64::total_cmp);
            percentile(&tt, 99.0)
        };
        assert!(
            p99(&prio) < p99(&base),
            "interactive p99 {} did not improve over FIFO {}",
            p99(&prio),
            p99(&base)
        );
        // And the mixed run reports per-class breakouts.
        assert_eq!(prio.report.class_breakdown.len(), 2);
        assert!(prio.report.render().contains("class interactive"));
        assert!(prio.report.render().contains("class batch"));
        assert!(base.report.class_breakdown.is_empty());
    }

    #[test]
    fn batch_slo_feeds_goodput_and_breakdown() {
        let mixed: Vec<Request> = (0..8)
            .map(|i| {
                let class =
                    if i % 2 == 0 { RequestClass::Interactive } else { RequestClass::Batch };
                req(i, i as f64 * 500.0, class)
            })
            .collect();
        let tight = ServeConfig {
            slo_ttft_batch: Some(1.0), // nothing meets a 1-cycle TTFT
            ..ServeConfig::default()
        };
        let loose = ServeConfig::default();
        let a = run_pressured(&mixed, 600_000.0, &tight);
        let b = run_pressured(&mixed, 600_000.0, &loose);
        let batch = |res: &ServeResult| {
            res.report
                .class_breakdown
                .iter()
                .find(|c| c.class == RequestClass::Batch)
                .cloned()
                .unwrap()
        };
        assert_eq!(batch(&a).goodput, 0.0);
        assert!(batch(&b).goodput > 0.0);
        assert_eq!(batch(&a).slo_ttft, 1.0);
        // Overall goodput counts each class against its own SLO, so
        // tightening the batch SLO lowers it.
        assert!(a.report.goodput < b.report.goodput);
    }

    #[test]
    fn pressure_placement_is_deterministic_and_complete() {
        let reqs = stream(8.0, 20);
        let cfg = ServeConfig { placement: PlacementPolicy::Pressure, ..ServeConfig::default() };
        let m = machine();
        let a = simulate(&reqs, &m, &test_costs(), true, 8.0, &cfg).unwrap();
        let b = simulate(&reqs, &m, &test_costs(), true, 8.0, &cfg).unwrap();
        assert_eq!(a.report.completed + a.report.rejected, reqs.len());
        assert_eq!(a.report.render(), b.report.render());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.completed.to_bits(), y.completed.to_bits());
        }
    }

    #[test]
    fn placement_parse_is_loud() {
        assert_eq!(PlacementPolicy::parse("rr").unwrap(), PlacementPolicy::RoundRobin);
        assert_eq!(PlacementPolicy::parse("pressure").unwrap(), PlacementPolicy::Pressure);
        assert_eq!(
            PlacementPolicy::parse("pressure-search").unwrap(),
            PlacementPolicy::PressureSearch
        );
        let err = PlacementPolicy::parse("luck").unwrap_err();
        assert!(err.contains("round_robin, pressure"), "{err}");
        assert!(err.contains("pressure_search"), "{err}");
    }

    #[test]
    fn disagg_parse_round_trips_and_is_loud() {
        let d = DisaggConfig::parse("prefill=high,decode=low").unwrap();
        assert_eq!(d, DisaggConfig { prefill: ReuseClass::High, decode: ReuseClass::Low });
        assert_eq!(d.label(), "prefill=high,decode=low");
        // Aliases and swapped order normalise to the same canonical label.
        let alias = DisaggConfig::parse("decode=lo,prefill=high-reuse").unwrap();
        assert_eq!(alias, d);
        assert_eq!(alias.label(), "prefill=high,decode=low");
        let same = DisaggConfig::parse("prefill=low,decode=low").unwrap();
        assert_eq!(same.label(), "prefill=low,decode=low");

        let err = DisaggConfig::parse("prefill=high").unwrap_err();
        assert!(err.contains("must name both phases"), "{err}");
        let err = DisaggConfig::parse("prefill=warm,decode=low").unwrap_err();
        assert!(err.contains("unknown disagg role 'warm'"), "{err}");
        let err = DisaggConfig::parse("prefill=high,paint=low").unwrap_err();
        assert!(err.contains("unknown disagg phase 'paint'"), "{err}");
        let err = DisaggConfig::parse("prefill=high,prefill=low").unwrap_err();
        assert!(err.contains("duplicate disagg phase 'prefill'"), "{err}");
        let err = DisaggConfig::parse("prefill").unwrap_err();
        assert!(err.contains("phase=role"), "{err}");
    }

    #[test]
    fn oversized_kv_page_is_a_loud_error() {
        // Regression (satellite bugfix): a page larger than the whole
        // KV book meant no request could ever book a page — admission
        // silently rejected the entire stream instead of erroring.
        let m = machine();
        let cap = kv_capacity_words(&m);
        let cfg = ServeConfig { kv_page_words: cap as u64 + 1, ..ServeConfig::default() };
        let err = simulate(&stream(2.0, 5), &m, &test_costs(), true, 2.0, &cfg).unwrap_err();
        assert!(err.contains("exceeds the machine's whole KV book"), "{err}");
        assert!(err.contains("--kv-page-words"), "{err}");
        // The largest page that still fits is accepted.
        let cfg = ServeConfig { kv_page_words: cap as u64, ..ServeConfig::default() };
        simulate(&stream(2.0, 5), &m, &test_costs(), true, 2.0, &cfg).unwrap();
    }

    #[test]
    fn disagg_on_single_type_machine_is_a_loud_error() {
        // leaf+homo has one sub-accelerator design: there is nothing to
        // disaggregate across.
        let homo = build_serving_machine(
            &HarpClass::from_id("leaf+homo").unwrap(),
            2048.0,
            ContentionMode::Off,
        )
        .unwrap();
        let tys: std::collections::BTreeSet<&str> =
            homo.topology.accels.iter().map(|a| a.ty.as_str()).collect();
        assert_eq!(tys.len(), 1, "leaf+homo grew a second unit type");
        let cfg = ServeConfig {
            disagg: Some(DisaggConfig::parse("prefill=high,decode=low").unwrap()),
            ..ServeConfig::default()
        };
        let err = simulate(&stream(2.0, 5), &homo, &test_costs(), true, 2.0, &cfg).unwrap_err();
        assert!(err.contains("at least two sub-accelerator types"), "{err}");
    }

    fn disagg_cfg() -> ServeConfig {
        ServeConfig {
            disagg: Some(DisaggConfig { prefill: ReuseClass::High, decode: ReuseClass::Low }),
            ..ServeConfig::default()
        }
    }

    #[test]
    fn disagg_charges_and_conserves_kv_transfers() {
        // hier+xnode resolves distinct prefill/decode pools, so every
        // completed request that changed unit at prefill completion pays
        // exactly one hand-off; `run_pressured` asserts the bitwise
        // both-pools conservation invariant after every step.
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, i as f64 * 1000.0, RequestClass::Interactive)).collect();
        let r = run_pressured(&reqs, 600_000.0, &disagg_cfg());
        assert_eq!(r.report.completed, 6);
        assert!(r.report.kv_transfers > 0, "disagg run never charged a hand-off");
        assert!(r.report.kv_transfer_words > 0);
        assert_eq!(r.report.disagg.as_deref(), Some("prefill=high,decode=low"));
        assert!(r.report.render().contains("disagg prefill=high,decode=low"));
        // Every request hands off at most once per admission.
        assert!(r.report.kv_transfers <= r.report.completed + r.report.evictions);

        // Paged booking conserves through hand-offs too.
        let paged = ServeConfig { kv_page_words: 4096, ..disagg_cfg() };
        let p = run_pressured(&reqs, 600_000.0, &paged);
        assert_eq!(p.report.completed, 6);
        assert!(p.report.kv_transfers > 0);
    }

    #[test]
    fn disagg_runs_are_bit_identical() {
        let reqs = stream(2.0, 20);
        let m = machine();
        let a = simulate(&reqs, &m, &test_costs(), true, 2.0, &disagg_cfg()).unwrap();
        let b = simulate(&reqs, &m, &test_costs(), true, 2.0, &disagg_cfg()).unwrap();
        assert_eq!(a.report.render(), b.report.render());
        assert_eq!(a.report.kv_transfer_words, b.report.kv_transfer_words);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.completed.to_bits(), y.completed.to_bits());
        }
    }

    #[test]
    fn disagg_same_pools_degrades_to_colocated_bitwise() {
        // The differential contract: when both roles resolve to the
        // same unit pool (every role Unified), the disagg engine is the
        // co-located engine — records and report bitwise, render
        // identical bar the gated disagg line.
        let mut m = machine();
        for sa in &mut m.sub_accels {
            sa.role = crate::arch::partition::Role::Unified;
        }
        let reqs = stream(2.0, 20);
        let costs = test_costs();
        let colo = simulate(&reqs, &m, &costs, true, 2.0, &ServeConfig::default()).unwrap();
        let dis = simulate(&reqs, &m, &costs, true, 2.0, &disagg_cfg()).unwrap();
        assert_eq!(dis.report.kv_transfers, 0, "same-pool disagg charged a hand-off");
        assert_eq!(dis.report.kv_transfer_words, 0);
        assert_eq!(colo.records.len(), dis.records.len());
        for (x, y) in colo.records.iter().zip(&dis.records) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.admitted.to_bits(), y.admitted.to_bits());
            assert_eq!(x.first_token.to_bits(), y.first_token.to_bits());
            assert_eq!(x.completed.to_bits(), y.completed.to_bits());
        }
        assert_eq!(colo.report.goodput.to_bits(), dis.report.goodput.to_bits());
        let strip = |s: &str| {
            s.lines()
                .filter(|l| !l.trim_start().starts_with("disagg "))
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(strip(&colo.report.render()), strip(&dis.report.render()));
        assert_eq!(
            colo.report.render(),
            strip(&dis.report.render()) + "\n",
            "co-located render differs beyond the gated disagg line"
        );
    }

    #[test]
    fn pressure_search_is_deterministic_and_never_slower_per_step() {
        let reqs = stream(8.0, 20);
        let m = machine();
        let search =
            ServeConfig { placement: PlacementPolicy::PressureSearch, ..ServeConfig::default() };
        let a = simulate(&reqs, &m, &test_costs(), true, 8.0, &search).unwrap();
        let b = simulate(&reqs, &m, &test_costs(), true, 8.0, &search).unwrap();
        assert_eq!(a.report.completed + a.report.rejected, reqs.len());
        assert_eq!(a.report.render(), b.report.render());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.completed.to_bits(), y.completed.to_bits());
        }
        // The exported pressure signal is populated under the pressure
        // policies and dormant under round-robin.
        assert!(a.unit_pressure.iter().any(|&p| p > 0.0));
        let rr = simulate(&reqs, &m, &test_costs(), true, 8.0, &ServeConfig::default()).unwrap();
        assert!(rr.unit_pressure.iter().all(|&p| p == 0.0));
        // Refinement accepts only strict step-makespan improvements, so
        // the run can only finish sooner (or identically) than plain
        // pressure placement started from the same rotations.
        let plain =
            ServeConfig { placement: PlacementPolicy::Pressure, ..ServeConfig::default() };
        let p = simulate(&reqs, &m, &test_costs(), true, 8.0, &plain).unwrap();
        assert_eq!(p.report.completed, a.report.completed);
    }
}
