//! PJRT client wrapper: manifest parsing, compilation, execution.
//!
//! Manifest parsing is always available. Compiling and executing HLO
//! artifacts needs the vendored `xla` crate, which only the runtime
//! container ships — that half is gated behind the `pjrt` cargo feature.
//! Without the feature, [`Runtime::load`] still validates the manifest
//! and exposes its metadata, but [`Runtime::run`]/[`Runtime::bench`]
//! report the missing backend.

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{anyhow, bail};
use std::path::Path;

/// Shape + dtype of one tensor (dtype is always f32 in this build).
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact as described by `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    /// Golden statistics of the (single) output on the deterministic
    /// inputs, recorded by the python oracle at AOT time.
    pub golden_sum: f64,
    pub golden_absmax: f64,
}

/// Result of executing an artifact once.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub name: String,
    pub output_sum: f64,
    pub output_absmax: f64,
    pub elements: usize,
    pub wall_us: f64,
    /// Relative error of `sum` vs the golden.
    pub sum_rel_err: f64,
}

impl RunOutcome {
    /// Numerics match the python oracle within tolerance.
    pub fn passed(&self) -> bool {
        self.sum_rel_err < 1e-3
    }
}

#[cfg(feature = "pjrt")]
mod backend {
    use super::*;
    use crate::runtime::{input_value, INPUT_STRIDE};
    use std::path::PathBuf;
    use std::time::Instant;

    /// Wrap an xla-crate error into the local error type.
    fn xe<T, E: std::fmt::Debug>(r: std::result::Result<T, E>) -> Result<T> {
        r.map_err(|e| anyhow!("xla: {e:?}"))
    }

    struct Loaded {
        spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT runtime: a CPU client plus every compiled artifact.
    pub struct Runtime {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        loaded: Vec<Loaded>,
    }

    impl Runtime {
        /// Load every artifact listed in `<dir>/manifest.json`.
        pub fn load(dir: &Path) -> Result<Runtime> {
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?;
            let specs = parse_manifest(&text)?;
            let client = xe(xla::PjRtClient::cpu())?;
            let mut loaded = Vec::new();
            for spec in specs {
                let path: PathBuf = dir.join(&spec.file);
                let proto = xe(xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                ))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = xe(client.compile(&comp))?;
                loaded.push(Loaded { spec, exe });
            }
            Ok(Runtime { client, loaded })
        }

        pub fn artifact_names(&self) -> Vec<&str> {
            self.loaded.iter().map(|l| l.spec.name.as_str()).collect()
        }

        pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
            self.loaded.iter().find(|l| l.spec.name == name).map(|l| &l.spec)
        }

        /// Generate the deterministic inputs for an artifact.
        pub fn make_inputs(spec: &ArtifactSpec) -> Result<Vec<xla::Literal>> {
            spec.inputs
                .iter()
                .enumerate()
                .map(|(idx, t)| {
                    let offset = idx as u64 * INPUT_STRIDE;
                    let data: Vec<f32> =
                        (0..t.elements() as u64).map(|i| input_value(i + offset)).collect();
                    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                    xe(xla::Literal::vec1(&data).reshape(&dims))
                })
                .collect()
        }

        /// Execute an artifact once and compare against its golden stats.
        pub fn run(&self, name: &str) -> Result<RunOutcome> {
            let l = self
                .loaded
                .iter()
                .find(|l| l.spec.name == name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            let inputs = Self::make_inputs(&l.spec)?;
            let t0 = Instant::now();
            let bufs = xe(l.exe.execute::<xla::Literal>(&inputs))?;
            let result = xe(bufs[0][0].to_literal_sync())?;
            let wall_us = t0.elapsed().as_nanos() as f64 / 1e3;
            // Lowered with return_tuple=True → single-element tuple.
            let out = xe(result.to_tuple1())?;
            let values = xe(out.to_vec::<f32>())?;
            let output_sum: f64 = values.iter().map(|&v| v as f64).sum();
            let output_absmax =
                values.iter().map(|&v| (v as f64).abs()).fold(0.0f64, f64::max);
            let denom = l.spec.golden_sum.abs().max(1e-6);
            let sum_rel_err = (output_sum - l.spec.golden_sum).abs() / denom;
            Ok(RunOutcome {
                name: name.to_string(),
                output_sum,
                output_absmax,
                elements: values.len(),
                wall_us,
                sum_rel_err,
            })
        }

        /// Execute an artifact `iters` times, returning mean latency in
        /// µs (the serving-metric measurement of `examples/e2e_validate`).
        pub fn bench(&self, name: &str, iters: usize) -> Result<f64> {
            let l = self
                .loaded
                .iter()
                .find(|l| l.spec.name == name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            let inputs = Self::make_inputs(&l.spec)?;
            // Warm-up.
            let _ = xe(l.exe.execute::<xla::Literal>(&inputs))?;
            let t0 = Instant::now();
            for _ in 0..iters {
                let bufs = xe(l.exe.execute::<xla::Literal>(&inputs))?;
                // Force completion.
                let _ = xe(bufs[0][0].to_literal_sync())?;
            }
            Ok(t0.elapsed().as_nanos() as f64 / 1e3 / iters as f64)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::*;
    use crate::runtime::{input_value, INPUT_STRIDE};

    /// Stub runtime for builds without the `pjrt` feature (the offline
    /// image): manifest loading and metadata work; execution reports the
    /// missing backend.
    pub struct Runtime {
        specs: Vec<ArtifactSpec>,
    }

    impl Runtime {
        /// Load and validate `<dir>/manifest.json` (no compilation).
        pub fn load(dir: &Path) -> Result<Runtime> {
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?;
            let specs = parse_manifest(&text)?;
            Ok(Runtime { specs })
        }

        pub fn artifact_names(&self) -> Vec<&str> {
            self.specs.iter().map(|s| s.name.as_str()).collect()
        }

        pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
            self.specs.iter().find(|s| s.name == name)
        }

        /// Generate the deterministic inputs for an artifact (host-side
        /// buffers; the stub has no device to upload them to).
        pub fn make_inputs(spec: &ArtifactSpec) -> Result<Vec<Vec<f32>>> {
            Ok(spec
                .inputs
                .iter()
                .enumerate()
                .map(|(idx, t)| {
                    let offset = idx as u64 * INPUT_STRIDE;
                    (0..t.elements() as u64).map(|i| input_value(i + offset)).collect()
                })
                .collect())
        }

        pub fn run(&self, name: &str) -> Result<RunOutcome> {
            self.spec(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            Err(anyhow!(
                "PJRT backend not built: rebuild with `--features pjrt` (requires the vendored `xla` crate) to execute '{name}'"
            ))
        }

        pub fn bench(&self, name: &str, _iters: usize) -> Result<f64> {
            self.spec(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            Err(anyhow!("PJRT backend not built (enable the `pjrt` feature)"))
        }
    }
}

pub use backend::Runtime;

/// Parse `manifest.json`.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
    let arts = j
        .get("artifacts")
        .and_then(|a| a.as_arr())
        .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
    let mut out = Vec::new();
    for a in arts {
        let name = a
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("artifact missing name"))?
            .to_string();
        let file = a
            .get("file")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("artifact {name} missing file"))?
            .to_string();
        let inputs_json = a
            .get("inputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("artifact {name} missing inputs"))?;
        let mut inputs = Vec::new();
        for i in inputs_json {
            let shape: Vec<usize> = i
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("input missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?;
            if shape.is_empty() {
                bail!("artifact {name}: empty input shape");
            }
            inputs.push(TensorSpec { shape });
        }
        let golden_sum = a
            .get("golden_sum")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("artifact {name} missing golden_sum"))?;
        let golden_absmax =
            a.get("golden_absmax").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        out.push(ArtifactSpec { name, file, inputs, golden_sum, golden_absmax });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest() {
        let text = r#"{"artifacts":[
            {"name":"gemm","file":"gemm.hlo.txt",
             "inputs":[{"shape":[4,8]},{"shape":[8,4]}],
             "golden_sum": 1.25, "golden_absmax": 0.5}]}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].inputs[0].elements(), 32);
        assert_eq!(specs[0].golden_sum, 1.25);
    }

    #[test]
    fn rejects_malformed_manifest() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest(r#"{"artifacts":[{"name":"x"}]}"#).is_err());
        assert!(parse_manifest(
            r#"{"artifacts":[{"name":"x","file":"f","inputs":[{"shape":[]}],"golden_sum":0}]}"#
        )
        .is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_backend() {
        let dir = std::env::temp_dir().join("harp_stub_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"name":"gemm","file":"gemm.hlo.txt",
                "inputs":[{"shape":[2,2]}],"golden_sum":0.5}]}"#,
        )
        .unwrap();
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.artifact_names(), vec!["gemm"]);
        assert_eq!(rt.spec("gemm").unwrap().inputs[0].elements(), 4);
        let inputs = Runtime::make_inputs(rt.spec("gemm").unwrap()).unwrap();
        assert_eq!(inputs[0].len(), 4);
        let err = rt.run("gemm").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "unexpected error: {err}");
        assert!(rt.run("nope").is_err());
    }
}
