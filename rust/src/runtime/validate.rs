//! End-to-end validation: execute every artifact and check numerics
//! against the python oracle's goldens, plus consistency between the
//! functional workload (real einsum shapes) and the analytical model.

use crate::runtime::client::{Runtime, RunOutcome};
use crate::util::error::Result;
use std::path::Path;

/// Outcome of validating one artifact.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub outcome: RunOutcome,
    pub ok: bool,
}

/// Run every artifact in `dir` and validate numerics.
pub fn validate_all(dir: &Path) -> Result<Vec<ValidationReport>> {
    let rt = Runtime::load(dir)?;
    let names: Vec<String> = rt.artifact_names().iter().map(|s| s.to_string()).collect();
    let mut out = Vec::new();
    for name in names {
        let outcome = rt.run(&name)?;
        let ok = outcome.passed();
        out.push(ValidationReport { outcome, ok });
    }
    Ok(out)
}

/// Render validation reports as a table.
pub fn render_reports(reports: &[ValidationReport]) -> String {
    let mut t = crate::util::table::Table::new(&[
        "artifact",
        "elements",
        "output sum",
        "golden rel err",
        "wall µs",
        "status",
    ]);
    for r in reports {
        t.row(&[
            r.outcome.name.clone(),
            r.outcome.elements.to_string(),
            format!("{:.4}", r.outcome.output_sum),
            format!("{:.2e}", r.outcome.sum_rel_err),
            format!("{:.1}", r.outcome.wall_us),
            if r.ok { "PASS".into() } else { "FAIL".into() },
        ]);
    }
    t.render()
}
