//! PJRT runtime: load AOT-compiled artifacts and execute them.
//!
//! The python build step (`make artifacts`) lowers the L2 JAX model
//! (which calls the L1 Pallas kernels) to **HLO text** and writes a
//! `manifest.json` describing each artifact's inputs and golden outputs.
//! This module — the only place Rust touches XLA — loads the text with
//! `HloModuleProto::from_text_file`, compiles it once on the PJRT CPU
//! client, and executes it with deterministically generated inputs,
//! checking the results against the goldens the python oracle recorded.
//!
//! HLO *text* is the interchange format because jax ≥ 0.5 serialises
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Execution requires the `pjrt` cargo feature (and the vendored `xla`
//! crate); without it the [`client::Runtime`] stub still parses
//! manifests but reports the missing backend on `run`/`bench`.

pub mod client;
pub mod serve;
pub mod validate;

pub use client::{Runtime, RunOutcome, TensorSpec};
pub use validate::{validate_all, ValidationReport};

/// Deterministic input pattern shared with `python/compile/aot.py`:
/// `val(i) = ((i mod 251) - 125) / 251`, exactly representable in f32 on
/// both sides.
pub fn input_value(i: u64) -> f32 {
    ((i % 251) as f32 - 125.0) / 251.0
}

/// Per-input index offset so each operand gets distinct data.
pub const INPUT_STRIDE: u64 = 1_000_003;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_matches_python_formula() {
        assert_eq!(input_value(0), -125.0 / 251.0);
        assert_eq!(input_value(125), 0.0);
        assert_eq!(input_value(251), -125.0 / 251.0); // periodic
        assert!(input_value(1000) > -1.0 && input_value(1000) < 1.0);
    }
}
