//! Bench harness (criterion replacement) for `harness = false` benches.
//!
//! Two roles:
//!
//! 1. **Timing**: [`bench_fn`] warm-ups, runs timed iterations until a
//!    wall-clock budget or iteration cap is hit, and reports
//!    median/mean/p95 with outlier-robust statistics.
//! 2. **Figure output**: the paper-reproduction benches mostly *evaluate
//!    models* rather than time code; [`Series`] collects labelled rows
//!    and renders them as aligned text plus machine-readable JSON, so
//!    `cargo bench` regenerates each paper table/figure.

use super::json::{Json, JsonStreamWriter, JsonStyle};
use std::io;
use std::time::{Duration, Instant};

/// Result of timing one benchmark target.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Timing {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters   median {:>12}   mean {:>12}   p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Smoke mode for CI (`HARP_BENCH_SMOKE=1`): every [`bench_fn`] target
/// compiles and runs exactly once, with no statistical sampling — so
/// `cargo bench` doubles as a drift gate without the wall-clock cost.
/// Timing numbers are meaningless in this mode; the value is that a
/// bench that no longer builds or panics breaks CI instead of rotting.
pub fn bench_smoke() -> bool {
    std::env::var("HARP_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Time `f`, printing and returning statistics.
///
/// Runs a short warm-up, then samples until `budget` elapses or
/// `max_iters` samples are collected (min 10 samples; a single
/// un-batched sample under [`bench_smoke`]).
pub fn bench_fn<F: FnMut()>(name: &str, budget: Duration, max_iters: usize, mut f: F) -> Timing {
    // Warm-up: a few calls, also used to size batches for fast functions.
    let warm_start = Instant::now();
    f();
    let single = warm_start.elapsed().as_nanos().max(1) as f64;
    if bench_smoke() {
        // The warm-up call above already exercised the target once.
        let timing = Timing {
            name: name.to_string(),
            iters: 1,
            mean_ns: single,
            median_ns: single,
            p95_ns: single,
            min_ns: single,
        };
        println!("{}", timing.report());
        return timing;
    }
    let batch = if single < 1e4 { (1e5 / single).ceil() as usize } else { 1 }.max(1);

    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < 10 || (start.elapsed() < budget && samples.len() < max_iters) {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
        if samples.len() >= max_iters {
            break;
        }
    }
    // total_cmp: a NaN sample (a zero-duration batch divided away)
    // must not panic the whole bench run.
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let timing = Timing {
        name: name.to_string(),
        iters: n * batch,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        median_ns: samples[n / 2],
        p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        min_ns: samples[0],
    };
    println!("{}", timing.report());
    timing
}

/// A labelled series of (row-label, value) pairs — one paper bar group /
/// table column.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub rows: Vec<(String, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Series {
        Series { name: name.into(), rows: Vec::new() }
    }

    pub fn push(&mut self, label: &str, value: f64) {
        self.rows.push((label.into(), value));
    }

    pub fn get(&self, label: &str) -> Option<f64> {
        self.rows.iter().find(|(l, _)| l == label).map(|(_, v)| *v)
    }
}

/// A figure: several series sharing row labels, rendered like the
/// paper's grouped bar charts.
#[derive(Debug, Clone)]
pub struct Figure {
    pub title: String,
    pub value_label: String,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(title: &str, value_label: &str) -> Figure {
        Figure { title: title.into(), value_label: value_label.into(), series: Vec::new() }
    }

    pub fn add(&mut self, s: Series) {
        self.series.push(s);
    }

    /// All row labels in first-appearance order.
    fn labels(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for s in &self.series {
            for (l, _) in &s.rows {
                if !out.iter().any(|x| x == l) {
                    out.push(l.clone());
                }
            }
        }
        out
    }

    /// Render an aligned text table with a unicode bar per cell,
    /// normalised to the figure max.
    pub fn render(&self) -> String {
        let labels = self.labels();
        let max = self
            .series
            .iter()
            .flat_map(|s| s.rows.iter().map(|(_, v)| *v))
            .fold(0.0f64, f64::max)
            .max(1e-300);
        let lw = labels.iter().map(|l| l.len()).max().unwrap_or(4).max(8);
        let mut out = format!("== {} ==  ({})\n", self.title, self.value_label);
        out.push_str(&format!("{:<lw$}", ""));
        for s in &self.series {
            out.push_str(&format!("  {:>22}", s.name));
        }
        out.push('\n');
        for l in &labels {
            out.push_str(&format!("{l:<lw$}"));
            for s in &self.series {
                match s.get(l) {
                    Some(v) => {
                        let bar_len = ((v / max) * 10.0).round() as usize;
                        let bar: String = "▇".repeat(bar_len.max(if v > 0.0 { 1 } else { 0 }));
                        out.push_str(&format!("  {v:>10.4} {bar:<11}"));
                    }
                    None => out.push_str(&format!("  {:>10} {:<11}", "-", "")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Machine-readable form, written next to the text rendering.
    pub fn to_json(&self) -> Json {
        let series: Vec<Json> = self
            .series
            .iter()
            .map(|s| {
                let rows: Vec<Json> = s
                    .rows
                    .iter()
                    .map(|(l, v)| Json::obj().with("label", l.as_str()).with("value", *v))
                    .collect();
                Json::obj().with("name", s.name.as_str()).with("rows", rows)
            })
            .collect();
        Json::obj()
            .with("title", self.title.as_str())
            .with("value_label", self.value_label.as_str())
            .with("series", series)
    }

    /// Stream the figure document row by row — the exact bytes of
    /// `to_json()` through the same writer, without building the tree:
    /// peak heap is one row, however many rows the sweep produced.
    pub fn write_json<W: io::Write>(&self, w: &mut JsonStreamWriter<W>) -> io::Result<()> {
        w.begin_obj()?;
        w.key("title")?;
        w.str(&self.title)?;
        w.key("value_label")?;
        w.str(&self.value_label)?;
        w.key("series")?;
        w.begin_arr()?;
        for s in &self.series {
            w.begin_obj()?;
            w.key("name")?;
            w.str(&s.name)?;
            w.key("rows")?;
            w.begin_arr()?;
            for (l, v) in &s.rows {
                w.begin_obj()?;
                w.key("label")?;
                w.str(l)?;
                w.key("value")?;
                w.num(*v)?;
                w.end_obj()?;
            }
            w.end_arr()?;
            w.end_obj()?;
        }
        w.end_arr()?;
        w.end_obj()
    }

    /// Print the figure and persist JSON under `target/figures/`,
    /// streaming rows through a `BufWriter` as they serialize.
    pub fn emit(&self, file_stem: &str) {
        println!("{}", self.render());
        let dir = std::path::Path::new("target/figures");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{file_stem}.json"));
        let write = || -> io::Result<()> {
            let out = io::BufWriter::new(std::fs::File::create(&path)?);
            let mut w = JsonStreamWriter::new(out, JsonStyle::Pretty);
            self.write_json(&mut w)?;
            w.finish()?;
            Ok(())
        };
        if let Err(e) = write() {
            eprintln!("warn: could not write {}: {e}", path.display());
        } else {
            println!("[figure json: {}]\n", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_reports_sane_stats() {
        let t = bench_fn("noop-ish", Duration::from_millis(20), 50, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(t.iters >= 10);
        assert!(t.min_ns <= t.median_ns);
        assert!(t.median_ns <= t.p95_ns + 1.0);
    }

    #[test]
    fn figure_renders_all_series() {
        let mut fig = Figure::new("Fig X", "speedup");
        let mut a = Series::new("bw=2048");
        a.push("homogeneous", 1.0);
        a.push("cross-node", 1.4);
        let mut b = Series::new("bw=512");
        b.push("homogeneous", 1.0);
        fig.add(a);
        fig.add(b);
        let text = fig.render();
        assert!(text.contains("homogeneous"));
        assert!(text.contains("bw=2048"));
        assert!(text.contains("cross-node"));
        let j = fig.to_json();
        assert_eq!(j.get("series").unwrap().as_arr().unwrap().len(), 2);
    }

    /// The streamed figure document is byte-for-byte the tree-built one
    /// in both styles — `emit()`'s on-disk artifact cannot drift from
    /// `to_json()`.
    #[test]
    fn streamed_figure_matches_tree_bytes() {
        let mut fig = Figure::new("Fig Y", "energy (pJ)");
        let mut s = Series::new("bw=\"2048\""); // exercises key escaping
        for i in 0..40 {
            s.push(&format!("row-{i}\n"), i as f64 * 0.3 + 0.1);
        }
        fig.add(s);
        fig.add(Series::new("empty"));
        for style in [JsonStyle::Compact, JsonStyle::Pretty] {
            let mut w = JsonStreamWriter::new(Vec::new(), style);
            fig.write_json(&mut w).unwrap();
            let streamed = String::from_utf8(w.finish().unwrap()).unwrap();
            let tree = match style {
                JsonStyle::Compact => fig.to_json().to_string_compact(),
                JsonStyle::Pretty => fig.to_json().to_string_pretty(),
            };
            assert_eq!(streamed, tree, "{style:?}");
        }
    }
}
