//! Substrate utilities implemented from scratch.
//!
//! The build image is offline and only ships the `xla` crate's dependency
//! closure, so the usual ecosystem crates are unavailable. Each submodule
//! replaces one of them with a small, tested implementation:
//!
//! - [`json`] — parser + serializer (replaces `serde_json`), used for
//!   experiment configs, artifact manifests and machine-readable reports;
//!   includes a push-style streaming writer for row-shaped hot paths.
//! - [`binio`] — versioned `harp_bin` binary container (replaces
//!   `bincode`) for the cache spills' fast path, with bounds-checked
//!   slice decoding and offset-bearing errors.
//! - [`error`] — string-backed error with context chaining (replaces
//!   `anyhow`) for the runtime layer's fallible plumbing.
//! - [`cli`] — declarative flag/positional parser (replaces `clap`).
//! - [`rng`] — xorshift64* seeded PRNG (replaces `rand`), used by the
//!   mapper's random sampling so searches are reproducible.
//! - [`prop`] — mini property-testing runner (replaces `proptest`) with
//!   shrinking over integer-vector inputs.
//! - [`benchkit`] — timing/statistics harness for `cargo bench` binaries
//!   (replaces `criterion`).
//! - [`threadpool`] — scoped worker pool with a shared global thread
//!   budget, so nested fan-out (per-config sweeps over per-op searches)
//!   never oversubscribes (replaces `rayon`/`tokio` for this workload).
//! - [`table`] — fixed-width text table renderer for paper-style output.

pub mod benchkit;
pub mod binio;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
pub mod threadpool;
