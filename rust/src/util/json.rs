//! Minimal JSON parser and serializer (serde_json replacement).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are stored as `f64`; integer
//! accessors check for exact representability. Object key order is
//! preserved (insertion order) so emitted reports diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: pairs in insertion order plus an index for O(log n) lookup.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array element lookup.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(items) => items.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Builder: empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder: insert/overwrite a field, returning self (chainable).
    pub fn with(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(pairs) = &mut self {
            if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val.into();
            } else {
                pairs.push((key.to_string(), val.into()));
            }
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Convert an object into a map for bulk access.
    pub fn to_map(&self) -> Option<BTreeMap<String, Json>> {
        match self {
            Json::Obj(pairs) => Some(pairs.iter().cloned().collect()),
            _ => None,
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Reassemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo — 😀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn roundtrips() {
        let doc = r#"{"name":"harp","n":3,"arr":[1,2.5,true,null],"nested":{"k":"v"}}"#;
        let v = Json::parse(doc).unwrap();
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn builder_chains() {
        let v = Json::obj().with("a", 1u64).with("b", "x").with("a", 2u64);
        assert_eq!(v.get("a").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn integer_bounds() {
        assert_eq!(Json::parse("9007199254740992").unwrap().as_u64(), Some(1 << 53));
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    /// Machine-tree documents survive parse → serialize → parse for the
    /// generated tree of EVERY taxonomy point, with capacity shares
    /// populated — the serializer and the topology parser agree on one
    /// schema, including the contention fields.
    #[test]
    fn machine_tree_documents_round_trip_for_every_taxonomy_point() {
        use crate::arch::partition::{generate_topology, HardwareParams};
        use crate::arch::taxonomy::HarpClass;

        for class in HarpClass::all_points() {
            let mut t = generate_topology(&class, &HardwareParams::default()).unwrap();
            // Populate pinned capacity shares on every shared node's
            // users (proportional values, so validation always holds).
            let users = t.node_users();
            for (n, us) in users.iter().enumerate() {
                if us.len() < 2 || t.nodes[n].size_words == u64::MAX {
                    continue;
                }
                for (u, words) in t.booked_capacities(n, us) {
                    t.accels[u].capacity_share = Some(words);
                }
            }
            t.validate().unwrap();

            let text = t.to_json().to_string_pretty();
            let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("{class}: {e}"));
            let back = crate::arch::topology::MachineTopology::from_json(&parsed)
                .unwrap_or_else(|e| panic!("{class}: {e}"));
            // Serializing the re-parsed tree reproduces the document
            // byte-for-byte, and the structure classifies identically.
            assert_eq!(back.to_json().to_string_pretty(), text, "{class}");
            assert_eq!(back.classify().unwrap(), t.classify().unwrap(), "{class}");
            for (a, b) in t.accels.iter().zip(&back.accels) {
                assert_eq!(a.capacity_share, b.capacity_share, "{class}");
                assert_eq!(a.dram_share, b.dram_share, "{class}");
                assert_eq!(a.attach, b.attach, "{class}");
            }
        }
    }

    /// Workload documents survive parse → serialize → parse for EVERY
    /// registered built-in — the serializer and the cascade parser
    /// agree on one schema, byte for byte (the workload-side mirror of
    /// the machine-tree property above).
    #[test]
    fn workload_documents_round_trip_for_every_builtin() {
        use crate::workload::registry;
        use crate::workload::Cascade;

        for (key, spec) in registry::all_builtins() {
            let text = spec.to_json().to_string_pretty();
            let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("{key}: {e}"));
            let back =
                Cascade::from_json(&parsed).unwrap_or_else(|e| panic!("{key}: {e}"));
            // Serializing the re-parsed cascade reproduces the document
            // byte-for-byte, and the structure is preserved exactly.
            assert_eq!(back.to_json().to_string_pretty(), text, "{key}");
            let direct = spec.cascade();
            assert_eq!(back.name, direct.name, "{key}");
            assert_eq!(back.deps, direct.deps, "{key}");
            assert_eq!(back.total_macs(), direct.total_macs(), "{key}");
        }
    }
}
