//! Minimal JSON parser and serializer (serde_json replacement).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are stored as `f64`; integer
//! accessors check for exact representability. Object key order is
//! preserved (insertion order) so emitted reports diff cleanly.
//!
//! Two serialization paths share one set of byte-emission rules:
//! the tree path ([`Json::write_to`], with `to_string_compact` /
//! `to_string_pretty` as thin wrappers) and the push path
//! ([`JsonStreamWriter`]), which lets row-shaped hot emitters stream a
//! document to any [`io::Write`] without ever building the `Json` tree.
//! The byte format is pinned by goldens and parse→serialize fixpoint
//! suites: both paths funnel through the same `emit_*` helpers so they
//! cannot drift apart.

use std::collections::BTreeMap;
use std::fmt;
use std::io;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: pairs in insertion order; `get()` is a linear scan,
    /// which is the right trade for the small row-shaped objects this
    /// codebase emits (no side index to keep coherent).
    Obj(Vec<(String, Json)>),
}

/// Serialization style shared by the tree and streaming writers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JsonStyle {
    /// No whitespace at all.
    Compact,
    /// 2-space indentation, one element per line, `: ` after keys.
    Pretty,
}

impl JsonStyle {
    fn indent(self) -> Option<usize> {
        match self {
            JsonStyle::Compact => None,
            JsonStyle::Pretty => Some(2),
        }
    }
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, scratch: String::new() };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup (linear scan; see `Json::Obj`).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array element lookup.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(items) => items.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Builder: empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder: insert/overwrite a field, returning self (chainable).
    pub fn with(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(pairs) = &mut self {
            if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val.into();
            } else {
                pairs.push((key.to_string(), val.into()));
            }
        }
        self
    }

    /// Serialize compactly. Thin wrapper over [`Json::write_to`]; the
    /// bytes are pinned (goldens, fixpoint suites) and must not move.
    pub fn to_string_compact(&self) -> String {
        self.to_string_styled(JsonStyle::Compact)
    }

    /// Serialize with 2-space indentation. Thin wrapper over
    /// [`Json::write_to`]; the bytes are pinned and must not move.
    pub fn to_string_pretty(&self) -> String {
        self.to_string_styled(JsonStyle::Pretty)
    }

    fn to_string_styled(&self, style: JsonStyle) -> String {
        let mut out = Vec::new();
        self.write_to(&mut out, style).expect("writing to a Vec cannot fail");
        // The writer emits only UTF-8: ASCII structure plus `&str`
        // content and escapes.
        String::from_utf8(out).expect("serializer emits UTF-8")
    }

    /// Serialize into any byte sink without materializing a `String`.
    pub fn write_to<W: io::Write>(&self, out: &mut W, style: JsonStyle) -> io::Result<()> {
        let mut scratch = String::new();
        self.write_value(out, style, 0, &mut scratch)
    }

    fn write_value<W: io::Write>(
        &self,
        out: &mut W,
        style: JsonStyle,
        depth: usize,
        scratch: &mut String,
    ) -> io::Result<()> {
        match self {
            Json::Null => out.write_all(b"null"),
            Json::Bool(b) => out.write_all(if *b { b"true" } else { b"false" }),
            Json::Num(n) => emit_num(out, scratch, *n),
            Json::Str(s) => emit_escaped(out, scratch, s),
            Json::Arr(items) => {
                out.write_all(b"[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.write_all(b",")?;
                    }
                    emit_newline_indent(out, style, depth + 1)?;
                    item.write_value(out, style, depth + 1, scratch)?;
                }
                if !items.is_empty() {
                    emit_newline_indent(out, style, depth)?;
                }
                out.write_all(b"]")
            }
            Json::Obj(pairs) => {
                out.write_all(b"{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.write_all(b",")?;
                    }
                    emit_newline_indent(out, style, depth + 1)?;
                    emit_escaped(out, scratch, k)?;
                    out.write_all(b":")?;
                    if style.indent().is_some() {
                        out.write_all(b" ")?;
                    }
                    v.write_value(out, style, depth + 1, scratch)?;
                }
                if !pairs.is_empty() {
                    emit_newline_indent(out, style, depth)?;
                }
                out.write_all(b"}")
            }
        }
    }

    /// Convert an object into a map for bulk access.
    pub fn to_map(&self) -> Option<BTreeMap<String, Json>> {
        match self {
            Json::Obj(pairs) => Some(pairs.iter().cloned().collect()),
            _ => None,
        }
    }
}

fn emit_newline_indent<W: io::Write>(
    out: &mut W,
    style: JsonStyle,
    depth: usize,
) -> io::Result<()> {
    if let Some(w) = style.indent() {
        const SPACES: [u8; 64] = [b' '; 64];
        out.write_all(b"\n")?;
        let mut n = w * depth;
        while n > 0 {
            let chunk = n.min(SPACES.len());
            out.write_all(&SPACES[..chunk])?;
            n -= chunk;
        }
    }
    Ok(())
}

/// Number formatting rule shared by both writers. Integer-valued f64s
/// inside the exact range print without a fractional part; everything
/// else uses Rust's shortest round-trip `Display`, which preserves f64
/// bits through text.
fn emit_num<W: io::Write>(out: &mut W, scratch: &mut String, n: f64) -> io::Result<()> {
    use std::fmt::Write as _;
    scratch.clear();
    if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        let _ = write!(scratch, "{}", n as i64);
    } else {
        let _ = write!(scratch, "{n}");
    }
    out.write_all(scratch.as_bytes())
}

fn emit_escaped<W: io::Write>(out: &mut W, scratch: &mut String, s: &str) -> io::Result<()> {
    scratch.clear();
    escape_into(scratch, s);
    out.write_all(scratch.as_bytes())
}

fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write as _;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Push-style streaming serializer: emits the exact byte format of
/// [`Json::write_to`] without building a `Json` tree, so row-shaped hot
/// paths (figure tables, sweep rows, cache spills) keep peak heap at
/// one row instead of the whole document.
///
/// The escape/number scratch buffer is reused across values; it grows
/// to the longest single value ever emitted and then stays put —
/// [`JsonStreamWriter::scratch_growths`] counts the growths and serves
/// as the bench suite's peak-allocation proxy.
///
/// Misuse (a bare value inside an object, unbalanced `end_*`,
/// `finish()` mid-document) is a programmer error and panics: the
/// writer is for emitters whose shape is static, not for reflecting
/// untrusted data.
pub struct JsonStreamWriter<W: io::Write> {
    out: W,
    style: JsonStyle,
    /// One frame per open container: (is_object, has_items).
    stack: Vec<(bool, bool)>,
    /// Set between `key()` and the value that consumes it.
    pending_value: bool,
    /// A root value has been emitted (a second one is a misuse panic).
    root_done: bool,
    scratch: String,
    scratch_growths: usize,
}

impl<W: io::Write> JsonStreamWriter<W> {
    pub fn new(out: W, style: JsonStyle) -> Self {
        JsonStreamWriter {
            out,
            style,
            stack: Vec::new(),
            pending_value: false,
            root_done: false,
            scratch: String::new(),
            scratch_growths: 0,
        }
    }

    /// Separator + indent owed before a value in the current context.
    fn value_prefix(&mut self) -> io::Result<()> {
        if self.pending_value {
            // We are the value that follows `key()`; the separator and
            // indent went out with the key.
            self.pending_value = false;
            return Ok(());
        }
        let first = match self.stack.last_mut() {
            None => {
                assert!(!self.root_done, "JsonStreamWriter: second root value");
                self.root_done = true;
                return Ok(());
            }
            Some((is_obj, has_items)) => {
                assert!(!*is_obj, "JsonStreamWriter: value inside an object needs key()");
                let first = !*has_items;
                *has_items = true;
                first
            }
        };
        if !first {
            self.out.write_all(b",")?;
        }
        emit_newline_indent(&mut self.out, self.style, self.stack.len())
    }

    fn escaped(&mut self, s: &str) -> io::Result<()> {
        let cap = self.scratch.capacity();
        self.scratch.clear();
        escape_into(&mut self.scratch, s);
        if self.scratch.capacity() > cap {
            self.scratch_growths += 1;
        }
        self.out.write_all(self.scratch.as_bytes())
    }

    /// Emit an object key; the next call must emit its value.
    pub fn key(&mut self, k: &str) -> io::Result<()> {
        assert!(!self.pending_value, "JsonStreamWriter: key() right after key()");
        let first = match self.stack.last_mut() {
            Some((true, has_items)) => {
                let first = !*has_items;
                *has_items = true;
                first
            }
            _ => panic!("JsonStreamWriter: key() outside an object"),
        };
        if !first {
            self.out.write_all(b",")?;
        }
        emit_newline_indent(&mut self.out, self.style, self.stack.len())?;
        self.escaped(k)?;
        self.out.write_all(b":")?;
        if self.style.indent().is_some() {
            self.out.write_all(b" ")?;
        }
        self.pending_value = true;
        Ok(())
    }

    pub fn begin_obj(&mut self) -> io::Result<()> {
        self.value_prefix()?;
        self.out.write_all(b"{")?;
        self.stack.push((true, false));
        Ok(())
    }

    pub fn end_obj(&mut self) -> io::Result<()> {
        self.end(true, b"}")
    }

    pub fn begin_arr(&mut self) -> io::Result<()> {
        self.value_prefix()?;
        self.out.write_all(b"[")?;
        self.stack.push((false, false));
        Ok(())
    }

    pub fn end_arr(&mut self) -> io::Result<()> {
        self.end(false, b"]")
    }

    fn end(&mut self, obj: bool, closer: &'static [u8]) -> io::Result<()> {
        assert!(!self.pending_value, "JsonStreamWriter: key() without a value");
        let (is_obj, has_items) =
            self.stack.pop().expect("JsonStreamWriter: unbalanced end");
        assert_eq!(is_obj, obj, "JsonStreamWriter: mismatched container end");
        if has_items {
            emit_newline_indent(&mut self.out, self.style, self.stack.len())?;
        }
        self.out.write_all(closer)
    }

    pub fn null(&mut self) -> io::Result<()> {
        self.value_prefix()?;
        self.out.write_all(b"null")
    }

    pub fn bool(&mut self, b: bool) -> io::Result<()> {
        self.value_prefix()?;
        self.out.write_all(if b { b"true" } else { b"false" })
    }

    pub fn num(&mut self, n: f64) -> io::Result<()> {
        self.value_prefix()?;
        let cap = self.scratch.capacity();
        emit_num(&mut self.out, &mut self.scratch, n)?;
        if self.scratch.capacity() > cap {
            self.scratch_growths += 1;
        }
        Ok(())
    }

    pub fn str(&mut self, s: &str) -> io::Result<()> {
        self.value_prefix()?;
        self.escaped(s)
    }

    /// Emit a pre-built subtree at the current position. Lets callers
    /// stream the document skeleton while still using row-sized `Json`
    /// trees where convenient.
    pub fn value(&mut self, v: &Json) -> io::Result<()> {
        self.value_prefix()?;
        let depth = self.stack.len();
        v.write_value(&mut self.out, self.style, depth, &mut self.scratch)
    }

    /// How many times the reused value buffer had to grow. A streaming
    /// emitter settles to a small constant once the longest value has
    /// been seen; the bench suite asserts this stays bounded while the
    /// row count scales.
    pub fn scratch_growths(&self) -> usize {
        self.scratch_growths
    }

    /// Assert the document is complete, flush, and return the sink.
    pub fn finish(mut self) -> io::Result<W> {
        assert!(
            self.stack.is_empty() && !self.pending_value && self.root_done,
            "JsonStreamWriter: finish() on an incomplete document"
        );
        self.out.flush()?;
        Ok(self.out)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Reused string-decode buffer; each parsed string is copied out of
    /// it with one exact-size allocation instead of growing a fresh
    /// `String` per string.
    scratch: String,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        let out = self.string_into(&mut scratch).map(|()| scratch.as_str().to_owned());
        self.scratch = scratch;
        out
    }

    fn string_into(&mut self, out: &mut String) -> Result<(), JsonError> {
        self.expect(b'"')?;
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(()),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Reassemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo — 😀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn roundtrips() {
        let doc = r#"{"name":"harp","n":3,"arr":[1,2.5,true,null],"nested":{"k":"v"}}"#;
        let v = Json::parse(doc).unwrap();
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn builder_chains() {
        let v = Json::obj().with("a", 1u64).with("b", "x").with("a", 2u64);
        assert_eq!(v.get("a").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn integer_bounds() {
        assert_eq!(Json::parse("9007199254740992").unwrap().as_u64(), Some(1 << 53));
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    /// The serialized byte format is pinned absolutely here — not just
    /// as a fixpoint — so the `write_to` refactor (and any future one)
    /// cannot move the bytes that goldens and disk-spilled caches
    /// depend on: separators, indent shape, `: ` spacing, empty
    /// containers, escapes, and the integer/float number rule.
    #[test]
    fn serialized_bytes_are_pinned_for_both_styles() {
        let doc = Json::obj()
            .with("a", 1u64)
            .with("b", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)]))
            .with("c", Json::obj())
            .with("d", Json::obj().with("k", "v"))
            .with("e", Json::Arr(vec![]));
        assert_eq!(
            doc.to_string_compact(),
            r#"{"a":1,"b":[1,2.5],"c":{},"d":{"k":"v"},"e":[]}"#
        );
        assert_eq!(
            doc.to_string_pretty(),
            "{\n  \"a\": 1,\n  \"b\": [\n    1,\n    2.5\n  ],\n  \"c\": {},\n  \
             \"d\": {\n    \"k\": \"v\"\n  },\n  \"e\": []\n}"
        );

        assert_eq!(
            Json::Str("a\"b\\c\nd\r\te\u{1}é😀".into()).to_string_compact(),
            "\"a\\\"b\\\\c\\nd\\r\\te\\u0001é😀\""
        );
        for (n, s) in [
            (42.0, "42"),
            (-7.0, "-7"),
            (2.5, "2.5"),
            (0.1, "0.1"),
            (9007199254740992.0, "9007199254740992"),
        ] {
            assert_eq!(Json::Num(n).to_string_compact(), s);
        }
    }

    /// `write_to` and the `to_string_*` wrappers emit identical bytes
    /// for every real front-end document the repo generates (machine
    /// trees of all 16 taxonomy points, all registered workloads) —
    /// and streaming the same tree through `JsonStreamWriter::value`
    /// matches too, in both styles.
    #[test]
    fn write_to_and_stream_value_match_strings_for_real_documents() {
        use crate::arch::partition::{generate_topology, HardwareParams};
        use crate::arch::taxonomy::HarpClass;
        use crate::workload::registry;

        let mut docs: Vec<(String, Json)> = Vec::new();
        for class in HarpClass::all_points() {
            let t = generate_topology(&class, &HardwareParams::default()).unwrap();
            docs.push((format!("{class}"), t.to_json()));
        }
        for (key, spec) in registry::all_builtins() {
            docs.push((key.to_string(), spec.to_json()));
        }

        for (tag, doc) in &docs {
            for style in [JsonStyle::Compact, JsonStyle::Pretty] {
                let expect = match style {
                    JsonStyle::Compact => doc.to_string_compact(),
                    JsonStyle::Pretty => doc.to_string_pretty(),
                };
                let mut direct = Vec::new();
                doc.write_to(&mut direct, style).unwrap();
                assert_eq!(direct, expect.as_bytes(), "{tag} ({style:?}): write_to");

                let mut w = JsonStreamWriter::new(Vec::new(), style);
                w.value(doc).unwrap();
                let streamed = w.finish().unwrap();
                assert_eq!(streamed, expect.as_bytes(), "{tag} ({style:?}): stream");

                // Nested: a subtree emitted mid-document indents from
                // its container's depth, exactly like the tree writer.
                let wrapped = Json::obj().with("row", doc.clone());
                let mut w = JsonStreamWriter::new(Vec::new(), style);
                w.begin_obj().unwrap();
                w.key("row").unwrap();
                w.value(doc).unwrap();
                w.end_obj().unwrap();
                let streamed = w.finish().unwrap();
                let expect = match style {
                    JsonStyle::Compact => wrapped.to_string_compact(),
                    JsonStyle::Pretty => wrapped.to_string_pretty(),
                };
                assert_eq!(streamed, expect.as_bytes(), "{tag} ({style:?}): nested");
            }
        }
    }

    /// Manually driving the stream writer — keys, scalars, nested
    /// containers, empty containers, escapes — reproduces the tree
    /// writer's bytes exactly in both styles.
    #[test]
    fn stream_writer_matches_tree_writer_bytes() {
        let tree = Json::obj()
            .with("name", "h\"arp\n")
            .with("n", 3u64)
            .with("f", 2.5)
            .with("flag", true)
            .with("none", Json::Null)
            .with("empty_obj", Json::obj())
            .with("empty_arr", Json::Arr(vec![]))
            .with(
                "rows",
                Json::Arr(vec![
                    Json::obj().with("label", "a").with("value", 1u64),
                    Json::obj().with("label", "b").with("value", 0.5),
                ]),
            );
        for style in [JsonStyle::Compact, JsonStyle::Pretty] {
            let mut w = JsonStreamWriter::new(Vec::new(), style);
            w.begin_obj().unwrap();
            w.key("name").unwrap();
            w.str("h\"arp\n").unwrap();
            w.key("n").unwrap();
            w.num(3.0).unwrap();
            w.key("f").unwrap();
            w.num(2.5).unwrap();
            w.key("flag").unwrap();
            w.bool(true).unwrap();
            w.key("none").unwrap();
            w.null().unwrap();
            w.key("empty_obj").unwrap();
            w.begin_obj().unwrap();
            w.end_obj().unwrap();
            w.key("empty_arr").unwrap();
            w.begin_arr().unwrap();
            w.end_arr().unwrap();
            w.key("rows").unwrap();
            w.begin_arr().unwrap();
            for (label, value) in [("a", 1.0), ("b", 0.5)] {
                w.begin_obj().unwrap();
                w.key("label").unwrap();
                w.str(label).unwrap();
                w.key("value").unwrap();
                w.num(value).unwrap();
                w.end_obj().unwrap();
            }
            w.end_arr().unwrap();
            w.end_obj().unwrap();
            let bytes = w.finish().unwrap();
            let expect = match style {
                JsonStyle::Compact => tree.to_string_compact(),
                JsonStyle::Pretty => tree.to_string_pretty(),
            };
            assert_eq!(
                String::from_utf8(bytes).unwrap(),
                expect,
                "{style:?}: stream and tree writers drifted"
            );
        }
    }

    /// The reused scratch buffer stops growing once the longest value
    /// has been seen: emitting the same row shape thousands of times
    /// costs a bounded number of buffer growths, not one per row.
    #[test]
    fn stream_writer_scratch_growths_are_bounded() {
        let mut w = JsonStreamWriter::new(Vec::new(), JsonStyle::Compact);
        w.begin_arr().unwrap();
        for i in 0..5000 {
            w.begin_obj().unwrap();
            w.key("label").unwrap();
            w.str(&format!("point-{i}")).unwrap();
            w.key("value").unwrap();
            w.num(i as f64 * 0.125).unwrap();
            w.end_obj().unwrap();
        }
        w.end_arr().unwrap();
        let growths = w.scratch_growths();
        assert!(growths <= 8, "scratch buffer is not being reused: {growths} growths");
        w.finish().unwrap();
    }

    /// Machine-tree documents survive parse → serialize → parse for the
    /// generated tree of EVERY taxonomy point, with capacity shares
    /// populated — the serializer and the topology parser agree on one
    /// schema, including the contention fields.
    #[test]
    fn machine_tree_documents_round_trip_for_every_taxonomy_point() {
        use crate::arch::partition::{generate_topology, HardwareParams};
        use crate::arch::taxonomy::HarpClass;

        for class in HarpClass::all_points() {
            let mut t = generate_topology(&class, &HardwareParams::default()).unwrap();
            // Populate pinned capacity shares on every shared node's
            // users (proportional values, so validation always holds).
            let users = t.node_users();
            for (n, us) in users.iter().enumerate() {
                if us.len() < 2 || t.nodes[n].size_words == u64::MAX {
                    continue;
                }
                for (u, words) in t.booked_capacities(n, us) {
                    t.accels[u].capacity_share = Some(words);
                }
            }
            t.validate().unwrap();

            let text = t.to_json().to_string_pretty();
            let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("{class}: {e}"));
            let back = crate::arch::topology::MachineTopology::from_json(&parsed)
                .unwrap_or_else(|e| panic!("{class}: {e}"));
            // Serializing the re-parsed tree reproduces the document
            // byte-for-byte, and the structure classifies identically.
            assert_eq!(back.to_json().to_string_pretty(), text, "{class}");
            assert_eq!(back.classify().unwrap(), t.classify().unwrap(), "{class}");
            for (a, b) in t.accels.iter().zip(&back.accels) {
                assert_eq!(a.capacity_share, b.capacity_share, "{class}");
                assert_eq!(a.dram_share, b.dram_share, "{class}");
                assert_eq!(a.attach, b.attach, "{class}");
            }
        }
    }

    /// Workload documents survive parse → serialize → parse for EVERY
    /// registered built-in — the serializer and the cascade parser
    /// agree on one schema, byte for byte (the workload-side mirror of
    /// the machine-tree property above).
    #[test]
    fn workload_documents_round_trip_for_every_builtin() {
        use crate::workload::registry;
        use crate::workload::Cascade;

        for (key, spec) in registry::all_builtins() {
            let text = spec.to_json().to_string_pretty();
            let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("{key}: {e}"));
            let back =
                Cascade::from_json(&parsed).unwrap_or_else(|e| panic!("{key}: {e}"));
            // Serializing the re-parsed cascade reproduces the document
            // byte-for-byte, and the structure is preserved exactly.
            assert_eq!(back.to_json().to_string_pretty(), text, "{key}");
            let direct = spec.cascade();
            assert_eq!(back.name, direct.name, "{key}");
            assert_eq!(back.deps, direct.deps, "{key}");
            assert_eq!(back.total_macs(), direct.total_macs(), "{key}");
        }
    }
}
