//! Declarative command-line parsing (clap replacement).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, and
//! auto-generated `--help`. Used by `harp` (the main binary), the
//! examples and the bench binaries.

use std::collections::BTreeMap;
use std::fmt;

/// Argument specification for one command.
#[derive(Debug, Default)]
pub struct ArgSpec {
    name: String,
    about: String,
    options: Vec<OptDef>,
    positionals: Vec<PosDef>,
}

#[derive(Debug)]
struct OptDef {
    key: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

#[derive(Debug)]
struct PosDef {
    key: String,
    help: String,
    required: bool,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl ArgSpec {
    pub fn new(name: &str, about: &str) -> ArgSpec {
        ArgSpec { name: name.into(), about: about.into(), ..Default::default() }
    }

    /// A boolean `--flag`.
    pub fn flag(mut self, key: &str, help: &str) -> Self {
        self.options.push(OptDef {
            key: key.into(),
            help: help.into(),
            takes_value: false,
            default: None,
        });
        self
    }

    /// A `--key <value>` option with optional default.
    pub fn opt(mut self, key: &str, default: Option<&str>, help: &str) -> Self {
        self.options.push(OptDef {
            key: key.into(),
            help: help.into(),
            takes_value: true,
            default: default.map(|s| s.to_string()),
        });
        self
    }

    /// A positional argument.
    pub fn pos(mut self, key: &str, required: bool, help: &str) -> Self {
        self.positionals.push(PosDef { key: key.into(), help: help.into(), required });
        self
    }

    /// Render the help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for p in &self.positionals {
            if p.required {
                s.push_str(&format!(" <{}>", p.key));
            } else {
                s.push_str(&format!(" [{}]", p.key));
            }
        }
        if !self.options.is_empty() {
            s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
            for o in &self.options {
                let head = if o.takes_value {
                    format!("  --{} <value>", o.key)
                } else {
                    format!("  --{}", o.key)
                };
                let def = match &o.default {
                    Some(d) => format!(" [default: {d}]"),
                    None => String::new(),
                };
                s.push_str(&format!("{head:<28}{}{}\n", o.help, def));
            }
        } else {
            s.push('\n');
        }
        for p in &self.positionals {
            s.push_str(&format!("  {:<26}{}\n", format!("<{}>", p.key), p.help));
        }
        s
    }

    /// Parse a raw argv slice (not including the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut out = Args::default();
        for o in &self.options {
            if let Some(d) = &o.default {
                out.values.insert(o.key.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.help()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let def = self
                    .options
                    .iter()
                    .find(|o| o.key == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}\n\n{}", self.help())))?;
                if def.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{key} requires a value")))?
                        }
                    };
                    out.values.insert(key, val);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{key} does not take a value")));
                    }
                    out.flags.push(key);
                }
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        let required = self.positionals.iter().filter(|p| p.required).count();
        if out.positionals.len() < required {
            return Err(CliError(format!(
                "missing required positional argument(s)\n\n{}",
                self.help()
            )));
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, CliError> {
        let raw = self.get(key).ok_or_else(|| CliError(format!("missing --{key}")))?;
        raw.parse().map_err(|_| CliError(format!("--{key}: expected integer, got '{raw}'")))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, CliError> {
        let raw = self.get(key).ok_or_else(|| CliError(format!("missing --{key}")))?;
        raw.parse().map_err(|_| CliError(format!("--{key}: expected number, got '{raw}'")))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("t", "test")
            .opt("bw", Some("2048"), "bandwidth")
            .opt("workload", None, "workload name")
            .flag("verbose", "chatty")
            .pos("config", false, "config path")
    }

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("bw").unwrap(), 2048);
        assert!(a.get("workload").is_none());
    }

    #[test]
    fn parses_forms() {
        let a = spec()
            .parse(&argv(&["--bw", "512", "--workload=gpt3", "--verbose", "cfg.json"]))
            .unwrap();
        assert_eq!(a.get_usize("bw").unwrap(), 512);
        assert_eq!(a.get("workload"), Some("gpt3"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(0), Some("cfg.json"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(spec().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn help_is_error_carrier() {
        let err = spec().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.0.contains("USAGE"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(spec().parse(&argv(&["--bw"])).is_err());
    }
}
