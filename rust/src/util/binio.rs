//! Binary cache-spill container (`harp_bin`): a compact, versioned,
//! little-endian format for the eval-cache and mapping-cache spills.
//!
//! Layout of every spill: the 8-byte magic `harp_bin`, a length-prefixed
//! container-kind string (`"mapcache"`, `"evalcache"`), a `u32`
//! container-format revision — then kind-specific payload. All integers
//! are little-endian; `f64`s are written as their raw IEEE-754 bit
//! patterns (`to_bits`), so round trips are bit-exact by construction —
//! the same exactness contract the JSON spills get from shortest
//! round-trip `Display`.
//!
//! Reading is slice-based and fully bounds-checked: every decode failure
//! is a distinct [`BinError`] naming the offset and what was being read.
//! Truncation, doctored magic/kind/version bytes, implausible lengths,
//! and trailing garbage all error loudly — never a panic, never a quiet
//! partial load.

use std::fmt;
use std::io::{self, Write};
use std::path::Path;

/// First 8 bytes of every binary spill.
pub const HARP_BIN_MAGIC: [u8; 8] = *b"harp_bin";

/// On-disk format of a cache spill: JSON is the debug/interchange path,
/// binary is the fast path for million-point sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheFormat {
    Json,
    Binary,
}

impl CacheFormat {
    pub fn name(self) -> &'static str {
        match self {
            CacheFormat::Json => "json",
            CacheFormat::Binary => "binary",
        }
    }

    /// Parse the `--cache-format` / `"cache_format"` knob value.
    pub fn parse(s: &str) -> Result<CacheFormat, String> {
        match s {
            "json" => Ok(CacheFormat::Json),
            "binary" | "bin" => Ok(CacheFormat::Binary),
            other => Err(format!(
                "unknown cache format '{other}' (expected \"json\" or \"binary\")"
            )),
        }
    }

    /// Format implied by a spill path's extension; `None` when the
    /// extension says nothing either way.
    pub fn implied_by_extension(path: &Path) -> Option<CacheFormat> {
        match path.extension().and_then(|e| e.to_str()) {
            Some("bin") | Some("harpbin") => Some(CacheFormat::Binary),
            Some("json") => Some(CacheFormat::Json),
            _ => None,
        }
    }

    /// Resolve the format for a spill path against an optional explicit
    /// knob. An explicit knob that contradicts the extension is a loud
    /// error — a `.bin` file quietly written as JSON (or vice versa)
    /// would poison every later run that trusts the extension. With no
    /// knob the extension decides, defaulting to JSON (the historical
    /// behaviour: every pre-existing spill is JSON).
    pub fn resolve(path: &Path, knob: Option<CacheFormat>) -> Result<CacheFormat, String> {
        let implied = CacheFormat::implied_by_extension(path);
        match (knob, implied) {
            (Some(k), Some(i)) if k != i => Err(format!(
                "cache format conflict for {}: the knob says {} but the file \
                 extension implies {} — rename the file or drop the knob",
                path.display(),
                k.name(),
                i.name()
            )),
            (Some(k), _) => Ok(k),
            (None, Some(i)) => Ok(i),
            (None, None) => Ok(CacheFormat::Json),
        }
    }
}

/// Decode failure: every malformed-input mode gets its own variant with
/// an offset-bearing message, so any two different corruptions read
/// differently on stderr.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BinError {
    /// The first 8 bytes are not `harp_bin`.
    BadMagic { found: Vec<u8> },
    /// The container kind string is not the expected one (e.g. an
    /// eval-cache spill handed to the mapping cache).
    WrongKind { found: String, expected: &'static str },
    /// The container format revision is one this build cannot read.
    UnsupportedFormat { found: u32, expected: u32 },
    /// The file ends before a field does.
    Truncated { offset: usize, needed: usize, available: usize, what: &'static str },
    /// A field decoded to something impossible (bad UTF-8, implausible
    /// length, unknown enum tag, …).
    Malformed { offset: usize, detail: String },
    /// Bytes remain after the document — a concatenation or overwrite
    /// accident, not a valid spill.
    TrailingBytes { offset: usize, remaining: usize },
}

impl fmt::Display for BinError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BinError::BadMagic { found } => {
                write!(f, "bad magic: expected \"harp_bin\", found {found:02x?}")
            }
            BinError::WrongKind { found, expected } => write!(
                f,
                "wrong container kind: expected \"{expected}\", found \"{found}\""
            ),
            BinError::UnsupportedFormat { found, expected } => write!(
                f,
                "unsupported container format {found} (this build reads {expected})"
            ),
            BinError::Truncated { offset, needed, available, what } => write!(
                f,
                "truncated: need {needed} byte(s) for {what} at offset {offset}, \
                 only {available} left"
            ),
            BinError::Malformed { offset, detail } => {
                write!(f, "malformed at offset {offset}: {detail}")
            }
            BinError::TrailingBytes { offset, remaining } => write!(
                f,
                "{remaining} trailing byte(s) after the document (offset {offset})"
            ),
        }
    }
}

impl std::error::Error for BinError {}

/// Streaming binary encoder over any byte sink.
pub struct BinWriter<W: Write> {
    out: W,
}

impl<W: Write> BinWriter<W> {
    pub fn new(out: W) -> Self {
        BinWriter { out }
    }

    /// Magic + container kind + container-format revision.
    pub fn header(&mut self, kind: &str, format: u32) -> io::Result<()> {
        self.out.write_all(&HARP_BIN_MAGIC)?;
        self.str(kind)?;
        self.u32(format)
    }

    pub fn u8(&mut self, v: u8) -> io::Result<()> {
        self.out.write_all(&[v])
    }

    pub fn u32(&mut self, v: u32) -> io::Result<()> {
        self.out.write_all(&v.to_le_bytes())
    }

    pub fn u64(&mut self, v: u64) -> io::Result<()> {
        self.out.write_all(&v.to_le_bytes())
    }

    /// Raw IEEE-754 bits — the bit-exactness contract.
    pub fn f64(&mut self, v: f64) -> io::Result<()> {
        self.u64(v.to_bits())
    }

    /// Length-prefixed UTF-8.
    pub fn str(&mut self, s: &str) -> io::Result<()> {
        self.u32(s.len() as u32)?;
        self.out.write_all(s.as_bytes())
    }

    /// Flush and hand the sink back.
    pub fn finish(mut self) -> io::Result<W> {
        self.out.flush()?;
        Ok(self.out)
    }
}

/// Bounds-checked binary decoder over an in-memory spill.
pub struct BinReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BinReader { bytes, pos: 0 }
    }

    pub fn offset(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], BinError> {
        let available = self.bytes.len() - self.pos;
        if n > available {
            return Err(BinError::Truncated { offset: self.pos, needed: n, available, what });
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Validate magic, container kind, and format revision — each
    /// mismatch is its own loud error.
    pub fn header(&mut self, kind: &'static str, format: u32) -> Result<(), BinError> {
        let magic = self.take(HARP_BIN_MAGIC.len(), "magic")?;
        if magic != HARP_BIN_MAGIC {
            return Err(BinError::BadMagic { found: magic.to_vec() });
        }
        let found_kind = self.str("container kind")?;
        if found_kind != kind {
            return Err(BinError::WrongKind { found: found_kind, expected: kind });
        }
        let found_format = self.u32("container format")?;
        if found_format != format {
            return Err(BinError::UnsupportedFormat { found: found_format, expected: format });
        }
        Ok(())
    }

    pub fn u8(&mut self, what: &'static str) -> Result<u8, BinError> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u32(&mut self, what: &'static str) -> Result<u32, BinError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &'static str) -> Result<u64, BinError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn f64(&mut self, what: &'static str) -> Result<f64, BinError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    pub fn str(&mut self, what: &'static str) -> Result<String, BinError> {
        let offset = self.pos;
        let len = self.u32(what)? as usize;
        let bytes = self.take(len, what)?;
        std::str::from_utf8(bytes)
            .map(|s| s.to_owned())
            .map_err(|_| BinError::Malformed { offset, detail: format!("{what} is not UTF-8") })
    }

    /// Read a sequence length and sanity-check it against the bytes
    /// that remain (each element needs at least `min_elem_bytes`), so a
    /// doctored count can never drive a huge pre-allocation or a long
    /// walk off the end.
    pub fn seq_len(
        &mut self,
        min_elem_bytes: usize,
        what: &'static str,
    ) -> Result<usize, BinError> {
        let offset = self.pos;
        let n = self.u64(what)?;
        let remaining = (self.bytes.len() - self.pos) as u64;
        let plausible = match n.checked_mul(min_elem_bytes.max(1) as u64) {
            Some(need) => need <= remaining,
            None => false,
        };
        if !plausible {
            return Err(BinError::Malformed {
                offset,
                detail: format!(
                    "implausible {what} count {n} with {remaining} byte(s) left"
                ),
            });
        }
        Ok(n as usize)
    }

    /// Assert the document consumed every byte.
    pub fn finish(&self) -> Result<(), BinError> {
        let remaining = self.bytes.len() - self.pos;
        if remaining != 0 {
            return Err(BinError::TrailingBytes { offset: self.pos, remaining });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn sample() -> Vec<u8> {
        let mut w = BinWriter::new(Vec::new());
        w.header("testkind", 3).unwrap();
        w.u64(42).unwrap();
        w.str("héllo").unwrap();
        w.f64(0.1 + 0.2).unwrap();
        w.u8(7).unwrap();
        w.finish().unwrap()
    }

    #[test]
    fn round_trips_bit_exactly() {
        let bytes = sample();
        let mut r = BinReader::new(&bytes);
        r.header("testkind", 3).unwrap();
        assert_eq!(r.u64("n").unwrap(), 42);
        assert_eq!(r.str("s").unwrap(), "héllo");
        assert_eq!(r.f64("f").unwrap().to_bits(), (0.1f64 + 0.2).to_bits());
        assert_eq!(r.u8("b").unwrap(), 7);
        r.finish().unwrap();
    }

    #[test]
    fn every_truncation_is_a_distinct_loud_error() {
        let bytes = sample();
        let mut seen = std::collections::HashSet::new();
        for cut in 0..bytes.len() {
            let mut r = BinReader::new(&bytes[..cut]);
            let err = (|| -> Result<(), BinError> {
                r.header("testkind", 3)?;
                r.u64("n")?;
                r.str("s")?;
                r.f64("f")?;
                r.u8("b")?;
                r.finish()
            })()
            .unwrap_err();
            let msg = err.to_string();
            assert!(!msg.is_empty());
            // Distinct per cut: the message carries offset + remaining
            // byte counts, so no two prefixes read the same.
            assert!(seen.insert(msg.clone()), "cut {cut}: duplicate message {msg}");
        }
    }

    #[test]
    fn doctored_headers_reject_distinctly() {
        let bytes = sample();

        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xff;
        let err = BinReader::new(&bad_magic).header("testkind", 3).unwrap_err();
        assert!(matches!(err, BinError::BadMagic { .. }), "{err}");

        let mut r = BinReader::new(&bytes);
        let err = r.header("otherkind", 3).unwrap_err();
        assert!(matches!(err, BinError::WrongKind { .. }), "{err}");

        let mut r = BinReader::new(&bytes);
        let err = r.header("testkind", 4).unwrap_err();
        assert!(matches!(err, BinError::UnsupportedFormat { .. }), "{err}");

        let mut extended = bytes.clone();
        extended.push(0);
        let mut r = BinReader::new(&extended);
        r.header("testkind", 3).unwrap();
        r.u64("n").unwrap();
        r.str("s").unwrap();
        r.f64("f").unwrap();
        r.u8("b").unwrap();
        let err = r.finish().unwrap_err();
        assert!(matches!(err, BinError::TrailingBytes { .. }), "{err}");
    }

    #[test]
    fn implausible_sequence_counts_are_malformed_not_allocated() {
        let mut w = BinWriter::new(Vec::new());
        w.u64(u64::MAX).unwrap();
        let bytes = w.finish().unwrap();
        let mut r = BinReader::new(&bytes);
        let err = r.seq_len(16, "entries").unwrap_err();
        assert!(matches!(err, BinError::Malformed { .. }), "{err}");
        assert!(err.to_string().contains("implausible"));
    }

    #[test]
    fn format_resolution_and_conflicts() {
        let bin = PathBuf::from("cache.bin");
        let json = PathBuf::from("cache.json");
        let other = PathBuf::from("cache.spill");
        assert_eq!(CacheFormat::resolve(&bin, None), Ok(CacheFormat::Binary));
        assert_eq!(CacheFormat::resolve(&json, None), Ok(CacheFormat::Json));
        assert_eq!(CacheFormat::resolve(&other, None), Ok(CacheFormat::Json));
        assert_eq!(
            CacheFormat::resolve(&other, Some(CacheFormat::Binary)),
            Ok(CacheFormat::Binary)
        );
        let err = CacheFormat::resolve(&bin, Some(CacheFormat::Json)).unwrap_err();
        assert!(err.contains("conflict"), "{err}");
        let err = CacheFormat::resolve(&json, Some(CacheFormat::Binary)).unwrap_err();
        assert!(err.contains("conflict"), "{err}");
        assert!(CacheFormat::parse("bogus").is_err());
        assert_eq!(CacheFormat::parse("binary"), Ok(CacheFormat::Binary));
    }
}
