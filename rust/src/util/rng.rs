//! Seeded xorshift64* PRNG (rand replacement).
//!
//! Used by the mapper's random sampling; a fixed seed makes every search
//! — and therefore every figure reproduction — deterministic.

/// xorshift64* generator. Not cryptographic; statistical quality is
/// sufficient for map-space sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. A zero seed is remapped (xorshift requires a
    /// non-zero state).
    pub fn new(seed: u64) -> Rng {
        Rng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // Rejection-free bounded sampling via 128-bit multiply-shift.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.next_below(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below(i + 1);
            items.swap(i, j);
        }
    }

    /// Fork a child generator (e.g. one per thread) with decorrelated
    /// state.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn bounded_in_range() {
        let mut r = Rng::new(42);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(42);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
