//! Scoped parallel map with a shared global thread budget
//! (rayon/tokio replacement).
//!
//! The mapper evaluates thousands of independent candidate mappings per
//! operation, and the coordinator sweeps many (workload, machine,
//! bandwidth) configurations per figure; [`parallel_map`] fans a work
//! range out over OS threads with an atomic work-stealing cursor and
//! collects results in order.
//!
//! ## The shared pool budget
//!
//! Both layers fan out — per-config sweeps call `parallel_map`, and each
//! evaluation's per-op searches call it again underneath. A process-wide
//! budget of *extra* worker threads (the submitting thread always
//! participates and is not counted) keeps the total number of live
//! workers at the configured parallelism no matter how calls nest: a
//! nested call whose lease comes back empty simply runs inline on its
//! caller. Leases are returned when a call finishes, so sibling calls
//! re-acquire workers as they free up.
//!
//! Results are **independent of the worker count**: the cursor only
//! distributes *work*, every result lands in its index's slot, and
//! reductions run in index order — so `HARP_THREADS=1` and
//! `HARP_THREADS=16` produce bit-identical output.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Number of worker threads to use (respects `HARP_THREADS`, defaults to
/// available parallelism, capped at 16).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HARP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// The global budget of EXTRA workers (total parallelism − 1, since the
/// submitting thread always works too). Initialised lazily from
/// [`default_threads`].
fn extra_budget() -> &'static AtomicUsize {
    static BUDGET: OnceLock<AtomicUsize> = OnceLock::new();
    BUDGET.get_or_init(|| AtomicUsize::new(default_threads().saturating_sub(1)))
}

/// Override the global worker budget (the CLI's `--threads`). The total
/// number of concurrently live threads across all nested `parallel_map`
/// calls becomes `n` (the calling thread counts as one). Call before
/// spawning parallel work: outstanding leases are not rebalanced.
pub fn set_global_threads(n: usize) {
    extra_budget().store(n.max(1) - 1, Ordering::SeqCst);
}

/// Extra workers currently available to new `parallel_map` calls
/// (diagnostic; the submitting thread is always additional to this).
pub fn available_workers() -> usize {
    extra_budget().load(Ordering::Acquire)
}

/// A lease of extra workers from the global budget, returned on drop
/// (including unwinds, so a panicking work item cannot leak budget).
struct Lease(usize);

impl Lease {
    fn take(want: usize) -> Lease {
        let b = extra_budget();
        let mut cur = b.load(Ordering::Acquire);
        loop {
            let take = want.min(cur);
            if take == 0 {
                return Lease(0);
            }
            match b.compare_exchange_weak(cur, cur - take, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return Lease(take),
                Err(now) => cur = now,
            }
        }
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if self.0 > 0 {
            extra_budget().fetch_add(self.0, Ordering::AcqRel);
        }
    }
}

/// Apply `f` to every index in `0..n` on up to `threads` workers
/// (bounded by the shared global budget; the caller participates);
/// returns the results ordered by index. `f` must be `Sync` (called
/// concurrently). Nested calls are safe: when the budget is exhausted
/// they degrade to an inline serial loop instead of oversubscribing.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let lease = if threads > 1 { Lease::take(threads - 1) } else { Lease(0) };
    if lease.0 == 0 {
        return (0..n).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let work = || loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        let out = f(i);
        *slots[i].lock().unwrap() = Some(out);
    };
    std::thread::scope(|scope| {
        for _ in 0..lease.0 {
            scope.spawn(&work);
        }
        work();
    });
    drop(lease);
    slots.into_iter().map(|s| s.into_inner().unwrap().expect("worker completed")).collect()
}

/// Parallel fold: map each index then reduce with `combine`, seeded by
/// `init`. Reduction order is deterministic (index order), so the result
/// is identical for any worker count.
pub fn parallel_fold<T, A, F, C>(n: usize, threads: usize, f: F, init: A, combine: C) -> A
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    C: Fn(A, T) -> A,
{
    parallel_map(n, threads, f).into_iter().fold(init, combine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(1000, 8, |i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn fold_matches_serial() {
        let total = parallel_fold(500, 4, |i| i as u64, 0u64, |a, b| a + b);
        assert_eq!(total, (0..500u64).sum());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(parallel_map(10, 1, |i| i), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn nested_calls_complete_without_deadlock() {
        let out = parallel_map(6, 4, |i| {
            parallel_map(10, 4, move |j| i * 10 + j).into_iter().sum::<usize>()
        });
        let expect: Vec<usize> =
            (0..6).map(|i| (0..10).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn deeply_nested_degrades_to_serial() {
        // Three levels of nesting: inner levels must still produce
        // correct, ordered results even after the budget is exhausted.
        let out = parallel_map(3, 3, |a| {
            parallel_map(3, 3, move |b| {
                parallel_map(3, 3, move |c| a * 9 + b * 3 + c).into_iter().sum::<usize>()
            })
            .into_iter()
            .sum::<usize>()
        });
        let expect: Vec<usize> = (0..3)
            .map(|a| {
                (0..3)
                    .map(|b| (0..3).map(|c| a * 9 + b * 3 + c).sum::<usize>())
                    .sum::<usize>()
            })
            .collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn results_identical_across_thread_counts() {
        let serial = parallel_map(257, 1, |i| (i as u64).wrapping_mul(0x9E3779B9));
        for threads in [2, 4, 16] {
            assert_eq!(parallel_map(257, threads, |i| (i as u64).wrapping_mul(0x9E3779B9)), serial);
        }
    }

    #[test]
    fn budget_is_restored_after_calls() {
        // Whatever the ambient budget is (other tests run concurrently),
        // finishing a parallel_map must not permanently consume it.
        let before = available_workers();
        for _ in 0..8 {
            let _ = parallel_map(64, 8, |i| i);
        }
        // Eventually all leases return; allow concurrent tests to hold
        // some transiently.
        let after = available_workers();
        assert!(after + 16 >= before, "budget leaked: {before} -> {after}");
    }
}
