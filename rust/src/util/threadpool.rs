//! Scoped parallel map over an index range (rayon/tokio replacement).
//!
//! The mapper evaluates thousands of independent candidate mappings per
//! operation; [`parallel_map`] fans a work range out over OS threads with
//! an atomic work-stealing cursor and collects results in order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (respects `HARP_THREADS`, defaults to
/// available parallelism, capped at 16).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HARP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Apply `f` to every index in `0..n` on `threads` workers; returns the
/// results ordered by index. `f` must be `Sync` (called concurrently).
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots.into_iter().map(|s| s.into_inner().unwrap().expect("worker completed")).collect()
}

/// Parallel fold: map each index then reduce with `combine`, seeded by
/// `init`. Reduction order is deterministic (index order).
pub fn parallel_fold<T, A, F, C>(n: usize, threads: usize, f: F, init: A, combine: C) -> A
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    C: Fn(A, T) -> A,
{
    parallel_map(n, threads, f).into_iter().fold(init, combine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(1000, 8, |i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn fold_matches_serial() {
        let total = parallel_fold(500, 4, |i| i as u64, 0u64, |a, b| a + b);
        assert_eq!(total, (0..500u64).sum());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(parallel_map(10, 1, |i| i), (0..10).collect::<Vec<_>>());
    }
}
