//! Mini property-based testing runner (proptest replacement).
//!
//! Generates random cases from a seeded [`Rng`](super::rng::Rng), runs a
//! predicate, and on failure greedily shrinks the failing input before
//! reporting. Inputs are modelled as `Vec<usize>` drawn from per-element
//! ranges — enough to express dimension tuples, factor vectors and seeds,
//! which is what HARP's invariants quantify over.

use super::rng::Rng;

/// Inclusive ranges for each generated element.
pub struct Gen {
    pub ranges: Vec<(usize, usize)>,
}

impl Gen {
    /// `n` elements, each uniform in `[lo, hi]`.
    pub fn uniform(n: usize, lo: usize, hi: usize) -> Gen {
        Gen { ranges: vec![(lo, hi); n] }
    }

    /// Explicit per-element ranges.
    pub fn ranges(ranges: Vec<(usize, usize)>) -> Gen {
        Gen { ranges }
    }

    fn sample(&self, rng: &mut Rng) -> Vec<usize> {
        self.ranges.iter().map(|&(lo, hi)| rng.range(lo, hi)).collect()
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct Failure {
    pub input: Vec<usize>,
    pub message: String,
    pub shrunk_from: Vec<usize>,
}

/// Run `cases` random checks of `prop` over inputs from `gen`.
///
/// `prop` returns `Ok(())` on success, `Err(reason)` on violation.
/// Panics with a readable report (including the shrunk counterexample)
/// on the first failure — call it from `#[test]` functions.
pub fn check<F>(name: &str, seed: u64, cases: usize, gen: &Gen, prop: F)
where
    F: Fn(&[usize]) -> Result<(), String>,
{
    if let Some(fail) = check_quiet(seed, cases, gen, &prop) {
        panic!(
            "property '{name}' failed\n  counterexample: {:?}\n  (shrunk from {:?})\n  reason: {}",
            fail.input, fail.shrunk_from, fail.message
        );
    }
}

/// Like [`check`] but returns the failure instead of panicking (used to
/// test the runner itself).
pub fn check_quiet<F>(seed: u64, cases: usize, gen: &Gen, prop: &F) -> Option<Failure>
where
    F: Fn(&[usize]) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for _ in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            let (shrunk, msg) = shrink(gen, input.clone(), msg, prop);
            return Some(Failure { input: shrunk, message: msg, shrunk_from: input });
        }
    }
    None
}

/// Per-element shrink: binary-search each element down toward its lower
/// bound, keeping the smallest value that still fails. Repeats passes
/// until a fixed point (elements can unlock each other).
fn shrink<F>(
    gen: &Gen,
    mut input: Vec<usize>,
    mut msg: String,
    prop: &F,
) -> (Vec<usize>, String)
where
    F: Fn(&[usize]) -> Result<(), String>,
{
    let mut progress = true;
    while progress {
        progress = false;
        for i in 0..input.len() {
            let mut lo = gen.ranges[i].0;
            let mut hi = input[i];
            // Invariant: `hi` fails. Find the smallest failing value.
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                let mut candidate = input.clone();
                candidate[i] = mid;
                match prop(&candidate) {
                    Err(m) => {
                        hi = mid;
                        msg = m;
                    }
                    Ok(()) => lo = mid + 1,
                }
            }
            if hi < input[i] {
                input[i] = hi;
                progress = true;
            }
        }
    }
    (input, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let gen = Gen::uniform(3, 1, 100);
        check("sum-positive", 1, 200, &gen, |v| {
            if v.iter().sum::<usize>() >= 3 {
                Ok(())
            } else {
                Err("sum too small".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let gen = Gen::uniform(1, 0, 1000);
        let fail = check_quiet(7, 500, &gen, &|v: &[usize]| {
            if v[0] < 50 {
                Ok(())
            } else {
                Err("too big".into())
            }
        })
        .expect("must fail");
        // Greedy halving should land exactly on the boundary value 50.
        assert_eq!(fail.input, vec![50]);
    }

    #[test]
    fn respects_ranges() {
        let gen = Gen::ranges(vec![(2, 4), (10, 10)]);
        check("in-range", 3, 100, &gen, |v| {
            if (2..=4).contains(&v[0]) && v[1] == 10 {
                Ok(())
            } else {
                Err(format!("out of range: {v:?}"))
            }
        });
    }

    #[test]
    fn deterministic_counterexample() {
        let gen = Gen::uniform(2, 0, 99);
        let p = |v: &[usize]| {
            if v[0] + v[1] < 150 {
                Ok(())
            } else {
                Err("sum".to_string())
            }
        };
        let a = check_quiet(11, 300, &gen, &p).unwrap();
        let b = check_quiet(11, 300, &gen, &p).unwrap();
        assert_eq!(a.input, b.input);
    }
}
