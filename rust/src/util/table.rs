//! Fixed-width text table renderer for paper-style console output.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn row_str(&mut self, cells: &[&str]) {
        self.rows.push(cells.iter().map(|s| s.to_string()).collect());
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len().max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let empty = String::new();
                let c = cells.get(i).unwrap_or(&empty);
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        let sep = {
            let mut line = String::from("+");
            for w in &widths {
                line.push_str(&"-".repeat(w + 2));
                line.push('+');
            }
            line.push('\n');
            line
        };
        out.push_str(&sep);
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out.push_str(&sep);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["arch", "speedup"]);
        t.row_str(&["homogeneous", "1.00"]);
        t.row_str(&["cross-depth heterogeneous", "1.37"]);
        let s = t.render();
        assert!(s.contains("| arch "));
        assert!(s.contains("cross-depth heterogeneous"));
        // All lines same width.
        let widths: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = Table::new(&["a"]);
        t.row_str(&["1", "2"]);
        assert!(t.render().contains("2"));
    }
}
