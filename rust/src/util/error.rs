//! Minimal error type with context chaining (anyhow replacement).
//!
//! The offline image ships no ecosystem crates, so the runtime layer's
//! fallible plumbing uses this instead of `anyhow`: a string-backed
//! [`Error`], a defaulted [`Result`] alias, a [`Context`] extension
//! trait, and the [`anyhow!`](crate::anyhow)/[`bail!`](crate::bail)
//! macros with the familiar spelling.

use std::fmt;

/// A string-backed error. Context is prepended `outer: inner`, matching
/// the `{:#}` rendering convention call sites already use.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }

    /// Prepend a context layer.
    pub fn context(self, outer: impl fmt::Display) -> Error {
        Error { msg: format!("{outer}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(m: String) -> Error {
        Error::msg(m)
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Error {
        Error::msg(m)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error::msg(e.to_string())
    }
}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to any displayable error.
pub trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string (anyhow's spelling).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

/// Early-return an [`Error`] from a format string (anyhow's spelling).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(crate::anyhow!("inner {}", 42))
    }

    #[test]
    fn macro_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "inner 42");
    }

    #[test]
    fn bail_early_returns() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                crate::bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let e2 = Error::msg("x").context("outer");
        assert_eq!(e2.to_string(), "outer: x");
    }

    #[test]
    fn with_context_lazy() {
        let e = Err::<(), &str>("bad").with_context(|| "lazy".to_string()).unwrap_err();
        assert_eq!(e.to_string(), "lazy: bad");
    }
}
