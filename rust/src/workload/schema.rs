//! JSON cascade schema — the workload front-end mirroring the machine
//! topology front-end (`eval --workload FILE` ↔ `eval --topology FILE`).
//!
//! A workload document is a cascade DAG spelled out op by op:
//!
//! ```json
//! {
//!   "name": "my-workload",
//!   "ops": [
//!     { "name": "q_gen", "kind": "gemm", "phase": "prefill",
//!       "b": 1, "m": 24000, "n": 4096, "k": 4096, "repeat": 1 }
//!   ],
//!   "deps": [ ["q_gen", "logit"] ]
//! }
//! ```
//!
//! Every op is constructed through [`TensorOp::new`] — the same
//! validated path the built-in generators use — so a file can express
//! exactly what the generators can, and nothing more. Validation is
//! loud and distinct per failure: dangling deps, cycles, zero/negative
//! dims, duplicate op names, self-deps, duplicate edges, vector ops
//! with `k != 1`, and unknown kinds/phases each get their own error.
//!
//! Serialization is deterministic (ops and deps in declaration order,
//! every field emitted), so `parse → serialize` is a fixpoint:
//! re-parsing the emitted text and serializing again reproduces the
//! bytes — property-tested over every registered built-in in
//! `util/json.rs`, mirroring the machine-tree round-trip test.

use super::cascade::Cascade;
use super::einsum::{OpKind, Phase, TensorOp};
use crate::util::json::Json;
use std::collections::HashMap;

impl Cascade {
    /// Serialize to the workload JSON schema (inverse of
    /// [`Cascade::from_json`]). Deps are emitted as `[producer,
    /// consumer]` *name* pairs, so op names must be unique — which
    /// [`Cascade::from_json`] enforces, and every built-in generator
    /// guarantees (the round-trip test would fail otherwise).
    pub fn to_json(&self) -> Json {
        let ops: Vec<Json> = self
            .ops
            .iter()
            .map(|op| {
                Json::obj()
                    .with("name", op.name.as_str())
                    .with("kind", op.kind.name())
                    .with("phase", op.phase.name())
                    .with("b", op.b)
                    .with("m", op.m)
                    .with("n", op.n)
                    .with("k", op.k)
                    .with("repeat", op.count)
            })
            .collect();
        let deps: Vec<Json> = self
            .deps
            .iter()
            .map(|&(p, c)| {
                Json::Arr(vec![
                    Json::Str(self.ops[p].name.clone()),
                    Json::Str(self.ops[c].name.clone()),
                ])
            })
            .collect();
        Json::obj()
            .with("name", self.name.as_str())
            .with("ops", ops)
            .with("deps", deps)
    }

    /// Parse a workload document (the `--workload FILE` input; schema
    /// documented in the README). `b` defaults to 1, `k` defaults to 1
    /// for vector ops (and must be 1 if given), `repeat` defaults to 1;
    /// everything else — including the document `name`, which labels
    /// reports and keys the evaluation cache — is required.
    pub fn from_json(j: &Json) -> Result<Cascade, String> {
        reject_unknown_keys(j, &["name", "ops", "deps"], "workload document")?;
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or("workload needs a 'name' string")?;
        if name.is_empty() {
            // The name labels every report and keys the evaluation
            // cache — hold it to the same bar as op names.
            return Err("workload needs a non-empty 'name'".into());
        }
        let ops_json = j
            .get("ops")
            .and_then(|v| v.as_arr())
            .ok_or("workload needs an 'ops' array")?;
        if ops_json.is_empty() {
            return Err("workload needs at least one op".into());
        }
        let mut g = Cascade::new(name);
        let mut index: HashMap<String, usize> = HashMap::new();
        for o in ops_json {
            let op_name = o
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("every op needs a 'name' string")?;
            // A typo'd optional key ("repeats", "batch") would silently
            // fall back to its default and evaluate a different
            // workload — reject anything outside the schema instead.
            reject_unknown_keys(
                o,
                &["name", "kind", "phase", "b", "m", "n", "k", "repeat"],
                &format!("op '{op_name}'"),
            )?;
            let kind = o
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("op '{op_name}': needs a 'kind' (gemm|bmm|vector)"))
                .and_then(|s| {
                    OpKind::parse(s).map_err(|e| format!("op '{op_name}': {e}"))
                })?;
            let phase = o
                .get("phase")
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    format!("op '{op_name}': needs a 'phase' (encoder|prefill|decode)")
                })
                .and_then(|s| {
                    Phase::parse(s).map_err(|e| format!("op '{op_name}': {e}"))
                })?;
            let dim = |key: &str, default: Option<u64>| -> Result<u64, String> {
                match o.get(key) {
                    None => default.ok_or_else(|| {
                        format!("op '{op_name}': needs '{key}' (a positive integer)")
                    }),
                    Some(v) => v.as_u64().filter(|&x| x > 0).ok_or_else(|| {
                        format!("op '{op_name}': '{key}' must be a positive integer")
                    }),
                }
            };
            let b = dim("b", Some(1))?;
            let m = dim("m", None)?;
            let n = dim("n", None)?;
            let k = dim("k", if kind == OpKind::Vector { Some(1) } else { None })?;
            let repeat = dim("repeat", Some(1))?;
            let op = TensorOp::new(op_name, kind, phase, b, m, n, k, repeat)?;
            if index.insert(op_name.to_string(), g.ops.len()).is_some() {
                return Err(format!("duplicate op name '{op_name}'"));
            }
            g.push(op);
        }
        if let Some(deps) = j.get("deps") {
            let deps = deps
                .as_arr()
                .ok_or("'deps' must be an array of [producer, consumer] name pairs")?;
            for d in deps {
                let pair = d
                    .as_arr()
                    .filter(|a| a.len() == 2)
                    .ok_or("each dep must be a [producer, consumer] name pair")?;
                let mut idx = [0usize; 2];
                for (slot, v) in idx.iter_mut().zip(pair) {
                    let nm = v.as_str().ok_or("dep endpoints must be op-name strings")?;
                    *slot = *index
                        .get(nm)
                        .ok_or_else(|| format!("dangling dep: no op named '{nm}'"))?;
                }
                if idx[0] == idx[1] {
                    return Err(format!("op '{}' depends on itself", g.ops[idx[0]].name));
                }
                g.dep(idx[0], idx[1]);
            }
        }
        // Duplicate edges and cycles surface here with their own
        // messages ("duplicate edge …" / "… contains a cycle").
        g.validate()?;
        Ok(g)
    }
}

/// Error on any object key outside `known`, and on duplicate keys —
/// the loader's misspelled-field guard. (The JSON parser keeps every
/// pair and `get` returns the first, so an unrejected duplicate would
/// make a later `"m": 9999` edit silently inert.)
fn reject_unknown_keys(j: &Json, known: &[&str], what: &str) -> Result<(), String> {
    if let Json::Obj(pairs) = j {
        let mut seen: Vec<&str> = Vec::with_capacity(pairs.len());
        for (key, _) in pairs {
            if !known.contains(&key.as_str()) {
                return Err(format!(
                    "{what}: unknown key '{key}' (known: {})",
                    known.join(", ")
                ));
            }
            if seen.contains(&key.as_str()) {
                return Err(format!("{what}: duplicate key '{key}'"));
            }
            seen.push(key.as_str());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::transformer;

    fn parse(doc: &str) -> Result<Cascade, String> {
        Cascade::from_json(&Json::parse(doc).expect("valid JSON"))
    }

    #[test]
    fn bert_round_trips_through_the_schema() {
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let text = g.to_json().to_string_pretty();
        let back = Cascade::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, g.name);
        assert_eq!(back.deps, g.deps);
        assert_eq!(back.ops.len(), g.ops.len());
        for (a, b) in g.ops.iter().zip(&back.ops) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.phase, b.phase);
            assert_eq!((a.b, a.m, a.n, a.k, a.count), (b.b, b.m, b.n, b.k, b.count));
        }
        // Serialization is a fixpoint after the first round.
        assert_eq!(back.to_json().to_string_pretty(), text);
    }

    #[test]
    fn defaults_fill_in_b_k_repeat() {
        let g = parse(
            r#"{"name":"w","ops":[
                {"name":"v","kind":"vector","phase":"encoder","m":4,"n":4},
                {"name":"g","kind":"gemm","phase":"encoder","m":4,"n":4,"k":8}]}"#,
        )
        .unwrap();
        assert_eq!((g.ops[0].b, g.ops[0].k, g.ops[0].count), (1, 1, 1));
        assert_eq!(g.ops[1].k, 8);
        assert!(g.deps.is_empty());
    }

    #[test]
    fn distinct_errors_per_failure_mode() {
        let op = r#"{"name":"a","kind":"gemm","phase":"encoder","m":4,"n":4,"k":4}"#;
        let op_b = r#"{"name":"b","kind":"gemm","phase":"encoder","m":4,"n":4,"k":4}"#;
        let cases = [
            // (document, expected error fragment)
            (format!(r#"{{"ops":[{op}]}}"#), "needs a 'name' string"),
            (r#"{"name":"w"}"#.to_string(), "needs an 'ops' array"),
            (r#"{"name":"w","ops":[]}"#.to_string(), "at least one op"),
            (
                format!(r#"{{"name":"w","ops":[{op},{op}]}}"#),
                "duplicate op name 'a'",
            ),
            (
                r#"{"name":"w","ops":[{"name":"a","kind":"conv","phase":"encoder",
                    "m":4,"n":4,"k":4}]}"#
                    .to_string(),
                "unknown op kind 'conv'",
            ),
            (
                r#"{"name":"w","ops":[{"name":"a","kind":"gemm","phase":"warmup",
                    "m":4,"n":4,"k":4}]}"#
                    .to_string(),
                "unknown phase 'warmup'",
            ),
            (
                r#"{"name":"w","ops":[{"name":"a","kind":"gemm","phase":"encoder",
                    "m":0,"n":4,"k":4}]}"#
                    .to_string(),
                "'m' must be a positive integer",
            ),
            (
                r#"{"name":"w","ops":[{"name":"a","kind":"gemm","phase":"encoder",
                    "m":-4,"n":4,"k":4}]}"#
                    .to_string(),
                "'m' must be a positive integer",
            ),
            (
                r#"{"name":"w","ops":[{"name":"a","kind":"gemm","phase":"encoder",
                    "m":4,"n":4}]}"#
                    .to_string(),
                "needs 'k'",
            ),
            (
                r#"{"name":"w","ops":[{"name":"a","kind":"vector","phase":"encoder",
                    "m":4,"n":4,"k":3}]}"#
                    .to_string(),
                "vector ops are k = 1",
            ),
            (
                format!(r#"{{"name":"w","ops":[{op}],"deps":[["a","zzz"]]}}"#),
                "dangling dep: no op named 'zzz'",
            ),
            (
                format!(r#"{{"name":"w","ops":[{op}],"deps":[["a","a"]]}}"#),
                "depends on itself",
            ),
            (
                format!(r#"{{"name":"w","ops":[{op},{op_b}],"deps":[["a","b"],["a","b"]]}}"#),
                "duplicate edge",
            ),
            (
                format!(r#"{{"name":"w","ops":[{op},{op_b}],"deps":[["a","b"],["b","a"]]}}"#),
                "contains a cycle",
            ),
            (
                format!(r#"{{"name":"w","ops":[{op}],"deps":[["a"]]}}"#),
                "name pair",
            ),
            (
                r#"{"name":"w","ops":[{"name":"a","kind":"gemm","phase":"encoder",
                    "m":4,"n":4,"k":4,"repeats":1000}]}"#
                    .to_string(),
                "unknown key 'repeats'",
            ),
            (
                format!(r#"{{"name":"w","operations":[{op}]}}"#),
                "unknown key 'operations'",
            ),
            (
                r#"{"name":"w","ops":[{"name":"a","kind":"gemm","phase":"encoder",
                    "m":4,"n":4,"k":4,"m":9999}]}"#
                    .to_string(),
                "duplicate key 'm'",
            ),
            (
                format!(r#"{{"name":"w","ops":[{op}],"ops":[{op}]}}"#),
                "duplicate key 'ops'",
            ),
            (format!(r#"{{"name":"","ops":[{op}]}}"#), "non-empty 'name'"),
        ];
        for (doc, want) in cases {
            let err = parse(&doc).unwrap_err();
            assert!(err.contains(want), "expected '{want}' in: {err}");
        }
    }
}
