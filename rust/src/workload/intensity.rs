//! Arithmetic-intensity classification (paper §II-B, §III-A).
//!
//! Operations are assigned to sub-accelerators by reuse: an operation is
//! *high-reuse* when its arithmetic intensity clears the machine's
//! roofline tipping point (MACs/cycle ÷ words/cycle), scaled by a margin.
//! Decode-phase operations sit 1–2 orders of magnitude below the tipping
//! point, prefill/encoder GEMMs well above — exactly the paper's premise.

use super::einsum::{Phase, TensorOp};

/// Reuse class of an operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReuseClass {
    High,
    Low,
}

impl ReuseClass {
    pub fn name(self) -> &'static str {
        match self {
            ReuseClass::High => "high-reuse",
            ReuseClass::Low => "low-reuse",
        }
    }
}

/// Classifier configuration.
#[derive(Debug, Clone)]
pub struct Classifier {
    /// The roofline tipping point of the *whole* (unpartitioned) machine
    /// in MACs per word.
    pub tipping_ai: f64,
    /// Fraction of the tipping point above which an op counts as
    /// high-reuse. The paper's examples put high- and low-reuse ops 1-2
    /// orders of magnitude apart, so the result is insensitive to this
    /// margin; 0.5 keeps borderline encoder BMMs on the low-reuse side.
    pub margin: f64,
    /// If true, classify by phase when available: decode ⇒ low-reuse,
    /// prefill ⇒ high-reuse (the paper's inter-cascade policy maps the
    /// ENTIRE decode stage to the low-reuse sub-accelerator, including
    /// its nominally square GEMMs).
    pub phase_override: bool,
}

impl Classifier {
    pub fn new(tipping_ai: f64) -> Classifier {
        Classifier { tipping_ai, margin: 0.5, phase_override: true }
    }

    /// Classify one operation.
    pub fn classify(&self, op: &TensorOp) -> ReuseClass {
        if self.phase_override {
            match op.phase {
                Phase::Decode => return ReuseClass::Low,
                Phase::Prefill => return ReuseClass::High,
                Phase::Encoder => {}
            }
        }
        if op.arithmetic_intensity() >= self.tipping_ai * self.margin {
            ReuseClass::High
        } else {
            ReuseClass::Low
        }
    }
}

/// Roofline tipping point for a machine: the arithmetic intensity at
/// which compute and memory bounds meet.
pub fn tipping_point(macs_per_cycle: f64, words_per_cycle: f64) -> f64 {
    macs_per_cycle / words_per_cycle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::einsum::TensorOp;

    #[test]
    fn tipping_point_matches_table_iii() {
        // 40960 MACs, 2048 bits/cycle at 8-bit words = 256 words/cycle.
        let tp = tipping_point(40960.0, 256.0);
        assert_eq!(tp, 160.0);
    }

    #[test]
    fn encoder_gemm_high_bmm_low() {
        let c = Classifier::new(160.0);
        let qkv = TensorOp::gemm("q", Phase::Encoder, 256, 1024, 1024);
        let logit = TensorOp::bmm("logit", Phase::Encoder, 16, 256, 64, 256);
        assert_eq!(c.classify(&qkv), ReuseClass::High);
        assert_eq!(c.classify(&logit), ReuseClass::Low);
    }

    #[test]
    fn phase_override_sends_decode_low() {
        let c = Classifier::new(160.0);
        // A decode FFN GEMM is square-ish but still goes low-reuse by phase.
        let dec_ffn = TensorOp::gemm("ffn_dec", Phase::Decode, 1, 4096, 16384);
        assert_eq!(c.classify(&dec_ffn), ReuseClass::Low);
        let pre = TensorOp::gemm("ffn_pre", Phase::Prefill, 3000, 4096, 16384);
        assert_eq!(c.classify(&pre), ReuseClass::High);
    }

    #[test]
    fn intensity_only_when_override_disabled() {
        let mut c = Classifier::new(160.0);
        c.phase_override = false;
        // Decode GEMV: AI ≈ 1 ⇒ low regardless.
        let gemv = TensorOp::gemm("gemv", Phase::Decode, 1, 4096, 4096);
        assert_eq!(c.classify(&gemv), ReuseClass::Low);
    }
}
