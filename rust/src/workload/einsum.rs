//! Tensor operations as batched-GEMM-shaped einsums.
//!
//! Every operation HARP evaluates is expressed over four dimensions
//! `B × M × N × K` (batch, output rows, output cols, reduction):
//!
//! - GEMM:        `O[m,n] += A[m,k] * W[k,n]`            (`b = 1`)
//! - BMM:         `O[b,m,n] += A[b,m,k] * B[b,k,n]`      (per-head attention)
//! - Vector ops (softmax, layernorm, residual adds) are modelled as
//!   `k = 1` einsums — one multiply-accumulate per output element, which
//!   matches their O(1) arithmetic intensity.
//!
//! This is the same workload abstraction Timeloop's `problem` spec uses
//! for matrix workloads, specialised to the shapes in the paper.

/// The four einsum dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dim {
    B,
    M,
    N,
    K,
}

impl Dim {
    pub const ALL: [Dim; 4] = [Dim::B, Dim::M, Dim::N, Dim::K];

    pub fn index(self) -> usize {
        match self {
            Dim::B => 0,
            Dim::M => 1,
            Dim::N => 2,
            Dim::K => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dim::B => "B",
            Dim::M => "M",
            Dim::N => "N",
            Dim::K => "K",
        }
    }
}

/// The three operand tensors of an einsum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    /// Input / activation: `A[b, m, k]`.
    InputA,
    /// Weight / second input: `W[b?, k, n]`.
    InputB,
    /// Output: `O[b, m, n]` (read-modify-write over `k`).
    Output,
}

impl Operand {
    pub const ALL: [Operand; 3] = [Operand::InputA, Operand::InputB, Operand::Output];
}

/// Kind of operation; affects operand relevance (weights are shared
/// across batch in a GEMM but private per batch in a BMM) and how the
/// workload generators tag reuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Dense GEMM with batch folded into `m` (weights reused across rows).
    Gemm,
    /// Batched matrix multiply (attention logit/attend); all operands
    /// carry the batch dimension.
    Bmm,
    /// Elementwise / reduction vector op modelled as `k = 1`.
    Vector,
}

/// Which phase of the workload the operation belongs to. Used by the
/// inter-cascade partitioner (prefill → high-reuse sub-accelerator,
/// decode → low-reuse) and by the figure drivers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Encoder,
    Prefill,
    Decode,
}

impl Phase {
    pub const ALL: [Phase; 3] = [Phase::Encoder, Phase::Prefill, Phase::Decode];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Encoder => "encoder",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }
}

/// One tensor operation in a cascade.
#[derive(Debug, Clone)]
pub struct TensorOp {
    pub name: String,
    pub kind: OpKind,
    pub phase: Phase,
    pub b: u64,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Number of back-to-back serial repetitions of this op (used to
    /// represent the per-token decode loop compactly: each decode chunk
    /// op is one representative shape repeated `count` times).
    pub count: u64,
}

impl TensorOp {
    pub fn gemm(name: &str, phase: Phase, m: u64, k: u64, n: u64) -> TensorOp {
        TensorOp { name: name.into(), kind: OpKind::Gemm, phase, b: 1, m, n, k, count: 1 }
    }

    pub fn bmm(name: &str, phase: Phase, b: u64, m: u64, k: u64, n: u64) -> TensorOp {
        TensorOp { name: name.into(), kind: OpKind::Bmm, phase, b, m, n, k, count: 1 }
    }

    pub fn vector(name: &str, phase: Phase, b: u64, m: u64, n: u64) -> TensorOp {
        TensorOp { name: name.into(), kind: OpKind::Vector, phase, b, m, n, k: 1, count: 1 }
    }

    pub fn repeated(mut self, count: u64) -> TensorOp {
        self.count = count;
        self
    }

    /// Size of a dimension.
    pub fn dim(&self, d: Dim) -> u64 {
        match d {
            Dim::B => self.b,
            Dim::M => self.m,
            Dim::N => self.n,
            Dim::K => self.k,
        }
    }

    /// Multiply-accumulates for ONE repetition.
    pub fn macs(&self) -> u64 {
        self.b * self.m * self.n * self.k
    }

    /// MACs including the `count` repetitions.
    pub fn total_macs(&self) -> u64 {
        self.macs() * self.count
    }

    /// Footprint in words of one operand (one repetition).
    pub fn operand_words(&self, t: Operand) -> u64 {
        Dim::ALL
            .iter()
            .filter(|&&d| self.relevant(t, d))
            .map(|&d| self.dim(d))
            .product()
    }

    /// Total compulsory traffic in words (each operand touched once).
    pub fn footprint_words(&self) -> u64 {
        Operand::ALL.iter().map(|&t| self.operand_words(t)).sum()
    }

    /// Is dimension `d` an index of operand `t`?
    ///
    /// `A[b,m,k]`, `W[(b),k,n]`, `O[b,m,n]`. For a GEMM the weight is
    /// shared across batch (and `b = 1` anyway); for a BMM each batch has
    /// its own `B` matrix.
    pub fn relevant(&self, t: Operand, d: Dim) -> bool {
        match (t, d) {
            (Operand::InputA, Dim::B) => true,
            (Operand::InputA, Dim::M) => true,
            (Operand::InputA, Dim::K) => true,
            (Operand::InputA, Dim::N) => false,
            (Operand::InputB, Dim::B) => self.kind == OpKind::Bmm,
            (Operand::InputB, Dim::M) => false,
            (Operand::InputB, Dim::K) => true,
            (Operand::InputB, Dim::N) => true,
            (Operand::Output, Dim::B) => true,
            (Operand::Output, Dim::M) => true,
            (Operand::Output, Dim::K) => false,
            (Operand::Output, Dim::N) => true,
        }
    }

    /// Arithmetic intensity in MACs per word of compulsory traffic.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.macs() as f64 / self.footprint_words() as f64
    }

    pub fn describe(&self) -> String {
        format!(
            "{:<18} {:>7} B={} M={} N={} K={} ×{}  ({:.1} MACs/word)",
            self.name,
            match self.kind {
                OpKind::Gemm => "GEMM",
                OpKind::Bmm => "BMM",
                OpKind::Vector => "VEC",
            },
            self.b,
            self.m,
            self.n,
            self.k,
            self.count,
            self.arithmetic_intensity()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_macs_and_footprint() {
        let op = TensorOp::gemm("ffn", Phase::Encoder, 256, 1024, 4096);
        assert_eq!(op.macs(), 256 * 1024 * 4096);
        assert_eq!(op.operand_words(Operand::InputA), 256 * 1024);
        assert_eq!(op.operand_words(Operand::InputB), 1024 * 4096);
        assert_eq!(op.operand_words(Operand::Output), 256 * 4096);
    }

    #[test]
    fn bmm_weights_carry_batch() {
        let op = TensorOp::bmm("logit", Phase::Encoder, 16, 256, 64, 256);
        assert_eq!(op.operand_words(Operand::InputB), 16 * 64 * 256);
        let g = TensorOp::gemm("g", Phase::Encoder, 256, 64, 256);
        assert_eq!(g.operand_words(Operand::InputB), 64 * 256);
    }

    #[test]
    fn vector_ops_have_unit_intensity_scale() {
        let op = TensorOp::vector("softmax", Phase::Encoder, 16, 256, 256);
        assert!(op.arithmetic_intensity() < 1.0);
        assert_eq!(op.k, 1);
    }

    #[test]
    fn decode_gemv_is_low_intensity() {
        // Decode-stage QKV generation: M=1 GEMV, AI ≈ 1.
        let op = TensorOp::gemm("q_gen_dec", Phase::Decode, 1, 4096, 4096);
        assert!(op.arithmetic_intensity() < 1.01);
        // Prefill counterpart: AI in the hundreds.
        let p = TensorOp::gemm("q_gen_pre", Phase::Prefill, 3000, 4096, 4096);
        assert!(p.arithmetic_intensity() > 100.0);
    }

    #[test]
    fn repetition_scales_macs() {
        let op = TensorOp::gemm("d", Phase::Decode, 1, 64, 64).repeated(1000);
        assert_eq!(op.total_macs(), 1000 * 64 * 64);
    }
}
