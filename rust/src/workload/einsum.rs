//! Tensor operations as batched-GEMM-shaped einsums.
//!
//! Every operation HARP evaluates is expressed over four dimensions
//! `B × M × N × K` (batch, output rows, output cols, reduction):
//!
//! - GEMM:        `O[m,n] += A[m,k] * W[k,n]`            (`b = 1`)
//! - BMM:         `O[b,m,n] += A[b,m,k] * B[b,k,n]`      (per-head attention)
//! - Vector ops (softmax, layernorm, residual adds) are modelled as
//!   `k = 1` einsums — one multiply-accumulate per output element, which
//!   matches their O(1) arithmetic intensity.
//!
//! This is the same workload abstraction Timeloop's `problem` spec uses
//! for matrix workloads, specialised to the shapes in the paper.

/// The four einsum dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dim {
    B,
    M,
    N,
    K,
}

impl Dim {
    pub const ALL: [Dim; 4] = [Dim::B, Dim::M, Dim::N, Dim::K];

    /// Parse a dimension letter (the inverse of [`Dim::name`]).
    pub fn parse(s: &str) -> Result<Dim, String> {
        match s {
            "B" => Ok(Dim::B),
            "M" => Ok(Dim::M),
            "N" => Ok(Dim::N),
            "K" => Ok(Dim::K),
            other => Err(format!("unknown dim '{other}' (B|M|N|K)")),
        }
    }

    pub fn index(self) -> usize {
        match self {
            Dim::B => 0,
            Dim::M => 1,
            Dim::N => 2,
            Dim::K => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dim::B => "B",
            Dim::M => "M",
            Dim::N => "N",
            Dim::K => "K",
        }
    }
}

/// The three operand tensors of an einsum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    /// Input / activation: `A[b, m, k]`.
    InputA,
    /// Weight / second input: `W[b?, k, n]`.
    InputB,
    /// Output: `O[b, m, n]` (read-modify-write over `k`).
    Output,
}

impl Operand {
    pub const ALL: [Operand; 3] = [Operand::InputA, Operand::InputB, Operand::Output];
}

/// Kind of operation; affects operand relevance (weights are shared
/// across batch in a GEMM but private per batch in a BMM) and how the
/// workload generators tag reuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Dense GEMM with batch folded into `m` (weights reused across rows).
    Gemm,
    /// Batched matrix multiply (attention logit/attend); all operands
    /// carry the batch dimension.
    Bmm,
    /// Elementwise / reduction vector op modelled as `k = 1`.
    Vector,
}

impl OpKind {
    /// Canonical schema name (what the workload JSON emits).
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Gemm => "gemm",
            OpKind::Bmm => "bmm",
            OpKind::Vector => "vector",
        }
    }

    /// Parse a schema name (the inverse of [`OpKind::name`]).
    pub fn parse(s: &str) -> Result<OpKind, String> {
        match s {
            "gemm" => Ok(OpKind::Gemm),
            "bmm" => Ok(OpKind::Bmm),
            "vector" => Ok(OpKind::Vector),
            other => Err(format!("unknown op kind '{other}' (gemm|bmm|vector)")),
        }
    }
}

/// Which phase of the workload the operation belongs to. Used by the
/// inter-cascade partitioner (prefill → high-reuse sub-accelerator,
/// decode → low-reuse) and by the figure drivers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Encoder,
    Prefill,
    Decode,
}

impl Phase {
    pub const ALL: [Phase; 3] = [Phase::Encoder, Phase::Prefill, Phase::Decode];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Encoder => "encoder",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }

    /// Parse a schema name (the inverse of [`Phase::name`]).
    pub fn parse(s: &str) -> Result<Phase, String> {
        match s {
            "encoder" => Ok(Phase::Encoder),
            "prefill" => Ok(Phase::Prefill),
            "decode" => Ok(Phase::Decode),
            other => Err(format!("unknown phase '{other}' (encoder|prefill|decode)")),
        }
    }
}

/// One tensor operation in a cascade.
#[derive(Debug, Clone)]
pub struct TensorOp {
    pub name: String,
    pub kind: OpKind,
    pub phase: Phase,
    pub b: u64,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Number of back-to-back serial repetitions of this op (used to
    /// represent the per-token decode loop compactly: each decode chunk
    /// op is one representative shape repeated `count` times).
    pub count: u64,
}

impl TensorOp {
    /// The single validated constructor every operation goes through:
    /// the `gemm`/`bmm`/`vector` builders below AND the workload JSON
    /// loader ([`crate::workload::schema`]) both call it, so the
    /// built-in generators and `--workload` files can never drift on
    /// what a legal op is. Rejects zero dims, a zero repeat count, and
    /// vector ops with `k != 1`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        kind: OpKind,
        phase: Phase,
        b: u64,
        m: u64,
        n: u64,
        k: u64,
        count: u64,
    ) -> Result<TensorOp, String> {
        if name.is_empty() {
            return Err("op needs a non-empty name".into());
        }
        for (dim, v) in [("b", b), ("m", m), ("n", n), ("k", k), ("repeat", count)] {
            if v == 0 {
                return Err(format!("op '{name}': '{dim}' must be a positive integer"));
            }
        }
        if kind == OpKind::Vector && k != 1 {
            return Err(format!("op '{name}': vector ops are k = 1 einsums (got k = {k})"));
        }
        Ok(TensorOp { name: name.into(), kind, phase, b, m, n, k, count })
    }

    pub fn gemm(name: &str, phase: Phase, m: u64, k: u64, n: u64) -> TensorOp {
        TensorOp::new(name, OpKind::Gemm, phase, 1, m, n, k, 1)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn bmm(name: &str, phase: Phase, b: u64, m: u64, k: u64, n: u64) -> TensorOp {
        TensorOp::new(name, OpKind::Bmm, phase, b, m, n, k, 1)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn vector(name: &str, phase: Phase, b: u64, m: u64, n: u64) -> TensorOp {
        TensorOp::new(name, OpKind::Vector, phase, b, m, n, 1, 1)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Set the repeat count. Panics on 0, like the builders above — the
    /// schema rejects `repeat: 0`, so a zero here would create an op
    /// whose serialized form cannot re-parse.
    pub fn repeated(mut self, count: u64) -> TensorOp {
        assert!(count > 0, "op '{}': 'repeat' must be a positive integer", self.name);
        self.count = count;
        self
    }

    /// Size of a dimension.
    pub fn dim(&self, d: Dim) -> u64 {
        match d {
            Dim::B => self.b,
            Dim::M => self.m,
            Dim::N => self.n,
            Dim::K => self.k,
        }
    }

    /// Multiply-accumulates for ONE repetition.
    pub fn macs(&self) -> u64 {
        self.b * self.m * self.n * self.k
    }

    /// MACs including the `count` repetitions.
    pub fn total_macs(&self) -> u64 {
        self.macs() * self.count
    }

    /// Footprint in words of one operand (one repetition).
    pub fn operand_words(&self, t: Operand) -> u64 {
        Dim::ALL
            .iter()
            .filter(|&&d| self.relevant(t, d))
            .map(|&d| self.dim(d))
            .product()
    }

    /// Total compulsory traffic in words (each operand touched once).
    pub fn footprint_words(&self) -> u64 {
        Operand::ALL.iter().map(|&t| self.operand_words(t)).sum()
    }

    /// Is dimension `d` an index of operand `t`?
    ///
    /// `A[b,m,k]`, `W[(b),k,n]`, `O[b,m,n]`. For a GEMM the weight is
    /// shared across batch (and `b = 1` anyway); for a BMM each batch has
    /// its own `B` matrix.
    pub fn relevant(&self, t: Operand, d: Dim) -> bool {
        match (t, d) {
            (Operand::InputA, Dim::B) => true,
            (Operand::InputA, Dim::M) => true,
            (Operand::InputA, Dim::K) => true,
            (Operand::InputA, Dim::N) => false,
            (Operand::InputB, Dim::B) => self.kind == OpKind::Bmm,
            (Operand::InputB, Dim::M) => false,
            (Operand::InputB, Dim::K) => true,
            (Operand::InputB, Dim::N) => true,
            (Operand::Output, Dim::B) => true,
            (Operand::Output, Dim::M) => true,
            (Operand::Output, Dim::K) => false,
            (Operand::Output, Dim::N) => true,
        }
    }

    /// Arithmetic intensity in MACs per word of compulsory traffic.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.macs() as f64 / self.footprint_words() as f64
    }

    pub fn describe(&self) -> String {
        format!(
            "{:<18} {:>7} B={} M={} N={} K={} ×{}  ({:.1} MACs/word)",
            self.name,
            match self.kind {
                OpKind::Gemm => "GEMM",
                OpKind::Bmm => "BMM",
                OpKind::Vector => "VEC",
            },
            self.b,
            self.m,
            self.n,
            self.k,
            self.count,
            self.arithmetic_intensity()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_macs_and_footprint() {
        let op = TensorOp::gemm("ffn", Phase::Encoder, 256, 1024, 4096);
        assert_eq!(op.macs(), 256 * 1024 * 4096);
        assert_eq!(op.operand_words(Operand::InputA), 256 * 1024);
        assert_eq!(op.operand_words(Operand::InputB), 1024 * 4096);
        assert_eq!(op.operand_words(Operand::Output), 256 * 4096);
    }

    #[test]
    fn bmm_weights_carry_batch() {
        let op = TensorOp::bmm("logit", Phase::Encoder, 16, 256, 64, 256);
        assert_eq!(op.operand_words(Operand::InputB), 16 * 64 * 256);
        let g = TensorOp::gemm("g", Phase::Encoder, 256, 64, 256);
        assert_eq!(g.operand_words(Operand::InputB), 64 * 256);
    }

    #[test]
    fn vector_ops_have_unit_intensity_scale() {
        let op = TensorOp::vector("softmax", Phase::Encoder, 16, 256, 256);
        assert!(op.arithmetic_intensity() < 1.0);
        assert_eq!(op.k, 1);
    }

    #[test]
    fn decode_gemv_is_low_intensity() {
        // Decode-stage QKV generation: M=1 GEMV, AI ≈ 1.
        let op = TensorOp::gemm("q_gen_dec", Phase::Decode, 1, 4096, 4096);
        assert!(op.arithmetic_intensity() < 1.01);
        // Prefill counterpart: AI in the hundreds.
        let p = TensorOp::gemm("q_gen_pre", Phase::Prefill, 3000, 4096, 4096);
        assert!(p.arithmetic_intensity() > 100.0);
    }

    #[test]
    fn repetition_scales_macs() {
        let op = TensorOp::gemm("d", Phase::Decode, 1, 64, 64).repeated(1000);
        assert_eq!(op.total_macs(), 1000 * 64 * 64);
    }

    /// The schema-backed constructor rejects degenerate ops with a
    /// distinct message per failure — the loader's validation lives
    /// HERE, so builders and JSON files share one notion of legality.
    #[test]
    fn validated_constructor_rejects_degenerate_ops() {
        let ok = TensorOp::new("g", OpKind::Gemm, Phase::Encoder, 1, 4, 4, 4, 2).unwrap();
        assert_eq!((ok.b, ok.m, ok.n, ok.k, ok.count), (1, 4, 4, 4, 2));
        let err = TensorOp::new("g", OpKind::Gemm, Phase::Encoder, 1, 0, 4, 4, 1).unwrap_err();
        assert!(err.contains("'m' must be a positive integer"), "{err}");
        let err = TensorOp::new("g", OpKind::Bmm, Phase::Encoder, 1, 4, 4, 4, 0).unwrap_err();
        assert!(err.contains("'repeat' must be a positive integer"), "{err}");
        let err = TensorOp::new("v", OpKind::Vector, Phase::Encoder, 1, 4, 4, 7, 1).unwrap_err();
        assert!(err.contains("vector ops are k = 1"), "{err}");
        let err = TensorOp::new("", OpKind::Gemm, Phase::Encoder, 1, 4, 4, 4, 1).unwrap_err();
        assert!(err.contains("non-empty name"), "{err}");
    }

    #[test]
    fn kind_phase_dim_names_round_trip() {
        for kind in [OpKind::Gemm, OpKind::Bmm, OpKind::Vector] {
            assert_eq!(OpKind::parse(kind.name()).unwrap(), kind);
        }
        for phase in Phase::ALL {
            assert_eq!(Phase::parse(phase.name()).unwrap(), phase);
        }
        for dim in Dim::ALL {
            assert_eq!(Dim::parse(dim.name()).unwrap(), dim);
        }
        assert!(OpKind::parse("conv").is_err());
        assert!(Phase::parse("warmup").is_err());
        assert!(Dim::parse("Q").is_err());
    }
}
