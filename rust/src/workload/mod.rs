//! Workload representation: tensor operations, arithmetic intensity,
//! cascade dependency graphs, the paper's transformer workload
//! generators (Table II), the mixed-reuse workload families beyond
//! them (`families`), the JSON cascade schema (`schema`), and the
//! registry that fronts them all (`registry`).

pub mod arrivals;
pub mod cascade;
pub mod einsum;
pub mod families;
pub mod intensity;
pub mod registry;
pub mod schema;
pub mod transformer;

pub use cascade::Cascade;
pub use einsum::{OpKind, Phase, TensorOp};
pub use registry::{WorkloadSource, WorkloadSpec};
