//! Workload representation: tensor operations, arithmetic intensity,
//! cascade dependency graphs, and the paper's transformer workload
//! generators (Table II).

pub mod cascade;
pub mod einsum;
pub mod intensity;
pub mod transformer;

pub use cascade::Cascade;
pub use einsum::{OpKind, Phase, TensorOp};
