//! The workload registry: ONE front-end for every built-in family and
//! for cascades loaded from `--workload FILE` JSON documents —
//! symmetric to the machine front-end (`arch/topology.rs` +
//! `--topology FILE`). The CLI, experiment configs, the sweep engine,
//! and the figure drivers' evaluation cache all go through
//! [`WorkloadSpec`]; nothing downstream knows which family (or file) a
//! cascade came from.

use super::cascade::Cascade;
use super::families::{self, ConvNetConfig, MoeConfig, ServingMixConfig};
use super::transformer::{self, TransformerConfig};
use crate::mapper::search::cascade_fingerprint;
use crate::util::json::Json;

/// A named workload: a built-in generator config, or an explicit
/// cascade loaded from a JSON document.
#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    /// Paper Table II transformer (BERT / Llama-2 / GPT-3).
    Transformer(TransformerConfig),
    /// Mixture-of-experts prefill or decode.
    Moe(MoeConfig),
    /// CNN lowered to im2col GEMMs.
    Conv(ConvNetConfig),
    /// Grouped-query attention, decode-only, long context.
    GqaDecode(TransformerConfig),
    /// Prefill + decode request pools at a batch ratio.
    ServingMix(ServingMixConfig),
    /// Explicit cascade from a `--workload FILE` document.
    Cascade(Cascade),
}

impl WorkloadSpec {
    /// Display name (what figures and reports print).
    pub fn name(&self) -> &str {
        match self {
            WorkloadSpec::Transformer(c) | WorkloadSpec::GqaDecode(c) => &c.name,
            WorkloadSpec::Moe(c) => &c.name,
            WorkloadSpec::Conv(c) => &c.name,
            WorkloadSpec::ServingMix(c) => &c.name,
            WorkloadSpec::Cascade(c) => &c.name,
        }
    }

    /// Family tag (the `workload list` column).
    pub fn family(&self) -> &'static str {
        match self {
            WorkloadSpec::Transformer(_) => "transformer",
            WorkloadSpec::Moe(_) => "moe",
            WorkloadSpec::Conv(_) => "conv-im2col",
            WorkloadSpec::GqaDecode(_) => "gqa-decode",
            WorkloadSpec::ServingMix(_) => "serving-mix",
            WorkloadSpec::Cascade(_) => "file",
        }
    }

    /// Generate the cascade (built-ins) or clone the loaded one (files).
    pub fn cascade(&self) -> Cascade {
        match self {
            WorkloadSpec::Transformer(c) => transformer::cascade_for(c),
            WorkloadSpec::Moe(c) => families::moe_cascade(c),
            WorkloadSpec::Conv(c) => families::conv_cascade(c),
            WorkloadSpec::GqaDecode(c) => families::gqa_decode_cascade(c),
            WorkloadSpec::ServingMix(c) => families::serving_mix_cascade(c),
            WorkloadSpec::Cascade(c) => c.clone(),
        }
    }

    /// Serialize to the workload JSON schema: every built-in is a
    /// serializable definition, not code-only — re-parsing this and
    /// evaluating is bit-identical to the in-code cascade (the
    /// differential workload suite's contract).
    pub fn to_json(&self) -> Json {
        self.cascade().to_json()
    }

    /// Canonical evaluation-cache key. Built-ins key by display name
    /// (byte-stable across runs and processes, so disk-spilled caches
    /// written before the registry existed stay valid); file cascades
    /// add a content fingerprint so two documents sharing a `name` can
    /// never collide in the cache.
    pub fn cache_key(&self) -> String {
        match self {
            WorkloadSpec::Cascade(c) => {
                format!("file:{}:{:016x}", c.name, cascade_fingerprint(c))
            }
            _ => self.name().to_string(),
        }
    }
}

/// Canonical registry names, in `workload list` order.
pub fn names() -> &'static [&'static str] {
    &[
        "bert",
        "llama2",
        "gpt3",
        "moe_prefill",
        "moe_decode",
        "resnet50",
        "gqa_decode",
        "serving_mix",
    ]
}

/// Look a workload up by (case-insensitive) registered name.
pub fn by_name(name: &str) -> Option<WorkloadSpec> {
    if let Some(t) = transformer::by_name(name) {
        return Some(WorkloadSpec::Transformer(t));
    }
    let lower = name.to_ascii_lowercase();
    match lower.as_str() {
        "moe_prefill" | "moe-prefill" => Some(WorkloadSpec::Moe(families::moe_prefill())),
        "moe_decode" | "moe-decode" | "moe" => Some(WorkloadSpec::Moe(families::moe_decode())),
        "resnet50" | "resnet" | "cnn" => Some(WorkloadSpec::Conv(families::resnet50())),
        "gqa_decode" | "gqa-decode" | "gqa" => {
            Some(WorkloadSpec::GqaDecode(families::gqa_long_decode()))
        }
        "serving_mix" | "serving-mix" => {
            Some(WorkloadSpec::ServingMix(families::serving_mix()))
        }
        _ => None,
    }
}

/// Every registered built-in as `(registry name, spec)`, Table II first.
pub fn all_builtins() -> Vec<(&'static str, WorkloadSpec)> {
    names().iter().map(|&n| (n, by_name(n).expect("registered name"))).collect()
}

/// The paper's Table II grid as specs (what the paper-figure drivers
/// sweep — deliberately NOT the whole registry, so the committed
/// fig6–fig10 goldens never move when a family is added).
pub fn paper_specs() -> Vec<WorkloadSpec> {
    transformer::paper_workloads().into_iter().map(WorkloadSpec::Transformer).collect()
}

/// Does a CLI/config workload value look like a file path rather than a
/// registered name?
pub fn looks_like_path(s: &str) -> bool {
    s.ends_with(".json") || s.contains('/') || s.contains('\\')
}

/// Load a workload cascade from a JSON document on disk.
pub fn load_file(path: &str) -> Result<WorkloadSpec, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let cascade = Cascade::from_json(&doc).map_err(|e| format!("{path}: {e}"))?;
    Ok(WorkloadSpec::Cascade(cascade))
}

/// Classify a CLI/config workload value WITHOUT touching the
/// filesystem: a registered name becomes a spec, a path-shaped value a
/// lazy file source (so callers can resolve relative paths first), and
/// anything else errors loudly with the full list — never a silent
/// fallback. The single dispatch point for `--workload`, the config
/// `"workload"` key, and [`resolve`].
pub fn source_for(value: &str) -> Result<WorkloadSource, String> {
    if let Some(w) = by_name(value) {
        return Ok(WorkloadSource::Spec(w));
    }
    if looks_like_path(value) {
        return Ok(WorkloadSource::File(value.to_string()));
    }
    Err(format!(
        "unknown workload '{value}' (built-ins: {}; or give a cascade .json file)",
        names().join(", ")
    ))
}

/// Resolve a CLI workload value eagerly: a registered name, or a path
/// to a cascade JSON file (loaded immediately).
pub fn resolve(name_or_path: &str) -> Result<WorkloadSpec, String> {
    source_for(name_or_path)?.load()
}

/// Resolve a built-in-only selector (the CLI's `--model`): unknown
/// names — including path-shaped values — error with the registry
/// list and point at `--workload` for files.
pub fn resolve_builtin(name: &str) -> Result<WorkloadSpec, String> {
    by_name(name).ok_or_else(|| {
        format!(
            "unknown built-in workload '{name}' (built-ins: {}); use --workload for a \
             cascade .json file",
            names().join(", ")
        )
    })
}

/// Where an experiment config's workload comes from. File paths load
/// lazily so `ExperimentConfig::load` can first resolve them relative
/// to the config file's directory (exactly like the `topology` key).
#[derive(Debug, Clone)]
pub enum WorkloadSource {
    Spec(WorkloadSpec),
    File(String),
}

impl WorkloadSource {
    pub fn load(&self) -> Result<WorkloadSpec, String> {
        match self {
            WorkloadSource::Spec(s) => Ok(s.clone()),
            WorkloadSource::File(p) => load_file(p),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_name_resolves_and_generates() {
        for (key, spec) in all_builtins() {
            let g = spec.cascade();
            assert!(!g.ops.is_empty(), "{key}");
            g.validate().unwrap_or_else(|e| panic!("{key}: {e}"));
            assert_eq!(spec.cache_key(), spec.name(), "{key}: built-ins key by name");
        }
        assert_eq!(all_builtins().len(), names().len());
    }

    #[test]
    fn aliases_and_case_insensitivity() {
        assert_eq!(by_name("MoE").unwrap().name(), "MoE-decode");
        assert_eq!(by_name("moe-prefill").unwrap().name(), "MoE-prefill");
        assert_eq!(by_name("GQA").unwrap().name(), "GQA-long-decode");
        assert_eq!(by_name("cnn").unwrap().name(), "ResNet50-im2col");
        assert_eq!(by_name("bert").unwrap().name(), "BERT-large");
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn resolve_rejects_unknown_names_with_the_list() {
        let err = resolve("not-a-workload").unwrap_err();
        assert!(err.contains("unknown workload"), "{err}");
        assert!(err.contains("moe_decode"), "list missing from: {err}");
        // A path-shaped value that does not exist errors as a file.
        let err = resolve("does/not/exist.json").unwrap_err();
        assert!(err.contains("exist.json"), "{err}");
    }

    #[test]
    fn file_cache_keys_fingerprint_content() {
        let doc = |m: u64| {
            format!(
                r#"{{"name":"same","ops":[{{"name":"g","kind":"gemm","phase":"encoder",
                    "m":{m},"n":4,"k":4}}]}}"#
            )
        };
        let a = WorkloadSpec::Cascade(
            Cascade::from_json(&Json::parse(&doc(4)).unwrap()).unwrap(),
        );
        let b = WorkloadSpec::Cascade(
            Cascade::from_json(&Json::parse(&doc(8)).unwrap()).unwrap(),
        );
        assert_eq!(a.name(), b.name());
        assert_ne!(a.cache_key(), b.cache_key(), "same name, different content");
        assert!(a.cache_key().starts_with("file:same:"));
    }
}
