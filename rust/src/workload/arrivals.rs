//! Arrival-process front-end for the serving simulator: deterministic,
//! seeded request streams (synthetic Poisson and bursty processes) plus
//! a trace-file JSON schema, with per-request context/output lengths
//! drawn from the llama2 / GQA / MoE family shapes.
//!
//! Everything here is pure data generation — no threads, no clocks —
//! so a fixed seed yields bit-identical streams on any machine and
//! under any `HARP_THREADS`. Time is measured in cycles throughout;
//! offered load is expressed as requests per million cycles (Mcycle).

use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::families::{gqa_long_decode, moe_decode};
use crate::workload::transformer;

/// Model family a request belongs to. Each family pins the KV-cache
/// row width (`d_model`) and the base context/output lengths its
/// requests are drawn around.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RequestFamily {
    /// Dense decoder (`transformer::llama2`).
    Llama2,
    /// Long-context grouped-query decoder (`families::gqa_long_decode`).
    Gqa,
    /// Mixture-of-experts decoder (`families::moe_decode`).
    Moe,
}

impl RequestFamily {
    pub const ALL: [RequestFamily; 3] =
        [RequestFamily::Llama2, RequestFamily::Gqa, RequestFamily::Moe];

    pub fn name(self) -> &'static str {
        match self {
            RequestFamily::Llama2 => "llama2",
            RequestFamily::Gqa => "gqa",
            RequestFamily::Moe => "moe",
        }
    }

    pub fn parse(s: &str) -> Result<RequestFamily, String> {
        match s.to_ascii_lowercase().as_str() {
            "llama2" => Ok(RequestFamily::Llama2),
            "gqa" | "gqa_decode" => Ok(RequestFamily::Gqa),
            "moe" | "moe_decode" => Ok(RequestFamily::Moe),
            other => Err(format!(
                "unknown request family '{other}' (known: llama2, gqa, moe)"
            )),
        }
    }

    /// Model width — one KV-cache word per context position per unit of
    /// `d_model` (K and V fold into the constant factor; what matters
    /// for admission is that booking scales with `context × d_model`).
    pub fn d_model(self) -> u64 {
        match self {
            RequestFamily::Llama2 => transformer::llama2().d_model,
            RequestFamily::Gqa => gqa_long_decode().d_model,
            RequestFamily::Moe => moe_decode().d_model,
        }
    }

    pub fn heads(self) -> u64 {
        match self {
            RequestFamily::Llama2 => transformer::llama2().heads,
            RequestFamily::Gqa => gqa_long_decode().heads,
            RequestFamily::Moe => moe_decode().heads,
        }
    }

    /// Effective FFN width (MoE counts only the `top_k` active experts).
    pub fn d_ff_effective(self) -> u64 {
        match self {
            RequestFamily::Llama2 => transformer::llama2().d_ff,
            RequestFamily::Gqa => gqa_long_decode().d_ff,
            RequestFamily::Moe => {
                let cfg = moe_decode();
                cfg.d_ff * cfg.top_k
            }
        }
    }

    /// Base context length requests are drawn around (the family's
    /// canonical prefill sequence length).
    pub fn base_context(self) -> u64 {
        match self {
            RequestFamily::Llama2 => transformer::llama2().seq,
            RequestFamily::Gqa => gqa_long_decode().seq,
            RequestFamily::Moe => moe_decode().seq,
        }
    }

    /// Base output (decode) length requests are drawn around.
    pub fn base_output(self) -> u64 {
        match self {
            RequestFamily::Llama2 => transformer::llama2().decode_tokens,
            RequestFamily::Gqa => gqa_long_decode().decode_tokens,
            RequestFamily::Moe => moe_decode().decode_tokens,
        }
    }
}

/// Herald-style latency class. Admission orders the wait queue by
/// (class, arrival): every `interactive` request is admitted before any
/// `batch` request, and each class can carry its own TTFT SLO.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum RequestClass {
    /// Latency-sensitive traffic; admitted first. The default, so
    /// streams that never mention classes behave exactly like the
    /// classless FIFO engine did.
    #[default]
    Interactive,
    /// Throughput traffic; yields the admission queue to interactive.
    Batch,
}

impl RequestClass {
    pub const ALL: [RequestClass; 2] = [RequestClass::Interactive, RequestClass::Batch];

    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Interactive => "interactive",
            RequestClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Result<RequestClass, String> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" => Ok(RequestClass::Interactive),
            "batch" => Ok(RequestClass::Batch),
            other => Err(format!(
                "unknown request class '{other}' (known: interactive, batch)"
            )),
        }
    }

    /// Admission rank: lower admits first.
    pub fn rank(self) -> u8 {
        match self {
            RequestClass::Interactive => 0,
            RequestClass::Batch => 1,
        }
    }
}

/// One serving request: arrives at `arrival` (cycles), prefills
/// `context` tokens, then decodes `output` tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Position in the arrival-sorted stream.
    pub id: usize,
    /// Arrival time in cycles.
    pub arrival: f64,
    pub family: RequestFamily,
    /// Prompt length in tokens (KV cache booked over it).
    pub context: u64,
    /// Decode length in tokens.
    pub output: u64,
    /// Latency class used for admission ordering and per-class SLOs.
    pub class: RequestClass,
}

/// Synthetic arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Memoryless: exponential inter-arrival gaps at the offered rate.
    Poisson,
    /// Poisson burst epochs at a quarter of the offered rate, each
    /// releasing a geometric-ish clump (mean 4) of near-simultaneous
    /// requests — same mean load, much uglier tail.
    Bursty,
    /// Requests come from a trace file, not a generator.
    Trace,
}

impl ArrivalKind {
    pub fn name(self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Trace => "trace",
        }
    }

    pub fn parse(s: &str) -> Result<ArrivalKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "poisson" => Ok(ArrivalKind::Poisson),
            "bursty" => Ok(ArrivalKind::Bursty),
            "trace" => Ok(ArrivalKind::Trace),
            other => Err(format!(
                "unknown arrival process '{other}' (known: poisson, bursty, trace)"
            )),
        }
    }
}

/// Parse a workload mix: a bare family name (`llama2`) or a weighted
/// list (`llama2:3,gqa:1,moe:1`). Weights must be finite and positive.
pub fn parse_mix(s: &str) -> Result<Vec<(RequestFamily, f64)>, String> {
    parse_weighted(s, "workload mix", "family", &RequestFamily::parse)
}

/// Parse a class mix: a bare class name (`interactive`) or a weighted
/// list (`interactive:1,batch:3`). Same grammar and error shapes as the
/// workload mix.
pub fn parse_class_mix(s: &str) -> Result<Vec<(RequestClass, f64)>, String> {
    parse_weighted(s, "class mix", "class", &RequestClass::parse)
}

fn parse_weighted<T: Copy + PartialEq>(
    s: &str,
    what: &str,
    item: &str,
    parse_item: &dyn Fn(&str) -> Result<T, String>,
) -> Result<Vec<(T, f64)>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            return Err(format!("{what} '{s}': empty component"));
        }
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => {
                let weight: f64 = w.trim().parse().map_err(|_| {
                    format!("{what} component '{part}': weight '{w}' is not a number")
                })?;
                (n.trim(), weight)
            }
            None => (part, 1.0),
        };
        if !weight.is_finite() || weight <= 0.0 {
            return Err(format!(
                "{what} component '{part}': weight must be finite and positive"
            ));
        }
        let parsed =
            parse_item(name).map_err(|e| format!("{what} component '{part}': {e}"))?;
        if out.iter().any(|&(f, _)| f == parsed) {
            return Err(format!("{what} '{s}': {item} '{name}' listed twice"));
        }
        out.push((parsed, weight));
    }
    Ok(out)
}

/// Parameters of a synthetic request stream.
#[derive(Debug, Clone)]
pub struct StreamParams {
    pub kind: ArrivalKind,
    pub mix: Vec<(RequestFamily, f64)>,
    /// Latency-class mix. Empty or a single `interactive` entry is the
    /// classless default; a single non-default entry labels every
    /// request; multiple entries draw per-request classes by weight
    /// from a class-only RNG stream, so arrivals and lengths stay
    /// bit-identical across class mixes.
    pub classes: Vec<(RequestClass, f64)>,
    /// Offered load in requests per million cycles.
    pub load: f64,
    /// Stream length in requests.
    pub requests: usize,
    pub seed: u64,
}

/// Generate a synthetic stream. Deterministic in `seed`: one PRNG,
/// sequential draws, no wall clock — bit-identical across runs and
/// `HARP_THREADS`.
pub fn synthesize(p: &StreamParams) -> Result<Vec<Request>, String> {
    if p.kind == ArrivalKind::Trace {
        return Err("trace streams come from a trace file, not the generator".into());
    }
    if !p.load.is_finite() || p.load <= 0.0 {
        return Err(format!("offered load must be finite and positive, got {}", p.load));
    }
    if p.requests == 0 {
        return Err("request count must be positive".into());
    }
    if p.mix.is_empty() {
        return Err("workload mix must name at least one family".into());
    }
    if p.classes.iter().any(|&(_, w)| !w.is_finite() || w <= 0.0) {
        return Err("class mix weights must be finite and positive".into());
    }
    let rate = p.load / 1.0e6; // requests per cycle
    let mut rng = Rng::new(p.seed);
    let mut shape_rng = rng.fork(1);
    let mut reqs = Vec::with_capacity(p.requests);
    let mut t = 0.0f64;
    match p.kind {
        ArrivalKind::Poisson => {
            while reqs.len() < p.requests {
                // Exponential gap; next_f64 ∈ [0,1) keeps ln(1-u) finite.
                t += -(1.0 - rng.next_f64()).ln() / rate;
                reqs.push(draw_request(reqs.len(), t, &p.mix, &mut shape_rng));
            }
        }
        ArrivalKind::Bursty => {
            while reqs.len() < p.requests {
                t += -(1.0 - rng.next_f64()).ln() / (rate / 4.0);
                let burst = 1 + rng.next_below(7); // 1..=7, mean 4
                for i in 0..burst {
                    if reqs.len() >= p.requests {
                        break;
                    }
                    // Small fixed stagger so same-burst arrivals stay
                    // distinct (and the sort below stays meaningful).
                    let at = t + i as f64 * 64.0;
                    reqs.push(draw_request(reqs.len(), at, &p.mix, &mut shape_rng));
                }
            }
        }
        ArrivalKind::Trace => unreachable!(),
    }
    let mut reqs = finalize(reqs);
    assign_classes(&mut reqs, &p.classes, p.seed);
    Ok(reqs)
}

/// Seed salt for the class-label RNG. Classes ride on their own stream
/// (derived from the seed arithmetically, never from `Rng::fork`, which
/// consumes parent state) so gap/shape draws — and therefore the whole
/// default stream — are bit-identical whether or not classes are in
/// play.
const CLASS_SEED_SALT: u64 = 0xC1A5_5EED_BA7C_4A0B;

/// Label requests with latency classes, in arrival order. An empty mix
/// leaves the `Interactive` default untouched; a single-entry mix
/// labels uniformly without drawing; a weighted mix draws per request.
fn assign_classes(reqs: &mut [Request], classes: &[(RequestClass, f64)], seed: u64) {
    match classes {
        [] => {}
        [(only, _)] => {
            for r in reqs.iter_mut() {
                r.class = *only;
            }
        }
        mix => {
            let total: f64 = mix.iter().map(|&(_, w)| w).sum();
            let mut rng = Rng::new(seed ^ CLASS_SEED_SALT);
            for r in reqs.iter_mut() {
                let mut u = rng.next_f64() * total;
                r.class = mix[mix.len() - 1].0;
                for &(c, w) in mix {
                    if u < w {
                        r.class = c;
                        break;
                    }
                    u -= w;
                }
            }
        }
    }
}

/// Draw one request: family by mix weight, context/output uniform in
/// [base/4, base] of the family's canonical lengths.
fn draw_request(
    id: usize,
    arrival: f64,
    mix: &[(RequestFamily, f64)],
    rng: &mut Rng,
) -> Request {
    let total: f64 = mix.iter().map(|&(_, w)| w).sum();
    let mut u = rng.next_f64() * total;
    let mut family = mix[mix.len() - 1].0;
    for &(f, w) in mix {
        if u < w {
            family = f;
            break;
        }
        u -= w;
    }
    let context = draw_len(family.base_context(), rng);
    let output = draw_len(family.base_output(), rng);
    Request { id, arrival, family, context, output, class: RequestClass::Interactive }
}

fn draw_len(base: u64, rng: &mut Rng) -> u64 {
    let lo = (base / 4).max(1);
    lo + rng.next_below((base - lo + 1) as usize) as u64
}

/// Sort by arrival (total order, so degenerate floats cannot panic) and
/// re-number so `id` is the position in arrival order.
fn finalize(mut reqs: Vec<Request>) -> Vec<Request> {
    reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
    for (i, r) in reqs.iter_mut().enumerate() {
        r.id = i;
    }
    reqs
}

/// Parse a trace document:
///
/// ```json
/// { "requests": [
///     { "arrival": 0.0, "family": "llama2", "context": 512, "output": 64 }
/// ] }
/// ```
///
/// `arrival` is cycles (any order — the stream is sorted), `family` is
/// one of `llama2 | gqa | moe`, `context`/`output` are positive token
/// counts, and the optional `class` is `interactive | batch` (default
/// `interactive`). Every malformed field gets its own loud, distinct
/// error — in particular `context: 0` / `output: 0` are rejected here
/// rather than poisoning per-token latency downstream.
pub fn load_trace(text: &str) -> Result<Vec<Request>, String> {
    let j = Json::parse(text).map_err(|e| format!("trace: {e}"))?;
    reject_unknown_keys(&j, &["requests"], "trace")?;
    let arr = j
        .get("requests")
        .ok_or("trace: missing 'requests' array")?
        .as_arr()
        .ok_or("trace: 'requests' must be an array")?;
    if arr.is_empty() {
        return Err("trace: 'requests' must be non-empty".into());
    }
    let mut reqs = Vec::with_capacity(arr.len());
    for (i, r) in arr.iter().enumerate() {
        let what = format!("trace request {i}");
        reject_unknown_keys(r, &["arrival", "family", "context", "output", "class"], &what)?;
        let arrival = r
            .get("arrival")
            .and_then(Json::as_f64)
            .ok_or(format!("{what}: 'arrival' must be a number"))?;
        if !arrival.is_finite() || arrival < 0.0 {
            return Err(format!("{what}: 'arrival' must be finite and non-negative"));
        }
        let family = r
            .get("family")
            .and_then(Json::as_str)
            .ok_or(format!("{what}: 'family' must be a string"))
            .and_then(|s| RequestFamily::parse(s).map_err(|e| format!("{what}: {e}")))?;
        let context = r
            .get("context")
            .and_then(Json::as_u64)
            .ok_or(format!("{what}: 'context' must be a positive integer"))?;
        let output = r
            .get("output")
            .and_then(Json::as_u64)
            .ok_or(format!("{what}: 'output' must be a positive integer"))?;
        // Zero lengths get errors distinct from missing/non-integer
        // fields: a zero-output request would make the engine's forced
        // first decode token divide per-token latency by zero, and a
        // zero-context request books no KV yet still prefills.
        if context == 0 {
            return Err(format!(
                "{what}: 'context' is 0 — a request must prefill at least one token"
            ));
        }
        if output == 0 {
            return Err(format!(
                "{what}: 'output' is 0 — a request must decode at least one token \
                 (zero would poison per-token latency)"
            ));
        }
        let class = match r.get("class") {
            None => RequestClass::Interactive,
            Some(v) => v
                .as_str()
                .ok_or(format!("{what}: 'class' must be a string"))
                .and_then(|s| RequestClass::parse(s).map_err(|e| format!("{what}: {e}")))?,
        };
        reqs.push(Request { id: i, arrival, family, context, output, class });
    }
    Ok(finalize(reqs))
}

/// Same contract as the workload schema's guard: unknown and duplicate
/// keys are loud errors, not silent no-ops. Shared with the config
/// parser's `"arrivals"` object.
pub(crate) fn reject_unknown_keys(j: &Json, known: &[&str], what: &str) -> Result<(), String> {
    if let Json::Obj(pairs) = j {
        let mut seen: Vec<&str> = Vec::with_capacity(pairs.len());
        for (key, _) in pairs {
            if !known.contains(&key.as_str()) {
                return Err(format!(
                    "{what}: unknown key '{key}' (known: {})",
                    known.join(", ")
                ));
            }
            if seen.contains(&key.as_str()) {
                return Err(format!("{what}: duplicate key '{key}'"));
            }
            seen.push(key.as_str());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(seed: u64) -> Vec<Request> {
        synthesize(&StreamParams {
            kind: ArrivalKind::Poisson,
            mix: RequestFamily::ALL.iter().map(|&f| (f, 1.0)).collect(),
            classes: vec![],
            load: 2.0,
            requests: 50,
            seed,
        })
        .unwrap()
    }

    #[test]
    fn poisson_stream_is_sorted_and_sized() {
        let reqs = poisson(7);
        assert_eq!(reqs.len(), 50);
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(r.context >= 1 && r.output >= 1);
            assert!(r.context <= r.family.base_context());
            assert!(r.output <= r.family.base_output());
        }
    }

    #[test]
    fn streams_bit_identical_for_seed() {
        let (a, b) = (poisson(7), poisson(7));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!((x.family, x.context, x.output), (y.family, y.context, y.output));
        }
        assert_ne!(poisson(7)[0].arrival.to_bits(), poisson(8)[0].arrival.to_bits());
    }

    #[test]
    fn bursty_differs_but_is_deterministic() {
        let mk = |seed| {
            synthesize(&StreamParams {
                kind: ArrivalKind::Bursty,
                mix: vec![(RequestFamily::Llama2, 1.0)],
                classes: vec![],
                load: 2.0,
                requests: 50,
                seed,
            })
            .unwrap()
        };
        let (a, b) = (mk(7), mk(7));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
        }
        let p = poisson(7);
        assert!(a.iter().zip(&p).any(|(x, y)| x.arrival.to_bits() != y.arrival.to_bits()));
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn mix_parses_and_rejects() {
        assert_eq!(parse_mix("llama2").unwrap(), vec![(RequestFamily::Llama2, 1.0)]);
        let m = parse_mix("llama2:3, gqa:1").unwrap();
        assert_eq!(m, vec![(RequestFamily::Llama2, 3.0), (RequestFamily::Gqa, 1.0)]);
        for (s, want) in [
            ("", "empty component"),
            ("llama2:x", "is not a number"),
            ("llama2:-1", "finite and positive"),
            ("llama2:0", "finite and positive"),
            ("bert", "unknown request family"),
            ("llama2,llama2", "listed twice"),
        ] {
            let err = parse_mix(s).unwrap_err();
            assert!(err.contains(want), "mix '{s}': got '{err}', want '{want}'");
        }
    }

    #[test]
    fn synthetic_param_errors_are_loud() {
        let base = StreamParams {
            kind: ArrivalKind::Poisson,
            mix: vec![(RequestFamily::Llama2, 1.0)],
            classes: vec![],
            load: 2.0,
            requests: 10,
            seed: 1,
        };
        let err = synthesize(&StreamParams { load: 0.0, ..base.clone() }).unwrap_err();
        assert!(err.contains("load"), "{err}");
        let err = synthesize(&StreamParams { requests: 0, ..base.clone() }).unwrap_err();
        assert!(err.contains("request count"), "{err}");
        let err = synthesize(&StreamParams { mix: vec![], ..base.clone() }).unwrap_err();
        assert!(err.contains("mix"), "{err}");
        let err = synthesize(&StreamParams {
            classes: vec![(RequestClass::Batch, 0.0)],
            ..base.clone()
        })
        .unwrap_err();
        assert!(err.contains("class mix"), "{err}");
        let err = synthesize(&StreamParams { kind: ArrivalKind::Trace, ..base }).unwrap_err();
        assert!(err.contains("trace"), "{err}");
    }

    #[test]
    fn class_mix_parses_and_rejects() {
        assert_eq!(
            parse_class_mix("interactive").unwrap(),
            vec![(RequestClass::Interactive, 1.0)]
        );
        let m = parse_class_mix("interactive:1, batch:3").unwrap();
        assert_eq!(m, vec![(RequestClass::Interactive, 1.0), (RequestClass::Batch, 3.0)]);
        for (s, want) in [
            ("", "empty component"),
            ("batch:x", "is not a number"),
            ("batch:0", "finite and positive"),
            ("premium", "unknown request class"),
            ("batch,batch", "listed twice"),
        ] {
            let err = parse_class_mix(s).unwrap_err();
            assert!(err.contains(want), "class mix '{s}': got '{err}', want '{want}'");
        }
    }

    #[test]
    fn classes_ride_a_separate_stream() {
        // Arrivals, families, and lengths must be bit-identical whether
        // the stream is classless, uniformly labeled, or a weighted
        // draw — only the class labels may differ.
        let with = |classes: Vec<(RequestClass, f64)>| {
            synthesize(&StreamParams {
                kind: ArrivalKind::Poisson,
                mix: vec![(RequestFamily::Llama2, 1.0)],
                classes,
                load: 2.0,
                requests: 50,
                seed: 7,
            })
            .unwrap()
        };
        let plain = with(vec![]);
        let uniform = with(vec![(RequestClass::Batch, 1.0)]);
        let mixed =
            with(vec![(RequestClass::Interactive, 1.0), (RequestClass::Batch, 1.0)]);
        assert!(plain.iter().all(|r| r.class == RequestClass::Interactive));
        assert!(uniform.iter().all(|r| r.class == RequestClass::Batch));
        assert!(mixed.iter().any(|r| r.class == RequestClass::Interactive));
        assert!(mixed.iter().any(|r| r.class == RequestClass::Batch));
        for ((a, b), c) in plain.iter().zip(&uniform).zip(&mixed) {
            for r in [b, c] {
                assert_eq!(a.arrival.to_bits(), r.arrival.to_bits());
                assert_eq!(
                    (a.family, a.context, a.output),
                    (r.family, r.context, r.output)
                );
            }
        }
        // And the weighted draw itself is deterministic in the seed.
        let again =
            with(vec![(RequestClass::Interactive, 1.0), (RequestClass::Batch, 1.0)]);
        assert!(mixed.iter().zip(&again).all(|(a, b)| a.class == b.class));
    }

    const TRACE: &str = r#"{"requests":[
        {"arrival": 500.0, "family": "gqa", "context": 1024, "output": 32},
        {"arrival": 0.0, "family": "llama2", "context": 256, "output": 16}
    ]}"#;

    #[test]
    fn trace_loads_and_sorts() {
        let reqs = load_trace(TRACE).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].family, RequestFamily::Llama2);
        assert_eq!(reqs[0].id, 0);
        assert_eq!(reqs[1].family, RequestFamily::Gqa);
        assert!(reqs[0].arrival < reqs[1].arrival);
        // No "class" key → everything defaults to interactive.
        assert!(reqs.iter().all(|r| r.class == RequestClass::Interactive));
    }

    #[test]
    fn trace_carries_per_request_classes() {
        let doc = r#"{"requests":[
            {"arrival": 0.0, "family": "llama2", "context": 8, "output": 4, "class": "batch"},
            {"arrival": 1.0, "family": "llama2", "context": 8, "output": 4, "class": "interactive"},
            {"arrival": 2.0, "family": "llama2", "context": 8, "output": 4}
        ]}"#;
        let reqs = load_trace(doc).unwrap();
        assert_eq!(
            reqs.iter().map(|r| r.class).collect::<Vec<_>>(),
            vec![RequestClass::Batch, RequestClass::Interactive, RequestClass::Interactive]
        );
    }

    #[test]
    fn trace_errors_are_loud_and_distinct() {
        for (doc, want) in [
            ("[1]", "missing 'requests'"),
            (r#"{"requests": 3}"#, "'requests' must be an array"),
            (r#"{"requests": []}"#, "must be non-empty"),
            (r#"{"requests": [], "extra": 1}"#, "unknown key 'extra'"),
            (r#"{"requests": [{"family":"llama2","context":1,"output":1}]}"#,
             "'arrival' must be a number"),
            (r#"{"requests": [{"arrival":-1,"family":"llama2","context":1,"output":1}]}"#,
             "finite and non-negative"),
            (r#"{"requests": [{"arrival":0,"family":"bert","context":1,"output":1}]}"#,
             "unknown request family"),
            (r#"{"requests": [{"arrival":0,"family":"llama2","output":1}]}"#,
             "'context' must be a positive integer"),
            // Zero lengths are distinct from missing/non-integer fields.
            (r#"{"requests": [{"arrival":0,"family":"llama2","context":0,"output":1}]}"#,
             "'context' is 0"),
            (r#"{"requests": [{"arrival":0,"family":"llama2","context":1,"output":0}]}"#,
             "'output' is 0"),
            (r#"{"requests": [{"arrival":0,"family":"llama2","context":1,"output":1,"slo":9}]}"#,
             "unknown key 'slo'"),
            (r#"{"requests": [{"arrival":0,"family":"llama2","context":1,"output":1,"class":3}]}"#,
             "'class' must be a string"),
            (r#"{"requests": [{"arrival":0,"family":"llama2","context":1,"output":1,"class":"gold"}]}"#,
             "unknown request class"),
        ] {
            let err = load_trace(doc).unwrap_err();
            assert!(err.contains(want), "doc {doc}: got '{err}', want '{want}'");
        }
    }

    #[test]
    fn truncated_trace_never_panics() {
        let mut step = 97;
        while step < TRACE.len() {
            let cut = &TRACE[..step];
            // Must error (or, if the cut lands on a valid prefix, parse) —
            // never panic. All 97-byte-step cuts of TRACE are invalid JSON.
            assert!(load_trace(cut).is_err(), "cut at {step} unexpectedly parsed");
            step += 97;
        }
    }
}
