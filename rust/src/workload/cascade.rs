//! Cascades: DAGs of tensor operations with producer→consumer edges.
//!
//! The dependency structure is what distinguishes intra-cascade
//! partitioning (BERT: logit may only overlap V-generation) from
//! inter-cascade partitioning (GPT/Llama: the prefill and decode
//! sub-cascades are independent at batch granularity) — paper §II-B, §III-B.

use super::einsum::{Phase, TensorOp};

/// A directed acyclic graph of tensor operations.
#[derive(Debug, Clone, Default)]
pub struct Cascade {
    pub name: String,
    pub ops: Vec<TensorOp>,
    /// Edges as (producer index, consumer index).
    pub deps: Vec<(usize, usize)>,
}

/// Precomputed adjacency lists for a cascade, built once in O(V + E).
/// Per-node lists preserve `deps` order, so algorithms that switch from
/// the scanning accessors to this index produce identical traversals.
#[derive(Debug, Clone)]
pub struct CascadeAdj {
    pub preds: Vec<Vec<usize>>,
    pub succs: Vec<Vec<usize>>,
}

impl CascadeAdj {
    pub fn new(cascade: &Cascade) -> CascadeAdj {
        let n = cascade.ops.len();
        let mut preds = vec![Vec::new(); n];
        let mut succs = vec![Vec::new(); n];
        for &(p, c) in &cascade.deps {
            succs[p].push(c);
            preds[c].push(p);
        }
        CascadeAdj { preds, succs }
    }
}

impl Cascade {
    pub fn new(name: &str) -> Cascade {
        Cascade { name: name.into(), ops: Vec::new(), deps: Vec::new() }
    }

    /// Append an operation, returning its index.
    pub fn push(&mut self, op: TensorOp) -> usize {
        self.ops.push(op);
        self.ops.len() - 1
    }

    /// Add a dependency edge; panics on out-of-range indices.
    pub fn dep(&mut self, producer: usize, consumer: usize) {
        assert!(producer < self.ops.len() && consumer < self.ops.len());
        self.deps.push((producer, consumer));
    }

    /// Indices of direct predecessors of `op`. O(E) with a fresh `Vec`
    /// per call — fine for one-off queries; anything querying every node
    /// (schedulers, path analyses) should build a [`CascadeAdj`] once.
    pub fn predecessors(&self, op: usize) -> Vec<usize> {
        self.deps.iter().filter(|(_, c)| *c == op).map(|(p, _)| *p).collect()
    }

    /// Indices of direct successors of `op` (same O(E) caveat as
    /// [`Cascade::predecessors`]).
    pub fn successors(&self, op: usize) -> Vec<usize> {
        self.deps.iter().filter(|(p, _)| *p == op).map(|(_, c)| *c).collect()
    }

    /// Kahn topological order; `Err` if the graph has a cycle.
    pub fn topo_order(&self) -> Result<Vec<usize>, String> {
        self.topo_order_with(&CascadeAdj::new(self))
    }

    /// [`Cascade::topo_order`] against a prebuilt adjacency (avoids the
    /// O(V·E) per-node edge scans the naive version paid).
    pub fn topo_order_with(&self, adj: &CascadeAdj) -> Result<Vec<usize>, String> {
        let n = self.ops.len();
        let mut indeg: Vec<usize> = adj.preds.iter().map(|p| p.len()).collect();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = queue.pop() {
            order.push(i);
            for &s in &adj.succs[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        if order.len() == n {
            Ok(order)
        } else {
            Err(format!("cascade '{}' contains a cycle", self.name))
        }
    }

    /// Validate: acyclic, no self-edges, no duplicate edges.
    pub fn validate(&self) -> Result<(), String> {
        for &(p, c) in &self.deps {
            if p == c {
                return Err(format!("self-dependency on op {p}"));
            }
        }
        let mut seen = std::collections::HashSet::new();
        for e in &self.deps {
            if !seen.insert(*e) {
                return Err(format!("duplicate edge {e:?}"));
            }
        }
        self.topo_order().map(|_| ())
    }

    /// Total MACs across all operations (incl. repetitions).
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.total_macs()).sum()
    }

    /// Critical-path length under a per-op latency function
    /// (`latency(i)` must already include the op's `count` repetitions).
    pub fn critical_path<F: Fn(usize) -> f64>(&self, latency: F) -> f64 {
        let adj = CascadeAdj::new(self);
        let order = self.topo_order_with(&adj).expect("valid DAG");
        let mut finish = vec![0.0f64; self.ops.len()];
        // Forward pass in topological order.
        for &i in &order {
            let start =
                adj.preds[i].iter().map(|&p| finish[p]).fold(0.0f64, f64::max);
            finish[i] = start + latency(i);
        }
        finish.into_iter().fold(0.0f64, f64::max)
    }

    /// Ops of a given phase.
    pub fn ops_in_phase(&self, phase: Phase) -> Vec<usize> {
        (0..self.ops.len()).filter(|&i| self.ops[i].phase == phase).collect()
    }

    /// Merge another cascade in (no cross-edges added); returns the index
    /// offset applied to `other`'s ops. Used to join prefill + decode
    /// sub-cascades into one inter-cascade workload.
    pub fn merge(&mut self, other: &Cascade) -> usize {
        let offset = self.ops.len();
        self.ops.extend(other.ops.iter().cloned());
        self.deps.extend(other.deps.iter().map(|&(p, c)| (p + offset, c + offset)));
        offset
    }

    pub fn describe(&self) -> String {
        let mut s = format!(
            "cascade '{}': {} ops, {} edges, {:.3e} MACs\n",
            self.name,
            self.ops.len(),
            self.deps.len(),
            self.total_macs() as f64
        );
        for op in &self.ops {
            s.push_str("  ");
            s.push_str(&op.describe());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::einsum::Phase;

    fn diamond() -> Cascade {
        // a → b, a → c, b → d, c → d
        let mut g = Cascade::new("diamond");
        let a = g.push(TensorOp::gemm("a", Phase::Encoder, 4, 4, 4));
        let b = g.push(TensorOp::gemm("b", Phase::Encoder, 4, 4, 4));
        let c = g.push(TensorOp::gemm("c", Phase::Encoder, 4, 4, 4));
        let d = g.push(TensorOp::gemm("d", Phase::Encoder, 4, 4, 4));
        g.dep(a, b);
        g.dep(a, c);
        g.dep(b, d);
        g.dep(c, d);
        g
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        let pos: Vec<usize> =
            (0..4).map(|i| order.iter().position(|&x| x == i).unwrap()).collect();
        for &(p, c) in &g.deps {
            assert!(pos[p] < pos[c], "edge ({p},{c}) violated in {order:?}");
        }
    }

    #[test]
    fn detects_cycle() {
        let mut g = diamond();
        g.dep(3, 0);
        assert!(g.validate().is_err());
    }

    #[test]
    fn critical_path_on_diamond() {
        let g = diamond();
        // Unit latency each → path a→b→d = 3.
        assert_eq!(g.critical_path(|_| 1.0), 3.0);
        // Weighted: a=1, b=5, c=2, d=1 → a→b→d = 7.
        let lat = [1.0, 5.0, 2.0, 1.0];
        assert_eq!(g.critical_path(|i| lat[i]), 7.0);
    }

    #[test]
    fn merge_offsets_edges() {
        let mut g = diamond();
        let other = diamond();
        let off = g.merge(&other);
        assert_eq!(off, 4);
        assert_eq!(g.ops.len(), 8);
        assert!(g.deps.contains(&(4, 5)));
        g.validate().unwrap();
    }

    #[test]
    fn adjacency_matches_scanning_accessors() {
        let g = diamond();
        let adj = CascadeAdj::new(&g);
        for i in 0..g.ops.len() {
            assert_eq!(adj.preds[i], g.predecessors(i), "preds of {i}");
            assert_eq!(adj.succs[i], g.successors(i), "succs of {i}");
        }
        assert_eq!(g.topo_order_with(&adj).unwrap(), g.topo_order().unwrap());
    }

    #[test]
    fn phase_filter() {
        let mut g = Cascade::new("mixed");
        g.push(TensorOp::gemm("p", Phase::Prefill, 2, 2, 2));
        g.push(TensorOp::gemm("d", Phase::Decode, 2, 2, 2));
        assert_eq!(g.ops_in_phase(Phase::Prefill), vec![0]);
        assert_eq!(g.ops_in_phase(Phase::Decode), vec![1]);
    }
}
