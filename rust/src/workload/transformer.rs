//! Transformer workload generators — paper Table II.
//!
//! | Workload | Model      | Partitioning  | d_model | Seq length |
//! |----------|------------|---------------|---------|------------|
//! | Encoder  | BERT-large | Intra-cascade | 1024    | 256        |
//! | Decoder  | Llama-2    | Inter-cascade | 4096    | 3000/1000  |
//! | Decoder  | GPT-3      | Inter-cascade | 12288   | 3000/1000  |
//!
//! An encoder attention layer is emitted as the einsum cascade
//! `Q,K,V → logit → softmax → attend → deproj → FFN1 → FFN2` with the
//! dependency structure that limits intra-cascade overlap (only logit and
//! V-generation are independent — paper §II-B).
//!
//! A decoder workload is the prefill cascade (same einsums at prefill
//! sequence length) merged with the decode cascade: the autoregressive
//! token loop, compressed into chunks of `count`-repeated representative
//! shapes with the KV length taken at each chunk's midpoint. Prefill and
//! decode sub-cascades carry no cross-edges — they are decoupled at batch
//! granularity (paper §II-B), which is what inter-cascade partitioning
//! exploits.

use super::cascade::Cascade;
use super::einsum::{Phase, TensorOp};

/// Model hyper-parameters.
#[derive(Debug, Clone)]
pub struct TransformerConfig {
    pub name: String,
    pub d_model: u64,
    pub heads: u64,
    /// KV heads (grouped-query attention; == `heads` for plain MHA).
    /// Llama-2 serves with GQA — KV traffic shrinks by `heads/kv_heads`.
    pub kv_heads: u64,
    /// Feed-forward inner dimension (4 × d_model for the paper's models).
    pub d_ff: u64,
    /// Encoder / prefill sequence length.
    pub seq: u64,
    /// Number of generated tokens (decoder models only).
    pub decode_tokens: u64,
    /// Number of chunks the decode token loop is compressed into.
    pub decode_chunks: u64,
    /// Serving batch (continuous batching, as in the chatbot use-case of
    /// Bambhaniya et al. [5] and NeuPIM): this many requests move through
    /// prefill and decode together. Weights are shared across the batch
    /// (folded into `M`); KV caches are per-request (batch multiplies the
    /// BMM batch dimension). 1 for the encoder workload.
    pub batch: u64,
}

impl TransformerConfig {
    pub fn head_dim(&self) -> u64 {
        self.d_model / self.heads
    }

    /// Query heads per KV group.
    pub fn group_size(&self) -> u64 {
        self.heads / self.kv_heads
    }
}

/// BERT-large encoder workload (intra-cascade partitioning).
pub fn bert_large() -> TransformerConfig {
    TransformerConfig {
        name: "BERT-large".into(),
        d_model: 1024,
        heads: 16,
        kv_heads: 16,
        d_ff: 4096,
        seq: 256,
        decode_tokens: 0,
        decode_chunks: 0,
        batch: 1,
    }
}

/// Llama-2 decoder workload (inter-cascade partitioning, 3000/1000,
/// chatbot serving batch with grouped-query attention).
pub fn llama2() -> TransformerConfig {
    TransformerConfig {
        name: "Llama-2".into(),
        d_model: 4096,
        heads: 32,
        kv_heads: 4, // GQA, group size 8 (the Llama-2-70B family grouping)
        d_ff: 16384,
        seq: 3000,
        decode_tokens: 1000,
        decode_chunks: 4,
        batch: 64,
    }
}

/// GPT-3 decoder workload (inter-cascade partitioning, 3000/1000,
/// chatbot serving batch). Served with grouped KV heads (the serving
/// configuration of the chatbot use-case [5]; Duplex evaluates the same
/// GQA + continuous-batching regime): without KV grouping, batched
/// decode is pure KV streaming and no bandwidth partition can beat a
/// time-shared homogeneous machine — the prefill/decode balance the
/// paper's Fig 6 exhibits requires it.
pub fn gpt3() -> TransformerConfig {
    TransformerConfig {
        name: "GPT3".into(),
        d_model: 12288,
        heads: 96,
        kv_heads: 12,
        d_ff: 49152,
        seq: 3000,
        decode_tokens: 1000,
        decode_chunks: 4,
        batch: 64,
    }
}

/// All three Table II workloads.
pub fn paper_workloads() -> Vec<TransformerConfig> {
    vec![bert_large(), llama2(), gpt3()]
}

/// Look a Table II workload up by (case-insensitive) name. The full
/// registry — these three plus the mixed-reuse families — is
/// [`crate::workload::registry::by_name`]; CLI and configs go through
/// that.
pub fn by_name(name: &str) -> Option<TransformerConfig> {
    let lower = name.to_ascii_lowercase();
    match lower.as_str() {
        "bert" | "bert-large" => Some(bert_large()),
        "llama" | "llama2" | "llama-2" => Some(llama2()),
        "gpt" | "gpt3" | "gpt-3" => Some(gpt3()),
        _ => None,
    }
}

/// One attention + FFN layer at sequence length `seq`, tagged `phase`.
///
/// Returns the indices of the layer's first and final ops (for
/// chaining). Shared with the non-transformer families in
/// [`crate::workload::families`] (GQA long-context decode, serving
/// mix), so every attention block in the repo has one construction
/// path.
pub(crate) fn attention_layer(
    g: &mut Cascade,
    cfg: &TransformerConfig,
    phase: Phase,
    seq: u64,
    kv_len: u64,
    suffix: &str,
    count: u64,
) -> (usize, usize) {
    let d = cfg.d_model;
    let dh = cfg.head_dim();
    let nm = |base: &str| format!("{base}{suffix}");
    // Serving batch: weights are shared across requests, so the batch
    // folds into the GEMM row dimension; each request has its own KV
    // cache, so the batch multiplies the BMM batch dimension. With GQA,
    // `group_size` query heads share one KV head: the group folds into
    // the BMM row dimension (K/V reuse across the group), and the BMM
    // batch counts KV heads only.
    let rows = seq * cfg.batch;
    let bmm_b = cfg.kv_heads * cfg.batch;
    let bmm_m = seq * cfg.group_size();

    let q = g.push(TensorOp::gemm(&nm("q_gen"), phase, rows, d, d).repeated(count));
    let k = g.push(TensorOp::gemm(&nm("k_gen"), phase, rows, d, d).repeated(count));
    let v = g.push(TensorOp::gemm(&nm("v_gen"), phase, rows, d, d).repeated(count));
    // logit: P[b,m,n] = Q[b,m,dh] · K^T[b,dh,n], n = kv length.
    let logit =
        g.push(TensorOp::bmm(&nm("logit"), phase, bmm_b, bmm_m, dh, kv_len).repeated(count));
    let softmax =
        g.push(TensorOp::vector(&nm("softmax"), phase, bmm_b, bmm_m, kv_len).repeated(count));
    // attend: O[b,m,dh] = P[b,m,n] · V[b,n,dh].
    let attend =
        g.push(TensorOp::bmm(&nm("attend"), phase, bmm_b, bmm_m, kv_len, dh).repeated(count));
    let deproj = g.push(TensorOp::gemm(&nm("deproj"), phase, rows, d, d).repeated(count));
    let ffn1 = g.push(TensorOp::gemm(&nm("ffn1"), phase, rows, d, cfg.d_ff).repeated(count));
    let ffn2 = g.push(TensorOp::gemm(&nm("ffn2"), phase, rows, cfg.d_ff, d).repeated(count));

    // Dependency structure (paper §II-B): logit needs Q and K; attend
    // needs softmax(P) and V. V-generation is therefore the only GEMM
    // that can overlap logit — the limited intra-cascade opportunity.
    g.dep(q, logit);
    g.dep(k, logit);
    g.dep(logit, softmax);
    g.dep(softmax, attend);
    g.dep(v, attend);
    g.dep(attend, deproj);
    g.dep(deproj, ffn1);
    g.dep(ffn1, ffn2);

    (q, ffn2)
}

/// Encoder cascade (BERT): one attention layer at `cfg.seq`.
pub fn encoder_cascade(cfg: &TransformerConfig) -> Cascade {
    let mut g = Cascade::new(&cfg.name);
    attention_layer(&mut g, cfg, Phase::Encoder, cfg.seq, cfg.seq, "", 1);
    g.validate().expect("encoder cascade is a DAG");
    g
}

/// Decoder cascade (GPT-3 / Llama-2): prefill layer + compressed decode
/// token loop. No cross-edges between prefill and decode — the scheduler
/// may overlap them freely (inter-cascade decoupling).
pub fn decoder_cascade(cfg: &TransformerConfig) -> Cascade {
    assert!(cfg.decode_tokens > 0, "decoder cascade requires decode_tokens");
    let mut g = Cascade::new(&cfg.name);
    attention_layer(&mut g, cfg, Phase::Prefill, cfg.seq, cfg.seq, "_pre", 1);
    decode_chunk_loop(&mut g, cfg);
    g.validate().expect("decoder cascade is a DAG");
    g
}

/// Append the compressed decode token loop: `decode_tokens` single-token
/// steps compressed into `decode_chunks` chunks. Chunk c covers tokens
/// [c·T/C, (c+1)·T/C) with KV length sampled at the chunk midpoint
/// (starting from the `cfg.seq` context); its ops repeat `count` times
/// back-to-back, and chunks chain serially (tokens are autoregressive).
/// Shared by the Table II decoders and the decode-only families (GQA
/// long-context, serving mix) in [`crate::workload::families`].
pub(crate) fn decode_chunk_loop(g: &mut Cascade, cfg: &TransformerConfig) {
    chain_decode_chunks(
        g,
        cfg.seq,
        cfg.decode_tokens,
        cfg.decode_chunks,
        |g, kv_mid, suffix, count| {
            attention_layer(g, cfg, Phase::Decode, 1, kv_mid, suffix, count)
        },
    );
}

/// The chunk-compression policy itself, generalized over the layer
/// builder so every decode-bearing family (transformer, MoE) shares ONE
/// copy of the chunks/midpoint/remainder math and the serial chaining.
///
/// `layer(g, kv_mid, suffix, count)` must push the chunk's ops with
/// q/k/v generation as its FIRST THREE (the chaining wires the previous
/// tail to head, head+1, head+2) and return (head, tail) indices.
pub(crate) fn chain_decode_chunks<F>(
    g: &mut Cascade,
    context: u64,
    decode_tokens: u64,
    decode_chunks: u64,
    mut layer: F,
) where
    F: FnMut(&mut Cascade, u64, &str, u64) -> (usize, usize),
{
    let chunks = decode_chunks.max(1);
    // A chunk with zero tokens would carry `repeat: 0` ops, which the
    // schema (rightly) refuses to re-parse.
    assert!(decode_tokens >= chunks, "fewer decode tokens than chunks");
    let per = decode_tokens / chunks;
    let mut prev_tail: Option<usize> = None;
    for c in 0..chunks {
        let count = if c == chunks - 1 { decode_tokens - per * (chunks - 1) } else { per };
        let kv_mid = context + c * per + count / 2;
        let (head, tail) = layer(g, kv_mid, &format!("_dec{c}"), count);
        // Tokens are generated serially: chain chunks — the previous
        // tail gates the next chunk's q/k/v generation.
        if let Some(t) = prev_tail {
            g.dep(t, head);
            g.dep(t, head + 1);
            g.dep(t, head + 2);
        }
        prev_tail = Some(tail);
    }
}

/// The cascade for a workload config (encoder or decoder shape).
pub fn cascade_for(cfg: &TransformerConfig) -> Cascade {
    if cfg.decode_tokens > 0 {
        decoder_cascade(cfg)
    } else {
        encoder_cascade(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::einsum::{OpKind, Phase};

    #[test]
    fn bert_shapes_match_table_ii() {
        let g = encoder_cascade(&bert_large());
        assert_eq!(g.ops.len(), 9);
        let q = &g.ops[0];
        assert_eq!((q.m, q.k, q.n), (256, 1024, 1024));
        let logit = g.ops.iter().find(|o| o.name == "logit").unwrap();
        assert_eq!((logit.b, logit.m, logit.k, logit.n), (16, 256, 64, 256));
        let ffn1 = g.ops.iter().find(|o| o.name == "ffn1").unwrap();
        assert_eq!(ffn1.n, 4096);
    }

    #[test]
    fn bert_v_overlaps_logit_only() {
        let g = encoder_cascade(&bert_large());
        let v = g.ops.iter().position(|o| o.name == "v_gen").unwrap();
        let logit = g.ops.iter().position(|o| o.name == "logit").unwrap();
        // v has no path to logit and vice versa: independent.
        assert!(!g.predecessors(logit).contains(&v));
        let attend = g.ops.iter().position(|o| o.name == "attend").unwrap();
        assert!(g.predecessors(attend).contains(&v));
    }

    #[test]
    fn decoder_has_decoupled_phases() {
        let g = decoder_cascade(&llama2());
        let pre = g.ops_in_phase(Phase::Prefill);
        let dec = g.ops_in_phase(Phase::Decode);
        assert_eq!(pre.len(), 9);
        assert!(!dec.is_empty());
        // No edge crosses the prefill/decode boundary.
        for &(p, c) in &g.deps {
            let cross = (pre.contains(&p) && dec.contains(&c))
                || (dec.contains(&p) && pre.contains(&c));
            assert!(!cross, "unexpected cross-phase edge ({p},{c})");
        }
    }

    #[test]
    fn decode_token_counts_sum() {
        let cfg = gpt3();
        let g = decoder_cascade(&cfg);
        let total: u64 = g
            .ops_in_phase(Phase::Decode)
            .iter()
            .filter(|&&i| g.ops[i].name.starts_with("q_gen"))
            .map(|&i| g.ops[i].count)
            .sum();
        assert_eq!(total, cfg.decode_tokens);
    }

    #[test]
    fn decode_kv_grows_across_chunks() {
        let g = decoder_cascade(&llama2());
        let kvs: Vec<u64> = g
            .ops
            .iter()
            .filter(|o| o.phase == Phase::Decode && o.kind == OpKind::Bmm && o.name.starts_with("logit"))
            .map(|o| o.n)
            .collect();
        assert!(kvs.windows(2).all(|w| w[0] < w[1]), "kv lengths {kvs:?}");
        assert!(kvs[0] >= 3000);
    }

    #[test]
    fn gpt3_macs_dwarf_bert() {
        let bert = encoder_cascade(&bert_large()).total_macs();
        let gpt = decoder_cascade(&gpt3()).total_macs();
        assert!(gpt > 100 * bert);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(by_name("gpt3").unwrap().d_model, 12288);
        assert_eq!(by_name("BERT").unwrap().seq, 256);
        assert!(by_name("nope").is_none());
    }
}
