//! Mixed-reuse workload families beyond the paper's Table II
//! transformers — the varied multi-DNN workload set that Herald- and
//! MOSAIC-style heterogeneity studies need:
//!
//! - **MoE decode/prefill** — per-expert FFN GEMMs (each expert owns
//!   its weights, so weight reuse drops by the expert count) gated by a
//!   deliberately low-intensity router GEMM.
//! - **CNN via im2col** — a ResNet-ish layer stack lowered to
//!   `B×M×N×K` GEMMs ([`conv_gemm`]), whose arithmetic intensity spans
//!   both sides of the paper's tipping point within ONE cascade.
//! - **GQA long-context decode** — decode-only serving of a
//!   grouped-query model against a long KV cache: pure streaming.
//! - **Serving mix** — prefill and decode request pools interleaved at
//!   a configurable batch ratio (continuous batching), the operating
//!   point inter-cascade partitioning exists for.
//!
//! Every generator emits plain [`Cascade`]s through the same
//! [`TensorOp`] constructor path as the JSON loader
//! (`workload::schema`), so each family is a serializable definition:
//! `spec.to_json()` re-parses and evaluates bit-identically (the
//! differential workload suite asserts this).

use super::cascade::Cascade;
use super::einsum::{Phase, TensorOp};
use super::transformer::{
    attention_layer, chain_decode_chunks, decode_chunk_loop, TransformerConfig,
};

// ---- Mixture of Experts ----------------------------------------------------

/// MoE model hyper-parameters (Mixtral-8x7B-shaped defaults).
#[derive(Debug, Clone)]
pub struct MoeConfig {
    pub name: String,
    pub d_model: u64,
    /// Per-expert FFN inner dimension.
    pub d_ff: u64,
    /// Total experts (each owns its FFN weights).
    pub experts: u64,
    /// Active experts per token.
    pub top_k: u64,
    pub heads: u64,
    pub kv_heads: u64,
    /// Prefill length; for decode-only configs this is the already-
    /// prefilled context the KV cache starts at.
    pub seq: u64,
    /// Generated tokens; 0 ⇒ prefill-only cascade.
    pub decode_tokens: u64,
    pub decode_chunks: u64,
    pub batch: u64,
}

/// MoE prefill (one layer at full sequence length).
pub fn moe_prefill() -> MoeConfig {
    MoeConfig {
        name: "MoE-prefill".into(),
        d_model: 4096,
        d_ff: 14336,
        experts: 8,
        top_k: 2,
        heads: 32,
        kv_heads: 8,
        seq: 2048,
        decode_tokens: 0,
        decode_chunks: 0,
        batch: 8,
    }
}

/// MoE decode (chunk-compressed token loop over a prefilled context).
pub fn moe_decode() -> MoeConfig {
    MoeConfig {
        name: "MoE-decode".into(),
        decode_tokens: 512,
        decode_chunks: 4,
        batch: 64,
        ..moe_prefill()
    }
}

/// One MoE layer: attention (GQA) → router → per-expert FFN.
///
/// Returns the indices of the layer's first and final ops.
fn moe_layer(
    g: &mut Cascade,
    cfg: &MoeConfig,
    phase: Phase,
    seq: u64,
    kv_len: u64,
    suffix: &str,
    count: u64,
) -> (usize, usize) {
    assert!(cfg.top_k >= 1 && cfg.top_k <= cfg.experts, "top_k out of range");
    assert!(cfg.heads % cfg.kv_heads == 0 && cfg.d_model % cfg.heads == 0);
    let d = cfg.d_model;
    let dh = d / cfg.heads;
    let nm = |base: &str| format!("{base}{suffix}");
    let rows = seq * cfg.batch;
    let bmm_b = cfg.kv_heads * cfg.batch;
    let bmm_m = seq * (cfg.heads / cfg.kv_heads);

    let q = g.push(TensorOp::gemm(&nm("q_gen"), phase, rows, d, d).repeated(count));
    let k = g.push(TensorOp::gemm(&nm("k_gen"), phase, rows, d, d).repeated(count));
    let v = g.push(TensorOp::gemm(&nm("v_gen"), phase, rows, d, d).repeated(count));
    let logit =
        g.push(TensorOp::bmm(&nm("logit"), phase, bmm_b, bmm_m, dh, kv_len).repeated(count));
    let softmax =
        g.push(TensorOp::vector(&nm("softmax"), phase, bmm_b, bmm_m, kv_len).repeated(count));
    let attend =
        g.push(TensorOp::bmm(&nm("attend"), phase, bmm_b, bmm_m, kv_len, dh).repeated(count));
    let deproj = g.push(TensorOp::gemm(&nm("deproj"), phase, rows, d, d).repeated(count));
    // Router: every token scored against `experts` gates. N = experts
    // keeps the output tiny relative to the streamed activations — the
    // low-intensity gate this family exists to exercise.
    let router =
        g.push(TensorOp::gemm(&nm("router"), phase, rows, d, cfg.experts).repeated(count));
    // Experts: each expert owns its FFN weights, so the per-expert GEMM
    // batch carries the weight operand (a BMM with b = experts); the
    // routed token set (top_k · rows) is balanced across experts.
    let routed = (rows * cfg.top_k / cfg.experts).max(1);
    let up = g.push(
        TensorOp::bmm(&nm("expert_up"), phase, cfg.experts, routed, d, cfg.d_ff).repeated(count),
    );
    let down = g.push(
        TensorOp::bmm(&nm("expert_down"), phase, cfg.experts, routed, cfg.d_ff, d)
            .repeated(count),
    );

    g.dep(q, logit);
    g.dep(k, logit);
    g.dep(logit, softmax);
    g.dep(softmax, attend);
    g.dep(v, attend);
    g.dep(attend, deproj);
    g.dep(deproj, router);
    // Routing decides which expert sees which token.
    g.dep(router, up);
    g.dep(up, down);
    (q, down)
}

/// The cascade for an MoE config: prefill layer, or the chunk-compressed
/// decode loop — the SAME compression policy as the Table II decoders,
/// via `transformer::chain_decode_chunks` (moe_layer emits q/k/v first,
/// satisfying the chaining contract).
pub fn moe_cascade(cfg: &MoeConfig) -> Cascade {
    let mut g = Cascade::new(&cfg.name);
    if cfg.decode_tokens == 0 {
        moe_layer(&mut g, cfg, Phase::Prefill, cfg.seq, cfg.seq, "", 1);
    } else {
        chain_decode_chunks(
            &mut g,
            cfg.seq,
            cfg.decode_tokens,
            cfg.decode_chunks,
            |g, kv_mid, suffix, count| {
                moe_layer(g, cfg, Phase::Decode, 1, kv_mid, suffix, count)
            },
        );
    }
    g.validate().expect("moe cascade is a DAG");
    g
}

// ---- CNN via im2col --------------------------------------------------------

/// One convolution layer described by its output spatial extent.
#[derive(Debug, Clone)]
pub struct ConvLayerDef {
    pub name: &'static str,
    pub c_in: u64,
    pub h_out: u64,
    pub w_out: u64,
    pub kh: u64,
    pub kw: u64,
    pub c_out: u64,
    /// Back-to-back repetitions (a stage of identical residual blocks).
    pub repeat: u64,
}

/// A CNN lowered to a chain of im2col GEMMs.
#[derive(Debug, Clone)]
pub struct ConvNetConfig {
    pub name: String,
    /// Images per inference batch.
    pub batch: u64,
    pub layers: Vec<ConvLayerDef>,
}

/// im2col lowering: a `K_h×K_w` convolution over `C_in` channels
/// producing `C_out×H_out×W_out` becomes a GEMM with
/// `M = B·H_out·W_out` (output pixels), `K = C_in·K_h·K_w` (unrolled
/// input patch), `N = C_out` (filters).
pub fn conv_gemm(name: &str, phase: Phase, batch: u64, l: &ConvLayerDef) -> TensorOp {
    TensorOp::gemm(name, phase, batch * l.h_out * l.w_out, l.c_in * l.kh * l.kw, l.c_out)
}

/// ResNet-50-shaped representative stack at 224×224 input: the stem
/// convolution and one bottleneck's worth of convs per stage (with the
/// stage's block count as the repeat), then global-average-pool and the
/// classifier GEMM. Early wide-spatial layers sit BELOW the paper's
/// tipping point, late channel-heavy layers far above — mixed reuse in
/// one encoder cascade.
pub fn resnet50() -> ConvNetConfig {
    ConvNetConfig {
        name: "ResNet50-im2col".into(),
        batch: 8,
        layers: vec![
            ConvLayerDef { name: "conv1", c_in: 3, h_out: 112, w_out: 112, kh: 7, kw: 7, c_out: 64, repeat: 1 },
            ConvLayerDef { name: "res2_reduce", c_in: 256, h_out: 56, w_out: 56, kh: 1, kw: 1, c_out: 64, repeat: 3 },
            ConvLayerDef { name: "res2_conv", c_in: 64, h_out: 56, w_out: 56, kh: 3, kw: 3, c_out: 64, repeat: 3 },
            ConvLayerDef { name: "res2_expand", c_in: 64, h_out: 56, w_out: 56, kh: 1, kw: 1, c_out: 256, repeat: 3 },
            ConvLayerDef { name: "res3_conv", c_in: 128, h_out: 28, w_out: 28, kh: 3, kw: 3, c_out: 128, repeat: 4 },
            ConvLayerDef { name: "res4_conv", c_in: 256, h_out: 14, w_out: 14, kh: 3, kw: 3, c_out: 256, repeat: 6 },
            ConvLayerDef { name: "res5_conv", c_in: 512, h_out: 7, w_out: 7, kh: 3, kw: 3, c_out: 512, repeat: 3 },
        ],
    }
}

/// The cascade for a conv net: the layer chain, then
/// global-average-pool (vector) and the classifier GEMM.
pub fn conv_cascade(cfg: &ConvNetConfig) -> Cascade {
    let mut g = Cascade::new(&cfg.name);
    let mut prev: Option<usize> = None;
    for l in &cfg.layers {
        let id = g.push(conv_gemm(l.name, Phase::Encoder, cfg.batch, l).repeated(l.repeat));
        if let Some(p) = prev {
            g.dep(p, id);
        }
        prev = Some(id);
    }
    let last = cfg.layers.last().expect("conv net has layers");
    let feat = last.c_out * 4; // bottleneck expansion ×4
    let pool = g.push(TensorOp::vector("gap", Phase::Encoder, 1, cfg.batch, feat));
    let fc = g.push(TensorOp::gemm("fc", Phase::Encoder, cfg.batch, feat, 1000));
    if let Some(p) = prev {
        g.dep(p, pool);
    }
    g.dep(pool, fc);
    g.validate().expect("conv cascade is a DAG");
    g
}

// ---- GQA long-context decode ----------------------------------------------

/// Grouped-query attention, decode-only, long context (Llama-2-70B-ish
/// shapes serving a 32k-token prompt): every op streams KV cache or
/// weights, the regime where the low-reuse sub-accelerator earns its
/// bandwidth share.
pub fn gqa_long_decode() -> TransformerConfig {
    TransformerConfig {
        name: "GQA-long-decode".into(),
        d_model: 8192,
        heads: 64,
        kv_heads: 8,
        d_ff: 28672,
        // `seq` is the prefilled context the KV cache starts at — the
        // cascade itself contains no prefill ops.
        seq: 32768,
        decode_tokens: 256,
        decode_chunks: 4,
        batch: 16,
    }
}

/// Decode-only cascade: the chunk-compressed token loop with the KV
/// cache starting at `cfg.seq`, no prefill sub-cascade.
pub fn gqa_decode_cascade(cfg: &TransformerConfig) -> Cascade {
    assert!(cfg.decode_tokens > 0, "gqa decode cascade requires decode_tokens");
    let mut g = Cascade::new(&cfg.name);
    decode_chunk_loop(&mut g, cfg);
    g.validate().expect("gqa decode cascade is a DAG");
    g
}

// ---- Serving mix -----------------------------------------------------------

/// Continuous-batching operating point: a pool of requests in prefill
/// and a pool in decode move through the machine together, at a given
/// ratio of the serving batch.
#[derive(Debug, Clone)]
pub struct ServingMixConfig {
    pub name: String,
    /// The transformer whose requests are being served.
    pub base: TransformerConfig,
    pub prefill_requests: u64,
    pub decode_requests: u64,
}

/// Default mix: Llama-2 serving with 8 requests in prefill and 56 in
/// decode (the steady state of a 64-slot batch when outputs are ~7×
/// longer than the prefill residency).
pub fn serving_mix() -> ServingMixConfig {
    ServingMixConfig {
        name: "ServingMix-llama2-8p56d".into(),
        base: super::transformer::llama2(),
        prefill_requests: 8,
        decode_requests: 56,
    }
}

/// Interleave a prefill cascade and a decode cascade at the configured
/// batch ratio. No cross-edges — the pools are independent request
/// sets, decoupled at batch granularity (the inter-cascade premise).
pub fn serving_mix_cascade(cfg: &ServingMixConfig) -> Cascade {
    assert!(cfg.prefill_requests > 0 && cfg.decode_requests > 0, "both pools must be non-empty");
    let mut g = Cascade::new(&cfg.name);
    let mut pre = cfg.base.clone();
    pre.batch = cfg.prefill_requests;
    attention_layer(&mut g, &pre, Phase::Prefill, pre.seq, pre.seq, "_pre", 1);
    let mut dec = cfg.base.clone();
    dec.batch = cfg.decode_requests;
    decode_chunk_loop(&mut g, &dec);
    g.validate().expect("serving mix cascade is a DAG");
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::einsum::{OpKind, Operand};
    use crate::workload::intensity::{Classifier, ReuseClass};

    /// im2col dims and intensity against hand-computed values: a 3×3
    /// conv over 4 channels to 8 filters on 2×2 output pixels, batch 2.
    #[test]
    fn conv_gemm_im2col_hand_computed() {
        let l = ConvLayerDef {
            name: "t",
            c_in: 4,
            h_out: 2,
            w_out: 2,
            kh: 3,
            kw: 3,
            c_out: 8,
            repeat: 1,
        };
        let op = conv_gemm("t", Phase::Encoder, 2, &l);
        assert_eq!((op.b, op.m, op.k, op.n), (1, 8, 36, 8));
        // MACs = M·K·N = 8·36·8 = 2304; words = A(8·36) + W(36·8) + O(8·8)
        // = 288 + 288 + 64 = 640.
        assert_eq!(op.macs(), 2304);
        assert_eq!(op.footprint_words(), 640);
        assert_eq!(op.arithmetic_intensity(), 2304.0 / 640.0);
    }

    /// The ResNet stack straddles the Table III tipping point (160,
    /// which the classifier's 0.5 margin turns into an effective
    /// high-reuse threshold of 80 MACs/word): the stem is low-reuse,
    /// the late channel-heavy stages high-reuse.
    #[test]
    fn resnet_layers_straddle_the_tipping_point() {
        let c = Classifier::new(160.0);
        let cfg = resnet50();
        let g = conv_cascade(&cfg);
        let class_of = |name: &str| {
            c.classify(g.ops.iter().find(|o| o.name == name).unwrap_or_else(|| {
                panic!("missing op {name}")
            }))
        };
        // conv1: M=8·112·112=100352, K=147, N=64 → AI ≈ 44.6 < 80.
        assert_eq!(class_of("conv1"), ReuseClass::Low);
        // res4: M=8·14·14=1568, K=2304, N=256 → AI ≈ 200.9 > 80.
        assert_eq!(class_of("res4_conv"), ReuseClass::High);
        assert_eq!(class_of("res5_conv"), ReuseClass::High);
        // The head: global-average-pool and the tiny FC are low-reuse.
        assert_eq!(class_of("gap"), ReuseClass::Low);
        assert_eq!(class_of("fc"), ReuseClass::Low);
        // Exact hand-computed AI for res4: MACs = 1568·2304·256,
        // words = 1568·2304 + 2304·256 + 1568·256.
        let res4 = g.ops.iter().find(|o| o.name == "res4_conv").unwrap();
        let macs = 1568u64 * 2304 * 256;
        let words = 1568u64 * 2304 + 2304 * 256 + 1568 * 256;
        assert_eq!(res4.macs(), macs);
        assert_eq!(res4.arithmetic_intensity(), macs as f64 / words as f64);
    }

    /// MoE decode: the router is low-intensity by construction, and the
    /// per-expert FFN is a BMM whose weight operand carries the expert
    /// batch (each expert owns its weights — hand-computed footprints).
    #[test]
    fn moe_ops_hand_computed() {
        let cfg = moe_decode();
        let g = moe_cascade(&cfg);
        let router = g.ops.iter().find(|o| o.name == "router_dec0").unwrap();
        // rows = batch = 64; MACs = 64·4096·8 = 2_097_152;
        // words = 64·4096 + 4096·8 + 64·8 = 295_424 → AI ≈ 7.1.
        assert_eq!((router.m, router.k, router.n), (64, 4096, 8));
        assert_eq!(router.macs(), 2_097_152);
        assert_eq!(router.footprint_words(), 295_424);
        assert!(router.arithmetic_intensity() < 10.0);

        let up = g.ops.iter().find(|o| o.name == "expert_up_dec0").unwrap();
        assert_eq!(up.kind, OpKind::Bmm);
        // routed = 64·2/8 = 16 tokens per expert, b = 8 experts.
        assert_eq!((up.b, up.m, up.k, up.n), (8, 16, 4096, 14336));
        // The weight operand carries the expert batch: 8·4096·14336.
        assert_eq!(up.operand_words(Operand::InputB), 8 * 4096 * 14336);
        // Decode-phase ops classify low-reuse under the paper's policy.
        let c = Classifier::new(160.0);
        assert_eq!(c.classify(up), ReuseClass::Low);
        assert_eq!(c.classify(router), ReuseClass::Low);

        // Prefill MoE: the same expert GEMM is high-reuse (tokens ≫).
        let pre = moe_cascade(&moe_prefill());
        let up_pre = pre.ops.iter().find(|o| o.name == "expert_up").unwrap();
        assert_eq!((up_pre.b, up_pre.m), (8, 2048 * 8 * 2 / 8));
        assert_eq!(c.classify(up_pre), ReuseClass::High);
    }

    /// GQA decode BMM: KV streaming dominates — hand-computed intensity
    /// stays in single digits despite the huge MAC count.
    #[test]
    fn gqa_decode_bmm_hand_computed() {
        let cfg = gqa_long_decode();
        let g = gqa_decode_cascade(&cfg);
        assert!(g.ops_in_phase(Phase::Prefill).is_empty(), "decode-only cascade");
        let logit = g.ops.iter().find(|o| o.name == "logit_dec0").unwrap();
        // b = kv_heads·batch = 128, m = group = 8, k = dh = 128,
        // kv₀ = 32768 + 32 = 32800.
        assert_eq!((logit.b, logit.m, logit.k, logit.n), (128, 8, 128, 32800));
        let macs = 128u64 * 8 * 128 * 32800;
        let words = 128u64 * 8 * 128 + 128 * 128 * 32800 + 128 * 8 * 32800;
        assert_eq!(logit.macs(), macs);
        assert_eq!(logit.footprint_words(), words);
        assert!(logit.arithmetic_intensity() < 10.0, "{}", logit.arithmetic_intensity());
        // KV grows across chunks, and the chunks chain serially.
        let kvs: Vec<u64> = g
            .ops
            .iter()
            .filter(|o| o.name.starts_with("logit"))
            .map(|o| o.n)
            .collect();
        assert!(kvs.windows(2).all(|w| w[0] < w[1]), "{kvs:?}");
    }

    /// The serving mix keeps the pools decoupled (no cross edges) at
    /// the configured batch ratio.
    #[test]
    fn serving_mix_pools_are_decoupled() {
        let cfg = serving_mix();
        let g = serving_mix_cascade(&cfg);
        let pre = g.ops_in_phase(Phase::Prefill);
        let dec = g.ops_in_phase(Phase::Decode);
        assert_eq!(pre.len(), 9);
        assert!(!dec.is_empty());
        for &(p, c) in &g.deps {
            let cross =
                (pre.contains(&p) && dec.contains(&c)) || (dec.contains(&p) && pre.contains(&c));
            assert!(!cross, "unexpected cross-pool edge ({p},{c})");
        }
        // Prefill rows fold the prefill pool; decode BMMs batch the
        // decode pool's KV caches.
        let q = &g.ops[pre[0]];
        assert_eq!(q.m, cfg.base.seq * cfg.prefill_requests);
        let logit = g.ops.iter().find(|o| o.name == "logit_dec0").unwrap();
        assert_eq!(logit.b, cfg.base.kv_heads * cfg.decode_requests);
    }

    /// Decode token counts are preserved by the chunk compression in
    /// every decode-bearing family.
    #[test]
    fn decode_token_counts_sum_across_families() {
        let moe = moe_cascade(&moe_decode());
        let total: u64 = moe
            .ops
            .iter()
            .filter(|o| o.name.starts_with("q_gen_dec"))
            .map(|o| o.count)
            .sum();
        assert_eq!(total, moe_decode().decode_tokens);
        let gqa = gqa_decode_cascade(&gqa_long_decode());
        let total: u64 =
            gqa.ops.iter().filter(|o| o.name.starts_with("q_gen_dec")).map(|o| o.count).sum();
        assert_eq!(total, gqa_long_decode().decode_tokens);
    }
}
