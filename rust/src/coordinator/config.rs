//! JSON experiment configuration (the CLI's `--config` input).
//!
//! Example:
//! ```json
//! {
//!   "workload": "gpt3",
//!   "machine": "hier+xdepth",
//!   "dram_bw_bits": 2048,
//!   "bw_frac_low": 0.75,
//!   "samples": 400,
//!   "dynamic_bw": false,
//!   "contention": "off",
//!   "alloc": "greedy"
//! }
//! ```
//!
//! `"alloc"` selects the op → sub-accelerator allocation policy
//! (`greedy` | `round_robin` | `critical_path` | `search`); like
//! `"contention"` it is an evaluation knob, so it composes with both
//! `machine` ids and `topology` files.
//!
//! `"workload"` is a registered name (`harp workload list`) or a path
//! to a cascade JSON file (same schema as `--workload FILE`; see the
//! README). Like `"topology"`, a relative path resolves against the
//! config file's directory.
//!
//! `"contention": "on"` books shared tree nodes (co-attached units get
//! exclusive capacity slices and arbitrated edge bandwidth) instead of
//! the historical double-booking; it applies to generated machines and
//! `topology` files alike, so it is NOT rejected alongside the hardware
//! keys below.
//!
//! Instead of a taxonomy id, `"topology": "machine.json"` points at an
//! explicit machine-tree description (same schema as `--topology`; see
//! the README) — the taxonomy point is then *derived* from the tree.
//!
//! `"mapping_cache": "mappings.json"` points at a persistent
//! `(shape, unit) → mapping` cache file (the CLI's `--mapping-cache`);
//! relative paths resolve against the config file's directory.
//! `"cache_format": "json" | "binary"` pins its on-disk format (the
//! CLI's `--cache-format`); without it the file extension decides
//! (`.bin`/`.harpbin` → binary, otherwise JSON). The key is rejected
//! when no `"mapping_cache"` is present — a knob that silently did
//! nothing would hide a typo.
//!
//! `"arrivals": {...}` describes a serving request stream (see
//! [`ArrivalsConfig`]); it is consumed by `harp serve --config` and
//! rejected by the eval path.

use crate::arch::partition::{HardwareParams, MachineConfig};
use crate::arch::taxonomy::HarpClass;
use crate::arch::topology::MachineTopology;
use crate::coordinator::experiment::{default_bw_frac_low, EvalOptions};
use crate::runtime::serve::{DisaggConfig, PlacementPolicy, DEFAULT_SLO_TTFT};
use crate::util::binio::CacheFormat;
use crate::util::json::Json;
use crate::workload::arrivals::{self, ArrivalKind, RequestClass, RequestFamily};
use crate::workload::cascade::Cascade;
use crate::workload::registry::{self, WorkloadSource};

/// The `"arrivals"` object of a serve config (the config-file form of
/// `harp serve`'s stream flags):
///
/// ```json
/// { "arrivals": { "process": "poisson", "mix": "llama2:3,gqa:1",
///                 "class_mix": "interactive:1,batch:3",
///                 "load": 2.0, "requests": 64, "seed": 7,
///                 "slo_ttft": 2000000, "slo_ttft_batch": 8000000,
///                 "kv_page_words": 4096, "placement": "pressure",
///                 "disagg": "prefill=high,decode=low" } }
/// ```
///
/// With `"process": "trace"` the stream comes from a `"trace"` file
/// (relative paths resolve against the config's directory) and the
/// generator knobs (`mix`/`class_mix`/`load`/`requests`/`seed`) are
/// rejected as dead (a trace carries per-request classes itself). The
/// engine knobs (`slo_ttft`, `slo_ttft_batch`, `kv_page_words`,
/// `placement`, `disagg`) apply to both stream forms. The key only
/// applies to `harp serve`; `harp eval` rejects it.
#[derive(Debug, Clone)]
pub struct ArrivalsConfig {
    pub process: ArrivalKind,
    pub mix: Vec<(RequestFamily, f64)>,
    /// Latency-class mix for synthetic streams (default: everything
    /// `interactive`, the classless-engine behavior).
    pub class_mix: Vec<(RequestClass, f64)>,
    /// Offered load in requests per million cycles.
    pub load: f64,
    pub requests: usize,
    pub seed: u64,
    /// TTFT SLO in cycles (goodput counts completions under it).
    pub slo_ttft: f64,
    /// TTFT SLO for `batch` requests; `None` inherits `slo_ttft`.
    pub slo_ttft_batch: Option<f64>,
    /// KV booking page size in words (0 = whole-request booking).
    pub kv_page_words: u64,
    /// Unit-placement policy for the engine's prefill/decode ops.
    pub placement: PlacementPolicy,
    /// Role-disaggregated prefill/decode pools (`None` = co-located).
    /// An engine knob like `placement`, so it applies to both stream
    /// forms (synthetic and trace).
    pub disagg: Option<DisaggConfig>,
    /// Trace file path (with `"process": "trace"` only).
    pub trace: Option<String>,
}

fn parse_arrivals(j: &Json) -> Result<ArrivalsConfig, String> {
    arrivals::reject_unknown_keys(
        j,
        &[
            "process",
            "mix",
            "class_mix",
            "load",
            "requests",
            "seed",
            "slo_ttft",
            "slo_ttft_batch",
            "kv_page_words",
            "placement",
            "disagg",
            "trace",
        ],
        "'arrivals'",
    )?;
    let process = j
        .get("process")
        .ok_or("'arrivals' needs a \"process\" (poisson | bursty | trace)")?
        .as_str()
        .ok_or_else(|| "'arrivals.process' must be a string".to_string())
        .and_then(ArrivalKind::parse)?;
    let trace = match j.get("trace") {
        Some(v) => Some(v.as_str().ok_or("'arrivals.trace' must be a file path")?.to_string()),
        None => None,
    };
    if process == ArrivalKind::Trace {
        // The trace fixes the stream (including per-request classes);
        // generator knobs would be dead.
        for k in ["mix", "class_mix", "load", "requests", "seed"] {
            if j.get(k).is_some() {
                return Err(format!(
                    "'arrivals.{k}' does not apply when \"process\" is \"trace\""
                ));
            }
        }
        if trace.is_none() {
            return Err("'arrivals.process' \"trace\" requires a \"trace\" file path".into());
        }
    } else if trace.is_some() {
        return Err("'arrivals.trace' does nothing unless \"process\" is \"trace\"".into());
    }
    let mix = match j.get("mix") {
        Some(v) => {
            let s = v.as_str().ok_or("'arrivals.mix' must be a string like \"llama2:3,gqa:1\"")?;
            arrivals::parse_mix(s)?
        }
        None => vec![(RequestFamily::Llama2, 1.0)],
    };
    let class_mix = match j.get("class_mix") {
        Some(v) => {
            let s = v
                .as_str()
                .ok_or("'arrivals.class_mix' must be a string like \"interactive:1,batch:3\"")?;
            arrivals::parse_class_mix(s)?
        }
        None => vec![(RequestClass::Interactive, 1.0)],
    };
    let load = match j.get("load") {
        Some(v) => {
            let l = v.as_f64().ok_or("'arrivals.load' must be a number")?;
            if !l.is_finite() || l <= 0.0 {
                return Err("'arrivals.load' must be finite and positive".into());
            }
            l
        }
        None => 2.0,
    };
    let requests = match j.get("requests") {
        Some(v) => {
            let n = v.as_usize().ok_or("'arrivals.requests' must be a positive integer")?;
            if n == 0 {
                return Err("'arrivals.requests' must be a positive integer".into());
            }
            n
        }
        None => 64,
    };
    let seed = match j.get("seed") {
        Some(v) => v.as_u64().ok_or("'arrivals.seed' must be a non-negative integer")?,
        None => 7,
    };
    let slo_ttft = match j.get("slo_ttft") {
        Some(v) => {
            let s = v.as_f64().ok_or("'arrivals.slo_ttft' must be a number of cycles")?;
            if !s.is_finite() || s <= 0.0 {
                return Err("'arrivals.slo_ttft' must be finite and positive".into());
            }
            s
        }
        None => DEFAULT_SLO_TTFT,
    };
    let slo_ttft_batch = match j.get("slo_ttft_batch") {
        Some(v) => {
            let s = v.as_f64().ok_or("'arrivals.slo_ttft_batch' must be a number of cycles")?;
            if !s.is_finite() || s <= 0.0 {
                return Err("'arrivals.slo_ttft_batch' must be finite and positive".into());
            }
            Some(s)
        }
        None => None,
    };
    let kv_page_words = match j.get("kv_page_words") {
        Some(v) => v
            .as_u64()
            .ok_or("'arrivals.kv_page_words' must be a non-negative integer (0 = whole-request)")?,
        None => 0,
    };
    let placement = match j.get("placement") {
        Some(v) => {
            let s = v
                .as_str()
                .ok_or("'arrivals.placement' must be a string (round_robin | pressure)")?;
            PlacementPolicy::parse(s)?
        }
        None => PlacementPolicy::RoundRobin,
    };
    let disagg = match j.get("disagg") {
        Some(v) => {
            let s = v.as_str().ok_or(
                "'arrivals.disagg' must be a string like \"prefill=high,decode=low\"",
            )?;
            Some(DisaggConfig::parse(s)?)
        }
        None => None,
    };
    Ok(ArrivalsConfig {
        process,
        mix,
        class_mix,
        load,
        requests,
        seed,
        slo_ttft,
        slo_ttft_batch,
        kv_page_words,
        placement,
        disagg,
        trace,
    })
}

/// A parsed experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// The workload: a registered spec, or a cascade file to load.
    pub workload: WorkloadSource,
    /// Taxonomy point; `None` when `topology` supplies the machine.
    pub class: Option<HarpClass>,
    pub params: HardwareParams,
    pub opts: EvalOptions,
    /// Path to a machine-tree JSON file (overrides `class`).
    pub topology: Option<String>,
    /// Path to a persistent `(shape, unit) → mapping` cache file (the
    /// CLI's `--mapping-cache`). Like `topology`, relative paths
    /// resolve against the config file's directory. The file is opened
    /// by the CLI driver (after the search budget is final), not here.
    pub mapping_cache: Option<String>,
    /// Explicit on-disk format for `mapping_cache` (the CLI's
    /// `--cache-format`); `None` defers to the file extension. The
    /// knob-vs-extension conflict check runs when the file is opened.
    pub cache_format: Option<CacheFormat>,
    /// Serving stream description (`harp serve --config` only; the
    /// eval path rejects configs that carry it).
    pub arrivals: Option<ArrivalsConfig>,
}

impl ExperimentConfig {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<ExperimentConfig, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let workload_name = j
            .get("workload")
            .and_then(|v| v.as_str())
            .ok_or("missing 'workload' (a registered name or a cascade .json file)")?;
        // File sources stay lazy: `load()` resolves them against the
        // config file's directory first — exactly like 'topology'.
        let workload = registry::source_for(workload_name)?;
        let topology = j.get("topology").and_then(|v| v.as_str()).map(String::from);
        if topology.is_some() {
            // The tree fixes the machine and its hardware; reject keys
            // that would otherwise be silently ignored.
            for k in [
                "machine", "dram_bw_bits", "total_macs", "llb_bytes", "l1_bytes",
                "roof_ratio", "bw_frac_low",
            ] {
                if j.get(k).is_some() {
                    return Err(format!(
                        "'{k}' does not apply when 'topology' supplies the machine"
                    ));
                }
            }
        }
        let class = match j.get("machine").and_then(|v| v.as_str()) {
            Some(id) => Some(
                HarpClass::from_id(id).ok_or_else(|| format!("unknown machine id '{id}'"))?,
            ),
            None if topology.is_some() => None,
            None => return Err("missing 'machine' id (or a 'topology' file)".into()),
        };

        let mut params = HardwareParams::default();
        if let Some(v) = j.get("dram_bw_bits").and_then(|v| v.as_f64()) {
            params.dram_bw_bits = v;
        }
        if let Some(v) = j.get("total_macs").and_then(|v| v.as_u64()) {
            params.total_macs = v;
        }
        if let Some(v) = j.get("llb_bytes").and_then(|v| v.as_u64()) {
            params.llb_bytes = v;
        }
        if let Some(v) = j.get("l1_bytes").and_then(|v| v.as_u64()) {
            params.l1_bytes = v;
        }
        if let Some(v) = j.get("roof_ratio").and_then(|v| v.as_f64()) {
            params.roof_ratio = v;
        }

        let mut opts = EvalOptions::default();
        if let Some(v) = j.get("samples").and_then(|v| v.as_usize()) {
            opts.samples = v;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_u64()) {
            opts.seed = v;
        }
        if let Some(v) = j.get("dynamic_bw").and_then(|v| v.as_bool()) {
            opts.dynamic_bw = v;
        }
        if let Some(v) = j.get("contention") {
            let s = v
                .as_str()
                .ok_or("'contention' must be \"off\" or \"on\"")?;
            opts.contention = crate::arch::topology::ContentionMode::parse(s)?;
        }
        if let Some(v) = j.get("alloc") {
            let s = v.as_str().ok_or(
                "'alloc' must be a policy name (greedy | round_robin | critical_path | search)",
            )?;
            opts.alloc = crate::hhp::allocator::AllocPolicy::parse(s)?;
        }
        if let Some(v) = j.get("bw_frac_low").and_then(|v| v.as_f64()) {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("bw_frac_low {v} out of [0,1]"));
            }
            opts.bw_frac_low = Some(v);
        }
        let mapping_cache = match j.get("mapping_cache") {
            Some(v) => Some(
                v.as_str()
                    .ok_or("'mapping_cache' must be a file path")?
                    .to_string(),
            ),
            None => None,
        };
        let cache_format = match j.get("cache_format") {
            Some(v) => {
                let s = v.as_str().ok_or("'cache_format' must be \"json\" or \"binary\"")?;
                if mapping_cache.is_none() {
                    return Err(
                        "'cache_format' does nothing without 'mapping_cache'".to_string()
                    );
                }
                Some(CacheFormat::parse(s)?)
            }
            None => None,
        };
        let arrivals = match j.get("arrivals") {
            Some(a) => Some(parse_arrivals(a)?),
            None => None,
        };
        Ok(ExperimentConfig {
            workload,
            class,
            params,
            opts,
            topology,
            mapping_cache,
            cache_format,
            arrivals,
        })
    }

    /// Load from a file path. Relative `topology` and `workload` file
    /// paths are resolved against the config file's directory, so
    /// configs are relocatable.
    pub fn load(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let mut cfg = ExperimentConfig::parse(&text)?;
        let resolve = |file: &str| -> String {
            let p = std::path::Path::new(file);
            match std::path::Path::new(path).parent() {
                Some(dir) if p.is_relative() => dir.join(p).to_string_lossy().into_owned(),
                _ => file.to_string(),
            }
        };
        if let Some(t) = &cfg.topology {
            cfg.topology = Some(resolve(t));
        }
        if let WorkloadSource::File(w) = &cfg.workload {
            cfg.workload = WorkloadSource::File(resolve(w));
        }
        if let Some(mc) = &cfg.mapping_cache {
            cfg.mapping_cache = Some(resolve(mc));
        }
        if let Some(arr) = &mut cfg.arrivals {
            if let Some(t) = &arr.trace {
                arr.trace = Some(resolve(t));
            }
        }
        Ok(cfg)
    }

    /// Realise the machine this configuration asks for: either the
    /// partition policy applied to the taxonomy point (with the
    /// bandwidth-fraction policy resolved against `cascade`), or the
    /// explicit memory tree loaded from the topology file.
    pub fn build_machine(&self, cascade: &Cascade) -> Result<MachineConfig, String> {
        if let Some(path) = &self.topology {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
            let topo = MachineTopology::from_json(&doc).map_err(|e| format!("{path}: {e}"))?;
            return MachineConfig::from_topology(topo);
        }
        let class = self.class.as_ref().ok_or("need a 'machine' id or 'topology' file")?;
        let mut params = self.params.clone();
        params.bw_frac_low =
            self.opts.bw_frac_low.unwrap_or_else(|| default_bw_frac_low(cascade));
        MachineConfig::build(class, &params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let c = ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth","dram_bw_bits":512,
                "bw_frac_low":0.6,"samples":99,"dynamic_bw":true}"#,
        )
        .unwrap();
        assert_eq!(c.workload.load().unwrap().name(), "GPT3");
        assert_eq!(c.class.as_ref().unwrap().id(), "hier+xdepth");
        assert_eq!(c.params.dram_bw_bits, 512.0);
        assert_eq!(c.opts.samples, 99);
        assert_eq!(c.opts.bw_frac_low, Some(0.6));
        assert!(c.opts.dynamic_bw);
        assert!(c.topology.is_none());
        assert!(c.mapping_cache.is_none());
    }

    #[test]
    fn mapping_cache_key_parses_and_rejects_non_strings() {
        let c = ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth","mapping_cache":"maps.json"}"#,
        )
        .unwrap();
        assert_eq!(c.mapping_cache.as_deref(), Some("maps.json"));
        let err = ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth","mapping_cache":7}"#,
        )
        .unwrap_err();
        assert!(err.contains("mapping_cache"), "{err}");
    }

    #[test]
    fn cache_format_key_parses_and_rejects_dead_or_bogus_knobs() {
        let c = ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth",
                "mapping_cache":"maps.spill","cache_format":"binary"}"#,
        )
        .unwrap();
        assert_eq!(c.cache_format, Some(CacheFormat::Binary));
        // Absent knob defers to the extension (resolved at open time).
        let c = ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth","mapping_cache":"maps.json"}"#,
        )
        .unwrap();
        assert_eq!(c.cache_format, None);
        // A knob with nothing to format is a typo, not a no-op.
        let err = ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth","cache_format":"binary"}"#,
        )
        .unwrap_err();
        assert!(err.contains("does nothing without 'mapping_cache'"), "{err}");
        // Garbage values list the valid set.
        let err = ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth",
                "mapping_cache":"m.spill","cache_format":"msgpack"}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown cache format"), "{err}");
        assert!(ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth",
                "mapping_cache":"m.spill","cache_format":7}"#,
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(ExperimentConfig::parse(r#"{"machine":"leaf+homo"}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"workload":"bert","machine":"leaf+xdepth"}"#)
            .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"leaf+homo","bw_frac_low":1.5}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(r#"{"workload":"bert"}"#).is_err()); // no machine
        assert!(ExperimentConfig::parse("not json").is_err());
    }

    #[test]
    fn defaults_fill_in() {
        let c = ExperimentConfig::parse(r#"{"workload":"bert","machine":"leaf+homo"}"#).unwrap();
        assert_eq!(c.params.total_macs, 40960);
        assert_eq!(c.opts.bw_frac_low, None);
        assert_eq!(c.opts.contention, crate::arch::topology::ContentionMode::Off);
    }

    #[test]
    fn contention_key_parses_and_rejects_garbage() {
        use crate::arch::topology::ContentionMode;
        let on = ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"hier+xnode","contention":"on"}"#,
        )
        .unwrap();
        assert_eq!(on.opts.contention, ContentionMode::Booked);
        let off = ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"hier+xnode","contention":"off"}"#,
        )
        .unwrap();
        assert_eq!(off.opts.contention, ContentionMode::Off);
        assert!(ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"hier+xnode","contention":"maybe"}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"hier+xnode","contention":true}"#
        )
        .is_err());
        // Contention composes with an explicit topology file (it is an
        // evaluation knob, not a hardware key).
        let topo = ExperimentConfig::parse(
            r#"{"workload":"bert","topology":"m.json","contention":"on"}"#,
        )
        .unwrap();
        assert_eq!(topo.opts.contention, ContentionMode::Booked);
    }

    #[test]
    fn alloc_key_parses_and_rejects_garbage() {
        use crate::hhp::allocator::AllocPolicy;
        for (value, want) in [
            ("greedy", AllocPolicy::Greedy),
            ("round_robin", AllocPolicy::RoundRobin),
            ("critical_path", AllocPolicy::CriticalPath),
            ("search", AllocPolicy::Search),
        ] {
            let c = ExperimentConfig::parse(&format!(
                r#"{{"workload":"bert","machine":"hier+xnode","alloc":"{value}"}}"#
            ))
            .unwrap_or_else(|e| panic!("{value}: {e}"));
            assert_eq!(c.opts.alloc, want, "{value}");
        }
        // Defaults to greedy when absent.
        let c = ExperimentConfig::parse(r#"{"workload":"bert","machine":"leaf+homo"}"#).unwrap();
        assert_eq!(c.opts.alloc, AllocPolicy::Greedy);
        // Garbage is loud and lists the valid set.
        let err = ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"leaf+homo","alloc":"optimal"}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown allocation policy"), "{err}");
        assert!(err.contains("critical_path"), "{err}");
        assert!(ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"leaf+homo","alloc":7}"#
        )
        .is_err());
        // Like contention, alloc composes with an explicit topology.
        let topo = ExperimentConfig::parse(
            r#"{"workload":"bert","topology":"m.json","alloc":"search"}"#,
        )
        .unwrap();
        assert_eq!(topo.opts.alloc, AllocPolicy::Search);
    }

    #[test]
    fn topology_key_replaces_machine_id() {
        let c = ExperimentConfig::parse(
            r#"{"workload":"bert","topology":"examples/topologies/herald_cross_node.json"}"#,
        )
        .unwrap();
        assert!(c.class.is_none());
        assert_eq!(c.topology.as_deref(), Some("examples/topologies/herald_cross_node.json"));
        // Keys the tree supersedes are rejected loudly, not ignored.
        for doc in [
            r#"{"workload":"bert","topology":"m.json","machine":"leaf+homo"}"#,
            r#"{"workload":"bert","topology":"m.json","dram_bw_bits":512}"#,
            r#"{"workload":"bert","topology":"m.json","bw_frac_low":0.9}"#,
        ] {
            let err = ExperimentConfig::parse(doc).unwrap_err();
            assert!(err.contains("does not apply"), "{doc}: {err}");
        }
    }

    #[test]
    fn build_machine_applies_bw_policy() {
        let c = ExperimentConfig::parse(r#"{"workload":"gpt3","machine":"leaf+xnode"}"#).unwrap();
        let cascade = c.workload.load().unwrap().cascade();
        let m = c.build_machine(&cascade).unwrap();
        // Decoder cascade → the 75/25 policy.
        let lo = m.sub_accels[1].spec.dram().bw_words_per_cycle;
        assert!((lo - 192.0).abs() < 1e-9);
    }

    /// The workload key is the full registry: new families parse, and
    /// unknown names error with the list (never a silent fallback).
    #[test]
    fn workload_key_spans_the_registry_and_files() {
        for name in ["moe_decode", "resnet50", "gqa_decode", "serving_mix"] {
            let c = ExperimentConfig::parse(&format!(
                r#"{{"workload":"{name}","machine":"leaf+xnode"}}"#
            ))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!c.workload.load().unwrap().cascade().ops.is_empty(), "{name}");
        }
        let err = ExperimentConfig::parse(r#"{"workload":"mamba","machine":"leaf+homo"}"#)
            .unwrap_err();
        assert!(err.contains("unknown workload"), "{err}");
        assert!(err.contains("moe_decode"), "list missing: {err}");
        // A .json value is a file source, deferred to load time.
        let c = ExperimentConfig::parse(
            r#"{"workload":"cascades/mine.json","machine":"leaf+homo"}"#,
        )
        .unwrap();
        match &c.workload {
            WorkloadSource::File(p) => assert_eq!(p, "cascades/mine.json"),
            other => panic!("expected a file source, got {other:?}"),
        }
    }

    #[test]
    fn arrivals_key_parses_with_defaults() {
        let c = ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"hier+xnode",
                "arrivals":{"process":"poisson"}}"#,
        )
        .unwrap();
        let a = c.arrivals.unwrap();
        assert_eq!(a.process, ArrivalKind::Poisson);
        assert_eq!(a.mix, vec![(RequestFamily::Llama2, 1.0)]);
        assert_eq!(a.load, 2.0);
        assert_eq!(a.requests, 64);
        assert_eq!(a.seed, 7);
        assert_eq!(a.slo_ttft, DEFAULT_SLO_TTFT);
        assert_eq!(a.class_mix, vec![(RequestClass::Interactive, 1.0)]);
        assert!(a.slo_ttft_batch.is_none());
        assert_eq!(a.kv_page_words, 0);
        assert_eq!(a.placement, PlacementPolicy::RoundRobin);
        assert!(a.disagg.is_none());
        assert!(a.trace.is_none());
        // Absent key stays absent — eval configs are untouched.
        let c = ExperimentConfig::parse(r#"{"workload":"bert","machine":"leaf+homo"}"#).unwrap();
        assert!(c.arrivals.is_none());
    }

    #[test]
    fn arrivals_key_full_form_and_trace() {
        let c = ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"hier+xnode",
                "arrivals":{"process":"bursty","mix":"llama2:3,gqa:1","load":4.5,
                            "class_mix":"interactive:1,batch:3","requests":128,
                            "seed":11,"slo_ttft":500000,"slo_ttft_batch":4000000,
                            "kv_page_words":4096,"placement":"pressure",
                            "disagg":"prefill=high,decode=low"}}"#,
        )
        .unwrap();
        let a = c.arrivals.unwrap();
        assert_eq!(a.process, ArrivalKind::Bursty);
        assert_eq!(a.mix.len(), 2);
        assert_eq!(
            a.class_mix,
            vec![(RequestClass::Interactive, 1.0), (RequestClass::Batch, 3.0)]
        );
        assert_eq!(a.load, 4.5);
        assert_eq!(a.requests, 128);
        assert_eq!(a.seed, 11);
        assert_eq!(a.slo_ttft, 500000.0);
        assert_eq!(a.slo_ttft_batch, Some(4000000.0));
        assert_eq!(a.kv_page_words, 4096);
        assert_eq!(a.placement, PlacementPolicy::Pressure);
        assert_eq!(a.disagg.unwrap().label(), "prefill=high,decode=low");
        let c = ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"hier+xnode",
                "arrivals":{"process":"trace","trace":"stream.json",
                            "kv_page_words":512,"placement":"pressure",
                            "disagg":"prefill=high,decode=low"}}"#,
        )
        .unwrap();
        let a = c.arrivals.unwrap();
        // Engine knobs (pages, placement, SLOs, disagg) still apply to
        // traces; only the stream-generator knobs are dead.
        assert_eq!(a.trace.as_deref(), Some("stream.json"));
        assert_eq!(a.kv_page_words, 512);
        assert_eq!(a.placement, PlacementPolicy::Pressure);
        assert!(a.disagg.is_some());
    }

    #[test]
    fn arrivals_key_errors_are_loud_and_distinct() {
        for (arr, want) in [
            (r#"{"mix":"llama2"}"#, "needs a \"process\""),
            (r#"{"process":"sinusoid"}"#, "unknown arrival process"),
            (r#"{"process":7}"#, "'arrivals.process' must be a string"),
            (r#"{"process":"poisson","slo":1}"#, "unknown key 'slo'"),
            (r#"{"process":"poisson","load":0}"#, "'arrivals.load' must be finite"),
            (r#"{"process":"poisson","load":"fast"}"#, "'arrivals.load' must be a number"),
            (r#"{"process":"poisson","requests":0}"#, "'arrivals.requests'"),
            (r#"{"process":"poisson","mix":"bert"}"#, "unknown request family"),
            (r#"{"process":"poisson","slo_ttft":-1}"#, "'arrivals.slo_ttft'"),
            (r#"{"process":"poisson","slo_ttft_batch":0}"#, "'arrivals.slo_ttft_batch'"),
            (r#"{"process":"poisson","class_mix":"gold"}"#, "unknown request class"),
            (r#"{"process":"poisson","class_mix":7}"#, "'arrivals.class_mix' must be a string"),
            (r#"{"process":"poisson","kv_page_words":-4}"#, "'arrivals.kv_page_words'"),
            (r#"{"process":"poisson","placement":"luck"}"#, "unknown placement policy"),
            (r#"{"process":"poisson","disagg":7}"#, "'arrivals.disagg'"),
            (r#"{"process":"poisson","disagg":"prefill=warm,decode=low"}"#, "unknown disagg role"),
            (r#"{"process":"poisson","disagg":"prefill=high"}"#, "must name both phases"),
            (r#"{"process":"poisson","trace":"t.json"}"#, "does nothing unless"),
            (r#"{"process":"trace"}"#, "requires a \"trace\""),
            (r#"{"process":"trace","trace":"t.json","load":2}"#, "does not apply"),
            (r#"{"process":"trace","trace":"t.json","class_mix":"batch"}"#, "does not apply"),
        ] {
            let doc = format!(
                r#"{{"workload":"bert","machine":"hier+xnode","arrivals":{arr}}}"#
            );
            let err = ExperimentConfig::parse(&doc).unwrap_err();
            assert!(err.contains(want), "arrivals {arr}: got '{err}', want '{want}'");
        }
    }

    #[test]
    fn relative_trace_path_resolves_against_config_dir() {
        let dir = std::env::temp_dir().join("harp_config_arrivals_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg_path = dir.join("cfg.json");
        std::fs::write(
            &cfg_path,
            r#"{"workload":"bert","machine":"hier+xnode",
                "arrivals":{"process":"trace","trace":"stream.json"}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::load(cfg_path.to_str().unwrap()).unwrap();
        let trace = c.arrivals.unwrap().trace.unwrap();
        assert!(
            std::path::Path::new(&trace).parent() == Some(dir.as_path()),
            "trace not resolved against config dir: {trace}"
        );
        let _ = std::fs::remove_file(&cfg_path);
    }

    /// A relative workload file in a config resolves against the
    /// config's directory and loads through the schema parser.
    #[test]
    fn relative_workload_file_resolves_against_config_dir() {
        let dir = std::env::temp_dir().join("harp_config_workload_test");
        std::fs::create_dir_all(&dir).unwrap();
        let wl_path = dir.join("tiny.json");
        std::fs::write(
            &wl_path,
            r#"{"name":"tiny","ops":[{"name":"g","kind":"gemm","phase":"encoder",
                "m":8,"n":8,"k":8}]}"#,
        )
        .unwrap();
        let cfg_path = dir.join("cfg.json");
        std::fs::write(
            &cfg_path,
            r#"{"workload":"tiny.json","machine":"leaf+homo"}"#,
        )
        .unwrap();
        let c = ExperimentConfig::load(cfg_path.to_str().unwrap()).unwrap();
        let wl = c.workload.load().unwrap();
        assert_eq!(wl.name(), "tiny");
        assert_eq!(wl.cascade().ops.len(), 1);
        let _ = std::fs::remove_file(&wl_path);
        let _ = std::fs::remove_file(&cfg_path);
    }
}
