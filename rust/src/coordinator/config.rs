//! JSON experiment configuration (the CLI's `--config` input).
//!
//! Example:
//! ```json
//! {
//!   "workload": "gpt3",
//!   "machine": "hier+xdepth",
//!   "dram_bw_bits": 2048,
//!   "bw_frac_low": 0.75,
//!   "samples": 400,
//!   "dynamic_bw": false
//! }
//! ```

use crate::arch::partition::HardwareParams;
use crate::arch::taxonomy::HarpClass;
use crate::coordinator::experiment::EvalOptions;
use crate::util::json::Json;
use crate::workload::transformer::{self, TransformerConfig};

/// A parsed experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub workload: TransformerConfig,
    pub class: HarpClass,
    pub params: HardwareParams,
    pub opts: EvalOptions,
}

impl ExperimentConfig {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<ExperimentConfig, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let workload_name = j
            .get("workload")
            .and_then(|v| v.as_str())
            .ok_or("missing 'workload' (bert|llama2|gpt3)")?;
        let workload = transformer::by_name(workload_name)
            .ok_or_else(|| format!("unknown workload '{workload_name}'"))?;
        let machine_id =
            j.get("machine").and_then(|v| v.as_str()).ok_or("missing 'machine' id")?;
        let class = HarpClass::from_id(machine_id)
            .ok_or_else(|| format!("unknown machine id '{machine_id}'"))?;

        let mut params = HardwareParams::default();
        if let Some(v) = j.get("dram_bw_bits").and_then(|v| v.as_f64()) {
            params.dram_bw_bits = v;
        }
        if let Some(v) = j.get("total_macs").and_then(|v| v.as_u64()) {
            params.total_macs = v;
        }
        if let Some(v) = j.get("llb_bytes").and_then(|v| v.as_u64()) {
            params.llb_bytes = v;
        }
        if let Some(v) = j.get("l1_bytes").and_then(|v| v.as_u64()) {
            params.l1_bytes = v;
        }
        if let Some(v) = j.get("roof_ratio").and_then(|v| v.as_f64()) {
            params.roof_ratio = v;
        }

        let mut opts = EvalOptions::default();
        if let Some(v) = j.get("samples").and_then(|v| v.as_usize()) {
            opts.samples = v;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_u64()) {
            opts.seed = v;
        }
        if let Some(v) = j.get("dynamic_bw").and_then(|v| v.as_bool()) {
            opts.dynamic_bw = v;
        }
        if let Some(v) = j.get("bw_frac_low").and_then(|v| v.as_f64()) {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("bw_frac_low {v} out of [0,1]"));
            }
            opts.bw_frac_low = Some(v);
        }
        Ok(ExperimentConfig { workload, class, params, opts })
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        ExperimentConfig::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let c = ExperimentConfig::parse(
            r#"{"workload":"gpt3","machine":"hier+xdepth","dram_bw_bits":512,
                "bw_frac_low":0.6,"samples":99,"dynamic_bw":true}"#,
        )
        .unwrap();
        assert_eq!(c.workload.d_model, 12288);
        assert_eq!(c.class.id(), "hier+xdepth");
        assert_eq!(c.params.dram_bw_bits, 512.0);
        assert_eq!(c.opts.samples, 99);
        assert_eq!(c.opts.bw_frac_low, Some(0.6));
        assert!(c.opts.dynamic_bw);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(ExperimentConfig::parse(r#"{"machine":"leaf+homo"}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"workload":"bert","machine":"leaf+xdepth"}"#)
            .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"workload":"bert","machine":"leaf+homo","bw_frac_low":1.5}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse("not json").is_err());
    }

    #[test]
    fn defaults_fill_in() {
        let c = ExperimentConfig::parse(r#"{"workload":"bert","machine":"leaf+homo"}"#).unwrap();
        assert_eq!(c.params.total_macs, 40960);
        assert_eq!(c.opts.bw_frac_low, None);
    }
}
