//! One full evaluation: taxonomy point + hardware budget + cascade →
//! mapped, scheduled, aggregated statistics (the whole Fig 5 pipeline).

use crate::arch::partition::{HardwareParams, MachineConfig};
use crate::arch::taxonomy::HarpClass;
use crate::arch::topology::ContentionMode;
use crate::hhp::allocator::{self, AllocPolicy};
use crate::hhp::scheduler::{schedule, ScheduleOptions, ScheduleResult};
use crate::hhp::stats::CascadeStats;
use crate::mapper::blackbox::{BlackboxMapper, MappedOp};
use crate::mapper::mapcache::MapCache;
use crate::mapper::search::SearchBudget;
use std::sync::Arc;
use crate::workload::cascade::Cascade;
use crate::workload::einsum::Phase;
use crate::workload::intensity::Classifier;

/// Version stamp of the evaluation pipeline baked into every cache
/// fingerprint. **Bump this whenever the cost model, mapper, partition
/// policy, scheduler, or workload generators change numerically** — it
/// is what keeps a disk-spilled evaluation cache from silently serving
/// results computed by an older model.
pub const EVAL_MODEL_VERSION: u32 = 1;

/// Evaluation knobs.
#[derive(Debug, Clone)]
pub struct EvalOptions {
    /// Mapper random samples per unique (shape, sub-accelerator).
    pub samples: usize,
    /// Mapper seed (deterministic searches).
    pub seed: u64,
    /// Dynamic bandwidth re-granting in the scheduler (ablation).
    pub dynamic_bw: bool,
    /// Override the low-reuse bandwidth fraction; `None` applies the
    /// paper's policy (0.75 for decoder workloads, 0.5 otherwise).
    pub bw_frac_low: Option<f64>,
    /// Shared-node contention: `Off` double-books shared tree nodes
    /// (the historical model — bit-identical to pre-contention
    /// results); `Booked` hands each co-attached unit its booked
    /// capacity slice and arbitrates shared edge bandwidth.
    pub contention: ContentionMode,
    /// Op → sub-accelerator allocation policy. `Greedy` (the default)
    /// is bit-identical to the historical allocator; `Search`
    /// co-optimises the assignment with the overlap scheduler.
    pub alloc: AllocPolicy,
    /// Mapper threads.
    pub threads: usize,
    /// Persistent `(shape, unit) → mapping` cache shared by every
    /// mapper the evaluation constructs. Excluded from
    /// [`EvalOptions::fingerprint`]: a (validated) cache hit is bitwise
    /// the fresh search, so cached evaluations are shareable with and
    /// without it.
    pub map_cache: Option<Arc<MapCache>>,
}

impl Default for EvalOptions {
    fn default() -> EvalOptions {
        EvalOptions {
            samples: 400,
            seed: 0x4841_5250,
            // NeuPIM-style bandwidth reallocation: an idle unit's DRAM
            // share is re-granted to the busy ones. The static partition
            // (Fig 10) still applies whenever both units are busy.
            dynamic_bw: true,
            bw_frac_low: None,
            contention: ContentionMode::Off,
            alloc: AllocPolicy::Greedy,
            threads: crate::util::threadpool::default_threads(),
            map_cache: None,
        }
    }
}

impl EvalOptions {
    /// Fast settings for tests / CI.
    pub fn quick() -> EvalOptions {
        EvalOptions { samples: 60, ..EvalOptions::default() }
    }

    /// Canonical fingerprint of the knobs that can change evaluation
    /// results. `threads` is deliberately excluded: the batched mapper
    /// pipeline is bit-identical for every worker count, so cached
    /// results are shareable across serial and parallel runs. Used by
    /// the coordinator's cross-driver evaluation cache.
    ///
    /// The [`EVAL_MODEL_VERSION`] stamp invalidates disk-spilled caches
    /// whenever the cost model changes — without it a reused `--cache`
    /// file would silently serve stale numbers.
    ///
    /// The allocation policy is appended only when it differs from the
    /// default: `greedy` keys stay byte-identical to every fingerprint
    /// written before the policy knob existed, so old disk spills stay
    /// valid, while a non-default policy can never be served a cached
    /// greedy result (or vice versa).
    pub fn fingerprint(&self) -> String {
        let mut fp = format!(
            "m{EVAL_MODEL_VERSION}|s{}|r{:#018x}|dyn{}|ct{}",
            self.samples,
            self.seed,
            self.dynamic_bw,
            self.contention.name()
        );
        if self.alloc != AllocPolicy::Greedy {
            fp.push_str("|a");
            fp.push_str(self.alloc.name());
        }
        fp
    }

    /// Search-budget fingerprint for the persistent mapping cache's
    /// header: the knobs (beyond the per-entry key and
    /// [`EVAL_MODEL_VERSION`]) that can move a mapping-search result.
    pub fn mapping_search_fingerprint(&self) -> String {
        format!("s{}|r{:#018x}", self.samples, self.seed)
    }

    /// Open (or create) the persistent mapping cache at `path`, pinned
    /// to this binary's model version and these options' search budget,
    /// and attach it to the evaluation. The spill format follows the
    /// path's extension (`.bin`/`.harpbin` → binary, otherwise JSON).
    /// Errors are the loud
    /// [`MapCacheError`](crate::mapper::mapcache::MapCacheError)
    /// rejections, already formatted.
    pub fn attach_mapping_cache(&mut self, path: &std::path::Path) -> Result<(), String> {
        let fmt = crate::util::binio::CacheFormat::resolve(path, None)
            .expect("extension-only resolution cannot conflict");
        self.attach_mapping_cache_format(path, fmt)
    }

    /// [`EvalOptions::attach_mapping_cache`] with the spill format
    /// decided by the caller (who resolved the `cache_format` knob
    /// against the extension via
    /// [`CacheFormat::resolve`](crate::util::binio::CacheFormat::resolve)).
    pub fn attach_mapping_cache_format(
        &mut self,
        path: &std::path::Path,
        fmt: crate::util::binio::CacheFormat,
    ) -> Result<(), String> {
        let cache = MapCache::with_file_format(
            path,
            EVAL_MODEL_VERSION as u64,
            self.mapping_search_fingerprint(),
            fmt,
        )
        .map_err(|e| e.to_string())?;
        self.map_cache = Some(Arc::new(cache));
        Ok(())
    }
}

/// Full result of one evaluation.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub machine: MachineConfig,
    pub assignment: Vec<usize>,
    pub mapped: Vec<MappedOp>,
    pub sched: ScheduleResult,
    pub stats: CascadeStats,
}

/// The paper's bandwidth-partitioning policy (§V-D): decoder cascades
/// grant 75% of DRAM bandwidth to the low-reuse side; encoder cascades
/// split evenly (the "two conflicting forces" compromise).
pub fn default_bw_frac_low(cascade: &Cascade) -> f64 {
    let has_decode = cascade.ops.iter().any(|o| o.phase == Phase::Decode);
    if has_decode {
        0.75
    } else {
        0.5
    }
}

/// Evaluate `cascade` on the machine for `class` under `params`.
pub fn evaluate_cascade_on_config(
    class: &HarpClass,
    params: &HardwareParams,
    cascade: &Cascade,
    opts: &EvalOptions,
) -> Result<EvalResult, String> {
    let mut params = params.clone();
    params.bw_frac_low = opts.bw_frac_low.unwrap_or_else(|| default_bw_frac_low(cascade));
    let machine = MachineConfig::build(class, &params)?;
    evaluate_cascade_on_machine(&machine, cascade, opts)
}

/// Evaluate `cascade` on an already-built machine — taxonomy-generated
/// or an arbitrary memory tree loaded from a `--topology` file. Any
/// number of sub-accelerators at any attach depths flow through the
/// same allocate → map → schedule → aggregate pipeline.
pub fn evaluate_cascade_on_machine(
    machine: &MachineConfig,
    cascade: &Cascade,
    opts: &EvalOptions,
) -> Result<EvalResult, String> {
    // Re-flatten under the requested contention mode when it differs
    // from how the machine was built: the mapper then sees booked
    // capacities (tiling shrinks to the slice) and the scheduler
    // arbitrates shared-node bandwidth.
    let contended;
    let machine = if machine.contention == opts.contention {
        machine
    } else {
        contended = machine.clone().with_contention(opts.contention)?;
        &contended
    };
    // Classify against the UNPARTITIONED machine's tipping point: the
    // allocation question is "would this op saturate the whole datapath".
    let classifier = Classifier::new(machine.params.tipping_ai());
    let mapper = BlackboxMapper {
        budget: SearchBudget { samples: opts.samples, seed: opts.seed },
        threads: opts.threads,
        cache: opts.map_cache.clone(),
    };
    let sched_opts = ScheduleOptions { dynamic_bw: opts.dynamic_bw };
    // `Search` co-optimises the assignment with the scheduler and hands
    // back the mapping results it probed with, so the final schedule
    // reproduces the searched makespan exactly; the closed-form
    // policies assign first and map once.
    let (assignment, mapped) = match opts.alloc {
        AllocPolicy::Search => {
            allocator::search_allocation(cascade, machine, &classifier, &mapper, &sched_opts)
        }
        policy => {
            let assignment = allocator::allocate_policy(policy, cascade, machine, &classifier);
            let mapped = mapper.map_cascade(cascade, machine, &assignment);
            (assignment, mapped)
        }
    };
    let sched = schedule(cascade, machine, &mapped, &sched_opts);
    let stats = CascadeStats::aggregate(cascade, machine, &mapped, &sched, opts.alloc);
    Ok(EvalResult { machine: machine.clone(), assignment, mapped, sched, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::taxonomy::{ComputePlacement, HeterogeneityLoc};
    use crate::workload::transformer;

    #[test]
    fn bert_eval_pipeline_runs() {
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let class = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous);
        let r = evaluate_cascade_on_config(
            &class,
            &HardwareParams::default(),
            &g,
            &EvalOptions::quick(),
        )
        .unwrap();
        assert!(r.stats.latency_cycles > 0.0);
        assert_eq!(r.assignment.len(), g.ops.len());
        // Homogeneous machine keeps everything on unit 0.
        assert!(r.assignment.iter().all(|&s| s == 0));
    }

    #[test]
    fn bw_policy_follows_workload() {
        let enc = transformer::encoder_cascade(&transformer::bert_large());
        let dec = transformer::decoder_cascade(&transformer::llama2());
        assert_eq!(default_bw_frac_low(&enc), 0.5);
        assert_eq!(default_bw_frac_low(&dec), 0.75);
    }

    /// Contention is an evaluation knob: `Booked` shrinks the tiling
    /// space on shared-node machines, while on machines without shared
    /// bounded nodes it changes nothing at all — bit-identically.
    #[test]
    fn contention_mode_flows_through_evaluation() {
        let g = transformer::decoder_cascade(&transformer::llama2());
        let mut on = EvalOptions::quick();
        on.contention = ContentionMode::Booked;

        // leaf+xnode: disjoint subtrees, nothing shared but the
        // unbounded root → identical numbers in both modes.
        let free = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node());
        let a = evaluate_cascade_on_config(&free, &HardwareParams::default(), &g, &EvalOptions::quick())
            .unwrap();
        let b = evaluate_cascade_on_config(&free, &HardwareParams::default(), &g, &on).unwrap();
        assert_eq!(a.stats.latency_cycles, b.stats.latency_cycles);
        assert_eq!(a.stats.energy_pj, b.stats.energy_pj);

        // hier+xnode: the shared low LLB is actually booked — the
        // machine the result carries shows the sliced capacities.
        let shared =
            HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node());
        let r = evaluate_cascade_on_config(&shared, &HardwareParams::default(), &g, &on).unwrap();
        assert_eq!(r.machine.contention, ContentionMode::Booked);
        use crate::arch::level::LevelKind;
        let llb1 = r.machine.sub_accels[1].spec.level(LevelKind::LLB).unwrap().size_words;
        let llb2 = r.machine.sub_accels[2].spec.level(LevelKind::LLB).unwrap().size_words;
        let node = r.machine.topology.nodes[r.machine.topology.accels[2].attach].size_words;
        assert_eq!(llb1 + llb2, node, "booked slices must sum to the shared node");
        assert!(r.stats.latency_cycles > 0.0);
    }

    #[test]
    fn fingerprint_distinguishes_contention() {
        let off = EvalOptions::default();
        let mut on = EvalOptions::default();
        on.contention = ContentionMode::Booked;
        assert_ne!(off.fingerprint(), on.fingerprint());
    }

    /// Cache safety for the allocation knob: `greedy` keeps the
    /// pre-policy fingerprint bytes (old disk spills stay valid), and
    /// every other policy gets a distinct fingerprint — the evaluator
    /// cache can never serve a `greedy` result for `--alloc search`.
    #[test]
    fn fingerprint_distinguishes_alloc_policies() {
        let base = EvalOptions::default();
        assert_eq!(base.alloc, AllocPolicy::Greedy);
        assert!(
            !base.fingerprint().contains("|a"),
            "greedy fingerprint must keep the legacy byte shape: {}",
            base.fingerprint()
        );
        let mut fps = vec![base.fingerprint()];
        for p in [AllocPolicy::RoundRobin, AllocPolicy::CriticalPath, AllocPolicy::Search] {
            let mut o = EvalOptions::default();
            o.alloc = p;
            fps.push(o.fingerprint());
        }
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j], "policies {i} and {j} share a fingerprint");
            }
        }
    }

    /// The policy knob flows through the whole pipeline: `search` never
    /// reports a worse makespan than `greedy` on the same point, every
    /// policy's stats carry its name + a full valid assignment, and the
    /// searched stats' latency equals its own schedule (no drift
    /// between the oracle and the final evaluation).
    #[test]
    fn alloc_policy_flows_through_evaluation() {
        let g = transformer::decoder_cascade(&transformer::llama2());
        let class = HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node());
        let mut results = Vec::new();
        for p in AllocPolicy::ALL {
            let mut opts = EvalOptions { samples: 8, ..EvalOptions::default() };
            opts.alloc = p;
            let r = evaluate_cascade_on_config(&class, &HardwareParams::default(), &g, &opts)
                .unwrap();
            assert_eq!(r.stats.alloc_policy, p.name());
            assert_eq!(r.stats.assignment, r.assignment);
            assert_eq!(r.assignment.len(), g.ops.len());
            assert_eq!(r.stats.latency_cycles, r.sched.makespan);
            results.push((p, r.stats.latency_cycles));
        }
        let greedy = results.iter().find(|(p, _)| *p == AllocPolicy::Greedy).unwrap().1;
        let search = results.iter().find(|(p, _)| *p == AllocPolicy::Search).unwrap().1;
        assert!(
            search <= greedy + 1e-9 * greedy,
            "search makespan {search} worse than greedy {greedy}"
        );
    }

    #[test]
    fn override_bw_fraction() {
        let g = transformer::decoder_cascade(&transformer::llama2());
        let class = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node());
        let mut opts = EvalOptions::quick();
        opts.bw_frac_low = Some(0.5);
        let r =
            evaluate_cascade_on_config(&class, &HardwareParams::default(), &g, &opts).unwrap();
        let lo_bw = r.machine.sub_accels[1].spec.dram().bw_words_per_cycle;
        assert!((lo_bw - 128.0).abs() < 1e-9); // 50% of 256 w/cyc
    }
}
