//! Coordinator: experiment configuration, end-to-end evaluation of
//! (workload, taxonomy point) pairs, figure drivers for every paper
//! artifact, and report output.

pub mod config;
pub mod experiment;
pub mod figures;

pub use experiment::{
    evaluate_cascade_on_config, evaluate_cascade_on_machine, EvalOptions, EvalResult,
};
