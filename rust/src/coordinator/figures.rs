//! Figure drivers: one function per paper table/figure, each returning
//! the rendered [`Figure`]/text that `cargo bench` and the CLI print.
//!
//! | Driver | Paper artifact |
//! |---|---|
//! | [`fig1_roofline`] | Fig 1 — roofline split across sub-accelerators |
//! | [`table1`] | Table I — classification of existing works |
//! | [`table2_table3`] | Tables II/III — workload + hardware parameters |
//! | [`fig6_speedup`] | Fig 6 — speedup vs leaf+homogeneous (+ BERT utilisation zoom) |
//! | [`fig7_energy`] | Fig 7 — energy by memory level |
//! | [`fig8_mults_per_joule`] | Fig 8 — energy efficiency |
//! | [`fig9_subaccel_energy`] | Fig 9 — on-chip energy by sub-accelerator role |
//! | [`fig10_bw_partition`] | Fig 10 — 75/25 vs 50/50 bandwidth partitioning |

use crate::arch::partition::HardwareParams;
use crate::arch::taxonomy::{prior_works, HarpClass};
use crate::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions, EvalResult};
use crate::model::roofline::machine_rooflines;
use crate::util::benchkit::{Figure, Series};
use crate::util::table::Table;
use crate::workload::transformer::{self, TransformerConfig};
use std::collections::HashMap;

/// Memoising evaluator shared by the figure drivers (several figures
/// reuse the same (workload, config, bandwidth) evaluations).
pub struct Evaluator {
    pub opts: EvalOptions,
    cache: HashMap<String, EvalResult>,
}

impl Evaluator {
    pub fn new(opts: EvalOptions) -> Evaluator {
        Evaluator { opts, cache: HashMap::new() }
    }

    /// Evaluate (workload, class) at `dram_bw_bits`, memoised.
    pub fn eval(
        &mut self,
        wl: &TransformerConfig,
        class: &HarpClass,
        dram_bw_bits: f64,
        bw_frac_low: Option<f64>,
    ) -> &EvalResult {
        let key = format!(
            "{}|{}|{}|{:?}|{}",
            wl.name,
            class.id(),
            dram_bw_bits,
            bw_frac_low,
            self.opts.dynamic_bw
        );
        if !self.cache.contains_key(&key) {
            let cascade = transformer::cascade_for(wl);
            let params = HardwareParams { dram_bw_bits, ..HardwareParams::default() };
            let mut opts = self.opts.clone();
            opts.bw_frac_low = bw_frac_low;
            let r = evaluate_cascade_on_config(class, &params, &cascade, &opts)
                .expect("valid eval point");
            self.cache.insert(key.clone(), r);
        }
        &self.cache[&key]
    }
}

/// Fig 1: rooflines of the homogeneous machine vs the heterogeneous
/// split, sampled over an arithmetic-intensity sweep.
pub fn fig1_roofline() -> Figure {
    let params = HardwareParams::default();
    let points = HarpClass::eval_points();
    let homo = crate::arch::partition::MachineConfig::build(&points[0].1, &params).unwrap();
    let het = crate::arch::partition::MachineConfig::build(&points[1].1, &params).unwrap();
    let mut fig = Figure::new(
        "Fig 1: roofline partitioning (attainable MACs/cycle)",
        "attainable MACs/cycle at each arithmetic intensity",
    );
    let ais = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0];
    for r in machine_rooflines(&homo).into_iter().chain(machine_rooflines(&het)) {
        let mut s = Series::new(&r.name);
        for &ai in &ais {
            s.push(&format!("AI={ai}"), r.attainable(ai));
        }
        fig.add(s);
    }
    fig
}

/// Table I: classification of existing works under the taxonomy.
pub fn table1() -> String {
    let mut t = Table::new(&["work", "hierarchical?", "heterogeneity location", "remarks"]);
    for w in prior_works() {
        t.row(&[
            w.name.to_string(),
            w.class.placement.name().to_string(),
            w.class.heterogeneity.name(),
            w.remark.to_string(),
        ]);
    }
    t.render()
}

/// Tables II + III: workload and hardware parameters (printed as the
/// header of every bench run, for provenance).
pub fn table2_table3() -> String {
    let mut t2 = Table::new(&["workload", "partitioning", "d_model", "seq (prefill/decode)"]);
    for wl in transformer::paper_workloads() {
        let part = if wl.decode_tokens > 0 { "inter-cascade" } else { "intra-cascade" };
        let seq = if wl.decode_tokens > 0 {
            format!("{}/{}", wl.seq, wl.decode_tokens)
        } else {
            format!("{}", wl.seq)
        };
        t2.row(&[wl.name.clone(), part.into(), wl.d_model.to_string(), seq]);
    }
    let p = HardwareParams::default();
    let mut t3 = Table::new(&["parameter", "value"]);
    t3.row_str(&["datawidth (bits/word)", "8"]);
    t3.row(&["number of MACs".into(), p.total_macs.to_string()]);
    t3.row_str(&["DRAM bandwidth (bits/cycle)", "sweep: 2048, 512"]);
    t3.row(&["LLB size".into(), format!("{} MB", p.llb_bytes as f64 / (1 << 20) as f64)]);
    t3.row(&["L1 size (per array)".into(), format!("{} MB", p.l1_bytes as f64 / (1 << 20) as f64)]);
    t3.row(&["RF size (per PE)".into(), format!("{} B", p.rf_bytes_per_pe)]);
    t3.row(&["high:low reuse compute roof".into(), format!("{}:1", p.roof_ratio)]);
    format!("Table II (workloads)\n{}\nTable III (hardware)\n{}", t2.render(), t3.render())
}

/// Fig 6: speedup of every configuration vs leaf+homogeneous at both
/// bandwidth sweep points, plus the BERT utilisation-over-time zoom.
pub fn fig6_speedup(ev: &mut Evaluator) -> (Figure, Figure) {
    let mut fig = Figure::new(
        "Fig 6: speedup normalized to leaf+homogeneous",
        "speedup (higher is better)",
    );
    for bw in [2048.0, 512.0] {
        let mut s = Series::new(&format!("bw={bw} b/cyc"));
        for wl in transformer::paper_workloads() {
            let base = ev
                .eval(&wl, &HarpClass::eval_points()[0].1, bw, None)
                .stats
                .latency_cycles;
            for (tag, class) in HarpClass::eval_points() {
                let lat = ev.eval(&wl, &class, bw, None).stats.latency_cycles;
                s.push(&format!("{} ({tag}) {}", wl.name, class.id()), base / lat);
            }
        }
        fig.add(s);
    }

    // Zoom: PE-weighted utilisation over time, BERT, homo vs cross-node.
    let mut zoom = Figure::new(
        "Fig 6 (zoom): BERT utilisation over time",
        "fraction of total PEs busy per time slice",
    );
    let bert = transformer::bert_large();
    for (tag, class) in [&HarpClass::eval_points()[0], &HarpClass::eval_points()[1]] {
        let r = ev.eval(&bert, class, 2048.0, None);
        let tl = r.stats.utilization_timeline.clone();
        let mut s = Series::new(&format!("({tag}) {}", class.id()));
        for (i, v) in tl.iter().enumerate().step_by(4) {
            s.push(&format!("t{i:02}"), *v);
        }
        zoom.add(s);
    }
    (fig, zoom)
}

/// Fig 7: energy by memory hierarchy level for every configuration.
pub fn fig7_energy(ev: &mut Evaluator) -> Vec<Figure> {
    use crate::arch::level::LevelKind;
    let mut out = Vec::new();
    for wl in transformer::paper_workloads() {
        let mut fig = Figure::new(
            &format!("Fig 7: energy breakdown, {} (µJ)", wl.name),
            "energy in µJ by level",
        );
        for (tag, class) in HarpClass::eval_points() {
            let r = ev.eval(&wl, &class, 2048.0, None);
            let mut s = Series::new(&format!("({tag}) {}", class.id()));
            for k in LevelKind::ALL {
                let e = r.stats.energy_by_level.get(&k).copied().unwrap_or(0.0);
                s.push(k.name(), e * 1e-6); // pJ → µJ
            }
            s.push("MAC", r.stats.mac_energy_pj * 1e-6);
            s.push("NoC", r.stats.noc_energy_pj * 1e-6);
            s.push("TOTAL", r.stats.energy_pj * 1e-6);
            fig.add(s);
        }
        out.push(fig);
    }
    out
}

/// Fig 8: multiplications per joule, normalised to leaf+homogeneous.
pub fn fig8_mults_per_joule(ev: &mut Evaluator) -> Figure {
    let mut fig = Figure::new(
        "Fig 8: multiplications per joule (normalized to leaf+homogeneous)",
        "relative energy efficiency",
    );
    for (tag, class) in HarpClass::eval_points() {
        let mut s = Series::new(&format!("({tag}) {}", class.id()));
        for wl in transformer::paper_workloads() {
            let base =
                ev.eval(&wl, &HarpClass::eval_points()[0].1, 2048.0, None).stats.mults_per_joule();
            let v = ev.eval(&wl, &class, 2048.0, None).stats.mults_per_joule();
            s.push(&wl.name, v / base);
        }
        fig.add(s);
    }
    fig
}

/// Fig 9: on-chip energy split between sub-accelerators running
/// high- vs low-reuse operations (heterogeneous configs only).
pub fn fig9_subaccel_energy(ev: &mut Evaluator) -> Figure {
    let mut fig = Figure::new(
        "Fig 9: on-chip memory-system energy by sub-accelerator role (µJ)",
        "L1 + LLB + NoC energy in µJ (datapath excluded)",
    );
    let het_points: Vec<(char, HarpClass)> =
        HarpClass::eval_points().into_iter().skip(1).collect(); // b, c, d
    // Two decoder operating points: the serving batch used for the
    // performance figures, and single-request decoding (batch = 1, the
    // regime where decode is pure streaming and the paper's "low-reuse
    // dominates on-chip energy" claim is most pronounced).
    let mut workloads = transformer::paper_workloads();
    for base in [transformer::llama2(), transformer::gpt3()] {
        let mut wl = base;
        wl.batch = 1;
        wl.name = format!("{} (b=1)", wl.name);
        workloads.push(wl);
    }
    for (tag, class) in het_points {
        let mut s = Series::new(&format!("({tag}) {}", class.id()));
        for wl in &workloads {
            let r = ev.eval(wl, &class, 2048.0, None);
            for role in ["high-reuse", "low-reuse"] {
                let e = r.stats.buffer_energy_by_role.get(role).copied().unwrap_or(0.0);
                s.push(&format!("{} {}", wl.name, role), e * 1e-6);
            }
        }
        fig.add(s);
    }
    fig
}

/// Fig 10: the 75/25 vs 50/50 bandwidth-partition sensitivity study on
/// the decoder workloads (cross-node config).
pub fn fig10_bw_partition(ev: &mut Evaluator) -> Figure {
    let mut fig = Figure::new(
        "Fig 10: bandwidth partitioning sensitivity (decoder workloads)",
        "speedup vs leaf+homogeneous",
    );
    let xnode = HarpClass::eval_points()[1].1.clone();
    let homo = HarpClass::eval_points()[0].1.clone();
    for (label, frac) in [("75% to low-reuse", Some(0.75)), ("50/50 naive", Some(0.5))] {
        let mut s = Series::new(label);
        for wl in [transformer::llama2(), transformer::gpt3()] {
            let base = ev.eval(&wl, &homo, 2048.0, None).stats.latency_cycles;
            let lat = ev.eval(&wl, &xnode, 2048.0, frac).stats.latency_cycles;
            s.push(&wl.name, base / lat);
        }
        fig.add(s);
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_works() {
        let t = table1();
        for name in ["TPUv1", "NeuPIM", "Symphony", "RaPiD"] {
            assert!(t.contains(name), "missing {name}");
        }
    }

    #[test]
    fn tables_render_parameters() {
        let t = table2_table3();
        assert!(t.contains("12288"));
        assert!(t.contains("40960"));
        assert!(t.contains("3000/1000"));
    }

    #[test]
    fn fig1_has_tipping_structure() {
        let fig = fig1_roofline();
        assert_eq!(fig.series.len(), 3); // unified + high + low
        // Homogeneous roofline saturates at its peak.
        let uni = &fig.series[0];
        assert_eq!(uni.get("AI=1024").unwrap(), 40960.0);
        assert!(uni.get("AI=1").unwrap() < 300.0);
    }
}
