//! Figure drivers: one function per paper table/figure, each returning
//! the rendered [`Figure`]/text that `cargo bench` and the CLI print.
//!
//! | Driver | Paper artifact |
//! |---|---|
//! | [`fig1_roofline`] | Fig 1 — roofline split across sub-accelerators |
//! | [`table1`] | Table I — classification of existing works |
//! | [`table2_table3`] | Tables II/III — workload + hardware parameters |
//! | [`fig6_speedup`] | Fig 6 — speedup vs leaf+homogeneous (+ BERT utilisation zoom) |
//! | [`fig7_energy`] | Fig 7 — energy by memory level |
//! | [`fig8_mults_per_joule`] | Fig 8 — energy efficiency |
//! | [`fig9_subaccel_energy`] | Fig 9 — on-chip energy by sub-accelerator role |
//! | [`fig10_bw_partition`] | Fig 10 — 75/25 vs 50/50 bandwidth partitioning |
//!
//! Every driver first fans its evaluation points out over the shared
//! thread pool (see [`Evaluator::warm`]) and then assembles the figure
//! serially from cache hits, so the rendered output is byte-identical
//! for any worker count while the wall-clock scales with the pool.

use crate::arch::partition::HardwareParams;
use crate::arch::taxonomy::{prior_works, HarpClass};
use crate::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions, EVAL_MODEL_VERSION};
use crate::hhp::allocator::AllocPolicy;
use crate::hhp::stats::CascadeStats;
use crate::model::roofline::machine_rooflines;
use crate::util::benchkit::{Figure, Series};
use crate::util::binio::{BinError, BinReader, BinWriter, CacheFormat};
use crate::util::json::{Json, JsonStreamWriter, JsonStyle};
use crate::util::table::Table;
use crate::util::threadpool::parallel_map;
use crate::workload::einsum::Phase;
use crate::workload::registry::{self, WorkloadSpec};
use crate::workload::transformer;
use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// One evaluation point: (workload, machine class, DRAM bw bits,
/// bandwidth-fraction override). Any registered family — or a cascade
/// loaded from a `--workload FILE` document — is a valid point.
pub type EvalPoint = (WorkloadSpec, HarpClass, f64, Option<f64>);

/// An evaluation point with an explicit allocation policy — what the
/// `fig_alloc_ablation` driver fans out over [`Evaluator::warm_alloc`].
pub type AllocEvalPoint = (WorkloadSpec, HarpClass, f64, AllocPolicy);

/// Canonical fingerprint of one evaluation point — every knob that can
/// change the result. The worker count is deliberately excluded:
/// results are bit-identical across `HARP_THREADS`, so cache entries
/// are shareable between serial and parallel runs (and across
/// processes, via the disk spill).
pub fn eval_key(
    workload: &str,
    class: &HarpClass,
    dram_bw_bits: f64,
    bw_frac_low: Option<f64>,
    opts: &EvalOptions,
) -> String {
    let frac = match bw_frac_low {
        Some(v) => format!("{v}"),
        None => "policy".to_string(),
    };
    format!("{workload}|{}|{dram_bw_bits}|{frac}|{}", class.id(), opts.fingerprint())
}

/// Binary eval-cache spill container kind ([`crate::util::binio`]).
const EVALCACHE_BIN_KIND: &str = "evalcache";
/// Revision of the binary eval-cache payload layout.
const EVALCACHE_BIN_FORMAT: u32 = 1;

/// Loud rejection of a binary eval-cache spill. The JSON spill keeps
/// its historical leniency (an unreadable file is a cold cache — every
/// entry is keyed by its full options fingerprint, so a stale entry
/// simply never hits); the binary fast path instead carries a header
/// this loader checks, and every mismatch reads differently on stderr.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EvalCacheError {
    /// The file exists but cannot be read.
    Io(String),
    /// Not an eval-cache spill, or a structurally broken one.
    Malformed(String),
    /// Written by a different evaluation-model version.
    VersionMismatch { found: u64, expected: u64 },
    /// Written under different evaluation options.
    StaleFingerprint { found: String, expected: String },
}

impl fmt::Display for EvalCacheError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EvalCacheError::Io(e) => write!(f, "cannot read eval cache: {e}"),
            EvalCacheError::Malformed(d) => write!(f, "malformed eval cache: {d}"),
            EvalCacheError::VersionMismatch { found, expected } => write!(
                f,
                "eval cache version mismatch: written by eval model version {found}, \
                 this binary is version {expected} — delete the file to regenerate it"
            ),
            EvalCacheError::StaleFingerprint { found, expected } => write!(
                f,
                "stale eval cache: evaluated under options \"{found}\", this run uses \
                 \"{expected}\" — serving it would change results; delete the file or \
                 use a separate cache per option set"
            ),
        }
    }
}

impl std::error::Error for EvalCacheError {}

/// Memoising evaluator shared by the figure drivers (several figures
/// reuse the same (workload, config, bandwidth) evaluations).
///
/// Thread-safe and cross-driver: the cache uses interior mutability so
/// drivers can fan evaluation points out over the thread pool, and a
/// per-key `OnceLock` guarantees each point is computed exactly once
/// even when looked up concurrently — latecomers block on the winner's
/// cell instead of recomputing. Entries persist for the evaluator's
/// lifetime (all drivers of a `figures` run share one), and optionally
/// spill to a file — pretty JSON (the debug/interchange path) or the
/// `harp_bin` binary fast path — so later *processes* start warm too.
pub struct Evaluator {
    pub opts: EvalOptions,
    cache: Mutex<HashMap<String, Arc<OnceLock<Arc<CascadeStats>>>>>,
    spill: Option<PathBuf>,
    format: CacheFormat,
    dirty: AtomicBool,
}

impl fmt::Debug for Evaluator {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Evaluator")
            .field("entries", &self.len())
            .field("spill", &self.spill)
            .field("format", &self.format)
            .finish()
    }
}

impl Evaluator {
    pub fn new(opts: EvalOptions) -> Evaluator {
        Evaluator {
            opts,
            cache: Mutex::new(HashMap::new()),
            spill: None,
            format: CacheFormat::Json,
            dirty: AtomicBool::new(false),
        }
    }

    /// Evaluator backed by a JSON spill file: previously persisted
    /// points load on construction (unreadable files or entries are
    /// ignored — a cold cache, not an error); [`Evaluator::persist`]
    /// writes new ones back. The historical constructor: every spill
    /// written before the binary format existed loads through here.
    /// Format-aware callers use [`Evaluator::with_spill`].
    pub fn with_cache_file(opts: EvalOptions, path: &Path) -> Evaluator {
        let ev = Evaluator {
            spill: Some(path.to_path_buf()),
            ..Evaluator::new(opts)
        };
        ev.load_json_lenient(path);
        ev
    }

    /// Evaluator backed by a spill file in an explicit format (the
    /// caller resolved the `cache_format` knob against the extension
    /// via [`CacheFormat::resolve`]). JSON keeps the historical
    /// leniency of [`Evaluator::with_cache_file`]; a binary spill that
    /// exists but will not load is a loud [`EvalCacheError`] — a fast
    /// path that quietly recomputed a million points would defeat its
    /// purpose.
    pub fn with_spill(
        opts: EvalOptions,
        path: &Path,
        format: CacheFormat,
    ) -> Result<Evaluator, EvalCacheError> {
        let ev = Evaluator {
            spill: Some(path.to_path_buf()),
            format,
            ..Evaluator::new(opts)
        };
        match format {
            CacheFormat::Json => ev.load_json_lenient(path),
            CacheFormat::Binary => {
                if path.exists() {
                    let bytes = std::fs::read(path).map_err(|e| {
                        EvalCacheError::Io(format!("{}: {e}", path.display()))
                    })?;
                    ev.load_bin(&bytes)?;
                }
            }
        }
        Ok(ev)
    }

    /// The spill format this evaluator was bound with.
    pub fn format(&self) -> CacheFormat {
        self.format
    }

    fn load_json_lenient(&self, path: &Path) {
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(Json::Obj(pairs)) = Json::parse(&text) {
                let mut map = self.cache.lock().unwrap();
                for (k, v) in pairs {
                    if let Some(stats) = CascadeStats::from_json(&v) {
                        let cell = Arc::new(OnceLock::new());
                        let _ = cell.set(Arc::new(stats));
                        map.insert(k, cell);
                    }
                }
            }
        }
    }

    /// Binary loader: magic/kind/revision problems and truncation
    /// surface as `Malformed` with the decoder's offset-bearing text,
    /// then the model version and options fingerprint get their
    /// dedicated rejections.
    fn load_bin(&self, bytes: &[u8]) -> Result<(), EvalCacheError> {
        let mal = |e: BinError| EvalCacheError::Malformed(e.to_string());
        let mut r = BinReader::new(bytes);
        r.header(EVALCACHE_BIN_KIND, EVALCACHE_BIN_FORMAT).map_err(mal)?;
        let found_version = r.u64("model version").map_err(mal)?;
        if found_version != EVAL_MODEL_VERSION as u64 {
            return Err(EvalCacheError::VersionMismatch {
                found: found_version,
                expected: EVAL_MODEL_VERSION as u64,
            });
        }
        let found_fp = r.str("options fingerprint").map_err(mal)?;
        let expected_fp = self.opts.fingerprint();
        if found_fp != expected_fp {
            return Err(EvalCacheError::StaleFingerprint {
                found: found_fp,
                expected: expected_fp,
            });
        }
        let n = r.seq_len(8, "entries").map_err(mal)?;
        let mut map = self.cache.lock().unwrap();
        for _ in 0..n {
            let key = r.str("entry key").map_err(mal)?;
            let stats = CascadeStats::read_bin(&mut r).map_err(|e| {
                EvalCacheError::Malformed(format!("entry \"{key}\": {e}"))
            })?;
            let cell = Arc::new(OnceLock::new());
            let _ = cell.set(Arc::new(stats));
            map.insert(key, cell);
        }
        drop(map);
        r.finish().map_err(mal)
    }

    /// Number of completed cached evaluation points.
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().values().filter(|c| c.get().is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write every computed point to the spill file (no-op without one,
    /// or when nothing new was computed). Keys are sorted so the file is
    /// byte-stable for a given entry set. When the options carry a
    /// file-backed mapping cache ([`EvalOptions::map_cache`]) it spills
    /// too — one call flushes both persistence layers at end of run.
    ///
    /// Both formats stream entry-by-entry through a `BufWriter`: peak
    /// heap is one entry, not the whole document. The JSON bytes are
    /// identical to the old whole-document `to_string_pretty()` path
    /// (pinned by the unit tests), so existing spills keep diffing
    /// clean across this change.
    pub fn persist(&self) -> std::io::Result<()> {
        if let Some(mc) = &self.opts.map_cache {
            mc.persist()?;
        }
        let Some(path) = &self.spill else { return Ok(()) };
        if !self.dirty.load(Ordering::Acquire) {
            return Ok(());
        }
        let map = self.cache.lock().unwrap();
        let mut keys: Vec<&String> = map.keys().collect();
        keys.sort();
        let out = std::io::BufWriter::new(std::fs::File::create(path)?);
        match self.format {
            CacheFormat::Json => {
                let mut w = JsonStreamWriter::new(out, JsonStyle::Pretty);
                w.begin_obj()?;
                for k in keys {
                    if let Some(stats) = map[k.as_str()].get() {
                        w.key(k)?;
                        stats.write_json(&mut w)?;
                    }
                }
                w.end_obj()?;
                w.finish()?;
            }
            CacheFormat::Binary => {
                let mut w = BinWriter::new(out);
                w.header(EVALCACHE_BIN_KIND, EVALCACHE_BIN_FORMAT)?;
                w.u64(EVAL_MODEL_VERSION as u64)?;
                w.str(&self.opts.fingerprint())?;
                let n = keys.iter().filter(|k| map[k.as_str()].get().is_some()).count();
                w.u64(n as u64)?;
                for k in keys {
                    if let Some(stats) = map[k.as_str()].get() {
                        w.str(k)?;
                        stats.write_bin(&mut w)?;
                    }
                }
                w.finish()?;
            }
        }
        Ok(())
    }

    /// Evaluate (workload, class) at `dram_bw_bits`, memoised across
    /// drivers, threads, and (with a spill file) processes. Built-in
    /// workloads key by name (so pre-registry disk spills stay valid);
    /// file cascades key by name + content fingerprint.
    pub fn eval(
        &self,
        wl: &WorkloadSpec,
        class: &HarpClass,
        dram_bw_bits: f64,
        bw_frac_low: Option<f64>,
    ) -> Arc<CascadeStats> {
        self.eval_with(wl, class, dram_bw_bits, bw_frac_low, self.opts.alloc)
    }

    /// [`Evaluator::eval`] with an explicit allocation policy override —
    /// what lets one evaluator sweep policies (`fig_alloc_ablation`)
    /// while sharing cache entries with the policy-agnostic drivers:
    /// the key includes the overridden fingerprint, so a `greedy` point
    /// here IS the same cache entry fig6 warms.
    pub fn eval_with(
        &self,
        wl: &WorkloadSpec,
        class: &HarpClass,
        dram_bw_bits: f64,
        bw_frac_low: Option<f64>,
        alloc: AllocPolicy,
    ) -> Arc<CascadeStats> {
        let mut opts = self.opts.clone();
        opts.alloc = alloc;
        let key = eval_key(&wl.cache_key(), class, dram_bw_bits, bw_frac_low, &opts);
        let cell = {
            let mut map = self.cache.lock().unwrap();
            map.entry(key).or_insert_with(|| Arc::new(OnceLock::new())).clone()
        };
        cell.get_or_init(|| {
            let cascade = wl.cascade();
            let params = HardwareParams { dram_bw_bits, ..HardwareParams::default() };
            opts.bw_frac_low = bw_frac_low;
            let r = evaluate_cascade_on_config(class, &params, &cascade, &opts)
                .expect("valid eval point");
            self.dirty.store(true, Ordering::Release);
            Arc::new(r.stats)
        })
        .clone()
    }

    /// Fan a set of evaluation points out over the thread pool, warming
    /// the cache. Duplicate points coalesce on their `OnceLock`; each
    /// point's own mapper searches fan out underneath, bounded by the
    /// shared pool budget.
    pub fn warm(&self, points: &[EvalPoint]) {
        parallel_map(points.len(), self.opts.threads, |i| {
            let (wl, class, bw, frac) = &points[i];
            self.eval(wl, class, *bw, *frac);
        });
    }

    /// [`Evaluator::warm`] for policy-explicit points (the allocation
    /// ablation's sweep axis).
    pub fn warm_alloc(&self, points: &[AllocEvalPoint]) {
        parallel_map(points.len(), self.opts.threads, |i| {
            let (wl, class, bw, alloc) = &points[i];
            self.eval_with(wl, class, *bw, None, *alloc);
        });
    }
}

/// Cross-product of workloads × classes × bandwidths as warm-up points
/// (the point list every grid-shaped driver feeds [`Evaluator::warm`]).
fn cross_points(
    wls: &[WorkloadSpec],
    classes: &[(char, HarpClass)],
    bws: &[f64],
) -> Vec<EvalPoint> {
    let mut points = Vec::with_capacity(wls.len() * classes.len() * bws.len());
    for &bw in bws {
        for wl in wls {
            for (_, class) in classes {
                points.push((wl.clone(), class.clone(), bw, None));
            }
        }
    }
    points
}

/// Fig 1: rooflines of the homogeneous machine vs the heterogeneous
/// split, sampled over an arithmetic-intensity sweep.
pub fn fig1_roofline() -> Figure {
    let params = HardwareParams::default();
    let points = HarpClass::eval_points();
    let homo = crate::arch::partition::MachineConfig::build(&points[0].1, &params).unwrap();
    let het = crate::arch::partition::MachineConfig::build(&points[1].1, &params).unwrap();
    let mut fig = Figure::new(
        "Fig 1: roofline partitioning (attainable MACs/cycle)",
        "attainable MACs/cycle at each arithmetic intensity",
    );
    let ais = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0];
    for r in machine_rooflines(&homo).into_iter().chain(machine_rooflines(&het)) {
        let mut s = Series::new(&r.name);
        for &ai in &ais {
            s.push(&format!("AI={ai}"), r.attainable(ai));
        }
        fig.add(s);
    }
    fig
}

/// Table I: classification of existing works under the taxonomy.
pub fn table1() -> String {
    let mut t = Table::new(&["work", "hierarchical?", "heterogeneity location", "remarks"]);
    for w in prior_works() {
        t.row(&[
            w.name.to_string(),
            w.class.placement.name().to_string(),
            w.class.heterogeneity.name(),
            w.remark.to_string(),
        ]);
    }
    t.render()
}

/// Tables II + III: workload and hardware parameters (printed as the
/// header of every bench run, for provenance).
pub fn table2_table3() -> String {
    let mut t2 = Table::new(&["workload", "partitioning", "d_model", "seq (prefill/decode)"]);
    for wl in transformer::paper_workloads() {
        let part = if wl.decode_tokens > 0 { "inter-cascade" } else { "intra-cascade" };
        let seq = if wl.decode_tokens > 0 {
            format!("{}/{}", wl.seq, wl.decode_tokens)
        } else {
            format!("{}", wl.seq)
        };
        t2.row(&[wl.name.clone(), part.into(), wl.d_model.to_string(), seq]);
    }
    let p = HardwareParams::default();
    let mut t3 = Table::new(&["parameter", "value"]);
    t3.row_str(&["datawidth (bits/word)", "8"]);
    t3.row(&["number of MACs".into(), p.total_macs.to_string()]);
    t3.row_str(&["DRAM bandwidth (bits/cycle)", "sweep: 2048, 512"]);
    t3.row(&["LLB size".into(), format!("{} MB", p.llb_bytes as f64 / (1 << 20) as f64)]);
    t3.row(&["L1 size (per array)".into(), format!("{} MB", p.l1_bytes as f64 / (1 << 20) as f64)]);
    t3.row(&["RF size (per PE)".into(), format!("{} B", p.rf_bytes_per_pe)]);
    t3.row(&["high:low reuse compute roof".into(), format!("{}:1", p.roof_ratio)]);
    format!("Table II (workloads)\n{}\nTable III (hardware)\n{}", t2.render(), t3.render())
}

/// Speedup-vs-leaf+homogeneous figure over an arbitrary workload list —
/// the Fig 6 shape, reusable for ANY registered family or file cascade.
/// `fig6_speedup` feeds it the Table II grid; `fig6_style_speedup`
/// drives a single workload through the same sweep.
pub fn speedup_figure(
    ev: &Evaluator,
    title: &str,
    ylabel: &str,
    wls: &[WorkloadSpec],
    bws: &[f64],
) -> Figure {
    let classes = HarpClass::eval_points();
    ev.warm(&cross_points(wls, &classes, bws));

    let mut fig = Figure::new(title, ylabel);
    for &bw in bws {
        let mut s = Series::new(&format!("bw={bw} b/cyc"));
        for wl in wls {
            let base = ev.eval(wl, &classes[0].1, bw, None).latency_cycles;
            for (tag, class) in &classes {
                let lat = ev.eval(wl, class, bw, None).latency_cycles;
                s.push(&format!("{} ({tag}) {}", wl.name(), class.id()), base / lat);
            }
        }
        fig.add(s);
    }
    fig
}

/// Fig 6-style speedup sweep for ONE workload (any registered family or
/// a loaded `--workload FILE` cascade) at both paper bandwidths.
pub fn fig6_style_speedup(ev: &Evaluator, wl: &WorkloadSpec) -> Figure {
    speedup_figure(
        ev,
        &format!("Fig 6-style speedup, {} (normalized to leaf+homogeneous)", wl.name()),
        "speedup (higher is better)",
        std::slice::from_ref(wl),
        &[2048.0, 512.0],
    )
}

/// Fig 6: speedup of every configuration vs leaf+homogeneous at both
/// bandwidth sweep points, plus the BERT utilisation-over-time zoom.
pub fn fig6_speedup(ev: &Evaluator) -> (Figure, Figure) {
    let classes = HarpClass::eval_points();
    let fig = speedup_figure(
        ev,
        "Fig 6: speedup normalized to leaf+homogeneous",
        "speedup (higher is better)",
        &registry::paper_specs(),
        &[2048.0, 512.0],
    );

    // Zoom: PE-weighted utilisation over time, BERT, homo vs cross-node.
    let mut zoom = Figure::new(
        "Fig 6 (zoom): BERT utilisation over time",
        "fraction of total PEs busy per time slice",
    );
    let bert = WorkloadSpec::Transformer(transformer::bert_large());
    for (tag, class) in [&classes[0], &classes[1]] {
        let r = ev.eval(&bert, class, 2048.0, None);
        let mut s = Series::new(&format!("({tag}) {}", class.id()));
        for (i, v) in r.utilization_timeline.iter().enumerate().step_by(4) {
            s.push(&format!("t{i:02}"), *v);
        }
        zoom.add(s);
    }
    (fig, zoom)
}

/// Table II-style summary of every REGISTERED workload (the `harp
/// workload list` body): registry name, display name, family, size,
/// phase structure, and the arithmetic-intensity span that drives
/// reuse classification.
pub fn workload_table() -> String {
    let mut t = Table::new(&[
        "name", "workload", "family", "ops", "edges", "MACs", "phases", "AI min..max",
    ]);
    for (key, spec) in registry::all_builtins() {
        let g = spec.cascade();
        let phases: Vec<&str> = Phase::ALL
            .iter()
            .filter(|p| !g.ops_in_phase(**p).is_empty())
            .map(|p| p.name())
            .collect();
        let lo = g
            .ops
            .iter()
            .map(|o| o.arithmetic_intensity())
            .fold(f64::INFINITY, f64::min);
        let hi = g.ops.iter().map(|o| o.arithmetic_intensity()).fold(0.0f64, f64::max);
        t.row(&[
            key.to_string(),
            g.name.clone(),
            spec.family().to_string(),
            g.ops.len().to_string(),
            g.deps.len().to_string(),
            format!("{:.3e}", g.total_macs() as f64),
            phases.join("+"),
            format!("{lo:.1}..{hi:.1}"),
        ]);
    }
    t.render()
}

/// Fig 7: energy by memory hierarchy level for every configuration.
pub fn fig7_energy(ev: &Evaluator) -> Vec<Figure> {
    use crate::arch::level::LevelKind;
    let classes = HarpClass::eval_points();
    let wls = registry::paper_specs();
    ev.warm(&cross_points(&wls, &classes, &[2048.0]));

    let mut out = Vec::new();
    for wl in &wls {
        let mut fig = Figure::new(
            &format!("Fig 7: energy breakdown, {} (µJ)", wl.name()),
            "energy in µJ by level",
        );
        for (tag, class) in &classes {
            let r = ev.eval(wl, class, 2048.0, None);
            let mut s = Series::new(&format!("({tag}) {}", class.id()));
            for k in LevelKind::ALL {
                let e = r.energy_by_level.get(&k).copied().unwrap_or(0.0);
                s.push(k.name(), e * 1e-6); // pJ → µJ
            }
            s.push("MAC", r.mac_energy_pj * 1e-6);
            s.push("NoC", r.noc_energy_pj * 1e-6);
            s.push("TOTAL", r.energy_pj * 1e-6);
            fig.add(s);
        }
        out.push(fig);
    }
    out
}

/// Fig 8: multiplications per joule, normalised to leaf+homogeneous.
pub fn fig8_mults_per_joule(ev: &Evaluator) -> Figure {
    let classes = HarpClass::eval_points();
    let wls = registry::paper_specs();
    ev.warm(&cross_points(&wls, &classes, &[2048.0]));

    let mut fig = Figure::new(
        "Fig 8: multiplications per joule (normalized to leaf+homogeneous)",
        "relative energy efficiency",
    );
    for (tag, class) in &classes {
        let mut s = Series::new(&format!("({tag}) {}", class.id()));
        for wl in &wls {
            let base = ev.eval(wl, &classes[0].1, 2048.0, None).mults_per_joule();
            let v = ev.eval(wl, class, 2048.0, None).mults_per_joule();
            s.push(wl.name(), v / base);
        }
        fig.add(s);
    }
    fig
}

/// Fig 9: on-chip energy split between sub-accelerators running
/// high- vs low-reuse operations (heterogeneous configs only).
pub fn fig9_subaccel_energy(ev: &Evaluator) -> Figure {
    let mut fig = Figure::new(
        "Fig 9: on-chip memory-system energy by sub-accelerator role (µJ)",
        "L1 + LLB + NoC energy in µJ (datapath excluded)",
    );
    let het_points: Vec<(char, HarpClass)> =
        HarpClass::eval_points().into_iter().skip(1).collect(); // b, c, d
    // Two decoder operating points: the serving batch used for the
    // performance figures, and single-request decoding (batch = 1, the
    // regime where decode is pure streaming and the paper's "low-reuse
    // dominates on-chip energy" claim is most pronounced).
    let mut workloads = registry::paper_specs();
    for base in [transformer::llama2(), transformer::gpt3()] {
        let mut wl = base;
        wl.batch = 1;
        wl.name = format!("{} (b=1)", wl.name);
        workloads.push(WorkloadSpec::Transformer(wl));
    }
    ev.warm(&cross_points(&workloads, &het_points, &[2048.0]));

    for (tag, class) in &het_points {
        let mut s = Series::new(&format!("({tag}) {}", class.id()));
        for wl in &workloads {
            let r = ev.eval(wl, class, 2048.0, None);
            for role in ["high-reuse", "low-reuse"] {
                let e = r.buffer_energy_by_role.get(role).copied().unwrap_or(0.0);
                s.push(&format!("{} {}", wl.name(), role), e * 1e-6);
            }
        }
        fig.add(s);
    }
    fig
}

/// Fig 10: the 75/25 vs 50/50 bandwidth-partition sensitivity study on
/// the decoder workloads (cross-node config).
pub fn fig10_bw_partition(ev: &Evaluator) -> Figure {
    let mut fig = Figure::new(
        "Fig 10: bandwidth partitioning sensitivity (decoder workloads)",
        "speedup vs leaf+homogeneous",
    );
    let xnode = HarpClass::eval_points()[1].1.clone();
    let homo = HarpClass::eval_points()[0].1.clone();
    let decoders = || {
        [
            WorkloadSpec::Transformer(transformer::llama2()),
            WorkloadSpec::Transformer(transformer::gpt3()),
        ]
    };
    let mut points: Vec<EvalPoint> = Vec::new();
    for wl in decoders() {
        points.push((wl.clone(), homo.clone(), 2048.0, None));
        points.push((wl.clone(), xnode.clone(), 2048.0, Some(0.75)));
        points.push((wl, xnode.clone(), 2048.0, Some(0.5)));
    }
    ev.warm(&points);

    for (label, frac) in [("75% to low-reuse", Some(0.75)), ("50/50 naive", Some(0.5))] {
        let mut s = Series::new(label);
        for wl in decoders() {
            let base = ev.eval(&wl, &homo, 2048.0, None).latency_cycles;
            let lat = ev.eval(&wl, &xnode, 2048.0, frac).latency_cycles;
            s.push(wl.name(), base / lat);
        }
        fig.add(s);
    }
    fig
}

/// The workload grid the allocation ablation sweeps: the paper's
/// Table II transformers plus the MoE families — the mixed-reuse
/// cascades where the op → unit assignment has the most room to move.
fn alloc_ablation_specs() -> Vec<WorkloadSpec> {
    let mut wls = registry::paper_specs();
    for name in ["moe_prefill", "moe_decode"] {
        wls.push(registry::by_name(name).expect("registered"));
    }
    wls
}

/// Allocation-policy ablation: speedup of every [`AllocPolicy`] over
/// `greedy` for each (workload, taxonomy point) at the paper's primary
/// bandwidth. One series per policy; values are
/// `greedy latency / policy latency`, so `greedy` pins 1.0, a value
/// above 1.0 means the policy beat the paper's fixed heuristic, and
/// `search` is ≥ 1.0 by construction (it starts from greedy and keeps
/// only strict improvements — a local optimum, so it may still trail
/// another policy's row). Points fan out through
/// [`Evaluator::warm_alloc`]; the `greedy` column shares cache entries
/// with the fig6 grid.
pub fn fig_alloc_ablation(ev: &Evaluator) -> Figure {
    let classes = HarpClass::eval_points();
    let wls = alloc_ablation_specs();
    let mut points: Vec<AllocEvalPoint> = Vec::new();
    for policy in AllocPolicy::ALL {
        for wl in &wls {
            for (_, class) in &classes {
                points.push((wl.clone(), class.clone(), 2048.0, policy));
            }
        }
    }
    ev.warm_alloc(&points);

    let mut fig = Figure::new(
        "Allocation-policy ablation: speedup over greedy (policy × machine × workload)",
        "greedy latency / policy latency (higher is better; greedy = 1)",
    );
    for policy in AllocPolicy::ALL {
        let mut s = Series::new(policy.name());
        for wl in &wls {
            for (tag, class) in &classes {
                let base = ev
                    .eval_with(wl, class, 2048.0, None, AllocPolicy::Greedy)
                    .latency_cycles;
                let lat = ev.eval_with(wl, class, 2048.0, None, policy).latency_cycles;
                s.push(&format!("{} ({tag}) {}", wl.name(), class.id()), base / lat);
            }
        }
        fig.add(s);
    }
    fig
}

/// Offered-load grid (requests per Mcycle) the serving knee sweeps.
pub const SERVING_LOAD_GRID: [f64; 5] = [0.5, 1.0, 2.0, 4.0, 8.0];

/// Serving saturation knee: goodput vs offered load per taxonomy
/// point, over a fixed seeded Poisson stream of the three request
/// families in equal parts. One series per machine class; the grid
/// rows carry goodput at each offered load and the final `knee` row
/// the first load where the class stops keeping up (see
/// [`serve::saturation_knee`]). Calibration probes fan out through
/// [`Evaluator::warm`]; the simulation itself is single-threaded and
/// seeded, so the figure is byte-identical for any worker count.
pub fn fig_serving_knee(ev: &Evaluator) -> Figure {
    use crate::runtime::serve;
    use crate::workload::arrivals::{self, ArrivalKind, RequestFamily};

    let classes = HarpClass::eval_points();
    let families: Vec<RequestFamily> = RequestFamily::ALL.to_vec();
    let mix: Vec<(RequestFamily, f64)> = families.iter().map(|&f| (f, 1.0)).collect();
    let cfg = serve::ServeConfig::default();

    let mut fig = Figure::new(
        "Serving saturation knee: goodput vs offered load (per taxonomy point)",
        "goodput (SLO-meeting completions per Mcycle)",
    );
    for (tag, class) in &classes {
        let costs = serve::calibrate(ev, class, 2048.0, &families);
        let machine = serve::build_serving_machine(class, 2048.0, ev.opts.contention)
            .expect("taxonomy point builds");
        let mut s = Series::new(&format!("({tag}) {}", class.id()));
        let mut curve: Vec<(f64, f64)> = Vec::new();
        for &load in &SERVING_LOAD_GRID {
            let stream = arrivals::synthesize(&arrivals::StreamParams {
                kind: ArrivalKind::Poisson,
                mix: mix.clone(),
                classes: vec![],
                load,
                requests: 40,
                seed: 0x5EED ^ ev.opts.seed,
            })
            .expect("valid stream params");
            let r = serve::simulate(
                &stream,
                &machine,
                &costs,
                ev.opts.dynamic_bw,
                load,
                &cfg,
            )
            .expect("serving machine is bounded");
            s.push(&format!("load={load}"), r.report.goodput);
            curve.push((load, r.report.goodput));
        }
        s.push("knee", serve::saturation_knee(&curve));
        fig.add(s);
    }
    fig
}

/// Per-class serving knee: the same saturation sweep as
/// [`fig_serving_knee`], but over a mixed-priority stream
/// (interactive:1, batch:3) with class-aware admission. Two series per
/// taxonomy point — one per latency class — each carrying its own
/// goodput curve over [`SERVING_LOAD_GRID`] and its own knee, so the
/// figure shows how far priority admission defends interactive goodput
/// past the aggregate knee. The arrival/shape stream is bit-identical
/// to the classless sweep (class labels ride a separate RNG), so any
/// divergence from [`fig_serving_knee`] is pure scheduling policy.
pub fn fig_serving_knee_class(ev: &Evaluator) -> Figure {
    use crate::runtime::serve;
    use crate::workload::arrivals::{self, ArrivalKind, RequestClass, RequestFamily};

    let classes = HarpClass::eval_points();
    let families: Vec<RequestFamily> = RequestFamily::ALL.to_vec();
    let mix: Vec<(RequestFamily, f64)> = families.iter().map(|&f| (f, 1.0)).collect();
    let class_mix = vec![(RequestClass::Interactive, 1.0), (RequestClass::Batch, 3.0)];
    let cfg = serve::ServeConfig::default();

    let mut fig = Figure::new(
        "Per-class serving knee: goodput vs offered load (interactive:1, batch:3)",
        "goodput (SLO-meeting completions per Mcycle)",
    );
    for (tag, class) in &classes {
        let costs = serve::calibrate(ev, class, 2048.0, &families);
        let machine = serve::build_serving_machine(class, 2048.0, ev.opts.contention)
            .expect("taxonomy point builds");
        let mut series: Vec<(Series, Vec<(f64, f64)>)> = RequestClass::ALL
            .iter()
            .map(|c| {
                (Series::new(&format!("({tag}) {} [{}]", class.id(), c.name())), Vec::new())
            })
            .collect();
        for &load in &SERVING_LOAD_GRID {
            let stream = arrivals::synthesize(&arrivals::StreamParams {
                kind: ArrivalKind::Poisson,
                mix: mix.clone(),
                classes: class_mix.clone(),
                load,
                requests: 40,
                seed: 0x5EED ^ ev.opts.seed,
            })
            .expect("valid stream params");
            let r = serve::simulate(
                &stream,
                &machine,
                &costs,
                ev.opts.dynamic_bw,
                load,
                &cfg,
            )
            .expect("serving machine is bounded");
            for (i, c) in RequestClass::ALL.iter().enumerate() {
                let goodput = r
                    .report
                    .class_breakdown
                    .iter()
                    .find(|b| b.class == *c)
                    .map(|b| b.goodput)
                    .unwrap_or(0.0);
                series[i].0.push(&format!("load={load}"), goodput);
                series[i].1.push((load, goodput));
            }
        }
        for (mut s, curve) in series {
            s.push("knee", serve::saturation_knee(&curve));
            fig.add(s);
        }
    }
    fig
}

/// Offered-load grid for the disaggregation figure (a subset of
/// [`SERVING_LOAD_GRID`]: the sweep runs every point twice, so it trades
/// grid resolution for two engines per load).
pub const DISAGG_LOAD_GRID: [f64; 3] = [1.0, 2.0, 4.0];

/// Disaggregated vs co-located serving: for every taxonomy point with
/// at least two sub-accelerator types, serve the same seeded stream
/// both co-located (the default engine) and role-disaggregated
/// (`prefill=high,decode=low`), and report goodput + p50 TTFT per
/// offered load, the KV words moved between the pools, and the
/// disagg curve's knee — with a distinct `saturated` row
/// ([`serve::saturation_knee_checked`]) separating "knee on the grid"
/// from "never saturated on this grid". Single-type (homogeneous)
/// points are skipped: disaggregation is undefined there, and the
/// engine rejects it loudly.
pub fn fig_serving_disagg(ev: &Evaluator) -> Figure {
    use crate::runtime::serve;
    use crate::workload::arrivals::{self, ArrivalKind, RequestFamily};
    use crate::workload::intensity::ReuseClass;

    let classes = HarpClass::eval_points();
    let families: Vec<RequestFamily> = RequestFamily::ALL.to_vec();
    let mix: Vec<(RequestFamily, f64)> = families.iter().map(|&f| (f, 1.0)).collect();
    let coloc_cfg = serve::ServeConfig::default();
    let disagg_cfg = serve::ServeConfig {
        disagg: Some(serve::DisaggConfig {
            prefill: ReuseClass::High,
            decode: ReuseClass::Low,
        }),
        ..serve::ServeConfig::default()
    };

    let mut fig = Figure::new(
        "Disaggregated vs co-located serving: goodput / TTFT / KV hand-off traffic",
        "goodput (SLO-meeting completions per Mcycle) and p50 TTFT (cycles)",
    );
    for (tag, class) in &classes {
        let machine = serve::build_serving_machine(class, 2048.0, ev.opts.contention)
            .expect("taxonomy point builds");
        let mut tys: Vec<&str> =
            machine.topology.accels.iter().map(|a| a.ty.as_str()).collect();
        tys.sort_unstable();
        tys.dedup();
        if tys.len() < 2 {
            // Homogeneous point: nothing to disaggregate across.
            continue;
        }
        let costs = serve::calibrate(ev, class, 2048.0, &families);
        let mut coloc = Series::new(&format!("({tag}) {} [coloc]", class.id()));
        let mut disagg = Series::new(&format!("({tag}) {} [disagg]", class.id()));
        let mut curve: Vec<(f64, f64)> = Vec::new();
        for &load in &DISAGG_LOAD_GRID {
            let stream = arrivals::synthesize(&arrivals::StreamParams {
                kind: ArrivalKind::Poisson,
                mix: mix.clone(),
                classes: vec![],
                load,
                requests: 24,
                seed: 0x5EED ^ ev.opts.seed,
            })
            .expect("valid stream params");
            let c = serve::simulate(
                &stream,
                &machine,
                &costs,
                ev.opts.dynamic_bw,
                load,
                &coloc_cfg,
            )
            .expect("serving machine is bounded");
            let d = serve::simulate(
                &stream,
                &machine,
                &costs,
                ev.opts.dynamic_bw,
                load,
                &disagg_cfg,
            )
            .expect("disagg runs on every >=2-type point");
            coloc.push(&format!("goodput load={load}"), c.report.goodput);
            coloc.push(&format!("p50_ttft load={load}"), c.report.p50_ttft);
            disagg.push(&format!("goodput load={load}"), d.report.goodput);
            disagg.push(&format!("p50_ttft load={load}"), d.report.p50_ttft);
            disagg.push(
                &format!("kv_moved_words load={load}"),
                d.report.kv_transfer_words as f64,
            );
            curve.push((load, d.report.goodput));
        }
        let (knee, saturated) = serve::saturation_knee_checked(&curve);
        disagg.push("knee", knee);
        disagg.push("saturated", if saturated { 1.0 } else { 0.0 });
        fig.add(coloc);
        fig.add(disagg);
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_works() {
        let t = table1();
        for name in ["TPUv1", "NeuPIM", "Symphony", "RaPiD"] {
            assert!(t.contains(name), "missing {name}");
        }
    }

    #[test]
    fn tables_render_parameters() {
        let t = table2_table3();
        assert!(t.contains("12288"));
        assert!(t.contains("40960"));
        assert!(t.contains("3000/1000"));
    }

    #[test]
    fn workload_table_lists_every_registered_name() {
        let t = workload_table();
        for name in registry::names() {
            assert!(t.contains(name), "missing {name}:\n{t}");
        }
        // Display names and families render alongside the registry keys.
        for s in ["MoE-decode", "conv-im2col", "serving-mix", "prefill+decode"] {
            assert!(t.contains(s), "missing {s}:\n{t}");
        }
    }

    #[test]
    fn fig1_has_tipping_structure() {
        let fig = fig1_roofline();
        assert_eq!(fig.series.len(), 3); // unified + high + low
        // Homogeneous roofline saturates at its peak.
        let uni = &fig.series[0];
        assert_eq!(uni.get("AI=1024").unwrap(), 40960.0);
        assert!(uni.get("AI=1").unwrap() < 300.0);
    }

    #[test]
    fn evaluator_caches_by_point() {
        let ev = Evaluator::new(EvalOptions { samples: 10, ..EvalOptions::default() });
        let wl = WorkloadSpec::Transformer(transformer::bert_large());
        let class = HarpClass::eval_points()[0].1.clone();
        assert!(ev.is_empty());
        let a = ev.eval(&wl, &class, 2048.0, None);
        assert_eq!(ev.len(), 1);
        let b = ev.eval(&wl, &class, 2048.0, None);
        // A cache hit returns the same allocation, not a recomputation.
        assert!(Arc::ptr_eq(&a, &b));
        // A different bandwidth is a different point.
        let c = ev.eval(&wl, &class, 512.0, None);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(ev.len(), 2);
    }

    /// The policy-explicit entry point shares cache cells with the
    /// policy-agnostic one for the evaluator's own policy, and keys
    /// other policies separately — a `greedy` result can never be
    /// served for a `search` request.
    #[test]
    fn eval_with_policy_caches_separately_but_shares_default() {
        let ev = Evaluator::new(EvalOptions { samples: 8, ..EvalOptions::default() });
        let wl = WorkloadSpec::Transformer(transformer::bert_large());
        let class = HarpClass::eval_points()[1].1.clone();
        let a = ev.eval(&wl, &class, 2048.0, None);
        let b = ev.eval_with(&wl, &class, 2048.0, None, AllocPolicy::Greedy);
        assert!(Arc::ptr_eq(&a, &b), "default policy shares the eval() cache entry");
        let c = ev.eval_with(&wl, &class, 2048.0, None, AllocPolicy::RoundRobin);
        assert!(!Arc::ptr_eq(&a, &c), "a different policy is a different point");
        assert_eq!(c.alloc_policy, "round_robin");
        assert_eq!(ev.len(), 2);
    }

    #[test]
    fn eval_key_distinguishes_alloc_policy() {
        let class = HarpClass::eval_points()[0].1.clone();
        let base = eval_key("bert", &class, 2048.0, None, &EvalOptions::default());
        let mut o = EvalOptions::default();
        o.alloc = AllocPolicy::Search;
        assert_ne!(base, eval_key("bert", &class, 2048.0, None, &o));
    }

    #[test]
    fn eval_key_distinguishes_knobs() {
        let class_a = HarpClass::eval_points()[0].1.clone();
        let class_b = HarpClass::eval_points()[1].1.clone();
        let opts = EvalOptions::default();
        let base = eval_key("bert", &class_a, 2048.0, None, &opts);
        assert_ne!(base, eval_key("gpt3", &class_a, 2048.0, None, &opts));
        assert_ne!(base, eval_key("bert", &class_b, 2048.0, None, &opts));
        assert_ne!(base, eval_key("bert", &class_a, 512.0, None, &opts));
        assert_ne!(base, eval_key("bert", &class_a, 2048.0, Some(0.5), &opts));
        let mut o2 = EvalOptions::default();
        o2.samples += 1;
        assert_ne!(base, eval_key("bert", &class_a, 2048.0, None, &o2));
        // Threads must NOT change the key: results are thread-invariant.
        let mut o3 = EvalOptions::default();
        o3.threads = 1;
        assert_eq!(base, eval_key("bert", &class_a, 2048.0, None, &o3));
    }

    #[test]
    fn disk_spill_roundtrip() {
        let dir = std::env::temp_dir().join("harp_evaluator_spill_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        let _ = std::fs::remove_file(&path);

        let opts = EvalOptions { samples: 10, ..EvalOptions::default() };
        let wl = WorkloadSpec::Transformer(transformer::bert_large());
        let class = HarpClass::eval_points()[0].1.clone();

        let ev = Evaluator::with_cache_file(opts.clone(), &path);
        assert!(ev.is_empty());
        let fresh = ev.eval(&wl, &class, 2048.0, None);
        ev.persist().unwrap();

        // A new evaluator starts warm and returns identical numbers
        // WITHOUT recomputing (seeding a different `samples` would
        // change a fresh search, so a matching key must come from disk).
        let ev2 = Evaluator::with_cache_file(opts, &path);
        assert_eq!(ev2.len(), 1);
        let cached = ev2.eval(&wl, &class, 2048.0, None);
        assert_eq!(cached.latency_cycles, fresh.latency_cycles);
        assert_eq!(cached.energy_pj, fresh.energy_pj);
        assert_eq!(cached.utilization_timeline, fresh.utilization_timeline);

        let _ = std::fs::remove_file(&path);
    }

    /// The streamed JSON persist path emits byte-for-byte what the old
    /// whole-document `to_string_pretty()` path wrote, so pre-existing
    /// spills stay diff-clean across the streaming change.
    #[test]
    fn streamed_persist_matches_tree_bytes() {
        let dir = std::env::temp_dir().join("harp_evaluator_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        let _ = std::fs::remove_file(&path);

        let opts = EvalOptions { samples: 10, ..EvalOptions::default() };
        let wl = WorkloadSpec::Transformer(transformer::bert_large());
        let ev = Evaluator::with_cache_file(opts, &path);
        for (_, class) in HarpClass::eval_points().iter().take(2) {
            ev.eval(&wl, class, 2048.0, None);
        }
        ev.persist().unwrap();

        let streamed = std::fs::read_to_string(&path).unwrap();
        let map = ev.cache.lock().unwrap();
        let mut keys: Vec<&String> = map.keys().collect();
        keys.sort();
        let mut obj = Json::obj();
        for k in keys {
            obj = obj.with(k, map[k.as_str()].get().unwrap().to_json());
        }
        assert_eq!(streamed, obj.to_string_pretty());

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn binary_spill_round_trips_and_rejects_mismatches() {
        let dir = std::env::temp_dir().join("harp_evaluator_bin_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.bin");
        let _ = std::fs::remove_file(&path);

        let opts = EvalOptions { samples: 10, ..EvalOptions::default() };
        let wl = WorkloadSpec::Transformer(transformer::bert_large());
        let class = HarpClass::eval_points()[0].1.clone();

        let fmt = CacheFormat::resolve(&path, None).unwrap();
        assert_eq!(fmt, CacheFormat::Binary);
        let ev = Evaluator::with_spill(opts.clone(), &path, fmt).unwrap();
        let fresh = ev.eval(&wl, &class, 2048.0, None);
        ev.persist().unwrap();

        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"harp_bin"));

        // Warm start serves bit-identical numbers without recomputing:
        // a fresh search under different samples would differ, so a
        // matching entry must come from disk.
        let ev2 = Evaluator::with_spill(opts.clone(), &path, fmt).unwrap();
        assert_eq!(ev2.len(), 1);
        let cached = ev2.eval(&wl, &class, 2048.0, None);
        assert_eq!(cached.latency_cycles.to_bits(), fresh.latency_cycles.to_bits());
        assert_eq!(cached.energy_pj.to_bits(), fresh.energy_pj.to_bits());
        assert_eq!(cached.to_json().to_string_pretty(), fresh.to_json().to_string_pretty());

        // Re-persisting the untouched cache is a no-op (not dirty) and
        // the file keeps its bytes.
        ev2.persist().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), bytes);

        // Different options → StaleFingerprint, not a quiet cold cache.
        let other = EvalOptions { samples: 11, ..EvalOptions::default() };
        let err = Evaluator::with_spill(other, &path, fmt).unwrap_err();
        assert!(matches!(err, EvalCacheError::StaleFingerprint { .. }), "{err}");
        assert!(err.to_string().contains("stale eval cache"), "{err}");

        // Doctored magic → Malformed naming the magic.
        let mut doctored = bytes.clone();
        doctored[0] ^= 0xff;
        std::fs::write(&path, &doctored).unwrap();
        let err = Evaluator::with_spill(opts.clone(), &path, fmt).unwrap_err();
        assert!(matches!(err, EvalCacheError::Malformed(_)), "{err}");
        assert!(err.to_string().contains("magic"), "{err}");

        // JSON text behind a .bin extension → Malformed, not a panic.
        std::fs::write(&path, b"{\"not\": \"a spill\"}").unwrap();
        let err = Evaluator::with_spill(opts, &path, fmt).unwrap_err();
        assert!(matches!(err, EvalCacheError::Malformed(_)), "{err}");

        let _ = std::fs::remove_file(&path);
    }
}
