//! Per-operation cost statistics produced by the nest analysis.

use crate::arch::level::LevelKind;

/// What bounds the operation's latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory(LevelKind),
}

impl Bound {
    pub fn name(self) -> String {
        match self {
            Bound::Compute => "compute".into(),
            Bound::Memory(k) => format!("{}-bw", k.name()),
        }
    }
}

/// Access counts and energy at one storage level.
#[derive(Debug, Clone)]
pub struct LevelStats {
    pub kind: LevelKind,
    pub reads: f64,
    pub writes: f64,
    pub energy_pj: f64,
}

impl LevelStats {
    pub fn accesses(&self) -> f64 {
        self.reads + self.writes
    }
}

/// Full statistics for one operation under one mapping, for a SINGLE
/// repetition of the op (scale with [`OpStats::scaled`] for `count` > 1).
#[derive(Debug, Clone)]
pub struct OpStats {
    /// Latency in cycles: max of compute and every bandwidth bound.
    pub cycles: f64,
    /// Pure compute cycles (padded MACs / active PEs).
    pub compute_cycles: f64,
    /// Real (unpadded) MACs.
    pub macs: f64,
    /// Total energy in pJ.
    pub energy_pj: f64,
    /// MAC (datapath) energy component.
    pub mac_energy_pj: f64,
    /// Inter-level NoC hop energy component.
    pub noc_energy_pj: f64,
    /// Per-level storage stats, innermost first.
    pub levels: Vec<LevelStats>,
    /// Words crossing each boundary, as (parent level, words). Boundary
    /// `i` connects level `i` (child) to `i+1` (parent).
    pub boundary_words: Vec<(LevelKind, f64)>,
    /// Words moved at the DRAM boundary (= memory traffic).
    pub dram_words: f64,
    /// Spatial × padding utilisation of the PE array in [0, 1].
    pub utilization: f64,
    /// What bound the latency.
    pub bound: Bound,
    /// Latency floor from compute + on-chip bandwidth only (no DRAM) —
    /// used when the scheduler re-grants DRAM bandwidth.
    pub onchip_bound_cycles: f64,
}

impl OpStats {
    /// Scale for an op repeated `count` times back-to-back (latency and
    /// all traffic/energy multiply; utilisation and bound are invariant).
    pub fn scaled(&self, count: u64) -> OpStats {
        let c = count as f64;
        OpStats {
            cycles: self.cycles * c,
            compute_cycles: self.compute_cycles * c,
            macs: self.macs * c,
            energy_pj: self.energy_pj * c,
            mac_energy_pj: self.mac_energy_pj * c,
            noc_energy_pj: self.noc_energy_pj * c,
            levels: self
                .levels
                .iter()
                .map(|l| LevelStats {
                    kind: l.kind,
                    reads: l.reads * c,
                    writes: l.writes * c,
                    energy_pj: l.energy_pj * c,
                })
                .collect(),
            boundary_words: self.boundary_words.iter().map(|&(k, w)| (k, w * c)).collect(),
            dram_words: self.dram_words * c,
            utilization: self.utilization,
            bound: self.bound,
            onchip_bound_cycles: self.onchip_bound_cycles * c,
        }
    }

    /// Energy at one level kind (0 if the spec lacks that level).
    pub fn level_energy(&self, kind: LevelKind) -> f64 {
        self.levels.iter().filter(|l| l.kind == kind).map(|l| l.energy_pj).sum()
    }

    /// Recompute latency if the DRAM share changes (the scheduler uses
    /// this when re-granting bandwidth between sub-accelerators). The
    /// outermost boundary is positionally the tree root (DRAM) whatever
    /// the hierarchy's level kinds are.
    pub fn latency_with_dram_bw(&self, dram_bw_words: f64) -> f64 {
        let root_cycles = match self.boundary_words.last() {
            Some(&(_, words)) => words / dram_bw_words,
            None => 0.0,
        };
        // Never faster than the compute and on-chip bounds.
        self.compute_cycles.max(root_cycles).max(self.non_dram_bound_cycles())
    }

    /// The latency floor imposed by compute and on-chip levels only.
    pub fn non_dram_bound_cycles(&self) -> f64 {
        // Stored at analysis time.
        self.onchip_bound_cycles
    }

    /// Recompute latency when EVERY boundary's bandwidth may differ from
    /// the spec the op was analysed on — the shared-node contention
    /// re-grant, where idle siblings return capacity on intermediate
    /// edges too, not just at DRAM. `bw[j]` feeds boundary `j` (between
    /// levels `j` and `j+1`); with the spec's own bandwidths this
    /// reproduces the analysed `cycles` bit-identically (same divisions,
    /// same max).
    pub fn latency_with_boundary_bw(&self, bw: &[f64]) -> f64 {
        assert_eq!(bw.len(), self.boundary_words.len(), "one bandwidth per boundary");
        let mut cycles = self.compute_cycles;
        for (&(_, words), &b) in self.boundary_words.iter().zip(bw) {
            let c = words / b;
            if c > cycles {
                cycles = c;
            }
        }
        cycles
    }

    /// Multiplications per joule.
    pub fn mults_per_joule(&self) -> f64 {
        self.macs / (self.energy_pj * 1e-12)
    }

    /// On-chip energy: everything except the outermost level (the tree
    /// root — DRAM in every canonical machine).
    pub fn onchip_energy_pj(&self) -> f64 {
        self.energy_pj - self.levels.last().map(|l| l.energy_pj).unwrap_or(0.0)
    }
}

impl OpStats {
    /// Zeroed stats — a building block for tests and scheduler mocks.
    pub fn new_empty() -> OpStats {
        OpStats {
            cycles: 0.0,
            compute_cycles: 0.0,
            macs: 0.0,
            energy_pj: 0.0,
            mac_energy_pj: 0.0,
            noc_energy_pj: 0.0,
            levels: Vec::new(),
            boundary_words: Vec::new(),
            dram_words: 0.0,
            utilization: 0.0,
            bound: Bound::Compute,
            onchip_bound_cycles: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> OpStats {
        let mut s = OpStats::new_empty();
        s.cycles = 100.0;
        s.compute_cycles = 80.0;
        s.onchip_bound_cycles = 80.0;
        s.macs = 1000.0;
        s.energy_pj = 500.0;
        s.dram_words = 640.0;
        s.boundary_words = vec![(LevelKind::L1, 100.0), (LevelKind::DRAM, 640.0)];
        s.levels = vec![LevelStats {
            kind: LevelKind::DRAM,
            reads: 600.0,
            writes: 40.0,
            energy_pj: 300.0,
        }];
        s
    }

    #[test]
    fn scaling_multiplies_extensive_quantities() {
        let s = sample().scaled(3);
        assert_eq!(s.cycles, 300.0);
        assert_eq!(s.macs, 3000.0);
        assert_eq!(s.dram_words, 1920.0);
        assert_eq!(s.levels[0].reads, 1800.0);
    }

    #[test]
    fn latency_rebinds_to_dram_bw() {
        let s = sample();
        // 640 words at 1 w/cyc → 640 cycles dominates.
        assert_eq!(s.latency_with_dram_bw(1.0), 640.0);
        // At very high bw the on-chip bound (80) holds.
        assert_eq!(s.latency_with_dram_bw(1e9), 80.0);
    }

    #[test]
    fn latency_rebinds_per_boundary() {
        let s = sample();
        // Spec-equivalent bandwidths reproduce the analysed latency: the
        // sample has 100 L1 words and 640 DRAM words; at (1, 6.4) w/cyc
        // both boundaries hit exactly 100 cycles.
        assert_eq!(s.latency_with_boundary_bw(&[1.0, 6.4]), 100.0);
        // Squeezing an INTERMEDIATE boundary dominates — the case
        // latency_with_dram_bw cannot express.
        assert_eq!(s.latency_with_boundary_bw(&[0.5, 6.4]), 200.0);
        // Unconstrained bandwidths fall back to the compute floor.
        assert_eq!(s.latency_with_boundary_bw(&[1e9, 1e9]), 80.0);
        // More bandwidth never increases latency (re-grant monotonicity).
        assert!(
            s.latency_with_boundary_bw(&[2.0, 12.8])
                <= s.latency_with_boundary_bw(&[1.0, 6.4])
        );
    }

    #[test]
    fn onchip_energy_excludes_dram() {
        let s = sample();
        assert_eq!(s.onchip_energy_pj(), 200.0);
    }

    #[test]
    fn mults_per_joule_units() {
        let s = sample();
        // 1000 MACs / 500 pJ = 2e12 MAC/J.
        assert!((s.mults_per_joule() - 2e12).abs() < 1.0);
    }
}
