//! Roofline view (paper Fig 1): compute roof + bandwidth partitioning
//! across sub-accelerators vs a homogeneous machine.

use crate::arch::partition::MachineConfig;

/// One roofline: attainable MACs/cycle as a function of arithmetic
/// intensity for a (sub-)machine.
#[derive(Debug, Clone)]
pub struct Roofline {
    pub name: String,
    /// Compute roof in MACs per cycle.
    pub peak_macs: f64,
    /// Memory bandwidth in words per cycle.
    pub bw_words: f64,
}

impl Roofline {
    /// Attainable throughput at arithmetic intensity `ai` (MACs/word).
    pub fn attainable(&self, ai: f64) -> f64 {
        (ai * self.bw_words).min(self.peak_macs)
    }

    /// The tipping point: AI at which the machine turns compute-bound.
    pub fn tipping_ai(&self) -> f64 {
        self.peak_macs / self.bw_words
    }
}

/// Rooflines of every sub-accelerator in a machine.
pub fn machine_rooflines(m: &MachineConfig) -> Vec<Roofline> {
    m.sub_accels
        .iter()
        .map(|s| Roofline {
            name: format!("{} ({})", s.spec.name, s.role.name()),
            peak_macs: s.spec.peak_macs() as f64,
            bw_words: s.spec.dram().bw_words_per_cycle,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::partition::HardwareParams;
    use crate::arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};

    #[test]
    fn attainable_follows_roofline() {
        let r = Roofline { name: "t".into(), peak_macs: 1000.0, bw_words: 10.0 };
        assert_eq!(r.tipping_ai(), 100.0);
        assert_eq!(r.attainable(50.0), 500.0); // memory-bound
        assert_eq!(r.attainable(200.0), 1000.0); // compute-bound
    }

    /// Paper §III-A: the high-reuse sub-accelerator has a higher compute
    /// roof but LOWER bandwidth than the low-reuse one; its tipping point
    /// moves right, the low-reuse one's moves left.
    #[test]
    fn heterogeneous_split_shifts_tipping_points() {
        let homo = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous),
            &HardwareParams::default(),
        )
        .unwrap();
        let het = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let rh = machine_rooflines(&homo);
        let rt = machine_rooflines(&het);
        let base = rh[0].tipping_ai();
        let high = &rt[0];
        let low = &rt[1];
        assert!(high.tipping_ai() > base);
        assert!(low.tipping_ai() < base);
        assert!(high.peak_macs > low.peak_macs);
        assert!(high.bw_words < low.bw_words);
    }
}
