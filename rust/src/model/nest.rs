//! Loop-nest analysis: mapping + op + spec → access counts, latency,
//! energy (the Timeloop cost-model equations, paper §VI-A).
//!
//! ## Method
//!
//! For each operand `T` and storage level `l`, the tile of `T` resident
//! at `l` has `Π_{d ∈ rel(T)} C(l, d)` words. The number of times the
//! child tile is (re)filled from level `l` follows the classic
//! *stationarity walk*: scan the loops above the child block from
//! innermost to outermost; loops over dimensions irrelevant to `T`
//! contribute ×1 (the tile is stationary) until the first relevant loop
//! is seen, after which every loop (relevant or not) multiplies.
//!
//! Outputs additionally generate partial-sum traffic: if a `K` loop with
//! factor > 1 sits outside the first output-relevant loop above a
//! boundary, evicted tiles are partial and must be read back, adding
//! `fills·tile − |O|` words of down-traffic at that boundary.
//!
//! The PE array's spatial fan-out sits between the RF (level 0) and the
//! first buffer: parent-side reads are multicast-discounted over spatial
//! dims irrelevant to `T`, and the spatial-`K` reduction tree collapses
//! output copies in the opposite direction.

use crate::arch::energy::HOP_PJ;
use crate::arch::level::LevelKind;
use crate::arch::spec::ArchSpec;
use crate::mapping::loopnest::{MapError, Mapping};
use crate::model::stats::{Bound, LevelStats, OpStats};
use crate::workload::einsum::{Dim, Operand, TensorOp};

/// Words of operand `T`'s tile resident at level `l`.
/// Level 0 (RF) is per-PE; higher levels include the spatial extent.
fn tile_words(op: &TensorOp, m: &Mapping, t: Operand, l: usize) -> u64 {
    Dim::ALL
        .iter()
        .filter(|&&d| op.relevant(t, d))
        .map(|&d| m.extent(l, d))
        .product()
}

/// The loops above child level `child`, innermost first:
/// blocks `child+1 ..= last`, each block ordered by its permutation.
fn loops_above<'a>(
    m: &'a Mapping,
    child: usize,
) -> impl Iterator<Item = (Dim, u64)> + 'a {
    (child + 1..m.temporal.len()).flat_map(move |l| {
        m.perms[l].iter().map(move |&d| (d, m.temporal[l][d.index()]))
    })
}

/// Stationarity walk: fills of operand `T`'s child-level tile.
fn fills(op: &TensorOp, m: &Mapping, t: Operand, child: usize) -> f64 {
    let mut seen_relevant = false;
    let mut f = 1.0f64;
    for (d, fac) in loops_above(m, child) {
        if fac == 1 {
            continue;
        }
        if op.relevant(t, d) {
            seen_relevant = true;
        }
        if seen_relevant {
            f *= fac as f64;
        }
    }
    f
}

/// Does a K loop with factor > 1 sit outside the first output-relevant
/// loop above `child`? (⇒ evicted output tiles are partial.)
fn psums_cross(op: &TensorOp, m: &Mapping, child: usize) -> bool {
    let mut seen_relevant = false;
    for (d, fac) in loops_above(m, child) {
        if fac == 1 {
            continue;
        }
        if op.relevant(Operand::Output, d) {
            seen_relevant = true;
        } else if d == Dim::K && seen_relevant {
            return true;
        }
    }
    false
}

/// Spatial extent over dimensions relevant to `T` (distinct data across
/// the array; irrelevant spatial dims are multicast/reduced by the NoC).
fn spatial_relevant(op: &TensorOp, m: &Mapping, t: Operand) -> f64 {
    let mut e = 1.0;
    for (d, f) in [m.spatial_row, m.spatial_col] {
        if op.relevant(t, d) {
            e *= f as f64;
        }
    }
    e
}

/// Analyze one op on one sub-accelerator under one mapping.
///
/// Returns an error if the mapping is structurally invalid or exceeds a
/// buffer capacity.
pub fn analyze(op: &TensorOp, spec: &ArchSpec, m: &Mapping) -> Result<OpStats, MapError> {
    m.validate(op, spec)?;
    let nlevels = spec.levels.len();
    let last = nlevels - 1;

    // ---- Capacity checks -------------------------------------------------
    // RF is per-PE: the spec stores aggregate capacity.
    let rf_per_pe = spec.levels[0].size_words / spec.peak_macs().max(1);
    let rf_tile: u64 = Operand::ALL.iter().map(|&t| tile_words(op, m, t, 0)).sum();
    if rf_tile > rf_per_pe {
        return Err(MapError::CapacityExceeded {
            level: spec.levels[0].kind.name(),
            tile: rf_tile,
            cap: rf_per_pe,
        });
    }
    for l in 1..last {
        let tile: u64 = Operand::ALL.iter().map(|&t| tile_words(op, m, t, l)).sum();
        if tile > spec.levels[l].size_words {
            return Err(MapError::CapacityExceeded {
                level: spec.levels[l].kind.name(),
                tile,
                cap: spec.levels[l].size_words,
            });
        }
    }

    // ---- Traffic per boundary --------------------------------------------
    let macs = op.macs() as f64;
    let padded_macs = Dim::ALL.iter().map(|&d| m.padded_dim(d) as f64).product::<f64>();
    let padded_out: f64 = Dim::ALL
        .iter()
        .filter(|&&d| op.relevant(Operand::Output, d))
        .map(|&d| m.padded_dim(d) as f64)
        .product();
    let active = m.active_pes() as f64;

    let mut level_reads = vec![0.0f64; nlevels];
    let mut level_writes = vec![0.0f64; nlevels];
    let mut noc_words_total = 0.0f64;
    let mut boundary_words: Vec<(LevelKind, f64)> = Vec::with_capacity(last);

    for child in 0..last {
        let parent = child + 1;
        let mut boundary = 0.0f64;

        for t in [Operand::InputA, Operand::InputB] {
            let tile = tile_words(op, m, t, child) as f64;
            let nfills = fills(op, m, t, child);
            let (parent_reads, noc, child_writes) = if child == 0 {
                // Spatial fan-out boundary: multicast discount on the
                // parent port; every PE still receives its copy.
                let distinct = nfills * tile * spatial_relevant(op, m, t);
                let copies = nfills * tile * active;
                (distinct, copies, copies)
            } else {
                let w = nfills * tile;
                (w, w, w)
            };
            level_reads[parent] += parent_reads;
            level_writes[child] += child_writes;
            noc_words_total += noc;
            boundary += parent_reads;
        }

        // Output: updates flow child→parent; partial tiles also return.
        let t = Operand::Output;
        let tile = tile_words(op, m, t, child) as f64;
        let nfills = fills(op, m, t, child);
        let up = if child == 0 {
            // Reduction tree collapses spatial-K copies.
            nfills * tile * spatial_relevant(op, m, t)
        } else {
            nfills * tile
        };
        let down = if psums_cross(op, m, child) { (up - padded_out).max(0.0) } else { 0.0 };
        level_writes[parent] += up;
        level_reads[parent] += down;
        level_reads[child] += up; // child reads its tile to send up
        level_writes[child] += down; // …and rewrites it on read-back
        noc_words_total += up + down;
        boundary += up + down;

        boundary_words.push((spec.levels[parent].kind, boundary));
    }

    // ---- Datapath-adjacent RF accesses ------------------------------------
    // Each MAC reads A and W, reads the previous partial (except the
    // first accumulation into a fresh output) and writes the new one.
    level_reads[0] += 2.0 * padded_macs + (padded_macs - padded_out).max(0.0);
    level_writes[0] += padded_macs;

    // ---- Latency -----------------------------------------------------------
    let compute_cycles = m.compute_cycles() as f64;
    let mut cycles = compute_cycles;
    let mut bound = Bound::Compute;
    let mut onchip_bound = compute_cycles;
    for (i, &(kind, words)) in boundary_words.iter().enumerate() {
        let bw = spec.levels[i + 1].bw_words_per_cycle;
        let c = words / bw;
        if c > cycles {
            cycles = c;
            bound = Bound::Memory(kind);
        }
        // Every boundary except the outermost (the tree root / DRAM) is
        // on-chip — positional, so custom level kinds need no casing.
        if i + 1 != last && c > onchip_bound {
            onchip_bound = c;
        }
    }

    // ---- Energy ------------------------------------------------------------
    let mac_energy = macs * spec.mac_energy_pj;
    let noc_energy = noc_words_total * HOP_PJ;
    let mut levels = Vec::with_capacity(nlevels);
    let mut energy = mac_energy + noc_energy;
    for (l, lv) in spec.levels.iter().enumerate() {
        let e = (level_reads[l] + level_writes[l]) * lv.energy_pj_per_word;
        energy += e;
        levels.push(LevelStats {
            kind: lv.kind,
            reads: level_reads[l],
            writes: level_writes[l],
            energy_pj: e,
        });
    }

    let dram_words = boundary_words.last().map(|&(_, w)| w).unwrap_or(0.0);
    let utilization = (active / (spec.rows * spec.cols) as f64) * (macs / padded_macs);

    Ok(OpStats {
        cycles,
        compute_cycles,
        macs,
        energy_pj: energy,
        mac_energy_pj: mac_energy,
        noc_energy_pj: noc_energy,
        levels,
        boundary_words,
        dram_words,
        utilization,
        bound,
        onchip_bound_cycles: onchip_bound,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::level::StorageLevel;
    use crate::workload::einsum::Phase;

    /// Tiny machine where everything is hand-checkable:
    /// 2×2 PEs, RF 8 w/PE, L1 256 w, LLB 4096 w.
    fn tiny() -> ArchSpec {
        let mut s = ArchSpec::leaf("tiny", 2, 2, 8, 256, 4096, 16.0, 4.0);
        // Make energies round numbers for assertions.
        s.levels[0].energy_pj_per_word = 1.0;
        s.levels[1].energy_pj_per_word = 2.0;
        s.levels[2].energy_pj_per_word = 10.0;
        s.levels[3].energy_pj_per_word = 100.0;
        s.mac_energy_pj = 0.5;
        s
    }

    fn op_8x8x8() -> TensorOp {
        TensorOp::gemm("g", Phase::Encoder, 8, 8, 8)
    }

    /// All-DRAM trivial mapping: every operand streams at full size.
    #[test]
    fn trivial_mapping_traffic_matches_closed_form() {
        let op = op_8x8x8();
        let spec = tiny();
        let m = Mapping::trivial(4, &op);
        let s = analyze(&op, &spec, &m).unwrap();
        assert_eq!(s.macs, 512.0);
        assert_eq!(s.compute_cycles, 512.0); // 1 PE
        // With a single scalar "tile" at RF/L1/LLB and all loops at DRAM:
        // walk above LLB = DRAM block [K,N,M,B] (innermost-first).
        // A (rel M,K): K relevant → ×8, N irrelevant after seen → ×8,
        // M ×8, B(1) → fills=512, tile=1 ⇒ DRAM reads A = 512 = MACs.
        let dram = s.levels.iter().find(|l| l.kind == LevelKind::DRAM).unwrap();
        // A: 512 reads; W: K inner relevant ⇒ 512 reads;
        // O: fills walk K(rel? no, K first, not relevant, not seen →1),
        //    N rel ×8, M rel ×8 → 64 up;
        //    psum: K outside first relevant O loop? K is INNERMOST, so no.
        assert_eq!(dram.reads, 512.0 + 512.0);
        assert_eq!(dram.writes, 64.0);
    }

    /// If K is outermost at DRAM, output partial sums round-trip.
    #[test]
    fn outer_k_generates_psum_traffic() {
        let op = op_8x8x8();
        let spec = tiny();
        let mut m = Mapping::trivial(4, &op);
        // DRAM block perm [M,N,B,K]: M innermost … K outermost.
        m.perms[3] = [Dim::M, Dim::N, Dim::B, Dim::K];
        let s = analyze(&op, &spec, &m).unwrap();
        let dram = s.levels.iter().find(|l| l.kind == LevelKind::DRAM).unwrap();
        // O fills: M rel ×8, N rel ×8, K after seen ×8 = 512 up.
        // down = 512 − 64 = 448 read-backs.
        assert_eq!(dram.writes, 512.0);
        // A reads: walk M (rel) ×8, N ×8, K ×8 = 512; W: M irrelevant &
        // first → 1, then N rel ×8, K ×8 = 64·tile(8? no tile=1)… W tile=1,
        // fills = 64 ⇒ 64 reads. Total reads = 512 + 64 + 448 psum readback.
        assert_eq!(dram.reads, 512.0 + 64.0 + 448.0);
    }

    /// Buffering the weight tile at LLB removes its DRAM refetches.
    #[test]
    fn llb_buffering_cuts_dram_traffic() {
        let op = op_8x8x8();
        let spec = tiny();
        let mut m = Mapping::trivial(4, &op);
        // Move K,N inside the LLB: weight (K×N = 64 words) resident.
        m.temporal[3] = [1, 8, 1, 1]; // DRAM iterates M only
        m.temporal[2] = [1, 1, 8, 8]; // LLB holds K×N
        let s = analyze(&op, &spec, &m).unwrap();
        let dram = s.levels.iter().find(|l| l.kind == LevelKind::DRAM).unwrap();
        // W: loops above LLB = DRAM [K,N,M,B] with only M(8) ≠ 1.
        // M irrelevant to W and no relevant loop above ⇒ fills = 1 ⇒
        // DRAM reads W = tile = 64 (compulsory only).
        // A: tile at LLB = M_llb(1)·K(8) = 8; fills: M rel ×8 ⇒ 64 reads.
        // O: tile at LLB = M(1)·N(8) = 8; fills: M ×8 ⇒ 64 up, no psums.
        assert_eq!(dram.reads, 64.0 + 64.0);
        assert_eq!(dram.writes, 64.0);
        assert!(s.dram_words < 512.0 + 512.0 + 64.0);
    }

    /// Spatial mapping: multicast discount and compute speedup.
    #[test]
    fn spatial_multicast_and_utilization() {
        let op = op_8x8x8();
        let spec = tiny();
        let mut m = Mapping::trivial(4, &op);
        m.spatial_row = (Dim::M, 2);
        m.spatial_col = (Dim::N, 2);
        m.temporal[3] = [1, 4, 4, 8]; // remaining M,N after spatial
        let s = analyze(&op, &spec, &m).unwrap();
        assert_eq!(s.compute_cycles, 128.0); // 512 MACs / 4 PEs
        assert_eq!(s.utilization, 1.0);
        // L1 reads of A: per-PE tile 1, per-PE fills = walk above RF:
        // (spatial skipped) L1(1,1,1,1), LLB(1..), DRAM [K,N,M,B] →
        // K ×8, N ×4, M ×4 = 128; distinct across array: A relevant to
        // M-row (×2) not N-col → 128·2 = 256 L1 reads (multicast ×2 on N).
        let l1 = s.levels.iter().find(|l| l.kind == LevelKind::L1).unwrap();
        // A: 256; W: fills: K×8 rel, N rel ×4, M after seen ×4 ⇒ 128;
        //    W distinct: N-col rel (×2), M-row no ⇒ 256.
        // O: fills: K first not rel →1? K relevant? no. Walk [K,N,M,B]:
        //    K skip(not rel, not seen), N rel → seen ×4, M ×4 = 16;
        //    wait K is innermost: contributes nothing before N.
        //    O up = 16 · tile(1) · spatial_rel(M,N → 2·2=4) = 64.
        //    psums: K inside first relevant ⇒ none.
        // Plus the L1→LLB boundary: O tile at L1 (2·2=4 words, fills 16)
        // is read out of L1 on its way up: +64 reads. A and W tiles are
        // written into L1 from the LLB: 256 + 256 writes; O written into
        // L1 from the array: +64.
        // L1 reads = A 256 + W 256 + O-up 64 = 576.
        assert_eq!(l1.reads, 576.0);
        assert_eq!(l1.writes, 576.0);
    }

    #[test]
    fn capacity_violation_detected() {
        let op = op_8x8x8();
        let spec = tiny();
        let mut m = Mapping::trivial(4, &op);
        // Put a 64-word weight tile in an 8-word/PE RF.
        m.temporal[0] = [1, 1, 8, 8];
        m.temporal[3] = [1, 8, 1, 1];
        let err = analyze(&op, &spec, &m).unwrap_err();
        assert!(matches!(err, MapError::CapacityExceeded { level: "RF", .. }));
    }

    #[test]
    fn bandwidth_bound_detected() {
        let op = TensorOp::gemm("lowreuse", Phase::Decode, 1, 512, 512);
        let spec = tiny();
        let mut m = Mapping::trivial(4, &op);
        m.spatial_row = (Dim::N, 2);
        m.spatial_col = (Dim::K, 2);
        m.temporal[3] = [1, 1, 256, 256];
        let s = analyze(&op, &spec, &m).unwrap();
        // GEMV: DRAM must stream ≥ 512·512 weight words at 4 w/cyc
        // while compute needs only 65536 cycles.
        assert_eq!(s.bound, Bound::Memory(LevelKind::DRAM));
        assert!(s.cycles > s.compute_cycles);
    }

    /// The nest analysis walks the level list by index, so hierarchies
    /// deeper than the canonical four levels (here: RF→L1→L2→LLB→DRAM)
    /// analyse without any special-casing.
    #[test]
    fn five_level_custom_hierarchy_analyzes() {
        let op = op_8x8x8();
        let mut spec = tiny();
        let l2 = StorageLevel::new(LevelKind::named("L2"), 1024, 8.0, 4.0);
        spec.levels.insert(2, l2);
        assert_eq!(spec.levels.len(), 5);
        let m = Mapping::trivial(5, &op);
        let s = analyze(&op, &spec, &m).unwrap();
        assert_eq!(s.boundary_words.len(), 4);
        assert_eq!(s.levels.len(), 5);
        // Same compulsory DRAM traffic as the 4-level walk: the extra
        // buffer holds a scalar tile and changes no fill counts.
        let m4 = Mapping::trivial(4, &op);
        let s4 = analyze(&op, &tiny(), &m4).unwrap();
        assert_eq!(s.dram_words, s4.dram_words);
        // The L2 level is on-chip: it contributes to energy, and the
        // outermost boundary is still the one that counts as DRAM.
        assert!(s.level_energy(LevelKind::named("L2")) > 0.0);
        assert!(s.energy_pj > s4.energy_pj);
    }

    #[test]
    fn near_llb_spec_has_fewer_boundaries() {
        let op = op_8x8x8();
        let leaf = tiny();
        let near = ArchSpec::near_llb("n", 2, 2, 8, 4096, 16.0, 4.0);
        let ml = Mapping::trivial(4, &op);
        let mn = Mapping::trivial(3, &op);
        let sl = analyze(&op, &leaf, &ml).unwrap();
        let sn = analyze(&op, &near, &mn).unwrap();
        assert_eq!(sl.boundary_words.len(), 3);
        assert_eq!(sn.boundary_words.len(), 2);
        // Same compulsory DRAM traffic, less NoC/hierarchy energy.
        assert!(sn.noc_energy_pj < sl.noc_energy_pj);
    }

    /// Booked contention shrinks the valid map space: a tile that fills
    /// the full shared LLB analyses fine on the `Off` flatten but is a
    /// capacity violation on the booked slice — the mechanism by which
    /// co-attached units stop double-booking each other's buffer space.
    #[test]
    fn booked_capacity_rejects_tiles_the_full_node_accepted() {
        use crate::arch::partition::Role;
        use crate::arch::spec::MappingConstraints;
        use crate::arch::topology::{AccelNode, ContentionMode, MachineTopology};

        let mut t = MachineTopology::new("co", 64.0);
        let llb = t.add_node(0, LevelKind::LLB, "llb.shared", 4096, 16.0, None);
        for i in 0..2u64 {
            t.add_accel(AccelNode {
                label: format!("u{i}"),
                ty: format!("ty{i}"),
                role: Role::Unified,
                rows: 2,
                cols: 2,
                rf_bytes_per_pe: 8,
                attach: llb,
                attach_bw: 16.0,
                dram_share: 32.0,
                capacity_share: None,
                mac_energy_pj: 0.5,
                fsm_group: None,
                constraints: MappingConstraints::default(),
            });
        }
        t.validate().unwrap();
        let full = t.flatten_with(0, ContentionMode::Off);
        let booked = t.flatten_with(0, ContentionMode::Booked);
        assert_eq!(full.levels[1].size_words, 4096);
        assert_eq!(booked.levels[1].size_words, 2048); // equal-PE split

        // 32×32×32 GEMM with a 32×32 output + 32-K A-tile at the LLB:
        // 32·32 + 32·32 + 32·32 = 3072 words — fits 4096, not 2048.
        let op = TensorOp::gemm("g", Phase::Encoder, 32, 32, 32);
        let mut m = Mapping::trivial(3, &op);
        m.temporal[1] = [1, 32, 32, 32];
        m.temporal[2] = [1, 1, 1, 1];
        analyze(&op, &full, &m).unwrap();
        let err = analyze(&op, &booked, &m).unwrap_err();
        assert!(matches!(err, MapError::CapacityExceeded { level: "LLB", .. }), "{err:?}");
    }

    #[test]
    fn energy_accounts_all_levels() {
        let op = op_8x8x8();
        let spec = tiny();
        let m = Mapping::trivial(4, &op);
        let s = analyze(&op, &spec, &m).unwrap();
        let sum: f64 = s.levels.iter().map(|l| l.energy_pj).sum::<f64>()
            + s.mac_energy_pj
            + s.noc_energy_pj;
        assert!((sum - s.energy_pj).abs() < 1e-6);
        assert!(s.level_energy(LevelKind::DRAM) > s.level_energy(LevelKind::LLB));
    }

    /// Total MACs and compulsory traffic are mapping-invariant lower
    /// bounds: any valid mapping moves at least the footprint at DRAM.
    #[test]
    fn compulsory_traffic_lower_bound() {
        let op = op_8x8x8();
        let spec = tiny();
        for perm in crate::mapping::loopnest::CANON_PERMS {
            let mut m = Mapping::trivial(4, &op);
            m.perms[3] = perm;
            let s = analyze(&op, &spec, &m).unwrap();
            assert!(s.dram_words >= op.footprint_words() as f64);
        }
    }
}
