//! The analytical cost model (Timeloop-like).
//!
//! [`nest`] walks a [`Mapping`](crate::mapping::Mapping)'s loop nest and
//! produces per-level access counts using the classic stationarity
//! analysis; [`stats`] holds the resulting per-operation statistics;
//! [`roofline`] provides the compute-roof/bandwidth split view of Fig 1.

pub mod nest;
pub mod roofline;
pub mod stats;

pub use nest::analyze;
pub use stats::{Bound, LevelStats, OpStats};
