//! # HARP — Heterogeneous and HierARchical Processors
//!
//! A from-scratch reproduction of the HARP evaluation framework
//! (Garg, Pellauer, Krishna — *"HARP: A Taxonomy for Heterogeneous and
//! Hierarchical Processors for Mixed-reuse Workloads"*, CS.DC 2025):
//! a Timeloop-like analytical cost model and mapper, the HARP taxonomy
//! for hierarchical/heterogeneous processors (HHPs), a resource
//! partitioner, and an overlap-aware cascade scheduler — driven by a
//! Rust coordinator that also executes the AOT-compiled JAX/Pallas
//! transformer workloads through PJRT for functional validation.
//!
//! ## Layer map
//!
//! - [`util`] — substrates built from scratch for the offline image
//!   (JSON, CLI parsing, PRNG, property testing, bench harness, errors,
//!   and a thread pool with a shared global budget so nested fan-out
//!   never oversubscribes; `HARP_THREADS` / `--threads` size it).
//! - [`workload`] — einsum operations, arithmetic intensity, cascade
//!   dependency graphs, the transformer generators (paper Table II)
//!   plus the mixed-reuse families (MoE, im2col CNN, GQA long-context
//!   decode, serving mix), the JSON cascade schema (`--workload FILE`),
//!   and the registry that fronts them all.
//! - [`arch`] — the machine memory tree (storage nodes with
//!   sub-accelerators attached at any depth), flattened per-unit specs,
//!   the HARP taxonomy itself with structural classification, the
//!   topology generator covering every taxonomy point, and energy
//!   tables (Table III).
//! - [`mapping`] — loop-nest mappings and taxonomy-derived constraints.
//! - [`model`] — the Timeloop-like nest analysis: per-level access
//!   counts, latency (compute vs bandwidth bound), energy.
//! - [`mapper`] — map-space enumeration and the seeded black-box
//!   search, run as a batched generate → parallel-evaluate → reduce
//!   pipeline that is bit-identical for every worker count.
//! - [`hhp`] — the paper's wrapper: operation allocation (a searchable
//!   policy space — greedy/round-robin/critical-path/schedule-aware
//!   local search over a reusable scheduler replay oracle), overlap
//!   scheduling with shared-bandwidth contention, cascade statistics.
//! - [`coordinator`] — experiment configs, sweeps, figure drivers, and
//!   the concurrent cross-driver evaluation cache (memoised by a
//!   canonical (workload, class, bandwidth, budget) fingerprint, with
//!   an optional JSON disk spill via `--cache`).
//! - [`runtime`] — PJRT client that loads `artifacts/*.hlo.txt` and
//!   executes the real transformer layers for end-to-end validation.

pub mod util;
pub mod workload;
pub mod arch;
pub mod mapping;
pub mod model;
pub mod mapper;
pub mod hhp;
pub mod coordinator;
pub mod runtime;

pub use arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
pub use coordinator::experiment::{
    evaluate_cascade_on_config, evaluate_cascade_on_machine, EvalOptions,
};
pub use workload::cascade::Cascade;
