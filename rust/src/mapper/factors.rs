//! Factorisation utilities for tiling-factor enumeration.

/// All divisors of `n`, ascending.
pub fn divisors(n: u64) -> Vec<u64> {
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Candidate tiling factors for a dimension of size `n` under a `limit`:
/// divisors of `n` plus powers of two (to allow modest padding), capped
/// at `min(n, limit)`, deduplicated, ascending. Always contains 1.
pub fn candidates(n: u64, limit: u64) -> Vec<u64> {
    let cap = n.min(limit).max(1);
    let mut out: Vec<u64> = divisors(n).into_iter().filter(|&d| d <= cap).collect();
    let mut p = 1u64;
    while p <= cap {
        if !out.contains(&p) {
            out.push(p);
        }
        p *= 2;
    }
    if !out.contains(&cap) {
        out.push(cap);
    }
    out.sort_unstable();
    out
}

/// Ceiling division.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// Largest power of two ≤ `n` (n ≥ 1).
pub fn pow2_floor(n: u64) -> u64 {
    debug_assert!(n >= 1);
    1u64 << (63 - n.leading_zeros())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_of_12() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(7), vec![1, 7]);
    }

    #[test]
    fn candidates_capped_and_padded() {
        let c = candidates(3000, 256);
        assert!(c.contains(&1));
        assert!(c.contains(&256)); // cap itself
        assert!(c.contains(&128)); // power of two
        assert!(c.contains(&250)); // divisor of 3000
        assert!(c.iter().all(|&f| f <= 256));
        assert!(c.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn candidates_of_one() {
        assert_eq!(candidates(1, 64), vec![1]);
    }

    #[test]
    fn candidates_limit_exceeds_size() {
        // Cap is the dimension size itself when the limit is larger.
        let c = candidates(6, 64);
        assert_eq!(c, vec![1, 2, 3, 4, 6]);
    }

    #[test]
    fn candidates_limit_one() {
        assert_eq!(candidates(3000, 1), vec![1]);
    }

    #[test]
    fn candidates_prime_size() {
        // Non-power-of-two prime: divisors {1, 7} plus padded powers.
        assert_eq!(candidates(7, 7), vec![1, 2, 4, 7]);
    }

    #[test]
    fn ceil_div_zero_numerator() {
        assert_eq!(ceil_div(0, 3), 0);
    }

    #[test]
    fn ceil_div_works() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
    }

    #[test]
    fn pow2_floor_works() {
        assert_eq!(pow2_floor(1), 1);
        assert_eq!(pow2_floor(2), 2);
        assert_eq!(pow2_floor(3), 2);
        assert_eq!(pow2_floor(192000), 131072);
        assert_eq!(pow2_floor(u64::MAX), 1 << 63);
    }
}
