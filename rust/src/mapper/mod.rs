//! Map-space search (the "Timeloop mapper" role, paper §VI-A).
//!
//! HARP runs the mapper *per (operation, sub-accelerator)* — black-box
//! mapping. Because the workload is partitioned operation-by-operation,
//! the joint design space is additive (`O(High + Low)`), not
//! multiplicative (paper §V-C).

pub mod blackbox;
pub mod factors;
pub mod mapcache;
pub mod search;

pub use blackbox::{BlackboxMapper, MappedOp};
pub use mapcache::{MapCache, MapCacheError};
pub use search::{search_best, search_best_threaded, SearchBudget};
