//! Seeded random + heuristic map-space search for one (op, spec) pair.
//!
//! The search combines:
//! 1. **Heuristic seeds** — structured mappings that greedily fill each
//!    buffer level (the shapes a human mapper would write), over every
//!    canonical permutation and spatial choice. These guarantee a decent
//!    floor even with a tiny random budget.
//! 2. **Random samples** — factor tuples drawn per dimension per level
//!    from the candidate sets, exploring the space Timeloop's random
//!    mapper would.
//!
//! Objective: minimise latency (cycles), tie-break on energy. Invalid
//! mappings (capacity, constraints) are rejected by the nest analysis.
//!
//! ## Batched parallel pipeline
//!
//! [`search_best`] runs in three phases: (1) *generate* every candidate
//! — heuristic seeds plus the seeded random factor tuples — serially, so
//! the PRNG stream is identical no matter what; (2) *evaluate* the
//! candidates in fixed-size chunks over the shared thread pool; (3)
//! *reduce* in candidate-index order with the latency/energy tie-break.
//! Because generation and reduction are order-deterministic and the nest
//! analysis is pure, the result is **bit-identical to the serial path**
//! for a fixed seed regardless of `HARP_THREADS`.

use crate::arch::spec::ArchSpec;
use crate::mapper::factors::{ceil_div, pow2_floor};
use crate::mapping::loopnest::{Mapping, CANON_PERMS};
use crate::model::nest::analyze;
use crate::model::stats::OpStats;
use crate::util::rng::Rng;
use crate::util::threadpool::{default_threads, parallel_map};
use crate::workload::einsum::{Dim, TensorOp};

/// Search effort knobs.
#[derive(Debug, Clone, Copy)]
pub struct SearchBudget {
    /// Number of random samples (heuristic seeds are always tried).
    pub samples: usize,
    /// PRNG seed; searches are deterministic per seed.
    pub seed: u64,
}

impl Default for SearchBudget {
    fn default() -> SearchBudget {
        SearchBudget { samples: 600, seed: 0x4841_5250 } // "HARP"
    }
}

/// Spatial (dim, factor) candidates for an axis of `limit` PEs.
fn spatial_choices(op: &TensorOp, limit: u64, forced: Option<Dim>) -> Vec<(Dim, u64)> {
    let dims: Vec<Dim> = match forced {
        Some(d) => vec![d],
        None => Dim::ALL.to_vec(),
    };
    let mut out = Vec::new();
    for d in dims {
        let size = op.dim(d);
        // Use the largest factor ≤ limit (max utilisation) plus a half
        // step for flexibility.
        let f = size.min(limit);
        if f >= 1 {
            out.push((d, f));
            if f > 2 {
                out.push((d, f / 2));
            }
        }
    }
    out.push((Dim::M, 1));
    // Full dedup (not just adjacent): size-1 dims and limit 1 produce the
    // same (dim, 1) candidate from several sources, and the (M, 1)
    // fallback may repeat an earlier entry non-adjacently.
    let mut seen = std::collections::HashSet::new();
    out.retain(|c| seen.insert(*c));
    out
}

/// The buffer-fill orders the heuristic sweeps: which dimensions get
/// large tiles at each level decides which operand stays resident
/// (e.g. M,N first ⇒ output-stationary LLB blocking — the classic
/// minimum-traffic blocking for big GEMMs).
const FILL_ORDERS: [[Dim; 3]; 6] = [
    [Dim::M, Dim::N, Dim::K],
    [Dim::N, Dim::M, Dim::K],
    [Dim::M, Dim::K, Dim::N],
    [Dim::N, Dim::K, Dim::M],
    [Dim::K, Dim::M, Dim::N],
    [Dim::K, Dim::N, Dim::B],
];

/// Greedy heuristic mapping: fill RF with a K-tile, then grow tiles
/// outward (in `fill_order`) to fill each buffer level toward capacity,
/// leaving the remainder at DRAM.
fn heuristic_mapping(
    op: &TensorOp,
    spec: &ArchSpec,
    perm: [Dim; 4],
    row: (Dim, u64),
    col: (Dim, u64),
    fill_order: [Dim; 3],
) -> Mapping {
    let nlevels = spec.levels.len();
    let mut m = Mapping {
        temporal: vec![[1u64; 4]; nlevels],
        perms: vec![perm; nlevels],
        spatial_row: row,
        spatial_col: col,
    };
    // Remaining extent per dim after spatial.
    let mut rem = [0u64; 4];
    for d in Dim::ALL {
        rem[d.index()] = ceil_div(op.dim(d), m.spatial(d)).max(1);
    }
    // RF: small K tile (operands stay scalar-ish; K-tile amortises
    // output accumulation traffic). Budget a third of the per-PE RF.
    let rf_per_pe = spec.levels[0].size_words / spec.peak_macs().max(1);
    let k_rf = rem[Dim::K.index()].min((rf_per_pe / 3).max(1));
    // Snap to a power of two (or the full remainder if smaller) —
    // allocation-free; mild padding is handled by the validator.
    let k_rf = if k_rf >= rem[Dim::K.index()] { rem[Dim::K.index()] } else { pow2_floor(k_rf) };
    m.temporal[0][Dim::K.index()] = k_rf;
    rem[Dim::K.index()] = ceil_div(rem[Dim::K.index()], k_rf);

    // Intermediate buffer levels: grow tiles in `fill_order` (then B) to
    // ~fill each level's capacity, keeping a double-buffering margin.
    let tile_sum = |m: &Mapping, l: usize| -> u64 {
        crate::workload::einsum::Operand::ALL
            .iter()
            .map(|&t| {
                Dim::ALL
                    .iter()
                    .filter(|&&dd| op.relevant(t, dd))
                    .map(|&dd| m.extent(l, dd))
                    .product::<u64>()
            })
            .sum()
    };
    for l in 1..nlevels - 1 {
        let cap = spec.levels[l].size_words;
        let budget = cap - cap / 8;
        for d in [fill_order[0], fill_order[1], fill_order[2], Dim::B] {
            let di = d.index();
            if rem[di] == 1 {
                continue;
            }
            // Largest factor whose tile still fits the budget: probe the
            // full remainder, then descending powers of two (allocation-
            // free; padding from non-divisor factors is tolerated).
            let mut f = rem[di];
            loop {
                m.temporal[l][di] = f;
                if tile_sum(&m, l) <= budget {
                    rem[di] = ceil_div(rem[di], f);
                    break;
                }
                m.temporal[l][di] = 1;
                if f == 1 {
                    break;
                }
                f = if f == rem[di] { pow2_floor(f - 1).max(1) } else { f / 2 };
            }
        }
    }
    // DRAM takes the rest.
    let last = nlevels - 1;
    for d in Dim::ALL {
        m.temporal[last][d.index()] = rem[d.index()];
    }
    m
}

/// Dimension sets for balanced growth (see [`balanced_mapping`]).
const GROW_SETS: [&[Dim]; 4] = [
    &[Dim::M, Dim::N, Dim::K],
    &[Dim::M, Dim::N],
    &[Dim::K, Dim::M, Dim::N],
    &[Dim::B, Dim::M, Dim::N, Dim::K],
];

/// Balanced heuristic: grow the listed dimensions ROUND-ROBIN by ×2 at
/// each buffer level until nothing fits. Alternating growth finds the
/// square-ish output tiles (`M_t ≈ N_t ≈ √capacity`) that minimise GEMM
/// traffic — the blocking sequential growth misses.
fn balanced_mapping(
    op: &TensorOp,
    spec: &ArchSpec,
    perm: [Dim; 4],
    row: (Dim, u64),
    col: (Dim, u64),
    grow: &[Dim],
) -> Mapping {
    let nlevels = spec.levels.len();
    let mut m = Mapping {
        temporal: vec![[1u64; 4]; nlevels],
        perms: vec![perm; nlevels],
        spatial_row: row,
        spatial_col: col,
    };
    let mut rem = [0u64; 4];
    for d in Dim::ALL {
        rem[d.index()] = ceil_div(op.dim(d), m.spatial(d)).max(1);
    }
    let tile_sum = |m: &Mapping, l: usize| -> u64 {
        crate::workload::einsum::Operand::ALL
            .iter()
            .map(|&t| {
                Dim::ALL
                    .iter()
                    .filter(|&&dd| op.relevant(t, dd))
                    .map(|&dd| m.extent(l, dd))
                    .product::<u64>()
            })
            .sum()
    };
    for l in 1..nlevels - 1 {
        let cap = spec.levels[l].size_words;
        let budget = cap - cap / 8;
        let mut stuck = [false; 4];
        loop {
            let mut grew = false;
            for &d in grow {
                let di = d.index();
                if stuck[di] || rem[di] == 1 {
                    continue;
                }
                let old = m.temporal[l][di];
                // Double the factor (capped at full coverage of the
                // remaining extent; mild padding is tolerated).
                let f = (old * 2).min(rem[di] * old);
                if f <= old {
                    stuck[di] = true;
                    continue;
                }
                m.temporal[l][di] = f;
                if tile_sum(&m, l) <= budget {
                    grew = true;
                } else {
                    m.temporal[l][di] = old;
                    stuck[di] = true;
                }
            }
            if !grew {
                break;
            }
        }
        for d in Dim::ALL {
            let di = d.index();
            rem[di] = ceil_div(rem[di], m.temporal[l][di]);
        }
    }
    let last = nlevels - 1;
    for d in Dim::ALL {
        m.temporal[last][d.index()] = rem[d.index()];
    }
    m
}

/// One random mapping sample.
fn random_mapping(op: &TensorOp, spec: &ArchSpec, rng: &mut Rng) -> Mapping {
    let nlevels = spec.levels.len();
    let row_choices = spatial_choices(op, spec.rows, None);
    let col_choices = spatial_choices(op, spec.cols, spec.constraints.forced_col_dim);
    let mut row = *rng.choose(&row_choices);
    let mut col = *rng.choose(&col_choices);
    if row.0 == col.0 {
        // Degenerate: collapse one axis.
        if rng.next_f64() < 0.5 {
            row = (row.0, row.1);
            col = (Dim::B, 1);
        } else {
            row = (Dim::B, 1);
        }
    }
    let mut m = Mapping {
        temporal: vec![[1u64; 4]; nlevels],
        perms: (0..nlevels).map(|_| *rng.choose(&CANON_PERMS)).collect(),
        spatial_row: row,
        spatial_col: col,
    };
    for d in Dim::ALL {
        let di = d.index();
        let mut rem = ceil_div(op.dim(d), m.spatial(d)).max(1);
        // Walk levels inner→outer, sampling a factor at each; DRAM
        // absorbs the remainder. Factors are random powers of two (or
        // the full remainder) — allocation-free, covering the same tile
        // shapes as divisor enumeration up to padding.
        for l in 0..nlevels - 1 {
            if rem == 1 {
                break;
            }
            let max_exp = 63 - rem.leading_zeros() as u64; // floor(log2 rem)
            let f = if rng.next_f64() < 0.15 {
                rem
            } else {
                1u64 << rng.next_below(max_exp as usize + 1)
            };
            m.temporal[l][di] = f;
            rem = ceil_div(rem, f);
        }
        m.temporal[nlevels - 1][di] = rem;
    }
    m
}

/// Result of a search: best mapping and its statistics.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub mapping: Mapping,
    pub stats: OpStats,
    pub evaluated: usize,
    pub valid: usize,
}

/// Is `a` better than `b`? Latency first, energy as tie-break.
fn better(a: &OpStats, b: &OpStats) -> bool {
    if (a.cycles - b.cycles).abs() > 1e-9 * b.cycles.max(1.0) {
        a.cycles < b.cycles
    } else {
        a.energy_pj < b.energy_pj
    }
}

/// Candidate-evaluation chunk size: big enough to amortise slot/cursor
/// overhead, small enough to load-balance the ~600-candidate default
/// budget across a 16-worker pool.
const EVAL_CHUNK: usize = 32;

/// Phase 1 of the pipeline: generate every candidate mapping, in the
/// canonical order (heuristic seeds, then the seeded random samples).
/// Serial on purpose — the PRNG stream must not depend on thread count.
fn generate_candidates(op: &TensorOp, spec: &ArchSpec, budget: &SearchBudget) -> Vec<Mapping> {
    let mut out = Vec::new();
    // Heuristic seeds: perms × spatial choices × buffer-fill orders.
    // (A fingerprint-dedup of seeds was tried during the perf pass and
    // reverted: hashing cost more than the duplicate analyses saved —
    // see EXPERIMENTS.md §Perf.)
    let row_choices = spatial_choices(op, spec.rows, None);
    let col_choices = spatial_choices(op, spec.cols, spec.constraints.forced_col_dim);
    for perm in CANON_PERMS {
        for &row in &row_choices {
            for &col in &col_choices {
                if row.0 == col.0 && row.1 > 1 && col.1 > 1 {
                    continue;
                }
                for order in FILL_ORDERS {
                    out.push(heuristic_mapping(op, spec, perm, row, col, order));
                }
                for grow in GROW_SETS {
                    out.push(balanced_mapping(op, spec, perm, row, col, grow));
                }
            }
        }
    }
    // Random exploration.
    let mut rng = Rng::new(budget.seed ^ shape_fingerprint(op));
    for _ in 0..budget.samples {
        out.push(random_mapping(op, spec, &mut rng));
    }
    out
}

/// Search the map space of `op` on `spec` using the shared thread pool
/// (up to [`default_threads`] workers).
pub fn search_best(op: &TensorOp, spec: &ArchSpec, budget: &SearchBudget) -> SearchResult {
    search_best_threaded(op, spec, budget, default_threads())
}

/// Search with an explicit worker cap. The batched pipeline: generate
/// serially, evaluate chunks in parallel, reduce in index order — so the
/// outcome is bit-identical for every `threads` value.
pub fn search_best_threaded(
    op: &TensorOp,
    spec: &ArchSpec,
    budget: &SearchBudget,
    threads: usize,
) -> SearchResult {
    let candidates = generate_candidates(op, spec, budget);
    let evaluated = candidates.len();

    // Phase 2: evaluate chunks concurrently. Each slot holds the chunk's
    // analysis outcomes in candidate order.
    let nchunks = evaluated.div_ceil(EVAL_CHUNK);
    let outcomes: Vec<Vec<Option<OpStats>>> = parallel_map(nchunks, threads, |c| {
        let lo = c * EVAL_CHUNK;
        let hi = (lo + EVAL_CHUNK).min(evaluated);
        candidates[lo..hi].iter().map(|m| analyze(op, spec, m).ok()).collect()
    });

    // Phase 3: deterministic index-order reduction, identical to the
    // serial scan (first-best-wins under the latency/energy tie-break).
    let mut best: Option<(usize, OpStats)> = None;
    let mut valid = 0usize;
    for (i, outcome) in outcomes.into_iter().flatten().enumerate() {
        if let Some(stats) = outcome {
            valid += 1;
            let replace = match &best {
                Some((_, b)) => better(&stats, b),
                None => true,
            };
            if replace {
                best = Some((i, stats));
            }
        }
    }

    let (best_idx, stats) = best.expect("at least one candidate mapping is valid");
    SearchResult { mapping: candidates[best_idx].clone(), stats, evaluated, valid }
}

/// Deterministic fingerprint of an op's shape (search seeding / caching).
pub fn shape_fingerprint(op: &TensorOp) -> u64 {
    let mut h = 0xcbf29ce484222325u64; // FNV-1a
    for v in [op.b, op.m, op.n, op.k, op.kind as u64] {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic fingerprint of everything about a sub-accelerator that
/// can change a mapping-search RESULT: array geometry, every storage
/// level (kind, capacity, bandwidth, access energy — energy feeds the
/// `better()` tie-break), MAC energy, and the mapping constraints. The
/// spec's `name` is deliberately excluded: renaming a unit cannot move
/// the numbers, so it must not miss the mapping cache. Keys the
/// persistent `(shape, unit) → mapping` cache together with
/// [`shape_fingerprint`].
pub fn spec_fingerprint(spec: &ArchSpec) -> u64 {
    const P: u64 = 0x100000001b3;
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(P);
    };
    mix(spec.rows);
    mix(spec.cols);
    mix(spec.levels.len() as u64);
    for lv in &spec.levels {
        mix(lv.kind.name().len() as u64);
        for b in lv.kind.name().bytes() {
            mix(b as u64);
        }
        mix(lv.size_words);
        mix(lv.bw_words_per_cycle.to_bits());
        mix(lv.energy_pj_per_word.to_bits());
    }
    mix(spec.mac_energy_pj.to_bits());
    match spec.constraints.forced_col_dim {
        Some(d) => mix(1 + d.index() as u64),
        None => mix(0),
    }
    mix(spec.constraints.forced_col_factor.map_or(0, |f| 1 + f));
    mix(spec.constraints.no_dram_psum as u64);
    h
}

/// Deterministic fingerprint of a whole cascade: every op's shape,
/// kind, phase, repeat count, and name, plus the dependency edges.
/// Unlike [`shape_fingerprint`] (deliberately name/phase-agnostic —
/// mappings depend only on shape), this distinguishes everything that
/// can change an *evaluation*: it keys file-loaded workloads in the
/// cross-run evaluation cache, where a document's `name` alone could
/// collide across different contents.
pub fn cascade_fingerprint(c: &crate::workload::cascade::Cascade) -> u64 {
    const P: u64 = 0x100000001b3;
    let mut h = 0xcbf29ce484222325u64;
    let mix = |h: u64, v: u64| -> u64 { (h ^ v).wrapping_mul(P) };
    for op in &c.ops {
        h = mix(h, shape_fingerprint(op));
        h = mix(h, op.count);
        h = mix(h, op.phase as u64);
        h = mix(h, op.name.len() as u64);
        for b in op.name.bytes() {
            h = mix(h, b as u64);
        }
    }
    for &(p, s) in &c.deps {
        h = mix(h, ((p as u64) << 32) ^ s as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::einsum::Phase;

    fn spec() -> ArchSpec {
        ArchSpec::leaf("s", 32, 32, 64, 64 << 10, 1 << 20, 256.0, 64.0)
    }

    #[test]
    fn search_finds_valid_mapping() {
        let op = TensorOp::gemm("g", Phase::Encoder, 256, 512, 256);
        let r = search_best(&op, &spec(), &SearchBudget { samples: 200, seed: 1 });
        assert!(r.valid > 0);
        r.mapping.validate(&op, &spec()).unwrap();
        // Should beat the 1-PE trivial mapping by a wide margin.
        assert!(r.stats.cycles < (op.macs() as f64) / 4.0);
    }

    #[test]
    fn search_is_deterministic() {
        let op = TensorOp::gemm("g", Phase::Encoder, 128, 256, 128);
        let b = SearchBudget { samples: 150, seed: 42 };
        let r1 = search_best(&op, &spec(), &b);
        let r2 = search_best(&op, &spec(), &b);
        assert_eq!(r1.mapping, r2.mapping);
        assert_eq!(r1.stats.cycles, r2.stats.cycles);
    }

    #[test]
    fn more_budget_never_worse() {
        let op = TensorOp::bmm("l", Phase::Encoder, 8, 64, 32, 64);
        let small = search_best(&op, &spec(), &SearchBudget { samples: 20, seed: 7 });
        let large = search_best(&op, &spec(), &SearchBudget { samples: 500, seed: 7 });
        assert!(large.stats.cycles <= small.stats.cycles + 1e-9);
    }

    #[test]
    fn forced_col_dim_respected() {
        let mut s = spec();
        s.constraints.forced_col_dim = Some(Dim::N);
        let op = TensorOp::gemm("g", Phase::Decode, 1, 512, 512);
        let r = search_best(&op, &s, &SearchBudget { samples: 100, seed: 3 });
        // Either no column parallelism or N across columns.
        assert!(r.mapping.spatial_col.1 == 1 || r.mapping.spatial_col.0 == Dim::N);
    }

    #[test]
    fn gemv_utilization_poor_on_wide_array() {
        // Decode GEMV on a big array: spatial options limited by M=1.
        let op = TensorOp::gemm("gemv", Phase::Decode, 1, 1024, 1024);
        let r = search_best(&op, &spec(), &SearchBudget { samples: 200, seed: 5 });
        // Cannot use M-parallelism: utilisation from N/K only.
        assert!(r.mapping.spatial_row.0 != Dim::M || r.mapping.spatial_row.1 == 1);
    }

    #[test]
    fn threaded_search_bit_identical_to_serial() {
        let op = TensorOp::gemm("g", Phase::Encoder, 96, 160, 224);
        let b = SearchBudget { samples: 80, seed: 9 };
        let serial = search_best_threaded(&op, &spec(), &b, 1);
        for threads in [2usize, 4, 16] {
            let r = search_best_threaded(&op, &spec(), &b, threads);
            assert_eq!(r.mapping, serial.mapping, "mapping differs at {threads} threads");
            assert_eq!(r.stats.cycles, serial.stats.cycles);
            assert_eq!(r.stats.energy_pj, serial.stats.energy_pj);
            assert_eq!(r.evaluated, serial.evaluated);
            assert_eq!(r.valid, serial.valid);
        }
    }

    fn assert_no_duplicates(c: &[(Dim, u64)]) {
        let mut sorted = c.to_vec();
        sorted.sort_by_key(|&(d, f)| (d.index(), f));
        sorted.dedup();
        assert_eq!(sorted.len(), c.len(), "duplicates in {c:?}");
    }

    #[test]
    fn spatial_choices_size_one_dims() {
        // Decode GEMV: M = 1 — every candidate for M collapses to (M, 1)
        // and must appear exactly once despite the (M, 1) fallback push.
        let op = TensorOp::gemm("gemv", Phase::Decode, 1, 64, 64);
        let c = spatial_choices(&op, 32, None);
        assert_eq!(c.iter().filter(|&&(d, f)| d == Dim::M && f == 1).count(), 1);
        assert_no_duplicates(&c);
    }

    #[test]
    fn spatial_choices_pe_limit_one() {
        let op = TensorOp::gemm("g", Phase::Encoder, 8, 8, 8);
        let c = spatial_choices(&op, 1, None);
        assert!(c.iter().all(|&(_, f)| f == 1), "limit 1 allows only unit factors: {c:?}");
        assert_no_duplicates(&c);
        assert!(!c.is_empty());
    }

    #[test]
    fn spatial_choices_non_power_of_two() {
        let op = TensorOp::gemm("g", Phase::Encoder, 3000, 12288, 49152);
        let c = spatial_choices(&op, 160, None);
        assert!(c.contains(&(Dim::M, 160))); // largest factor ≤ limit
        assert!(c.contains(&(Dim::M, 80))); // half step
        assert!(c.contains(&(Dim::M, 1))); // fallback
        assert!(c.iter().all(|&(_, f)| (1..=160).contains(&f)));
        assert_no_duplicates(&c);
    }

    #[test]
    fn spatial_choices_forced_dim_only() {
        let op = TensorOp::gemm("g", Phase::Encoder, 64, 128, 256);
        let c = spatial_choices(&op, 16, Some(Dim::N));
        assert!(c.iter().all(|&(d, f)| d == Dim::N || (d == Dim::M && f == 1)));
        assert!(c.contains(&(Dim::N, 16)));
        assert_no_duplicates(&c);
    }

    /// The mapper sees booked capacity: searching on a contended
    /// hier+xnode low-unit spec produces tilings that fit the SLICE of
    /// the shared LLB (not the full node), and the batched pipeline
    /// stays bit-identical across thread counts on booked specs.
    #[test]
    fn search_respects_booked_capacity_and_stays_deterministic() {
        use crate::arch::level::LevelKind;
        use crate::arch::partition::{HardwareParams, MachineConfig};
        use crate::arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
        use crate::arch::topology::ContentionMode;
        use crate::workload::einsum::Operand;

        let c =
            HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node());
        let m = MachineConfig::build(&c, &HardwareParams::default())
            .unwrap()
            .with_contention(ContentionMode::Booked)
            .unwrap();
        let booked = &m.sub_accels[1].spec; // low-leaf: shares its LLB
        let llb = booked.level_index(LevelKind::LLB).unwrap();
        let cap = booked.levels[llb].size_words;
        assert!(cap < (4 << 20)); // genuinely a slice, not the budget

        let op = TensorOp::gemm("g", Phase::Decode, 8, 2048, 2048);
        let b = SearchBudget { samples: 60, seed: 11 };
        let r = search_best(&op, booked, &b);
        assert!(r.valid > 0);
        r.mapping.validate(&op, booked).unwrap();
        // The winning tiling's LLB-resident tile fits the booked slice.
        let tile: u64 = Operand::ALL
            .iter()
            .map(|&t| {
                Dim::ALL
                    .iter()
                    .filter(|&&d| op.relevant(t, d))
                    .map(|&d| r.mapping.extent(llb, d))
                    .product::<u64>()
            })
            .sum();
        assert!(tile <= cap, "LLB tile {tile} exceeds booked slice {cap}");

        // Thread-count determinism survives booked specs.
        let serial = search_best_threaded(&op, booked, &b, 1);
        for threads in [2usize, 8] {
            let r = search_best_threaded(&op, booked, &b, threads);
            assert_eq!(r.mapping, serial.mapping);
            assert_eq!(r.stats.cycles, serial.stats.cycles);
        }
    }

    #[test]
    fn fingerprint_distinguishes_shapes() {
        let a = TensorOp::gemm("a", Phase::Encoder, 10, 20, 30);
        let b = TensorOp::gemm("b", Phase::Encoder, 10, 20, 31);
        assert_ne!(shape_fingerprint(&a), shape_fingerprint(&b));
        let c = TensorOp::gemm("c", Phase::Decode, 10, 20, 30);
        assert_eq!(shape_fingerprint(&a), shape_fingerprint(&c)); // name/phase-agnostic
    }

    /// The cascade fingerprint distinguishes everything an evaluation
    /// can see: shapes, phases, repeat counts, names, and edges.
    #[test]
    fn cascade_fingerprint_distinguishes_evaluation_inputs() {
        use crate::workload::cascade::Cascade;
        let base = || {
            let mut g = Cascade::new("w");
            let a = g.push(TensorOp::gemm("a", Phase::Encoder, 8, 8, 8));
            let b = g.push(TensorOp::gemm("b", Phase::Encoder, 8, 8, 8));
            g.dep(a, b);
            g
        };
        let h0 = cascade_fingerprint(&base());
        assert_eq!(h0, cascade_fingerprint(&base()), "deterministic");

        let mut shape = base();
        shape.ops[1].n = 16;
        assert_ne!(h0, cascade_fingerprint(&shape));
        let mut phase = base();
        phase.ops[1].phase = Phase::Decode;
        assert_ne!(h0, cascade_fingerprint(&phase));
        let mut count = base();
        count.ops[1].count = 4;
        assert_ne!(h0, cascade_fingerprint(&count));
        let mut name = base();
        name.ops[1].name = "c".into();
        assert_ne!(h0, cascade_fingerprint(&name));
        let mut edges = base();
        edges.deps.clear();
        assert_ne!(h0, cascade_fingerprint(&edges));
    }
}
