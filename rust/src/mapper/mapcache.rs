//! Persistent cross-process `(shape, unit) → mapping` cache.
//!
//! The mapper's per-(op, sub-accelerator) searches are the dominant
//! cost of an evaluation, and they are fully deterministic in
//! `(shape_fingerprint, spec_fingerprint, search budget, model
//! version)` — so their results can survive across runs. This cache
//! spills every searched [`SearchResult`] to a JSON file and serves
//! bit-identical stats on the next run.
//!
//! Format: one JSON object with a header and an `entries` map,
//!
//! ```json
//! {
//!   "harp_mapping_cache": 1,
//!   "model_version": 1,
//!   "search": "s600|r0x0000000048415250",
//!   "entries": { "<shape_fp>|<spec_fp>": { "mapping": …, "stats": …,
//!                "evaluated": n, "valid": n } }
//! }
//! ```
//!
//! written compactly on spill ([`MapCache::persist`]) and
//! pretty-printable for debugging ([`MapCache::debug_json`]); the
//! loader accepts either. Unlike the evaluation cache (which treats an
//! unreadable file as cold), a mapping cache that cannot be honoured is
//! rejected **loudly** with a distinct [`MapCacheError`] per cause —
//! serving a mapping searched under a different model version or
//! search budget would silently change results, the one thing the
//! repo's determinism contract forbids.
//!
//! JSON is the debug/interchange path. For million-point sweeps there
//! is also a `harp_bin` binary spill (selected by a `.bin` extension or
//! the `cache_format` knob, see [`CacheFormat`]): the same header
//! checks and the same loud rejections, with floats stored as raw
//! IEEE-754 bit patterns. Both formats stream entry-by-entry on
//! persist, so spilling never builds a whole-document string.
//!
//! Numeric exactness: every JSON float is written with Rust's shortest
//! round-trip `Display` and re-read with `str::parse::<f64>` (correctly
//! rounded), so a loaded `OpStats` is bitwise the one searched —
//! cache-hit-equals-fresh is property-tested in
//! `tests/mapping_cache.rs` and `tests/binary_cache.rs`.

use crate::arch::level::LevelKind;
use crate::mapper::search::SearchResult;
use crate::mapping::loopnest::Mapping;
use crate::model::stats::{Bound, LevelStats, OpStats};
use crate::util::binio::{BinError, BinReader, BinWriter, CacheFormat};
use crate::util::json::{Json, JsonStreamWriter, JsonStyle};
use crate::workload::einsum::Dim;
use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// On-disk format revision of the cache document itself (bump when the
/// JSON layout changes; distinct from the eval model version, which
/// tracks the numbers).
pub const MAPCACHE_FORMAT: u64 = 1;

/// Container kind string of the binary spill.
const BIN_KIND: &str = "mapcache";
/// Revision of the binary payload layout (bump when it changes).
const BIN_FORMAT: u32 = 1;

/// Why a mapping-cache file was rejected. Each cause is distinct so
/// callers (and users reading stderr) can tell a corrupt file from a
/// stale one.
#[derive(Debug, Clone, PartialEq)]
pub enum MapCacheError {
    /// The file exists but cannot be read.
    Io(String),
    /// Not a mapping-cache document, or a structurally broken one.
    Malformed(String),
    /// Written by a different evaluation-model version.
    VersionMismatch { found: u64, expected: u64 },
    /// Written under a different mapper search budget.
    StaleFingerprint { found: String, expected: String },
}

impl fmt::Display for MapCacheError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MapCacheError::Io(e) => write!(f, "cannot read mapping cache: {e}"),
            MapCacheError::Malformed(d) => write!(f, "malformed mapping cache: {d}"),
            MapCacheError::VersionMismatch { found, expected } => write!(
                f,
                "mapping cache version mismatch: written by eval model version {found}, \
                 this binary is version {expected} — delete the file to regenerate it"
            ),
            MapCacheError::StaleFingerprint { found, expected } => write!(
                f,
                "stale mapping cache: searched under budget \"{found}\", this run uses \
                 \"{expected}\" — serving it would change results; delete the file or \
                 use a separate cache per budget"
            ),
        }
    }
}

/// One cached mapping-search result (the value of an entry).
#[derive(Debug, Clone)]
pub struct CachedSearch {
    pub mapping: Mapping,
    pub stats: OpStats,
    pub evaluated: usize,
    pub valid: usize,
}

impl From<SearchResult> for CachedSearch {
    fn from(r: SearchResult) -> CachedSearch {
        CachedSearch {
            mapping: r.mapping,
            stats: r.stats,
            evaluated: r.evaluated,
            valid: r.valid,
        }
    }
}

impl CachedSearch {
    pub fn to_search_result(&self) -> SearchResult {
        SearchResult {
            mapping: self.mapping.clone(),
            stats: self.stats.clone(),
            evaluated: self.evaluated,
            valid: self.valid,
        }
    }
}

type Slot = Arc<OnceLock<Arc<CachedSearch>>>;

/// The cache: interior-mutable (shared via `Arc` across mapper worker
/// threads, same discipline as the coordinator's `Evaluator`), keyed by
/// `(shape_fingerprint, spec_fingerprint)`, versioned by the eval model
/// version and the mapper search-budget fingerprint.
pub struct MapCache {
    model_version: u64,
    search_fp: String,
    entries: Mutex<HashMap<String, Slot>>,
    spill: Option<PathBuf>,
    format: CacheFormat,
    dirty: AtomicBool,
}

impl fmt::Debug for MapCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MapCache")
            .field("model_version", &self.model_version)
            .field("search_fp", &self.search_fp)
            .field("entries", &self.len())
            .field("spill", &self.spill)
            .finish()
    }
}

impl MapCache {
    /// An empty in-memory cache (no spill file).
    pub fn new(model_version: u64, search_fp: impl Into<String>) -> MapCache {
        MapCache {
            model_version,
            search_fp: search_fp.into(),
            entries: Mutex::new(HashMap::new()),
            spill: None,
            format: CacheFormat::Json,
            dirty: AtomicBool::new(false),
        }
    }

    /// A cache bound to `path`: loads it if present (rejecting loudly a
    /// file that cannot be honoured), starts empty if missing.
    /// [`MapCache::persist`] writes back to the same path. The spill
    /// format follows the extension (`.bin` → binary, otherwise JSON);
    /// use [`MapCache::with_file_format`] to pass an explicit knob.
    pub fn with_file(
        path: impl Into<PathBuf>,
        model_version: u64,
        search_fp: impl Into<String>,
    ) -> Result<MapCache, MapCacheError> {
        let path = path.into();
        let fmt = CacheFormat::resolve(&path, None)
            .expect("extension-only resolution cannot conflict");
        MapCache::with_file_format(path, model_version, search_fp, fmt)
    }

    /// [`MapCache::with_file`] with the spill format decided by the
    /// caller (who resolved the `cache_format` knob against the
    /// extension via [`CacheFormat::resolve`] — conflicts error there,
    /// before any file is touched).
    pub fn with_file_format(
        path: impl Into<PathBuf>,
        model_version: u64,
        search_fp: impl Into<String>,
        fmt: CacheFormat,
    ) -> Result<MapCache, MapCacheError> {
        let path = path.into();
        let mut cache = MapCache::new(model_version, search_fp);
        cache.format = fmt;
        if path.exists() {
            match fmt {
                CacheFormat::Json => {
                    let text = std::fs::read_to_string(&path)
                        .map_err(|e| MapCacheError::Io(format!("{}: {e}", path.display())))?;
                    cache.load_document(&text)?;
                }
                CacheFormat::Binary => {
                    let bytes = std::fs::read(&path)
                        .map_err(|e| MapCacheError::Io(format!("{}: {e}", path.display())))?;
                    cache.load_document_bin(&bytes)?;
                }
            }
        }
        cache.spill = Some(path);
        Ok(cache)
    }

    /// The spill format this cache was bound with.
    pub fn format(&self) -> CacheFormat {
        self.format
    }

    fn load_document(&mut self, text: &str) -> Result<(), MapCacheError> {
        let doc = Json::parse(text)
            .map_err(|e| MapCacheError::Malformed(format!("not valid JSON: {e}")))?;
        match doc.get("harp_mapping_cache").and_then(Json::as_u64) {
            Some(MAPCACHE_FORMAT) => {}
            Some(v) => {
                return Err(MapCacheError::Malformed(format!(
                    "unsupported cache format {v} (this binary writes {MAPCACHE_FORMAT})"
                )))
            }
            None => {
                return Err(MapCacheError::Malformed(
                    "missing \"harp_mapping_cache\" marker — not a mapping cache".into(),
                ))
            }
        }
        let found_version = doc
            .get("model_version")
            .and_then(Json::as_u64)
            .ok_or_else(|| MapCacheError::Malformed("missing \"model_version\"".into()))?;
        if found_version != self.model_version {
            return Err(MapCacheError::VersionMismatch {
                found: found_version,
                expected: self.model_version,
            });
        }
        let found_fp = doc
            .get("search")
            .and_then(Json::as_str)
            .ok_or_else(|| MapCacheError::Malformed("missing \"search\" fingerprint".into()))?;
        if found_fp != self.search_fp {
            return Err(MapCacheError::StaleFingerprint {
                found: found_fp.to_string(),
                expected: self.search_fp.clone(),
            });
        }
        let pairs = match doc.get("entries") {
            Some(Json::Obj(pairs)) => pairs,
            _ => {
                return Err(MapCacheError::Malformed(
                    "missing or non-object \"entries\"".into(),
                ))
            }
        };
        let mut map = self.entries.lock().unwrap();
        for (key, val) in pairs {
            let entry = cached_search_from_json(val).map_err(|d| {
                MapCacheError::Malformed(format!("entry \"{key}\": {d}"))
            })?;
            let slot: Slot = Arc::new(OnceLock::new());
            let _ = slot.set(Arc::new(entry));
            map.insert(key.clone(), slot);
        }
        Ok(())
    }

    /// Binary loader: the same honour ladder as the JSON path — magic/
    /// kind/revision problems and truncation surface as `Malformed`
    /// with the decoder's offset-bearing text, then model version and
    /// search fingerprint get their dedicated rejections.
    fn load_document_bin(&mut self, bytes: &[u8]) -> Result<(), MapCacheError> {
        let mal = |e: BinError| MapCacheError::Malformed(e.to_string());
        let mut r = BinReader::new(bytes);
        r.header(BIN_KIND, BIN_FORMAT).map_err(mal)?;
        let found_version = r.u64("model version").map_err(mal)?;
        if found_version != self.model_version {
            return Err(MapCacheError::VersionMismatch {
                found: found_version,
                expected: self.model_version,
            });
        }
        let found_fp = r.str("search fingerprint").map_err(mal)?;
        if found_fp != self.search_fp {
            return Err(MapCacheError::StaleFingerprint {
                found: found_fp,
                expected: self.search_fp.clone(),
            });
        }
        let n = r.seq_len(8, "entries").map_err(mal)?;
        let mut map = self.entries.lock().unwrap();
        for _ in 0..n {
            let key = r.str("entry key").map_err(mal)?;
            let entry = read_cached_search(&mut r)
                .map_err(|e| MapCacheError::Malformed(format!("entry \"{key}\": {e}")))?;
            let slot: Slot = Arc::new(OnceLock::new());
            let _ = slot.set(Arc::new(entry));
            map.insert(key, slot);
        }
        drop(map);
        r.finish().map_err(mal)
    }

    fn key(shape_fp: u64, spec_fp: u64) -> String {
        format!("{shape_fp:016x}|{spec_fp:016x}")
    }

    /// Number of searched entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().values().filter(|s| s.get().is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serve the cached result for `(shape_fp, spec_fp)` or run
    /// `compute` exactly once (concurrent callers for the same key
    /// block on the winner). A hit is bitwise the result of the search
    /// that populated it.
    pub fn get_or_compute(
        &self,
        shape_fp: u64,
        spec_fp: u64,
        compute: impl FnOnce() -> CachedSearch,
    ) -> Arc<CachedSearch> {
        let slot = {
            let mut map = self.entries.lock().unwrap();
            map.entry(MapCache::key(shape_fp, spec_fp))
                .or_insert_with(|| Arc::new(OnceLock::new()))
                .clone()
        };
        let mut computed = false;
        let out = slot
            .get_or_init(|| {
                computed = true;
                Arc::new(compute())
            })
            .clone();
        if computed {
            self.dirty.store(true, Ordering::Relaxed);
        }
        out
    }

    /// The full document, keys sorted (byte-stable across runs and
    /// thread counts).
    pub fn to_json(&self) -> Json {
        let map = self.entries.lock().unwrap();
        let mut keys: Vec<&String> = map.keys().collect();
        keys.sort();
        let mut entries = Json::obj();
        for k in keys {
            if let Some(v) = map[k].get() {
                entries = entries.with(k, cached_search_to_json(v));
            }
        }
        Json::obj()
            .with("harp_mapping_cache", MAPCACHE_FORMAT)
            .with("model_version", self.model_version)
            .with("search", self.search_fp.as_str())
            .with("entries", entries)
    }

    /// Human-readable (pretty) form of the document, for debugging.
    pub fn debug_json(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Spill to the bound file if any entry was computed since load —
    /// compact JSON or `harp_bin`, whichever the cache was bound with.
    /// No-op without a file or new entries. Both formats stream
    /// entry-by-entry through a `BufWriter`: peak heap is one entry,
    /// not the whole document (the JSON bytes are identical to the old
    /// `to_json().to_string_compact()` path, which the unit tests pin).
    pub fn persist(&self) -> std::io::Result<()> {
        let path = match &self.spill {
            Some(p) if self.dirty.load(Ordering::Relaxed) => p.clone(),
            _ => return Ok(()),
        };
        let out = std::io::BufWriter::new(std::fs::File::create(&path)?);
        let map = self.entries.lock().unwrap();
        let mut keys: Vec<&String> = map.keys().collect();
        keys.sort();
        match self.format {
            CacheFormat::Json => {
                let mut w = JsonStreamWriter::new(out, JsonStyle::Compact);
                w.begin_obj()?;
                w.key("harp_mapping_cache")?;
                w.num(MAPCACHE_FORMAT as f64)?;
                w.key("model_version")?;
                w.num(self.model_version as f64)?;
                w.key("search")?;
                w.str(&self.search_fp)?;
                w.key("entries")?;
                w.begin_obj()?;
                for k in keys {
                    if let Some(v) = map[k].get() {
                        w.key(k)?;
                        w.value(&cached_search_to_json(v))?;
                    }
                }
                w.end_obj()?;
                w.end_obj()?;
                w.finish()?;
            }
            CacheFormat::Binary => {
                let mut w = BinWriter::new(out);
                w.header(BIN_KIND, BIN_FORMAT)?;
                w.u64(self.model_version)?;
                w.str(&self.search_fp)?;
                let n = keys.iter().filter(|k| map[k.as_str()].get().is_some()).count();
                w.u64(n as u64)?;
                for k in keys {
                    if let Some(v) = map[k].get() {
                        w.str(k)?;
                        write_cached_search(&mut w, v)?;
                    }
                }
                w.finish()?;
            }
        }
        self.dirty.store(false, Ordering::Relaxed);
        Ok(())
    }

    /// The spill path, if file-bound.
    pub fn path(&self) -> Option<&Path> {
        self.spill.as_deref()
    }
}

fn f64_field(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing number \"{key}\""))
}

fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key).and_then(Json::as_usize).ok_or_else(|| format!("missing count \"{key}\""))
}

fn cached_search_to_json(c: &CachedSearch) -> Json {
    Json::obj()
        .with("mapping", mapping_to_json(&c.mapping))
        .with("stats", op_stats_to_json(&c.stats))
        .with("evaluated", c.evaluated)
        .with("valid", c.valid)
}

fn cached_search_from_json(j: &Json) -> Result<CachedSearch, String> {
    Ok(CachedSearch {
        mapping: mapping_from_json(j.get("mapping").ok_or("missing \"mapping\"")?)?,
        stats: op_stats_from_json(j.get("stats").ok_or("missing \"stats\"")?)?,
        evaluated: usize_field(j, "evaluated")?,
        valid: usize_field(j, "valid")?,
    })
}

fn mapping_to_json(m: &Mapping) -> Json {
    let temporal: Vec<Json> = m
        .temporal
        .iter()
        .map(|t| Json::Arr(t.iter().map(|&f| Json::from(f)).collect()))
        .collect();
    let perms: Vec<Json> = m
        .perms
        .iter()
        .map(|p| Json::Arr(p.iter().map(|d| Json::from(d.name())).collect()))
        .collect();
    let spatial = |(d, f): (Dim, u64)| Json::Arr(vec![Json::from(d.name()), Json::from(f)]);
    Json::obj()
        .with("temporal", Json::Arr(temporal))
        .with("perms", Json::Arr(perms))
        .with("spatial_row", spatial(m.spatial_row))
        .with("spatial_col", spatial(m.spatial_col))
}

fn dims4(j: &Json) -> Result<[Dim; 4], String> {
    let arr = j.as_arr().ok_or("permutation is not an array")?;
    if arr.len() != 4 {
        return Err(format!("permutation has {} entries, want 4", arr.len()));
    }
    let mut out = [Dim::B; 4];
    for (slot, v) in out.iter_mut().zip(arr) {
        *slot = Dim::parse(v.as_str().ok_or("permutation entry is not a string")?)?;
    }
    Ok(out)
}

fn spatial_from(j: &Json) -> Result<(Dim, u64), String> {
    let arr = j.as_arr().ok_or("spatial mapping is not an array")?;
    match arr {
        [d, f] => Ok((
            Dim::parse(d.as_str().ok_or("spatial dim is not a string")?)?,
            f.as_u64().ok_or("spatial factor is not an integer")?,
        )),
        _ => Err("spatial mapping wants [dim, factor]".into()),
    }
}

fn mapping_from_json(j: &Json) -> Result<Mapping, String> {
    let temporal = j
        .get("temporal")
        .and_then(Json::as_arr)
        .ok_or("missing \"temporal\"")?
        .iter()
        .map(|row| {
            let arr = row.as_arr().ok_or("temporal block is not an array")?;
            if arr.len() != 4 {
                return Err(format!("temporal block has {} factors, want 4", arr.len()));
            }
            let mut out = [0u64; 4];
            for (slot, v) in out.iter_mut().zip(arr) {
                *slot = v.as_u64().ok_or("temporal factor is not an integer")?;
            }
            Ok(out)
        })
        .collect::<Result<Vec<[u64; 4]>, String>>()?;
    let perms = j
        .get("perms")
        .and_then(Json::as_arr)
        .ok_or("missing \"perms\"")?
        .iter()
        .map(dims4)
        .collect::<Result<Vec<[Dim; 4]>, String>>()?;
    Ok(Mapping {
        temporal,
        perms,
        spatial_row: spatial_from(j.get("spatial_row").ok_or("missing \"spatial_row\"")?)?,
        spatial_col: spatial_from(j.get("spatial_col").ok_or("missing \"spatial_col\"")?)?,
    })
}

fn op_stats_to_json(s: &OpStats) -> Json {
    let levels: Vec<Json> = s
        .levels
        .iter()
        .map(|l| {
            Json::obj()
                .with("kind", l.kind.name())
                .with("reads", l.reads)
                .with("writes", l.writes)
                .with("energy_pj", l.energy_pj)
        })
        .collect();
    let boundary: Vec<Json> = s
        .boundary_words
        .iter()
        .map(|&(k, w)| Json::Arr(vec![Json::from(k.name()), Json::from(w)]))
        .collect();
    let bound = match s.bound {
        Bound::Compute => "compute".to_string(),
        Bound::Memory(k) => format!("memory:{}", k.name()),
    };
    Json::obj()
        .with("cycles", s.cycles)
        .with("compute_cycles", s.compute_cycles)
        .with("macs", s.macs)
        .with("energy_pj", s.energy_pj)
        .with("mac_energy_pj", s.mac_energy_pj)
        .with("noc_energy_pj", s.noc_energy_pj)
        .with("levels", Json::Arr(levels))
        .with("boundary_words", Json::Arr(boundary))
        .with("dram_words", s.dram_words)
        .with("utilization", s.utilization)
        .with("bound", bound)
        .with("onchip_bound_cycles", s.onchip_bound_cycles)
}

fn op_stats_from_json(j: &Json) -> Result<OpStats, String> {
    let levels = j
        .get("levels")
        .and_then(Json::as_arr)
        .ok_or("missing \"levels\"")?
        .iter()
        .map(|l| {
            Ok(LevelStats {
                kind: LevelKind::named(
                    l.get("kind").and_then(Json::as_str).ok_or("level missing \"kind\"")?,
                ),
                reads: f64_field(l, "reads")?,
                writes: f64_field(l, "writes")?,
                energy_pj: f64_field(l, "energy_pj")?,
            })
        })
        .collect::<Result<Vec<LevelStats>, String>>()?;
    let boundary_words = j
        .get("boundary_words")
        .and_then(Json::as_arr)
        .ok_or("missing \"boundary_words\"")?
        .iter()
        .map(|b| {
            let arr = b.as_arr().ok_or("boundary entry is not an array")?;
            match arr {
                [k, w] => Ok((
                    LevelKind::named(k.as_str().ok_or("boundary kind is not a string")?),
                    w.as_f64().ok_or("boundary words is not a number")?,
                )),
                _ => Err("boundary entry wants [kind, words]".to_string()),
            }
        })
        .collect::<Result<Vec<(LevelKind, f64)>, String>>()?;
    let bound_txt = j.get("bound").and_then(Json::as_str).ok_or("missing \"bound\"")?;
    let bound = if bound_txt == "compute" {
        Bound::Compute
    } else if let Some(kind) = bound_txt.strip_prefix("memory:") {
        Bound::Memory(LevelKind::named(kind))
    } else {
        return Err(format!("unknown bound \"{bound_txt}\""));
    };
    Ok(OpStats {
        cycles: f64_field(j, "cycles")?,
        compute_cycles: f64_field(j, "compute_cycles")?,
        macs: f64_field(j, "macs")?,
        energy_pj: f64_field(j, "energy_pj")?,
        mac_energy_pj: f64_field(j, "mac_energy_pj")?,
        noc_energy_pj: f64_field(j, "noc_energy_pj")?,
        levels,
        boundary_words,
        dram_words: f64_field(j, "dram_words")?,
        utilization: f64_field(j, "utilization")?,
        bound,
        onchip_bound_cycles: f64_field(j, "onchip_bound_cycles")?,
    })
}

/// Binary twin of [`cached_search_to_json`]: same field order, floats
/// as raw bits, dim/level names as strings (self-describing, so the
/// reader can reject unknown names loudly).
fn write_cached_search<W: std::io::Write>(
    w: &mut BinWriter<W>,
    c: &CachedSearch,
) -> std::io::Result<()> {
    let m = &c.mapping;
    w.u64(m.temporal.len() as u64)?;
    for t in &m.temporal {
        for &f in t {
            w.u64(f)?;
        }
    }
    w.u64(m.perms.len() as u64)?;
    for p in &m.perms {
        for d in p {
            w.str(d.name())?;
        }
    }
    for (d, f) in [m.spatial_row, m.spatial_col] {
        w.str(d.name())?;
        w.u64(f)?;
    }
    let s = &c.stats;
    w.f64(s.cycles)?;
    w.f64(s.compute_cycles)?;
    w.f64(s.macs)?;
    w.f64(s.energy_pj)?;
    w.f64(s.mac_energy_pj)?;
    w.f64(s.noc_energy_pj)?;
    w.u64(s.levels.len() as u64)?;
    for l in &s.levels {
        w.str(l.kind.name())?;
        w.f64(l.reads)?;
        w.f64(l.writes)?;
        w.f64(l.energy_pj)?;
    }
    w.u64(s.boundary_words.len() as u64)?;
    for &(k, words) in &s.boundary_words {
        w.str(k.name())?;
        w.f64(words)?;
    }
    w.f64(s.dram_words)?;
    w.f64(s.utilization)?;
    match s.bound {
        Bound::Compute => w.u8(0)?,
        Bound::Memory(k) => {
            w.u8(1)?;
            w.str(k.name())?;
        }
    }
    w.f64(s.onchip_bound_cycles)?;
    w.u64(c.evaluated as u64)?;
    w.u64(c.valid as u64)
}

/// Inverse of [`write_cached_search`] — every malformed mode (unknown
/// dim name, bad bound tag, truncation) is a distinct loud [`BinError`].
fn read_cached_search(r: &mut BinReader<'_>) -> Result<CachedSearch, BinError> {
    fn dim(r: &mut BinReader<'_>) -> Result<Dim, BinError> {
        let offset = r.offset();
        let name = r.str("dim name")?;
        Dim::parse(&name).map_err(|e| BinError::Malformed { offset, detail: e })
    }

    let n = r.seq_len(32, "temporal blocks")?;
    let mut temporal = Vec::with_capacity(n);
    for _ in 0..n {
        let mut t = [0u64; 4];
        for slot in t.iter_mut() {
            *slot = r.u64("temporal factor")?;
        }
        temporal.push(t);
    }
    let n = r.seq_len(20, "permutations")?;
    let mut perms = Vec::with_capacity(n);
    for _ in 0..n {
        let mut p = [Dim::B; 4];
        for slot in p.iter_mut() {
            *slot = dim(r)?;
        }
        perms.push(p);
    }
    let spatial_row = (dim(r)?, r.u64("spatial factor")?);
    let spatial_col = (dim(r)?, r.u64("spatial factor")?);
    let cycles = r.f64("cycles")?;
    let compute_cycles = r.f64("compute_cycles")?;
    let macs = r.f64("macs")?;
    let energy_pj = r.f64("energy_pj")?;
    let mac_energy_pj = r.f64("mac_energy_pj")?;
    let noc_energy_pj = r.f64("noc_energy_pj")?;
    let n = r.seq_len(28, "levels")?;
    let mut levels = Vec::with_capacity(n);
    for _ in 0..n {
        levels.push(LevelStats {
            kind: LevelKind::named(&r.str("level kind")?),
            reads: r.f64("level reads")?,
            writes: r.f64("level writes")?,
            energy_pj: r.f64("level energy")?,
        });
    }
    let n = r.seq_len(12, "boundary words")?;
    let mut boundary_words = Vec::with_capacity(n);
    for _ in 0..n {
        boundary_words
            .push((LevelKind::named(&r.str("boundary kind")?), r.f64("boundary words")?));
    }
    let dram_words = r.f64("dram_words")?;
    let utilization = r.f64("utilization")?;
    let tag_offset = r.offset();
    let bound = match r.u8("bound tag")? {
        0 => Bound::Compute,
        1 => Bound::Memory(LevelKind::named(&r.str("bound level kind")?)),
        t => {
            return Err(BinError::Malformed {
                offset: tag_offset,
                detail: format!("unknown bound tag {t}"),
            })
        }
    };
    let onchip_bound_cycles = r.f64("onchip_bound_cycles")?;
    let evaluated = r.u64("evaluated")? as usize;
    let valid = r.u64("valid")? as usize;
    Ok(CachedSearch {
        mapping: Mapping { temporal, perms, spatial_row, spatial_col },
        stats: OpStats {
            cycles,
            compute_cycles,
            macs,
            energy_pj,
            mac_energy_pj,
            noc_energy_pj,
            levels,
            boundary_words,
            dram_words,
            utilization,
            bound,
            onchip_bound_cycles,
        },
        evaluated,
        valid,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_entry() -> CachedSearch {
        let mut stats = OpStats::new_empty();
        stats.cycles = 123.456789e3;
        stats.compute_cycles = 100.0;
        stats.macs = 4096.0;
        stats.energy_pj = 0.1 + 0.2; // deliberately non-representable
        stats.mac_energy_pj = 1.5e-3;
        stats.noc_energy_pj = 7.25;
        stats.levels = vec![LevelStats {
            kind: LevelKind::named("L2"),
            reads: 3.0,
            writes: 1.0 / 3.0,
            energy_pj: 9.9,
        }];
        stats.boundary_words = vec![(LevelKind::DRAM, 512.125)];
        stats.dram_words = 512.125;
        stats.utilization = 0.875;
        stats.bound = Bound::Memory(LevelKind::DRAM);
        stats.onchip_bound_cycles = 99.0;
        CachedSearch {
            mapping: Mapping {
                temporal: vec![[1, 2, 3, 4], [4, 3, 2, 1]],
                perms: vec![
                    [Dim::B, Dim::M, Dim::N, Dim::K],
                    [Dim::K, Dim::N, Dim::M, Dim::B],
                ],
                spatial_row: (Dim::M, 8),
                spatial_col: (Dim::N, 16),
            },
            stats,
            evaluated: 42,
            valid: 17,
        }
    }

    /// Entry serialization round-trips bitwise, including
    /// non-representable floats, custom level kinds, and the bound tag.
    #[test]
    fn entry_round_trips_bitwise() {
        let e = sample_entry();
        let j = cached_search_to_json(&e);
        // Through TEXT, not just the Json tree: exactness must survive
        // Display + parse.
        let back = cached_search_from_json(&Json::parse(&j.to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(back.mapping, e.mapping);
        assert_eq!(back.evaluated, e.evaluated);
        assert_eq!(back.valid, e.valid);
        assert_eq!(back.stats.cycles.to_bits(), e.stats.cycles.to_bits());
        assert_eq!(back.stats.energy_pj.to_bits(), e.stats.energy_pj.to_bits());
        assert_eq!(
            back.stats.levels[0].writes.to_bits(),
            e.stats.levels[0].writes.to_bits()
        );
        assert_eq!(back.stats.levels[0].kind, LevelKind::named("L2"));
        assert_eq!(back.stats.bound, Bound::Memory(LevelKind::DRAM));
        assert_eq!(
            back.stats.boundary_words[0].1.to_bits(),
            e.stats.boundary_words[0].1.to_bits()
        );
    }

    /// The four rejection causes are distinct errors with distinct
    /// messages.
    #[test]
    fn rejection_causes_are_distinct() {
        let dir = std::env::temp_dir().join(format!("harp-mapcache-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.json");

        let write_and_load = |text: &str| {
            std::fs::write(&path, text).unwrap();
            MapCache::with_file(&path, 1, "s4|r0x1").unwrap_err()
        };

        let garbage = write_and_load("{not json");
        assert!(matches!(garbage, MapCacheError::Malformed(_)));
        let not_a_cache = write_and_load("{\"samples\": 3}");
        assert!(matches!(not_a_cache, MapCacheError::Malformed(_)));
        let wrong_version = write_and_load(
            "{\"harp_mapping_cache\":1,\"model_version\":999,\"search\":\"s4|r0x1\",\
             \"entries\":{}}",
        );
        assert_eq!(
            wrong_version,
            MapCacheError::VersionMismatch { found: 999, expected: 1 }
        );
        let stale = write_and_load(
            "{\"harp_mapping_cache\":1,\"model_version\":1,\"search\":\"s999|r0x2\",\
             \"entries\":{}}",
        );
        assert_eq!(
            stale,
            MapCacheError::StaleFingerprint {
                found: "s999|r0x2".into(),
                expected: "s4|r0x1".into()
            }
        );
        assert_ne!(wrong_version.to_string(), stale.to_string());
        assert_ne!(garbage.to_string(), wrong_version.to_string());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Spill → load serves the identical entry; a malformed entry names
    /// its key.
    #[test]
    fn spill_load_round_trip_and_entry_errors() {
        let dir =
            std::env::temp_dir().join(format!("harp-mapcache-rt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.json");

        let cache = MapCache::with_file(&path, 1, "s4|r0x1").unwrap();
        let e = sample_entry();
        let stored = cache.get_or_compute(0xAB, 0xCD, || e.clone());
        assert_eq!(cache.len(), 1);
        cache.persist().unwrap();

        let warm = MapCache::with_file(&path, 1, "s4|r0x1").unwrap();
        assert_eq!(warm.len(), 1);
        let mut computed = false;
        let hit = warm.get_or_compute(0xAB, 0xCD, || {
            computed = true;
            sample_entry()
        });
        assert!(!computed, "warm cache must not recompute");
        assert_eq!(hit.stats.cycles.to_bits(), stored.stats.cycles.to_bits());
        assert_eq!(hit.mapping, stored.mapping);

        // Corrupt one entry: the error names the key.
        let doc = std::fs::read_to_string(&path).unwrap();
        let broken = doc.replace("\"evaluated\":42", "\"evaluated\":\"many\"");
        assert_ne!(doc, broken);
        std::fs::write(&path, broken).unwrap();
        let err = MapCache::with_file(&path, 1, "s4|r0x1").unwrap_err();
        match err {
            MapCacheError::Malformed(d) => assert!(d.contains(&MapCache::key(0xAB, 0xCD))),
            other => panic!("want Malformed, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The streaming JSON persist path writes byte-identical output to
    /// the tree path — old spills and the warm-run `cmp` gates in
    /// tier-1 cannot move.
    #[test]
    fn streamed_persist_matches_tree_bytes() {
        let dir = std::env::temp_dir()
            .join(format!("harp-mapcache-stream-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.json");
        std::fs::remove_file(&path).ok();

        let cache = MapCache::with_file(&path, 1, "s4|r0x1").unwrap();
        let e = sample_entry();
        cache.get_or_compute(0xAB, 0xCD, || e.clone());
        cache.get_or_compute(0x01, 0x02, || e.clone());
        cache.persist().unwrap();
        let streamed = std::fs::read_to_string(&path).unwrap();
        assert_eq!(streamed, cache.to_json().to_string_compact());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A `.bin` path selects the binary spill: round trip is bitwise,
    /// and the doctored-header rejections mirror the JSON ones.
    #[test]
    fn binary_spill_round_trips_and_rejects_doctored_headers() {
        let dir =
            std::env::temp_dir().join(format!("harp-mapcache-bin-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bin");
        std::fs::remove_file(&path).ok();

        let cache = MapCache::with_file(&path, 1, "s4|r0x1").unwrap();
        assert_eq!(cache.format(), CacheFormat::Binary);
        let e = sample_entry();
        let stored = cache.get_or_compute(0xAB, 0xCD, || e.clone());
        cache.persist().unwrap();
        let spilled = std::fs::read(&path).unwrap();
        assert_eq!(&spilled[..8], b"harp_bin");

        let warm = MapCache::with_file(&path, 1, "s4|r0x1").unwrap();
        assert_eq!(warm.len(), 1);
        let mut computed = false;
        let hit = warm.get_or_compute(0xAB, 0xCD, || {
            computed = true;
            sample_entry()
        });
        assert!(!computed, "warm binary cache must not recompute");
        assert_eq!(hit.stats.cycles.to_bits(), stored.stats.cycles.to_bits());
        assert_eq!(hit.stats.energy_pj.to_bits(), stored.stats.energy_pj.to_bits());
        assert_eq!(hit.mapping, stored.mapping);
        // A clean warm cache re-persists to the identical bytes.
        warm.persist().unwrap();
        assert_eq!(spilled, std::fs::read(&path).unwrap());

        // Version and budget mismatches get their dedicated rejections.
        let err = MapCache::with_file(&path, 2, "s4|r0x1").unwrap_err();
        assert_eq!(err, MapCacheError::VersionMismatch { found: 1, expected: 2 });
        let err = MapCache::with_file(&path, 1, "s9|r0x1").unwrap_err();
        assert!(matches!(err, MapCacheError::StaleFingerprint { .. }), "{err}");

        // Doctored magic is malformed, loudly.
        let mut bad = spilled.clone();
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        let err = MapCache::with_file(&path, 1, "s4|r0x1").unwrap_err();
        match &err {
            MapCacheError::Malformed(d) => assert!(d.contains("magic"), "{d}"),
            other => panic!("want Malformed, got {other:?}"),
        }

        // A JSON document behind a .bin extension is malformed too (not
        // a quiet JSON fallback — the format knob means what it says).
        std::fs::write(&path, cache.to_json().to_string_compact()).unwrap();
        let err = MapCache::with_file(&path, 1, "s4|r0x1").unwrap_err();
        assert!(matches!(err, MapCacheError::Malformed(_)), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
