//! Black-box per-operation mapping over a whole cascade (paper §V-C).
//!
//! Each operation is mapped independently on its assigned
//! sub-accelerator — the design space is additive. Results are cached by
//! (shape fingerprint, sub-accelerator) since transformer cascades
//! repeat shapes (Q/K/V projections, per-chunk decode ops), and the
//! per-op searches run in parallel on the thread pool.

use crate::arch::partition::MachineConfig;
use crate::arch::spec::ArchSpec;
use crate::mapper::mapcache::MapCache;
use crate::mapper::search::{
    search_best_threaded, shape_fingerprint, spec_fingerprint, SearchBudget, SearchResult,
};
use crate::model::stats::OpStats;
use crate::util::threadpool::{default_threads, parallel_map};
use crate::workload::cascade::Cascade;
use crate::workload::einsum::TensorOp;
use std::collections::HashMap;
use std::sync::Arc;

/// A mapped operation: which sub-accelerator it runs on and at what cost.
#[derive(Debug, Clone)]
pub struct MappedOp {
    pub op_index: usize,
    pub sub_accel: usize,
    /// Stats for ONE repetition (scale by `op.count` when scheduling).
    pub stats: OpStats,
    /// Mapper search metadata.
    pub evaluated: usize,
}

/// Best-mapping cost of one op on one CANDIDATE unit — a cell of the
/// allocation search's cost matrix ([`BlackboxMapper::map_units`]).
#[derive(Debug, Clone)]
pub struct OpUnitCost {
    /// Stats for ONE repetition on that unit.
    pub stats: OpStats,
    /// Mapper search metadata (candidates evaluated).
    pub evaluated: usize,
}

/// Black-box mapper with a shape-level cache.
pub struct BlackboxMapper {
    pub budget: SearchBudget,
    pub threads: usize,
    /// Optional persistent `(shape, unit) → mapping` cache. When set,
    /// every unique-group search consults it first; a hit serves stats
    /// bitwise identical to the search that populated it (the cache is
    /// keyed and versioned so anything else is rejected at load).
    pub cache: Option<Arc<MapCache>>,
}

impl Default for BlackboxMapper {
    fn default() -> BlackboxMapper {
        BlackboxMapper { budget: SearchBudget::default(), threads: default_threads(), cache: None }
    }
}

impl BlackboxMapper {
    pub fn with_budget(budget: SearchBudget) -> BlackboxMapper {
        BlackboxMapper { budget, threads: default_threads(), cache: None }
    }

    /// One unique-group search, through the persistent cache when one
    /// is attached. Keyed by `(shape_fingerprint, spec_fingerprint)` —
    /// everything else that can move the result (samples, seed, model
    /// version) is pinned by the cache's header at load time.
    fn search_unit(&self, op: &TensorOp, spec: &ArchSpec) -> SearchResult {
        match &self.cache {
            Some(cache) => cache
                .get_or_compute(shape_fingerprint(op), spec_fingerprint(spec), || {
                    search_best_threaded(op, spec, &self.budget, self.threads).into()
                })
                .to_search_result(),
            None => search_best_threaded(op, spec, &self.budget, self.threads),
        }
    }

    /// Map every op of `cascade` onto its assigned sub-accelerator
    /// (`assignment[i]` = sub-accel id for op `i`).
    ///
    /// Identical (shape, sub-accel) pairs are searched once; distinct
    /// pairs run concurrently.
    pub fn map_cascade(
        &self,
        cascade: &Cascade,
        machine: &MachineConfig,
        assignment: &[usize],
    ) -> Vec<MappedOp> {
        assert_eq!(assignment.len(), cascade.ops.len());
        // Group ops by (fingerprint, sub-accel).
        let mut groups: HashMap<(u64, usize), Vec<usize>> = HashMap::new();
        let mut group_keys: Vec<(u64, usize)> = Vec::new();
        for (i, op) in cascade.ops.iter().enumerate() {
            let key = (shape_fingerprint(op), assignment[i]);
            groups
                .entry(key)
                .or_insert_with(|| {
                    group_keys.push(key);
                    Vec::new()
                })
                .push(i);
        }
        // One search per unique group, in parallel; each search fans its
        // own candidate batch out too — the shared pool budget keeps the
        // two levels from oversubscribing.
        let results: Vec<SearchResult> = parallel_map(group_keys.len(), self.threads, |g| {
            let (_, sub) = group_keys[g];
            let rep_op_idx = groups[&group_keys[g]][0];
            let op = &cascade.ops[rep_op_idx];
            let spec = &machine.sub_accels[sub].spec;
            self.search_unit(op, spec)
        });
        // Fan results back out to ops.
        let by_key: HashMap<(u64, usize), &SearchResult> =
            group_keys.iter().cloned().zip(results.iter()).collect();
        (0..cascade.ops.len())
            .map(|i| {
                let key = (shape_fingerprint(&cascade.ops[i]), assignment[i]);
                let r = by_key[&key];
                MappedOp {
                    op_index: i,
                    sub_accel: assignment[i],
                    stats: r.stats.clone(),
                    evaluated: r.evaluated,
                }
            })
            .collect()
    }

    /// Map every op of `cascade` on EVERY candidate unit in
    /// `units_per_op[i]` — the allocation search's cost matrix. Entry
    /// `[i][u]` is `Some` exactly when `u ∈ units_per_op[i]`.
    ///
    /// The search pipeline is [`map_cascade`](BlackboxMapper::map_cascade)'s:
    /// unique (shape fingerprint, unit) pairs are searched once each,
    /// concurrently on the shared pool, then scattered back — so a cell
    /// is bit-identical to what `map_cascade` would produce for an
    /// assignment placing that op on that unit, and the whole matrix is
    /// thread-count invariant.
    pub fn map_units(
        &self,
        cascade: &Cascade,
        machine: &MachineConfig,
        units_per_op: &[Vec<usize>],
    ) -> Vec<Vec<Option<OpUnitCost>>> {
        assert_eq!(units_per_op.len(), cascade.ops.len());
        let nsub = machine.sub_accels.len();
        let mut group_keys: Vec<(u64, usize)> = Vec::new();
        let mut group_rep: Vec<usize> = Vec::new(); // representative op per group
        let mut seen: HashMap<(u64, usize), usize> = HashMap::new();
        for (i, op) in cascade.ops.iter().enumerate() {
            let fp = shape_fingerprint(op);
            for &u in &units_per_op[i] {
                assert!(u < nsub, "op {i}: candidate unit {u} out of range");
                seen.entry((fp, u)).or_insert_with(|| {
                    group_keys.push((fp, u));
                    group_rep.push(i);
                    group_keys.len() - 1
                });
            }
        }
        let results: Vec<SearchResult> = parallel_map(group_keys.len(), self.threads, |g| {
            let (_, sub) = group_keys[g];
            let op = &cascade.ops[group_rep[g]];
            self.search_unit(op, &machine.sub_accels[sub].spec)
        });
        let mut out: Vec<Vec<Option<OpUnitCost>>> =
            (0..cascade.ops.len()).map(|_| vec![None; nsub]).collect();
        for (i, op) in cascade.ops.iter().enumerate() {
            let fp = shape_fingerprint(op);
            for &u in &units_per_op[i] {
                let r = &results[seen[&(fp, u)]];
                out[i][u] =
                    Some(OpUnitCost { stats: r.stats.clone(), evaluated: r.evaluated });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::partition::{HardwareParams, MachineConfig};
    use crate::arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
    use crate::workload::einsum::{Phase, TensorOp};

    fn machine() -> MachineConfig {
        MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap()
    }

    fn small_cascade() -> Cascade {
        let mut g = Cascade::new("t");
        g.push(TensorOp::gemm("a", Phase::Encoder, 64, 128, 64));
        g.push(TensorOp::gemm("b", Phase::Encoder, 64, 128, 64)); // same shape as a
        g.push(TensorOp::bmm("c", Phase::Encoder, 4, 64, 32, 64));
        g.dep(0, 2);
        g
    }

    #[test]
    fn maps_every_op() {
        let g = small_cascade();
        let m = machine();
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 60, seed: 1 });
        let mapped = mapper.map_cascade(&g, &m, &[0, 0, 1]);
        assert_eq!(mapped.len(), 3);
        assert_eq!(mapped[2].sub_accel, 1);
        assert!(mapped.iter().all(|m| m.stats.cycles > 0.0));
    }

    #[test]
    fn identical_shapes_share_search() {
        let g = small_cascade();
        let m = machine();
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 60, seed: 1 });
        let mapped = mapper.map_cascade(&g, &m, &[0, 0, 1]);
        // Ops 0 and 1 have identical shapes on the same sub-accel: the
        // cached search must give identical stats.
        assert_eq!(mapped[0].stats.cycles, mapped[1].stats.cycles);
        assert_eq!(mapped[0].stats.energy_pj, mapped[1].stats.energy_pj);
    }

    /// The cost matrix agrees cell-for-cell with what `map_cascade`
    /// produces when an assignment places the op on that unit — the
    /// contract the allocation search relies on so its searched
    /// makespan carries over to the final evaluation exactly.
    #[test]
    fn map_units_cells_match_map_cascade() {
        let g = small_cascade();
        let m = machine();
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 30, seed: 5 });
        let units: Vec<Vec<usize>> = vec![vec![0, 1]; g.ops.len()];
        let costs = mapper.map_units(&g, &m, &units);
        assert_eq!(costs.len(), g.ops.len());
        for u in [0usize, 1] {
            let assignment = vec![u; g.ops.len()];
            let mapped = mapper.map_cascade(&g, &m, &assignment);
            for (i, mo) in mapped.iter().enumerate() {
                let cell = costs[i][u].as_ref().expect("candidate unit populated");
                assert_eq!(cell.stats.cycles, mo.stats.cycles, "op {i} unit {u}");
                assert_eq!(cell.stats.energy_pj, mo.stats.energy_pj, "op {i} unit {u}");
                assert_eq!(cell.evaluated, mo.evaluated);
            }
        }
        // Units outside the candidate set stay empty.
        let partial = mapper.map_units(&g, &m, &vec![vec![1]; g.ops.len()]);
        assert!(partial.iter().all(|row| row[0].is_none() && row[1].is_some()));
    }

    #[test]
    fn different_sub_accels_search_separately() {
        // A compute-bound 512³ GEMM (AI ≈ 170): the high-reuse unit's 4×
        // compute roof beats the low-reuse unit despite its 3× bandwidth.
        let mut g = Cascade::new("t2");
        g.push(TensorOp::gemm("x", Phase::Encoder, 512, 512, 512));
        g.push(TensorOp::gemm("y", Phase::Encoder, 512, 512, 512));
        let m = machine();
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 60, seed: 1 });
        let mapped = mapper.map_cascade(&g, &m, &[0, 1]);
        assert!(mapped[0].stats.cycles < mapped[1].stats.cycles);
    }
}
