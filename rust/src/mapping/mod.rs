//! Mapping layer: loop-nest mappings of one tensor operation onto one
//! sub-accelerator, plus the structural validation rules (taxonomy
//! constraints, factor products, spatial limits).

pub mod loopnest;

pub use loopnest::{Mapping, MapError};
