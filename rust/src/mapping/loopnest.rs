//! Loop-nest mapping representation (Timeloop-style).
//!
//! A mapping assigns, for each storage level of a sub-accelerator, a
//! *temporal tiling factor* per einsum dimension plus a per-level loop
//! permutation, and two *spatial factors* (PE-array rows and columns).
//!
//! Level blocks are ordered innermost-first, matching
//! `ArchSpec::levels`: block 0 iterates scalars within the RF tile,
//! block `l` iterates level-`l-1` tiles within level `l`'s tile, and the
//! outermost (DRAM) block iterates LLB tiles over the full tensors. The
//! spatial fan-out sits between the RF and the first buffer level (the
//! array is fed by L1 — or by the LLB for near-LLB sub-accelerators).
//!
//! Cumulative extent of dimension `d` at level `l`:
//! `C(0,d) = t[0][d]`, and for `l ≥ 1`
//! `C(l,d) = t[0][d] · s(d) · Π_{1≤j≤l} t[j][d]`.

use crate::arch::spec::ArchSpec;
use crate::workload::einsum::{Dim, TensorOp};
use std::fmt;

/// A complete mapping of one op onto one sub-accelerator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    /// Temporal factors `t[level][dim]`, innermost (RF) first; one entry
    /// per storage level including DRAM. Indexed by `Dim::index()`.
    pub temporal: Vec<[u64; 4]>,
    /// Loop permutation per level block; `perms[l][0]` is the innermost
    /// loop of block `l`.
    pub perms: Vec<[Dim; 4]>,
    /// Spatial mapping across PE-array rows: (dimension, factor).
    pub spatial_row: (Dim, u64),
    /// Spatial mapping across PE-array columns: (dimension, factor).
    pub spatial_col: (Dim, u64),
}

/// Why a mapping is invalid for (op, spec).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MapError {
    LevelMismatch { got: usize, want: usize },
    DimUncovered { dim: &'static str, got: u64, want: u64 },
    SpatialOverflow { axis: &'static str, got: u64, limit: u64 },
    ForcedColDim { want: &'static str, got: &'static str },
    ForcedColFactor { want: u64, got: u64 },
    SpatialDimClash { dim: &'static str },
    CapacityExceeded { level: &'static str, tile: u64, cap: u64 },
    ZeroFactor,
}

impl fmt::Display for MapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MapError::LevelMismatch { got, want } => {
                write!(f, "mapping has {got} level blocks, spec has {want} levels")
            }
            MapError::DimUncovered { dim, got, want } => {
                write!(f, "dimension {dim} covers {got}, needs ≥ {want}")
            }
            MapError::SpatialOverflow { axis, got, limit } => {
                write!(f, "spatial {axis} factor {got} exceeds array {axis} count {limit}")
            }
            MapError::ForcedColDim { want, got } => {
                write!(f, "constraint: columns must parallelise {want}, mapping uses {got}")
            }
            MapError::ForcedColFactor { want, got } => {
                write!(f, "constraint: column factor must be {want}, mapping uses {got}")
            }
            MapError::SpatialDimClash { dim } => {
                write!(f, "row and column spatial dims must differ (both {dim})")
            }
            MapError::CapacityExceeded { level, tile, cap } => {
                write!(f, "level {level} tile of {tile} words exceeds capacity {cap}")
            }
            MapError::ZeroFactor => write!(f, "zero factor in mapping"),
        }
    }
}

impl std::error::Error for MapError {}

/// The canonical loop permutations the mapper samples from. Orders are
/// innermost-first. These cover the classic stationarities:
/// output-stationary (K inner), weight-stationary (M inner… weights held
/// while M streams), input-stationary (N inner), plus batch-rotated
/// variants for BMMs.
pub const CANON_PERMS: [[Dim; 4]; 6] = [
    [Dim::K, Dim::N, Dim::M, Dim::B], // output-stationary-ish
    [Dim::M, Dim::K, Dim::N, Dim::B], // weight-stationary-ish
    [Dim::N, Dim::K, Dim::M, Dim::B], // input-A-stationary-ish
    [Dim::K, Dim::M, Dim::N, Dim::B],
    [Dim::N, Dim::M, Dim::K, Dim::B],
    [Dim::M, Dim::N, Dim::B, Dim::K],
];

impl Mapping {
    /// The trivial mapping: everything in one DRAM-level loop, no tiling,
    /// 1×1 spatial. Valid for any op that fits a single PE's RF.
    pub fn trivial(levels: usize, op: &TensorOp) -> Mapping {
        let mut temporal = vec![[1u64; 4]; levels];
        let last = levels - 1;
        for d in Dim::ALL {
            temporal[last][d.index()] = op.dim(d);
        }
        Mapping {
            temporal,
            perms: vec![CANON_PERMS[0]; levels],
            spatial_row: (Dim::M, 1),
            spatial_col: (Dim::N, 1),
        }
    }

    /// Spatial factor applied to dimension `d`.
    pub fn spatial(&self, d: Dim) -> u64 {
        let mut f = 1;
        if self.spatial_row.0 == d {
            f *= self.spatial_row.1;
        }
        if self.spatial_col.0 == d {
            f *= self.spatial_col.1;
        }
        f
    }

    /// Cumulative extent of dim `d` at level `l` (see module docs).
    pub fn extent(&self, l: usize, d: Dim) -> u64 {
        let mut e = self.temporal[0][d.index()];
        if l >= 1 {
            e *= self.spatial(d);
            for block in &self.temporal[1..=l] {
                e *= block[d.index()];
            }
        }
        e
    }

    /// Padded full extent of dim `d` (product of every factor).
    pub fn padded_dim(&self, d: Dim) -> u64 {
        self.extent(self.temporal.len() - 1, d)
    }

    /// Total temporal iterations = padded MACs / active PEs.
    pub fn compute_cycles(&self) -> u64 {
        let mut cycles: u64 = 1;
        for block in &self.temporal {
            for f in block {
                cycles *= f;
            }
        }
        cycles
    }

    /// Number of active PEs.
    pub fn active_pes(&self) -> u64 {
        self.spatial_row.1 * self.spatial_col.1
    }

    /// Structural validation (capacity checks live in the nest analysis,
    /// which knows tile sizes).
    pub fn validate(&self, op: &TensorOp, spec: &ArchSpec) -> Result<(), MapError> {
        if self.temporal.len() != spec.levels.len() {
            return Err(MapError::LevelMismatch {
                got: self.temporal.len(),
                want: spec.levels.len(),
            });
        }
        for block in &self.temporal {
            if block.iter().any(|&f| f == 0) {
                return Err(MapError::ZeroFactor);
            }
        }
        if self.spatial_row.1 == 0 || self.spatial_col.1 == 0 {
            return Err(MapError::ZeroFactor);
        }
        for d in Dim::ALL {
            let got = self.padded_dim(d);
            let want = op.dim(d);
            if got < want {
                return Err(MapError::DimUncovered { dim: d.name(), got, want });
            }
        }
        if self.spatial_row.1 > spec.rows {
            return Err(MapError::SpatialOverflow {
                axis: "row",
                got: self.spatial_row.1,
                limit: spec.rows,
            });
        }
        if self.spatial_col.1 > spec.cols {
            return Err(MapError::SpatialOverflow {
                axis: "col",
                got: self.spatial_col.1,
                limit: spec.cols,
            });
        }
        if self.spatial_row.0 == self.spatial_col.0 && self.spatial_row.1 > 1 && self.spatial_col.1 > 1
        {
            return Err(MapError::SpatialDimClash { dim: self.spatial_row.0.name() });
        }
        // Taxonomy-derived constraints (paper §V-C).
        if let Some(want) = spec.constraints.forced_col_dim {
            if self.spatial_col.1 > 1 && self.spatial_col.0 != want {
                return Err(MapError::ForcedColDim {
                    want: want.name(),
                    got: self.spatial_col.0.name(),
                });
            }
        }
        if let Some(want) = spec.constraints.forced_col_factor {
            if self.spatial_col.1 != want {
                return Err(MapError::ForcedColFactor { want, got: self.spatial_col.1 });
            }
        }
        Ok(())
    }
}

impl fmt::Display for Mapping {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "spatial[{}:{} × {}:{}]",
            self.spatial_row.0.name(),
            self.spatial_row.1,
            self.spatial_col.0.name(),
            self.spatial_col.1
        )?;
        for (l, block) in self.temporal.iter().enumerate() {
            write!(
                f,
                " L{l}[B{} M{} N{} K{}]",
                block[0], block[1], block[2], block[3]
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::einsum::Phase;

    fn spec() -> ArchSpec {
        ArchSpec::leaf("t", 16, 16, 64, 16384, 1 << 20, 64.0, 32.0)
    }

    fn op() -> TensorOp {
        TensorOp::gemm("g", Phase::Encoder, 64, 128, 32)
    }

    #[test]
    fn trivial_mapping_validates() {
        let m = Mapping::trivial(4, &op());
        m.validate(&op(), &spec()).unwrap();
        assert_eq!(m.padded_dim(Dim::M), 64);
        assert_eq!(m.compute_cycles(), 64 * 128 * 32);
        assert_eq!(m.active_pes(), 1);
    }

    #[test]
    fn extent_composes_spatial_and_temporal() {
        let mut m = Mapping::trivial(4, &op());
        m.temporal[3] = [1, 16, 8, 32]; // B M N K at DRAM
        m.temporal[0] = [1, 2, 1, 4];
        m.spatial_row = (Dim::M, 2);
        m.spatial_col = (Dim::N, 4);
        assert_eq!(m.extent(0, Dim::M), 2);
        assert_eq!(m.extent(1, Dim::M), 2 * 2); // spatial joins at level 1
        assert_eq!(m.padded_dim(Dim::M), 2 * 2 * 16);
        assert_eq!(m.padded_dim(Dim::N), 4 * 8);
        assert_eq!(m.padded_dim(Dim::K), 4 * 32);
    }

    #[test]
    fn undersized_mapping_rejected() {
        let mut m = Mapping::trivial(4, &op());
        m.temporal[3][Dim::M.index()] = 2; // covers 2 < 64
        assert!(matches!(
            m.validate(&op(), &spec()),
            Err(MapError::DimUncovered { dim: "M", .. })
        ));
    }

    #[test]
    fn spatial_limits_enforced() {
        let mut m = Mapping::trivial(4, &op());
        m.spatial_row = (Dim::M, 32); // rows = 16
        assert!(matches!(
            m.validate(&op(), &spec()),
            Err(MapError::SpatialOverflow { axis: "row", .. })
        ));
    }

    #[test]
    fn forced_col_dim_enforced() {
        let mut s = spec();
        s.constraints.forced_col_dim = Some(Dim::N);
        let mut m = Mapping::trivial(4, &op());
        m.spatial_col = (Dim::K, 4);
        m.temporal[3][Dim::K.index()] = 32; // keep K = 4 × 32 = 128 covered
        assert!(matches!(m.validate(&op(), &s), Err(MapError::ForcedColDim { .. })));
        // A unit column factor is exempt (nothing is parallelised).
        m.spatial_col = (Dim::K, 1);
        m.temporal[3][Dim::K.index()] = 128;
        m.validate(&op(), &s).unwrap();
    }

    #[test]
    fn same_dim_both_axes_rejected() {
        let mut m = Mapping::trivial(4, &op());
        m.spatial_row = (Dim::M, 2);
        m.spatial_col = (Dim::M, 2);
        assert!(matches!(m.validate(&op(), &spec()), Err(MapError::SpatialDimClash { .. })));
    }
}
