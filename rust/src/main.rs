//! `harp` — CLI for the HARP evaluation framework.
//!
//! Subcommands:
//! - `taxonomy`                       print Table I (prior works classified)
//! - `classify <name>`                classify one prior work
//! - `topology <class|list> | --file F`  print/derive a machine memory tree
//! - `workload <name|list> | --file F`   print/validate a workload cascade
//! - `eval …`                         evaluate one (workload, machine) point
//! - `serve …`                        simulate serving an arrival stream (SLO metrics)
//! - `figures …`                      regenerate every paper figure
//! - `roofline`                       print the Fig 1 roofline split
//! - `sweep …`                        bandwidth/partition sweep for a workload
//! - `validate [--artifacts DIR]`     run the AOT artifacts through PJRT

use harp::arch::partition::{generate_topology, HardwareParams};
use harp::arch::taxonomy::{classify, HarpClass};
use harp::arch::topology::MachineTopology;
use harp::coordinator::config::ExperimentConfig;
use harp::coordinator::experiment::{
    evaluate_cascade_on_config, evaluate_cascade_on_machine, EvalOptions,
};
use harp::coordinator::figures;
use harp::runtime::validate::{render_reports, validate_all};
use harp::util::binio::CacheFormat;
use harp::util::cli::{ArgSpec, Args};
use harp::util::json::{Json, JsonStreamWriter, JsonStyle};
use harp::util::table::Table;
use harp::util::threadpool;
use harp::workload::registry::{self, WorkloadSource};
use std::io::Write;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let rest = &argv[1..];
    let result = match cmd.as_str() {
        "taxonomy" => cmd_taxonomy(),
        "classify" => cmd_classify(rest),
        "topology" => cmd_topology(rest),
        "workload" => cmd_workload(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "figures" => cmd_figures(rest),
        "roofline" => cmd_roofline(),
        "sweep" => cmd_sweep(rest),
        "validate" => cmd_validate(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(msg) => {
            eprintln!("{msg}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "harp — taxonomy + evaluation framework for heterogeneous/hierarchical processors\n\
     \n\
     USAGE: harp <command> [options]\n\
     \n\
     COMMANDS:\n\
       taxonomy                 print Table I (existing works classified)\n\
       classify <name>          classify a prior work (e.g. 'neupim')\n\
       topology <class|list>    print the generated memory tree for a taxonomy point\n\
                                (or --file F to classify a machine-tree JSON)\n\
       workload <name|list>     print a registered workload cascade\n\
                                (or --file F to validate + print a cascade JSON)\n\
       eval [--config F | --workload W|FILE (--machine M | --topology F)] [--bw BITS]\n\
                                [--samples N] [--threads N] [--contention off|on]\n\
                                [--alloc greedy|round_robin|critical_path|search]\n\
                                [--mapping-cache FILE] [--cache-format json|binary]\n\
                                (--model NAME is the explicit built-in form of --workload)\n\
       serve [--config F | --workload-mix M] [--arrivals poisson|bursty|trace]\n\
                                [--load R] [--requests N] [--seed S] [--machine M]\n\
                                [--slo-ttft CYCLES] [--trace FILE] [--json]\n\
                                [--disagg prefill=ROLE,decode=ROLE] [--placement P]\n\
                                continuous-batching serving simulator: seeded request\n\
                                streams, admission/eviction under booked KV capacity,\n\
                                p50/p99 TTFT + goodput (NDJSON records with --json)\n\
       figures [--samples N] [--threads N] [--cache FILE] [--alloc POLICY]\n\
                                [--mapping-cache FILE] [--cache-format json|binary]\n\
                                regenerate Figs 1,6,7,8,9,10 + Tables I-III\n\
                                + the allocation-policy ablation\n\
       roofline                 print the Fig 1 roofline partitioning\n\
       sweep --workload W [--json]  DRAM bandwidth × machine sweep (NDJSON with --json)\n\
       validate [--artifacts D] execute AOT artifacts through PJRT + check numerics"
        .to_string()
}

fn cmd_taxonomy() -> Result<(), String> {
    println!("{}", figures::table1());
    Ok(())
}

fn cmd_classify(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("harp classify", "classify a prior work").pos(
        "name",
        true,
        "work name (substring match)",
    );
    let args = spec.parse(argv).map_err(|e| e.to_string())?;
    let name = args.positional(0).unwrap();
    match classify(name) {
        Some(w) => {
            println!("{}: {} — {}", w.name, w.class, w.remark);
            Ok(())
        }
        None => Err(format!("no prior work matching '{name}' (try 'harp taxonomy')")),
    }
}

fn cmd_topology(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new(
        "harp topology",
        "print the memory tree for a taxonomy point, or classify a machine-tree file",
    )
    .pos("class", false, "taxonomy id (e.g. hier+xdepth), or 'list' for every point")
    .opt("file", None, "describe + classify a machine-tree JSON file instead")
    .opt("bw", Some("2048"), "DRAM bandwidth in bits/cycle for the generated tree")
    .flag("json", "emit the machine-tree JSON instead of the ASCII rendering");
    let args = spec.parse(argv).map_err(|e| e.to_string())?;

    if let Some(path) = args.get("file") {
        // The file fixes the bandwidth; an explicit --bw would be dead.
        if argv.iter().any(|a| a == "--bw" || a.starts_with("--bw=")) {
            return Err("--file supplies the machine's bandwidth; drop --bw".into());
        }
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        let topo = MachineTopology::from_json(&doc).map_err(|e| format!("{path}: {e}"))?;
        if args.has_flag("json") {
            println!("{}", topo.to_json().to_string_pretty());
            return Ok(());
        }
        println!("{}", topo.describe());
        println!("classified: {}", topo.classify()?);
        return Ok(());
    }

    let id = args
        .positional(0)
        .ok_or("need a taxonomy id or --file FILE (try 'harp topology list')")?;
    if id == "list" {
        println!("every generatable taxonomy point (id → description):");
        for c in HarpClass::all_points() {
            println!("  {:<34} {}", c.id(), c);
        }
        return Ok(());
    }
    let class = HarpClass::from_id(id).ok_or_else(|| {
        format!("unknown taxonomy id '{id}' (try 'harp topology list')")
    })?;
    let params = HardwareParams {
        dram_bw_bits: args.get_f64("bw").map_err(|e| e.to_string())?,
        ..HardwareParams::default()
    };
    let topo = generate_topology(&class, &params)?;
    if args.has_flag("json") {
        println!("{}", topo.to_json().to_string_pretty());
        return Ok(());
    }
    println!("{}", topo.describe());
    let back = topo.classify()?;
    println!(
        "classified: {back}  [{}]",
        if back == class { "round-trip ok" } else { "ROUND-TRIP MISMATCH" }
    );
    Ok(())
}

fn cmd_workload(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new(
        "harp workload",
        "print a registered workload cascade, or validate + print a cascade JSON file",
    )
    .pos("name", false, "registered workload name (or 'list' for every built-in)")
    .opt("file", None, "cascade JSON file to load instead of a registered name")
    .flag("json", "emit the workload JSON schema instead of the description");
    let args = spec.parse(argv).map_err(|e| e.to_string())?;

    let wl = if let Some(path) = args.get("file") {
        if args.positional(0).is_some() {
            return Err("give a workload name or --file FILE, not both".into());
        }
        registry::load_file(path)?
    } else {
        let name = args
            .positional(0)
            .ok_or("need a workload name or --file FILE (try 'harp workload list')")?;
        if name == "list" {
            println!("registered workloads (pass the name to eval/sweep --workload):");
            println!("{}", figures::workload_table());
            println!(
                "or load a cascade file: harp workload --file examples/workloads/moe_decode.json"
            );
            return Ok(());
        }
        registry::by_name(name).ok_or_else(|| {
            format!(
                "unknown workload '{name}' (try 'harp workload list', or --file for a \
                 cascade JSON)"
            )
        })?
    };
    if args.has_flag("json") {
        println!("{}", wl.to_json().to_string_pretty());
    } else {
        println!("{}", wl.cascade().describe());
    }
    Ok(())
}

/// Parse an optional `--threads N`, apply it to the global pool budget,
/// and return it (so per-eval options can pick it up too).
fn apply_threads(args: &Args) -> Result<Option<usize>, String> {
    if args.get("threads").is_none() {
        return Ok(None);
    }
    let n = args.get_usize("threads").map_err(|e| e.to_string())?.max(1);
    threadpool::set_global_threads(n);
    Ok(Some(n))
}

fn parse_eval_opts(argv: &[String]) -> Result<(ExperimentConfig, bool), String> {
    let spec = ArgSpec::new("harp eval", "evaluate one (workload, machine) point")
        .opt("config", None, "JSON experiment config path")
        .opt(
            "workload",
            None,
            "registered workload name (see 'harp workload list') or a cascade .json file",
        )
        .opt(
            "model",
            None,
            "registered workload name only — the explicit built-in form of --workload \
             (giving both is an error)",
        )
        .opt(
            "machine",
            Some("leaf+homo"),
            "taxonomy id (leaf+homo|leaf+xnode|leaf+intra|hier+xdepth|hier+homo|hier+xnode|hier+xnode-cl|hier+intra|hier+compound)",
        )
        .opt(
            "topology",
            None,
            "machine-tree JSON file (replaces --machine; hardware comes from the file, so --bw/--bw-frac-low do not apply)",
        )
        .opt("bw", Some("2048"), "DRAM bandwidth in bits/cycle")
        .opt("bw-frac-low", None, "fraction of DRAM bandwidth to the low-reuse side")
        .opt("samples", Some("400"), "mapper samples per unique shape")
        .opt("threads", None, "worker threads (default: HARP_THREADS or core count)")
        .opt(
            "contention",
            Some("off"),
            "shared-node contention: off (double-book shared nodes, historical) | on \
             (book capacity slices + arbitrate shared edges)",
        )
        .opt(
            "alloc",
            Some("greedy"),
            "op → sub-accelerator allocation policy: greedy (paper heuristic) | \
             round_robin | critical_path | search (schedule-aware local search)",
        )
        .opt(
            "mapping-cache",
            None,
            "persistent (shape, unit) → mapping cache file, reused across runs \
             (created when missing; version or search-budget mismatches are rejected loudly)",
        )
        .opt(
            "cache-format",
            None,
            "on-disk format for the --mapping-cache spill: json (debug/interchange) | \
             binary (fast path); defaults to the file extension (.bin/.harpbin → binary)",
        )
        .flag("dynamic-bw", "re-grant idle units' bandwidth (ablation)")
        .flag("json", "emit machine-readable JSON");
    let args = spec.parse(argv).map_err(|e| e.to_string())?;
    let json = args.has_flag("json");
    let threads = apply_threads(&args)?;
    if let Some(path) = args.get("config") {
        // --contention has a default, so detect explicit use in raw
        // argv: silently ignoring it in favour of the config's value
        // would report the wrong model's numbers.
        if argv.iter().any(|a| a == "--contention" || a.starts_with("--contention=")) {
            return Err(
                "--config supplies the evaluation options; set \"contention\" in the \
                 config file instead of passing --contention"
                    .into(),
            );
        }
        // --alloc follows --contention's rule: it has a default, so
        // explicit use alongside --config must be a loud error, not a
        // silently ignored knob.
        if argv.iter().any(|a| a == "--alloc" || a.starts_with("--alloc=")) {
            return Err(
                "--config supplies the evaluation options; set \"alloc\" in the \
                 config file instead of passing --alloc"
                    .into(),
            );
        }
        // And the mapping cache: the config's "mapping_cache" key wins,
        // so the flag alongside --config must error, not shadow it.
        if argv.iter().any(|a| a == "--mapping-cache" || a.starts_with("--mapping-cache=")) {
            return Err(
                "--config supplies the evaluation options; set \"mapping_cache\" in \
                 the config file instead of passing --mapping-cache"
                    .into(),
            );
        }
        // Its format knob follows the same rule.
        if argv.iter().any(|a| a == "--cache-format" || a.starts_with("--cache-format=")) {
            return Err(
                "--config supplies the evaluation options; set \"cache_format\" in \
                 the config file instead of passing --cache-format"
                    .into(),
            );
        }
        // Same for the workload selectors: the config's "workload" key
        // wins, so a CLI selector alongside it must error loudly.
        for flag in ["--workload", "--model"] {
            if argv.iter().any(|a| a == flag || a.starts_with(&format!("{flag}="))) {
                return Err(format!(
                    "--config supplies the workload; set \"workload\" in the config \
                     file instead of passing {flag}"
                ));
            }
        }
        let mut cfg = ExperimentConfig::load(path)?;
        if cfg.arrivals.is_some() {
            return Err(
                "'arrivals' only applies to 'harp serve' — run 'harp serve --config' \
                 with this file, or drop the key for a static evaluation"
                    .into(),
            );
        }
        if let Some(n) = threads {
            cfg.opts.threads = n;
        }
        return Ok((cfg, json));
    }
    let workload = match (args.get("workload"), args.get("model")) {
        (Some(_), Some(_)) => {
            return Err(
                "give --workload OR --model, not both: they both select the workload \
                 (--model is the explicit built-in form; --workload also accepts a \
                 cascade .json file)"
                    .into(),
            )
        }
        (Some(w), None) => registry::resolve(w)?,
        (None, Some(m)) => registry::resolve_builtin(m)?,
        (None, None) => return Err("need --workload (or --model / --config)".into()),
    };
    let topology = args.get("topology").map(String::from);
    if topology.is_some() {
        // The tree fixes the machine and its hardware; refuse knobs that
        // would silently do nothing (--bw and --machine have defaults,
        // so detect explicit use in raw argv).
        let given =
            |flag: &str| argv.iter().any(|a| a == flag || a.starts_with(&format!("{flag}=")));
        if given("--bw") || given("--machine") || args.get("bw-frac-low").is_some() {
            return Err(
                "--topology supplies the machine and its bandwidth partitioning; \
                 drop --machine / --bw / --bw-frac-low (edit the topology file instead)"
                    .into(),
            );
        }
    }
    let machine_id = args.get("machine").unwrap();
    let class = if topology.is_some() {
        None
    } else {
        Some(
            HarpClass::from_id(machine_id)
                .ok_or_else(|| format!("unknown machine id '{machine_id}'"))?,
        )
    };
    let params = HardwareParams {
        dram_bw_bits: args.get_f64("bw").map_err(|e| e.to_string())?,
        ..HardwareParams::default()
    };
    let mut opts = EvalOptions {
        samples: args.get_usize("samples").map_err(|e| e.to_string())?,
        ..EvalOptions::default()
    };
    opts.dynamic_bw = args.has_flag("dynamic-bw");
    opts.contention =
        harp::arch::topology::ContentionMode::parse(args.get("contention").unwrap())?;
    opts.alloc = harp::hhp::allocator::AllocPolicy::parse(args.get("alloc").unwrap())?;
    if let Some(n) = threads {
        opts.threads = n;
    }
    if args.get("bw-frac-low").is_some() {
        opts.bw_frac_low = Some(args.get_f64("bw-frac-low").map_err(|e| e.to_string())?);
    }
    let mapping_cache = args.get("mapping-cache").map(String::from);
    let cache_format = match args.get("cache-format") {
        Some(s) => {
            if mapping_cache.is_none() {
                return Err("--cache-format does nothing without --mapping-cache".into());
            }
            Some(CacheFormat::parse(s)?)
        }
        None => None,
    };
    Ok((
        ExperimentConfig {
            workload: WorkloadSource::Spec(workload),
            class,
            params,
            opts,
            topology,
            mapping_cache,
            cache_format,
            arrivals: None,
        },
        json,
    ))
}

fn cmd_eval(argv: &[String]) -> Result<(), String> {
    let (mut cfg, json) = parse_eval_opts(argv)?;
    if let Some(path) = cfg.mapping_cache.clone() {
        let fmt = CacheFormat::resolve(Path::new(&path), cfg.cache_format)?;
        cfg.opts.attach_mapping_cache_format(Path::new(&path), fmt)?;
        let loaded = cfg.opts.map_cache.as_ref().map_or(0, |mc| mc.len());
        // The banner would corrupt --json output, so it stays off there
        // (warm and cold runs then emit byte-identical JSON).
        if loaded > 0 && !json {
            println!("[mapping cache: {loaded} mapping(s) loaded from {path}]");
        }
    }
    let cascade = cfg.workload.load()?.cascade();
    let machine = cfg.build_machine(&cascade)?;
    let r = evaluate_cascade_on_machine(&machine, &cascade, &cfg.opts)?;
    if let Some(mc) = &cfg.opts.map_cache {
        if let Err(e) = mc.persist() {
            eprintln!("warn: could not persist mapping cache: {e}");
        }
    }
    if json {
        // Streamed straight to stdout — byte-identical to the old
        // `println!("{}", to_json().to_string_pretty())` path without
        // building the document tree or its String.
        let stdout = std::io::stdout();
        let mut w = JsonStreamWriter::new(stdout.lock(), JsonStyle::Pretty);
        let io_err = |e: std::io::Error| format!("stdout: {e}");
        r.stats.write_json(&mut w).map_err(io_err)?;
        let mut out = w.finish().map_err(io_err)?;
        writeln!(out).map_err(io_err)?;
        return Ok(());
    }
    if cfg.topology.is_some() {
        println!("{}", r.machine.topology.describe());
    }
    println!("{}", r.machine.describe());
    println!("{}", cascade.describe());
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["alloc policy".into(), r.stats.alloc_policy.to_string()]);
    // Per-op assignment, compact: ops grouped by their unit.
    for (s, sub) in r.machine.sub_accels.iter().enumerate() {
        let ops: Vec<&str> = r
            .assignment
            .iter()
            .enumerate()
            .filter(|&(_, &u)| u == s)
            .map(|(i, _)| cascade.ops[i].name.as_str())
            .collect();
        if !ops.is_empty() {
            t.row(&[
                format!("ops on [{} {}]", sub.spec.name, sub.role.name()),
                format!("{} op(s): {}", ops.len(), truncate_list(&ops, 72)),
            ]);
        }
    }
    t.row(&["latency (cycles)".into(), format!("{:.3e}", r.stats.latency_cycles)]);
    t.row(&["energy (µJ)".into(), format!("{:.3}", r.stats.energy_pj * 1e-6)]);
    t.row(&["mults/joule".into(), format!("{:.3e}", r.stats.mults_per_joule())]);
    for (i, b) in r.stats.busy_fraction.iter().enumerate() {
        let sub = &r.machine.sub_accels[i];
        t.row(&[
            format!("busy[{} {}]", sub.spec.name, sub.role.name()),
            format!("{:.1}%", b * 100.0),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Join names with commas, cutting off (with an ellipsis) once the
/// rendered list would exceed `max` characters.
fn truncate_list(names: &[&str], max: usize) -> String {
    let mut out = String::new();
    for (i, n) in names.iter().enumerate() {
        let sep = if i == 0 { "" } else { ", " };
        if out.len() + sep.len() + n.len() > max {
            out.push_str(if i == 0 { "…" } else { ", …" });
            break;
        }
        out.push_str(sep);
        out.push_str(n);
    }
    out
}

fn cmd_serve(argv: &[String]) -> Result<(), String> {
    use harp::coordinator::config::ArrivalsConfig;
    use harp::runtime::serve;
    use harp::workload::arrivals::{self, ArrivalKind, RequestFamily, StreamParams};

    let spec = ArgSpec::new(
        "harp serve",
        "simulate serving a request arrival stream with continuous batching",
    )
    .opt("config", None, "JSON experiment config with an \"arrivals\" object")
    .opt(
        "workload-mix",
        Some("llama2"),
        "request family mix: NAME or NAME:W,NAME:W (families: llama2 | gqa | moe)",
    )
    .opt(
        "class-mix",
        Some("interactive"),
        "latency-class mix: NAME or NAME:W,NAME:W (classes: interactive | batch)",
    )
    .opt("arrivals", Some("poisson"), "arrival process: poisson | bursty | trace")
    .opt("load", Some("2"), "offered load in requests per million cycles")
    .opt("requests", Some("64"), "stream length in requests")
    .opt("seed", Some("7"), "stream PRNG seed")
    .opt(
        "machine",
        Some("hier+xnode"),
        "taxonomy id of the serving machine (see 'harp topology list')",
    )
    .opt("bw", Some("2048"), "DRAM bandwidth in bits/cycle")
    .opt("samples", Some("60"), "mapper samples per probe shape (cost calibration)")
    .opt("threads", None, "worker threads for calibration (default: HARP_THREADS or core count)")
    .opt("contention", Some("off"), "shared-node contention model (off | on)")
    .opt(
        "slo-ttft",
        Some("2000000"),
        "TTFT SLO in cycles; goodput counts completions under it",
    )
    .opt(
        "slo-ttft-batch",
        None,
        "TTFT SLO in cycles for batch-class requests (default: --slo-ttft)",
    )
    .opt(
        "kv-page-words",
        Some("0"),
        "KV booking page size in words (0 = whole-request booking)",
    )
    .opt(
        "placement",
        Some("round_robin"),
        "unit placement for serve steps: round_robin | pressure | pressure_search",
    )
    .opt(
        "disagg",
        None,
        "disaggregate prefill/decode pools by reuse role, e.g. \
         prefill=high,decode=low (needs a machine with >= 2 unit types)",
    )
    .opt("trace", None, "arrival trace JSON file (with --arrivals trace only)")
    .flag(
        "json",
        "stream one compact JSON object per completed request (NDJSON), then a summary \
         object, instead of the text report",
    );
    let args = spec.parse(argv).map_err(|e| e.to_string())?;
    let json = args.has_flag("json");
    let threads = apply_threads(&args)?;
    let given =
        |flag: &str| argv.iter().any(|a| a == flag || a.starts_with(&format!("{flag}=")));

    let (arr, class, bw, opts) = if let Some(path) = args.get("config") {
        // Every stream/machine knob has a default, so explicit use
        // alongside --config must be a loud error (the config's
        // "arrivals" object wins), mirroring eval's --config rule.
        for flag in [
            "--workload-mix",
            "--class-mix",
            "--arrivals",
            "--load",
            "--requests",
            "--seed",
            "--machine",
            "--bw",
            "--samples",
            "--contention",
            "--slo-ttft",
            "--slo-ttft-batch",
            "--kv-page-words",
            "--placement",
            "--disagg",
            "--trace",
        ] {
            if given(flag) {
                return Err(format!(
                    "--config supplies the serving options; set \"arrivals\" keys in the \
                     config file instead of passing {flag}"
                ));
            }
        }
        let cfg = ExperimentConfig::load(path)?;
        let Some(arr) = cfg.arrivals else {
            return Err(format!(
                "{path}: serving needs an \"arrivals\" object \
                 (process / mix / class_mix / load / requests / seed / slo_ttft / \
                 slo_ttft_batch / kv_page_words / placement / disagg / trace)"
            ));
        };
        if cfg.topology.is_some() {
            return Err(
                "serve generates its machine from the taxonomy point; drop 'topology' \
                 and set \"machine\" instead"
                    .into(),
            );
        }
        let class = cfg.class.expect("config parse guarantees machine or topology");
        let mut opts = cfg.opts;
        if let Some(n) = threads {
            opts.threads = n;
        }
        (arr, class, cfg.params.dram_bw_bits, opts)
    } else {
        let process = ArrivalKind::parse(args.get("arrivals").unwrap())?;
        let trace = args.get("trace").map(String::from);
        if process == ArrivalKind::Trace {
            // The trace fixes the stream (including per-request
            // classes); the generator knobs (all with defaults) would
            // be dead, so explicit use is an error.
            for flag in ["--workload-mix", "--class-mix", "--load", "--requests", "--seed"] {
                if given(flag) {
                    return Err(format!(
                        "{flag} does not apply with --arrivals trace (the trace file \
                         fixes the stream)"
                    ));
                }
            }
            if trace.is_none() {
                return Err("--arrivals trace requires --trace FILE".into());
            }
        } else if trace.is_some() {
            return Err("--trace does nothing without --arrivals trace".into());
        }
        let mix = arrivals::parse_mix(args.get("workload-mix").unwrap())?;
        let class_mix = arrivals::parse_class_mix(args.get("class-mix").unwrap())?;
        let load = args.get_f64("load").map_err(|e| e.to_string())?;
        let requests = args.get_usize("requests").map_err(|e| e.to_string())?;
        let seed_raw = args.get("seed").unwrap();
        let seed: u64 = seed_raw
            .parse()
            .map_err(|_| format!("--seed: expected a non-negative integer, got '{seed_raw}'"))?;
        let slo_ttft = args.get_f64("slo-ttft").map_err(|e| e.to_string())?;
        if !slo_ttft.is_finite() || slo_ttft <= 0.0 {
            return Err("--slo-ttft must be finite and positive".into());
        }
        let slo_ttft_batch = if given("--slo-ttft-batch") {
            let v = args.get_f64("slo-ttft-batch").map_err(|e| e.to_string())?;
            if !v.is_finite() || v <= 0.0 {
                return Err("--slo-ttft-batch must be finite and positive".into());
            }
            Some(v)
        } else {
            None
        };
        let kv_page_words = args.get_usize("kv-page-words").map_err(|e| e.to_string())? as u64;
        let placement = serve::PlacementPolicy::parse(args.get("placement").unwrap())?;
        let disagg = match args.get("disagg") {
            Some(s) => Some(serve::DisaggConfig::parse(s)?),
            None => None,
        };
        let machine_id = args.get("machine").unwrap();
        let class = HarpClass::from_id(machine_id)
            .ok_or_else(|| format!("unknown machine id '{machine_id}'"))?;
        let mut opts = EvalOptions {
            samples: args.get_usize("samples").map_err(|e| e.to_string())?,
            ..EvalOptions::default()
        };
        opts.contention =
            harp::arch::topology::ContentionMode::parse(args.get("contention").unwrap())?;
        if let Some(n) = threads {
            opts.threads = n;
        }
        let arr = ArrivalsConfig {
            process,
            mix,
            class_mix,
            load,
            requests,
            seed,
            slo_ttft,
            slo_ttft_batch,
            kv_page_words,
            placement,
            disagg,
            trace,
        };
        (arr, class, args.get_f64("bw").map_err(|e| e.to_string())?, opts)
    };

    let stream = if arr.process == ArrivalKind::Trace {
        let path = arr.trace.as_deref().expect("trace presence checked above");
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        arrivals::load_trace(&text).map_err(|e| format!("{path}: {e}"))?
    } else {
        arrivals::synthesize(&StreamParams {
            kind: arr.process,
            mix: arr.mix.clone(),
            classes: arr.class_mix.clone(),
            load: arr.load,
            requests: arr.requests,
            seed: arr.seed,
        })?
    };
    // Offered load: the generator's own rate for synthetic streams;
    // back-derived from the trace span otherwise.
    let offered_load = if arr.process == ArrivalKind::Trace {
        let span = stream.last().map(|r| r.arrival).unwrap_or(0.0).max(1.0);
        stream.len() as f64 * 1.0e6 / span
    } else {
        arr.load
    };
    // Calibrate exactly the families present in the stream.
    let mut families: Vec<RequestFamily> = stream.iter().map(|r| r.family).collect();
    families.sort();
    families.dedup();

    let dynamic_bw = opts.dynamic_bw;
    let contention = opts.contention;
    let ev = figures::Evaluator::new(opts);
    let costs = serve::calibrate(&ev, &class, bw, &families);
    let machine = serve::build_serving_machine(&class, bw, contention)?;
    let scfg = serve::ServeConfig {
        slo_ttft: arr.slo_ttft,
        slo_ttft_batch: arr.slo_ttft_batch,
        kv_page_words: arr.kv_page_words,
        placement: arr.placement,
        disagg: arr.disagg,
        ..serve::ServeConfig::default()
    };
    let result = serve::simulate(&stream, &machine, &costs, dynamic_bw, offered_load, &scfg)?;

    if json {
        serve_json(&result).map_err(|e| format!("stdout: {e}"))?;
    } else {
        println!("machine: {}  (bw {bw} bits/cycle)", class.id());
        print!("{}", result.report.render());
    }
    Ok(())
}

/// NDJSON serve output: one compact object per completed request (in
/// completion order), then one summary object — streamed, like
/// `sweep --json`.
fn serve_json(result: &harp::runtime::serve::ServeResult) -> std::io::Result<()> {
    let stdout = std::io::stdout();
    let mut lock = stdout.lock();
    for r in &result.records {
        // One writer per line: a writer owns exactly one root value.
        let mut w = JsonStreamWriter::new(&mut lock, JsonStyle::Compact);
        w.begin_obj()?;
        w.key("id")?;
        w.num(r.id as f64)?;
        w.key("family")?;
        w.str(r.family.name())?;
        w.key("arrival")?;
        w.num(r.arrival)?;
        w.key("context")?;
        w.num(r.context as f64)?;
        w.key("output")?;
        w.num(r.output as f64)?;
        w.key("admitted")?;
        w.num(r.admitted)?;
        w.key("ttft")?;
        w.num(r.ttft())?;
        w.key("per_token")?;
        w.num(r.per_token())?;
        w.key("completed")?;
        w.num(r.completed)?;
        w.key("evictions")?;
        w.num(r.evictions as f64)?;
        // New keys ride behind their knobs so default output stays
        // byte-identical: "class" appears only for classed streams,
        // "pages" only under paged booking.
        if !result.report.class_breakdown.is_empty() {
            w.key("class")?;
            w.str(r.class.name())?;
        }
        if result.report.kv_page_words > 0 {
            w.key("pages")?;
            w.num(r.peak_pages as f64)?;
        }
        w.end_obj()?;
        let mut out = w.finish()?;
        writeln!(out)?;
    }
    let rep = &result.report;
    let mut w = JsonStreamWriter::new(&mut lock, JsonStyle::Compact);
    w.begin_obj()?;
    w.key("summary")?;
    w.begin_obj()?;
    w.key("offered_load")?;
    w.num(rep.offered_load)?;
    w.key("requests")?;
    w.num(rep.requests as f64)?;
    w.key("completed")?;
    w.num(rep.completed as f64)?;
    w.key("rejected")?;
    w.num(rep.rejected as f64)?;
    w.key("evictions")?;
    w.num(rep.evictions as f64)?;
    w.key("span_cycles")?;
    w.num(rep.span_cycles)?;
    w.key("p50_ttft")?;
    w.num(rep.p50_ttft)?;
    w.key("p99_ttft")?;
    w.num(rep.p99_ttft)?;
    w.key("mean_per_token")?;
    w.num(rep.mean_per_token)?;
    w.key("throughput")?;
    w.num(rep.throughput)?;
    w.key("goodput")?;
    w.num(rep.goodput)?;
    w.key("slo_ttft")?;
    w.num(rep.slo_ttft)?;
    w.key("kv_capacity_words")?;
    w.num(rep.kv_capacity_words)?;
    if rep.kv_page_words > 0 {
        w.key("kv_page_words")?;
        w.num(rep.kv_page_words as f64)?;
        w.key("reprefill_tokens")?;
        w.num(rep.reprefill_tokens as f64)?;
    }
    // Disagg keys ride behind their knob like the page keys above, so
    // co-located NDJSON output stays byte-identical.
    if let Some(d) = &rep.disagg {
        w.key("disagg")?;
        w.str(d)?;
        w.key("kv_transfers")?;
        w.num(rep.kv_transfers as f64)?;
        w.key("kv_transfer_words")?;
        w.num(rep.kv_transfer_words as f64)?;
    }
    if !rep.class_breakdown.is_empty() {
        w.key("classes")?;
        w.begin_obj()?;
        for c in &rep.class_breakdown {
            w.key(c.class.name())?;
            w.begin_obj()?;
            w.key("requests")?;
            w.num(c.requests as f64)?;
            w.key("completed")?;
            w.num(c.completed as f64)?;
            w.key("p50_ttft")?;
            w.num(c.p50_ttft)?;
            w.key("p99_ttft")?;
            w.num(c.p99_ttft)?;
            w.key("goodput")?;
            w.num(c.goodput)?;
            w.key("slo_ttft")?;
            w.num(c.slo_ttft)?;
            w.end_obj()?;
        }
        w.end_obj()?;
    }
    w.end_obj()?;
    w.end_obj()?;
    let mut out = w.finish()?;
    writeln!(out)
}

fn cmd_figures(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("harp figures", "regenerate the paper figures")
        .opt("samples", Some("400"), "mapper samples per unique shape")
        .opt("threads", None, "worker threads for the sweep (default: HARP_THREADS or core count)")
        .opt("cache", None, "JSON evaluation-cache file, reused across runs")
        .opt(
            "contention",
            Some("off"),
            "shared-node contention model (off reproduces the paper figures)",
        )
        .opt(
            "alloc",
            Some("greedy"),
            "allocation policy for the paper-figure drivers (greedy reproduces the \
             paper; the ablation figure always sweeps every policy)",
        )
        .opt(
            "mapping-cache",
            None,
            "persistent (shape, unit) → mapping cache file — a finer-grained \
             layer than --cache that stays valid across workload/machine changes",
        )
        .opt(
            "cache-format",
            None,
            "on-disk format for the --cache/--mapping-cache spills: json \
             (debug/interchange) | binary (fast path); defaults to each file's \
             extension (.bin/.harpbin → binary)",
        );
    let args = spec.parse(argv).map_err(|e| e.to_string())?;
    let cache_fmt = match args.get("cache-format") {
        Some(s) => {
            if args.get("cache").is_none() && args.get("mapping-cache").is_none() {
                return Err(
                    "--cache-format does nothing without --cache or --mapping-cache".into(),
                );
            }
            Some(CacheFormat::parse(s)?)
        }
        None => None,
    };
    let mut opts = EvalOptions {
        samples: args.get_usize("samples").map_err(|e| e.to_string())?,
        ..EvalOptions::default()
    };
    opts.contention =
        harp::arch::topology::ContentionMode::parse(args.get("contention").unwrap())?;
    opts.alloc = harp::hhp::allocator::AllocPolicy::parse(args.get("alloc").unwrap())?;
    if let Some(n) = apply_threads(&args)? {
        opts.threads = n;
    }
    if let Some(path) = args.get("mapping-cache") {
        let fmt = CacheFormat::resolve(Path::new(path), cache_fmt)?;
        opts.attach_mapping_cache_format(Path::new(path), fmt)?;
        let loaded = opts.map_cache.as_ref().map_or(0, |mc| mc.len());
        if loaded > 0 {
            println!("[mapping cache: {loaded} mapping(s) loaded from {path}]");
        }
    }
    let ev = match args.get("cache") {
        Some(path) => {
            let fmt = CacheFormat::resolve(Path::new(path), cache_fmt)?;
            let ev = figures::Evaluator::with_spill(opts, Path::new(path), fmt)
                .map_err(|e| e.to_string())?;
            if !ev.is_empty() {
                println!("[evaluation cache: {} point(s) loaded from {path}]", ev.len());
            }
            ev
        }
        None => figures::Evaluator::new(opts),
    };
    println!("{}", figures::table2_table3());
    println!("{}", figures::table1());
    figures::fig1_roofline().emit("fig1_roofline");
    let (f6, zoom) = figures::fig6_speedup(&ev);
    f6.emit("fig6_speedup");
    zoom.emit("fig6_zoom_utilization");
    for (i, f) in figures::fig7_energy(&ev).into_iter().enumerate() {
        f.emit(&format!("fig7_energy_{i}"));
    }
    figures::fig8_mults_per_joule(&ev).emit("fig8_mults_per_joule");
    figures::fig9_subaccel_energy(&ev).emit("fig9_subaccel_energy");
    figures::fig10_bw_partition(&ev).emit("fig10_bw_partition");
    figures::fig_alloc_ablation(&ev).emit("fig_alloc_ablation");
    figures::fig_serving_knee(&ev).emit("fig_serving_knee");
    figures::fig_serving_knee_class(&ev).emit("fig_serving_knee_class");
    figures::fig_serving_disagg(&ev).emit("fig_serving_disagg");
    if let Err(e) = ev.persist() {
        eprintln!("warn: could not persist evaluation cache: {e}");
    }
    Ok(())
}

fn cmd_roofline() -> Result<(), String> {
    figures::fig1_roofline().emit("fig1_roofline");
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("harp sweep", "bandwidth × machine sweep")
        .opt(
            "workload",
            Some("gpt3"),
            "registered workload name (see 'harp workload list') or a cascade .json file",
        )
        .opt("samples", Some("200"), "mapper samples per unique shape")
        .opt("threads", None, "worker threads (default: HARP_THREADS or core count)")
        .opt("contention", Some("off"), "shared-node contention model (off | on)")
        .opt(
            "alloc",
            Some("greedy"),
            "allocation policy (greedy | round_robin | critical_path | search)",
        )
        .flag(
            "json",
            "stream one compact JSON object per sweep point (NDJSON), emitted as each \
             point completes instead of buffering the whole table",
        );
    let args = spec.parse(argv).map_err(|e| e.to_string())?;
    let json = args.has_flag("json");
    let wl = registry::resolve(args.get("workload").unwrap())?;
    let cascade = wl.cascade();
    let mut opts = EvalOptions {
        samples: args.get_usize("samples").map_err(|e| e.to_string())?,
        ..EvalOptions::default()
    };
    opts.contention =
        harp::arch::topology::ContentionMode::parse(args.get("contention").unwrap())?;
    opts.alloc = harp::hhp::allocator::AllocPolicy::parse(args.get("alloc").unwrap())?;
    if let Some(n) = apply_threads(&args)? {
        opts.threads = n;
    }
    let mut t =
        Table::new(&["machine", "bw (b/cyc)", "latency (cycles)", "energy (µJ)", "mults/J"]);
    for bw in [2048.0, 1024.0, 512.0] {
        for (_, class) in HarpClass::eval_points() {
            let params = HardwareParams { dram_bw_bits: bw, ..HardwareParams::default() };
            let r = evaluate_cascade_on_config(&class, &params, &cascade, &opts)?;
            if json {
                sweep_row_json(&wl.name(), &class.id(), bw, &r.stats)
                    .map_err(|e| format!("stdout: {e}"))?;
            } else {
                t.row(&[
                    class.id(),
                    format!("{bw}"),
                    format!("{:.3e}", r.stats.latency_cycles),
                    format!("{:.2}", r.stats.energy_pj * 1e-6),
                    format!("{:.3e}", r.stats.mults_per_joule()),
                ]);
            }
        }
    }
    if !json {
        println!("workload: {}", wl.name());
        println!("{}", t.render());
    }
    Ok(())
}

/// One NDJSON sweep row, streamed to stdout the moment its evaluation
/// completes — a consumer piping `harp sweep --json` sees results
/// incrementally, and no whole-sweep document is ever built in memory.
fn sweep_row_json(
    workload: &str,
    machine: &str,
    bw: f64,
    stats: &harp::hhp::stats::CascadeStats,
) -> std::io::Result<()> {
    let stdout = std::io::stdout();
    let mut w = JsonStreamWriter::new(stdout.lock(), JsonStyle::Compact);
    w.begin_obj()?;
    w.key("workload")?;
    w.str(workload)?;
    w.key("machine")?;
    w.str(machine)?;
    w.key("dram_bw_bits")?;
    w.num(bw)?;
    w.key("latency_cycles")?;
    w.num(stats.latency_cycles)?;
    w.key("energy_pj")?;
    w.num(stats.energy_pj)?;
    w.key("mults_per_joule")?;
    w.num(stats.mults_per_joule())?;
    w.end_obj()?;
    let mut out = w.finish()?;
    writeln!(out)
}

fn cmd_validate(argv: &[String]) -> Result<(), String> {
    let spec = ArgSpec::new("harp validate", "execute AOT artifacts via PJRT").opt(
        "artifacts",
        Some("artifacts"),
        "artifacts directory",
    );
    let args = spec.parse(argv).map_err(|e| e.to_string())?;
    let dir = args.get("artifacts").unwrap();
    let reports = validate_all(Path::new(dir)).map_err(|e| format!("{e:#}"))?;
    println!("{}", render_reports(&reports));
    if reports.iter().all(|r| r.ok) {
        println!("all {} artifacts PASS", reports.len());
        Ok(())
    } else {
        Err("some artifacts FAILED numeric validation".into())
    }
}
