//! Cascade-level statistics aggregation (the "wrapper" box of Fig 5).

use crate::arch::level::LevelKind;
use crate::arch::partition::{MachineConfig, Role};
use crate::hhp::allocator::AllocPolicy;
use crate::hhp::scheduler::ScheduleResult;
use crate::mapper::blackbox::MappedOp;
use crate::util::binio::{BinError, BinReader, BinWriter};
use crate::util::json::{Json, JsonStreamWriter};
use crate::workload::cascade::Cascade;
use crate::workload::einsum::Phase;
use std::collections::HashMap;
use std::io;

/// Aggregated results for one (cascade, machine) evaluation.
#[derive(Debug, Clone)]
pub struct CascadeStats {
    pub workload: String,
    pub machine: String,
    /// Cascade latency in cycles (scheduler makespan).
    pub latency_cycles: f64,
    /// Total energy in pJ.
    pub energy_pj: f64,
    /// Energy by storage level (RF / L1 / LLB / DRAM).
    pub energy_by_level: HashMap<LevelKind, f64>,
    /// MAC (datapath) energy.
    pub mac_energy_pj: f64,
    /// NoC hop energy.
    pub noc_energy_pj: f64,
    /// Energy at each unit's outermost level (the tree root — DRAM on
    /// every canonical machine). Tracked positionally so custom root
    /// level names from `--topology` files stay off-chip.
    pub offchip_energy_pj: f64,
    /// On-chip energy split by the role of the executing unit.
    pub onchip_energy_by_role: HashMap<&'static str, f64>,
    /// Memory-system (buffer) on-chip energy by role: L1 + LLB + NoC,
    /// excluding the datapath (MAC + RF). This is the Fig 9 metric —
    /// the datapath energy is the same work wherever it runs; the
    /// interesting split is what the memory system pays per role.
    pub buffer_energy_by_role: HashMap<&'static str, f64>,
    /// Total real MACs.
    pub macs: f64,
    /// Busy fraction per sub-accelerator.
    pub busy_fraction: Vec<f64>,
    /// PE-weighted utilisation timeline (Fig 6 zoom), 48 buckets.
    pub utilization_timeline: Vec<f64>,
    /// Energy per phase (prefill/decode/encoder).
    pub energy_by_phase: HashMap<&'static str, f64>,
    /// Occupancy/contention per *shared* tree node (≥2 users), in node
    /// id order. Reported in every mode — under `contention: off` it
    /// quantifies how much double-booking the run tolerated.
    pub node_contention: Vec<NodeContentionStats>,
    /// Name of the allocation policy that produced `assignment`
    /// (`"greedy"` is the byte-stable default).
    pub alloc_policy: &'static str,
    /// Per-op sub-accelerator assignment, in op order. Serialized (with
    /// `alloc_policy`) only for non-default policies so `greedy`
    /// documents keep their pre-policy-engine bytes; documents loaded
    /// from older caches report the default policy and an empty vector.
    pub assignment: Vec<usize>,
}

/// Occupancy of one shared memory-tree node over the schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeContentionStats {
    /// Node instance label (unique within a machine).
    pub node: String,
    /// Number of sub-accelerators whose root path uses the node.
    pub users: usize,
    /// Fraction of the makespan with ≥1 user busy.
    pub occupied_frac: f64,
    /// Fraction of the makespan with ≥2 users simultaneously busy —
    /// the time the node's capacity/bandwidth was actually contended.
    pub contended_frac: f64,
}

impl NodeContentionStats {
    fn to_json(&self) -> Json {
        Json::obj()
            .with("node", self.node.as_str())
            .with("users", self.users)
            .with("occupied_frac", self.occupied_frac)
            .with("contended_frac", self.contended_frac)
    }

    fn from_json(j: &Json) -> Option<NodeContentionStats> {
        Some(NodeContentionStats {
            node: j.get("node")?.as_str()?.to_string(),
            users: j.get("users")?.as_usize()?,
            occupied_frac: j.get("occupied_frac")?.as_f64()?,
            contended_frac: j.get("contended_frac")?.as_f64()?,
        })
    }
}

/// Sweep the busy intervals of a node's users: time with ≥1 and ≥2
/// users simultaneously busy, as fractions of `makespan`.
fn occupancy_sweep(intervals: &[(f64, f64)], makespan: f64) -> (f64, f64) {
    if makespan <= 0.0 || intervals.is_empty() {
        return (0.0, 0.0);
    }
    let mut events: Vec<(f64, i32)> = Vec::with_capacity(intervals.len() * 2);
    for &(start, end) in intervals {
        events.push((start, 1));
        events.push((end, -1));
    }
    // Ends sort before starts at equal times so touching intervals do
    // not count as overlap. total_cmp keeps the sort total (and
    // panic-free) even if a degenerate interval ever carries a NaN.
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let (mut occupied, mut contended) = (0.0f64, 0.0f64);
    let mut depth = 0i32;
    let mut prev = events[0].0;
    for (t, d) in events {
        let span = t - prev;
        if depth >= 1 {
            occupied += span;
        }
        if depth >= 2 {
            contended += span;
        }
        depth += d;
        prev = t;
    }
    (occupied / makespan, contended / makespan)
}

impl CascadeStats {
    /// Multiplications per joule (Fig 8's metric).
    pub fn mults_per_joule(&self) -> f64 {
        self.macs / (self.energy_pj * 1e-12)
    }

    /// On-chip energy: everything except the outermost (root) level.
    /// Positional, not name-keyed, so a custom root level name from a
    /// `--topology` file still counts as off-chip.
    pub fn onchip_energy_pj(&self) -> f64 {
        self.energy_pj - self.offchip_energy_pj
    }

    /// Aggregate mapped-op stats + schedule into cascade stats. The
    /// per-op assignment is read back from `mapped` (op order), and
    /// `alloc` records which policy produced it.
    pub fn aggregate(
        cascade: &Cascade,
        machine: &MachineConfig,
        mapped: &[MappedOp],
        sched: &ScheduleResult,
        alloc: AllocPolicy,
    ) -> CascadeStats {
        let mut energy_by_level: HashMap<LevelKind, f64> = HashMap::new();
        let mut onchip_energy_by_role: HashMap<&'static str, f64> = HashMap::new();
        let mut buffer_energy_by_role: HashMap<&'static str, f64> = HashMap::new();
        let mut energy_by_phase: HashMap<&'static str, f64> = HashMap::new();
        let mut energy = 0.0;
        let mut mac_e = 0.0;
        let mut noc_e = 0.0;
        let mut offchip = 0.0;
        let mut macs = 0.0;

        for m in mapped {
            let op = &cascade.ops[m.op_index];
            let s = m.stats.scaled(op.count);
            energy += s.energy_pj;
            mac_e += s.mac_energy_pj;
            noc_e += s.noc_energy_pj;
            offchip += s.levels.last().map(|l| l.energy_pj).unwrap_or(0.0);
            macs += s.macs;
            for lv in &s.levels {
                *energy_by_level.entry(lv.kind).or_insert(0.0) += lv.energy_pj;
            }
            let role: Role = machine.sub_accels[m.sub_accel].role;
            *onchip_energy_by_role.entry(role.name()).or_insert(0.0) +=
                s.onchip_energy_pj();
            // Buffer levels are positional: everything strictly between
            // the RF (index 0, part of the datapath) and the outermost
            // level (the tree root / DRAM) — L1 + LLB on the canonical
            // chain, plus any custom intermediate levels.
            let nlevels = s.levels.len();
            let buffers: f64 = s
                .levels
                .iter()
                .enumerate()
                .filter(|(i, _)| *i > 0 && i + 1 < nlevels)
                .map(|(_, l)| l.energy_pj)
                .sum::<f64>()
                + s.noc_energy_pj;
            *buffer_energy_by_role.entry(role.name()).or_insert(0.0) += buffers;
            *energy_by_phase.entry(phase_name(op.phase)).or_insert(0.0) += s.energy_pj;
        }

        // Shared-node occupancy: for every tree node used by ≥2 units,
        // how long it was occupied and how long actually contended.
        let users = machine.topology.node_users();
        let mut node_contention = Vec::new();
        for (n, node_users) in users.iter().enumerate() {
            if node_users.len() < 2 {
                continue;
            }
            let spans: Vec<(f64, f64)> = sched
                .intervals
                .iter()
                .filter(|iv| node_users.contains(&iv.sub_accel))
                .map(|iv| (iv.start, iv.end))
                .collect();
            let (occupied_frac, contended_frac) = occupancy_sweep(&spans, sched.makespan);
            node_contention.push(NodeContentionStats {
                node: machine.topology.nodes[n].label.clone(),
                users: node_users.len(),
                occupied_frac,
                contended_frac,
            });
        }

        let mut assignment = vec![0usize; cascade.ops.len()];
        for m in mapped {
            assignment[m.op_index] = m.sub_accel;
        }
        let busy_fraction =
            (0..machine.sub_accels.len()).map(|s| sched.busy_fraction(s)).collect();
        CascadeStats {
            workload: cascade.name.clone(),
            machine: machine.class.id(),
            latency_cycles: sched.makespan,
            energy_pj: energy,
            energy_by_level,
            mac_energy_pj: mac_e,
            noc_energy_pj: noc_e,
            offchip_energy_pj: offchip,
            onchip_energy_by_role,
            buffer_energy_by_role,
            macs,
            busy_fraction,
            utilization_timeline: sched.utilization_timeline(machine, 48),
            energy_by_phase,
            node_contention,
            alloc_policy: alloc.name(),
            assignment,
        }
    }

    /// Level energies in the deterministic serialization order: the
    /// canonical four first, then custom kinds (deeper `--topology`
    /// hierarchies) sorted by name.
    fn ordered_levels(&self) -> Vec<(LevelKind, f64)> {
        let mut out = Vec::with_capacity(self.energy_by_level.len());
        for k in LevelKind::ALL {
            if let Some(e) = self.energy_by_level.get(&k) {
                out.push((k, *e));
            }
        }
        let mut extra: Vec<LevelKind> = self
            .energy_by_level
            .keys()
            .filter(|k| k.canonical_depth().is_none())
            .copied()
            .collect();
        extra.sort();
        for k in extra {
            out.push((k, self.energy_by_level[&k]));
        }
        out
    }

    /// A role-keyed map in `ROLE_NAMES` order (deterministic, and the
    /// drift-guard test keeps the list exhaustive).
    fn ordered_roles(map: &HashMap<&'static str, f64>) -> Vec<(&'static str, f64)> {
        ROLE_NAMES.into_iter().filter_map(|r| map.get(r).map(|v| (r, *v))).collect()
    }

    fn ordered_phases(&self) -> Vec<(&'static str, f64)> {
        PHASE_NAMES
            .into_iter()
            .filter_map(|p| self.energy_by_phase.get(p).map(|v| (p, *v)))
            .collect()
    }

    /// Machine-readable report. Field order is deterministic (fixed key
    /// lists, not hash order), so emitted caches and reports diff
    /// cleanly; [`CascadeStats::from_json`] inverts it exactly — the
    /// pair is what the coordinator's disk-spilled evaluation cache uses.
    /// [`CascadeStats::write_json`] streams the same document without
    /// building this tree; both feed from the same `ordered_*` helpers.
    pub fn to_json(&self) -> Json {
        let mut levels = Json::obj();
        for (k, e) in self.ordered_levels() {
            levels = levels.with(k.name(), e);
        }
        let mut roles = Json::obj();
        for (r, v) in Self::ordered_roles(&self.onchip_energy_by_role) {
            roles = roles.with(r, v);
        }
        let mut buffers = Json::obj();
        for (r, v) in Self::ordered_roles(&self.buffer_energy_by_role) {
            buffers = buffers.with(r, v);
        }
        let mut phases = Json::obj();
        for (p, v) in self.ordered_phases() {
            phases = phases.with(p, v);
        }
        let mut j = Json::obj()
            .with("workload", self.workload.as_str())
            .with("machine", self.machine.as_str());
        // The allocation keys appear ONLY for non-default policies:
        // `greedy` documents are byte-identical to those written before
        // the policy engine existed, so the committed goldens and old
        // disk-spilled caches are untouched (the from_json inverse
        // treats the absent keys as the default).
        if self.alloc_policy != AllocPolicy::Greedy.name() {
            j = j.with("alloc", self.alloc_policy).with(
                "assignment",
                Json::Arr(self.assignment.iter().map(|&s| Json::Num(s as f64)).collect()),
            );
        }
        j.with("latency_cycles", self.latency_cycles)
            .with("energy_pj", self.energy_pj)
            .with("mults_per_joule", self.mults_per_joule())
            .with("macs", self.macs)
            .with("mac_energy_pj", self.mac_energy_pj)
            .with("noc_energy_pj", self.noc_energy_pj)
            .with("offchip_energy_pj", self.offchip_energy_pj)
            .with("energy_by_level", levels)
            .with("onchip_energy_by_role", roles)
            .with("buffer_energy_by_role", buffers)
            .with("energy_by_phase", phases)
            .with(
                "busy_fraction",
                Json::Arr(self.busy_fraction.iter().map(|&b| Json::Num(b)).collect()),
            )
            .with(
                "utilization_timeline",
                Json::Arr(self.utilization_timeline.iter().map(|&b| Json::Num(b)).collect()),
            )
            .with(
                "node_contention",
                Json::Arr(self.node_contention.iter().map(|c| c.to_json()).collect()),
            )
    }

    /// Stream the [`CascadeStats::to_json`] document — byte-identical
    /// in either style — without building the `Json` tree. This is the
    /// emitter the eval-cache spill, `eval --json`, and the sweep rows
    /// use, so serializing a million evaluations allocates one reused
    /// row buffer instead of a million tree nodes.
    pub fn write_json<W: io::Write>(&self, w: &mut JsonStreamWriter<W>) -> io::Result<()> {
        w.begin_obj()?;
        w.key("workload")?;
        w.str(&self.workload)?;
        w.key("machine")?;
        w.str(&self.machine)?;
        if self.alloc_policy != AllocPolicy::Greedy.name() {
            w.key("alloc")?;
            w.str(self.alloc_policy)?;
            w.key("assignment")?;
            w.begin_arr()?;
            for &s in &self.assignment {
                w.num(s as f64)?;
            }
            w.end_arr()?;
        }
        w.key("latency_cycles")?;
        w.num(self.latency_cycles)?;
        w.key("energy_pj")?;
        w.num(self.energy_pj)?;
        w.key("mults_per_joule")?;
        w.num(self.mults_per_joule())?;
        w.key("macs")?;
        w.num(self.macs)?;
        w.key("mac_energy_pj")?;
        w.num(self.mac_energy_pj)?;
        w.key("noc_energy_pj")?;
        w.num(self.noc_energy_pj)?;
        w.key("offchip_energy_pj")?;
        w.num(self.offchip_energy_pj)?;
        w.key("energy_by_level")?;
        w.begin_obj()?;
        for (k, e) in self.ordered_levels() {
            w.key(k.name())?;
            w.num(e)?;
        }
        w.end_obj()?;
        w.key("onchip_energy_by_role")?;
        w.begin_obj()?;
        for (r, v) in Self::ordered_roles(&self.onchip_energy_by_role) {
            w.key(r)?;
            w.num(v)?;
        }
        w.end_obj()?;
        w.key("buffer_energy_by_role")?;
        w.begin_obj()?;
        for (r, v) in Self::ordered_roles(&self.buffer_energy_by_role) {
            w.key(r)?;
            w.num(v)?;
        }
        w.end_obj()?;
        w.key("energy_by_phase")?;
        w.begin_obj()?;
        for (p, v) in self.ordered_phases() {
            w.key(p)?;
            w.num(v)?;
        }
        w.end_obj()?;
        w.key("busy_fraction")?;
        w.begin_arr()?;
        for &b in &self.busy_fraction {
            w.num(b)?;
        }
        w.end_arr()?;
        w.key("utilization_timeline")?;
        w.begin_arr()?;
        for &b in &self.utilization_timeline {
            w.num(b)?;
        }
        w.end_arr()?;
        w.key("node_contention")?;
        w.begin_arr()?;
        for c in &self.node_contention {
            w.begin_obj()?;
            w.key("node")?;
            w.str(&c.node)?;
            w.key("users")?;
            w.num(c.users as f64)?;
            w.key("occupied_frac")?;
            w.num(c.occupied_frac)?;
            w.key("contended_frac")?;
            w.num(c.contended_frac)?;
            w.end_obj()?;
        }
        w.end_arr()?;
        w.end_obj()
    }

    /// Binary codec for the eval-cache spill's fast path: every field
    /// in the same deterministic order as [`CascadeStats::to_json`],
    /// floats as raw IEEE-754 bits. Unlike the greedy-elides-its-keys
    /// JSON shape, the binary form always records the policy and
    /// assignment — the format is new, so it has no legacy bytes to
    /// preserve.
    pub fn write_bin<W: io::Write>(&self, w: &mut BinWriter<W>) -> io::Result<()> {
        w.str(&self.workload)?;
        w.str(&self.machine)?;
        w.str(self.alloc_policy)?;
        w.u64(self.assignment.len() as u64)?;
        for &s in &self.assignment {
            w.u64(s as u64)?;
        }
        w.f64(self.latency_cycles)?;
        w.f64(self.energy_pj)?;
        w.f64(self.macs)?;
        w.f64(self.mac_energy_pj)?;
        w.f64(self.noc_energy_pj)?;
        w.f64(self.offchip_energy_pj)?;
        let levels = self.ordered_levels();
        w.u64(levels.len() as u64)?;
        for (k, e) in levels {
            w.str(k.name())?;
            w.f64(e)?;
        }
        for map in [&self.onchip_energy_by_role, &self.buffer_energy_by_role] {
            let roles = Self::ordered_roles(map);
            w.u64(roles.len() as u64)?;
            for (r, v) in roles {
                w.str(r)?;
                w.f64(v)?;
            }
        }
        let phases = self.ordered_phases();
        w.u64(phases.len() as u64)?;
        for (p, v) in phases {
            w.str(p)?;
            w.f64(v)?;
        }
        w.u64(self.busy_fraction.len() as u64)?;
        for &b in &self.busy_fraction {
            w.f64(b)?;
        }
        w.u64(self.utilization_timeline.len() as u64)?;
        for &b in &self.utilization_timeline {
            w.f64(b)?;
        }
        w.u64(self.node_contention.len() as u64)?;
        for c in &self.node_contention {
            w.str(&c.node)?;
            w.u64(c.users as u64)?;
            w.f64(c.occupied_frac)?;
            w.f64(c.contended_frac)?;
        }
        Ok(())
    }

    /// Inverse of [`CascadeStats::write_bin`]. Every malformed mode is
    /// a distinct loud [`BinError`] — unknown policy/role/phase names
    /// included — never a quiet partial load.
    pub fn read_bin(r: &mut BinReader<'_>) -> Result<CascadeStats, BinError> {
        let malformed = |offset: usize, detail: String| BinError::Malformed { offset, detail };

        let workload = r.str("workload")?;
        let machine = r.str("machine")?;
        let policy_offset = r.offset();
        let policy_name = r.str("alloc policy")?;
        let alloc_policy = AllocPolicy::parse(&policy_name)
            .map_err(|_| malformed(policy_offset, format!("unknown alloc policy \"{policy_name}\"")))?
            .name();
        let n = r.seq_len(8, "assignment")?;
        let mut assignment = Vec::with_capacity(n);
        for _ in 0..n {
            assignment.push(r.u64("assignment slot")? as usize);
        }
        let latency_cycles = r.f64("latency_cycles")?;
        let energy_pj = r.f64("energy_pj")?;
        let macs = r.f64("macs")?;
        let mac_energy_pj = r.f64("mac_energy_pj")?;
        let noc_energy_pj = r.f64("noc_energy_pj")?;
        let offchip_energy_pj = r.f64("offchip_energy_pj")?;
        let n = r.seq_len(12, "energy_by_level")?;
        let mut energy_by_level = HashMap::new();
        for _ in 0..n {
            let kind = r.str("level kind")?;
            energy_by_level.insert(LevelKind::named(&kind), r.f64("level energy")?);
        }
        let mut role_maps: [HashMap<&'static str, f64>; 2] = [HashMap::new(), HashMap::new()];
        for map in role_maps.iter_mut() {
            let n = r.seq_len(12, "role energies")?;
            for _ in 0..n {
                let offset = r.offset();
                let role = r.str("role name")?;
                let key = ROLE_NAMES
                    .into_iter()
                    .find(|r| *r == role)
                    .ok_or_else(|| malformed(offset, format!("unknown role \"{role}\"")))?;
                map.insert(key, r.f64("role energy")?);
            }
        }
        let [onchip_energy_by_role, buffer_energy_by_role] = role_maps;
        let n = r.seq_len(12, "energy_by_phase")?;
        let mut energy_by_phase = HashMap::new();
        for _ in 0..n {
            let offset = r.offset();
            let phase = r.str("phase name")?;
            let key = PHASE_NAMES
                .into_iter()
                .find(|p| *p == phase)
                .ok_or_else(|| malformed(offset, format!("unknown phase \"{phase}\"")))?;
            energy_by_phase.insert(key, r.f64("phase energy")?);
        }
        let n = r.seq_len(8, "busy_fraction")?;
        let busy_fraction = (0..n)
            .map(|_| r.f64("busy fraction"))
            .collect::<Result<Vec<_>, _>>()?;
        let n = r.seq_len(8, "utilization_timeline")?;
        let utilization_timeline = (0..n)
            .map(|_| r.f64("utilization bucket"))
            .collect::<Result<Vec<_>, _>>()?;
        let n = r.seq_len(28, "node_contention")?;
        let mut node_contention = Vec::with_capacity(n);
        for _ in 0..n {
            node_contention.push(NodeContentionStats {
                node: r.str("node label")?,
                users: r.u64("node users")? as usize,
                occupied_frac: r.f64("occupied_frac")?,
                contended_frac: r.f64("contended_frac")?,
            });
        }
        Ok(CascadeStats {
            workload,
            machine,
            latency_cycles,
            energy_pj,
            energy_by_level,
            mac_energy_pj,
            noc_energy_pj,
            offchip_energy_pj,
            onchip_energy_by_role,
            buffer_energy_by_role,
            macs,
            busy_fraction,
            utilization_timeline,
            energy_by_phase,
            node_contention,
            alloc_policy,
            assignment,
        })
    }

    /// Inverse of [`CascadeStats::to_json`]. Returns `None` on any
    /// missing/malformed mandatory field (callers treat that as a cache
    /// miss, not an error). Floats round-trip exactly: the JSON writer
    /// emits the shortest representation that parses back bit-identical.
    pub fn from_json(j: &Json) -> Option<CascadeStats> {
        let f64_field = |key: &str| j.get(key).and_then(|v| v.as_f64());
        let arr_field = |key: &str| -> Option<Vec<f64>> {
            j.get(key)?.as_arr()?.iter().map(|v| v.as_f64()).collect()
        };

        let mut energy_by_level = HashMap::new();
        if let Some(Json::Obj(pairs)) = j.get("energy_by_level") {
            for (k, v) in pairs {
                // Canonical names resolve to the canonical kinds; any
                // other name round-trips through the interner.
                energy_by_level.insert(LevelKind::named(k), v.as_f64()?);
            }
        }
        let role_map = |key: &str| -> Option<HashMap<&'static str, f64>> {
            let mut out = HashMap::new();
            if let Some(Json::Obj(pairs)) = j.get(key) {
                for (k, v) in pairs {
                    if let Some(r) = ROLE_NAMES.into_iter().find(|r| *r == k.as_str()) {
                        out.insert(r, v.as_f64()?);
                    }
                }
            }
            Some(out)
        };
        let mut energy_by_phase = HashMap::new();
        if let Some(Json::Obj(pairs)) = j.get("energy_by_phase") {
            for (k, v) in pairs {
                if let Some(p) = PHASE_NAMES.into_iter().find(|p| *p == k.as_str()) {
                    energy_by_phase.insert(p, v.as_f64()?);
                }
            }
        }

        // Absent in documents written before the contention model: treat
        // as "no shared nodes" rather than a malformed cache entry.
        let node_contention = match j.get("node_contention").and_then(|v| v.as_arr()) {
            Some(items) => items
                .iter()
                .map(NodeContentionStats::from_json)
                .collect::<Option<Vec<_>>>()?,
            None => Vec::new(),
        };

        // Absent on `greedy` documents (and everything written before
        // the allocation-policy engine): the default policy with no
        // recorded assignment. A present-but-unknown policy name is a
        // malformed document (cache miss), not a silent default.
        let alloc_policy = match j.get("alloc") {
            Some(v) => AllocPolicy::parse(v.as_str()?).ok()?.name(),
            None => AllocPolicy::Greedy.name(),
        };
        let assignment = match j.get("assignment").and_then(|v| v.as_arr()) {
            Some(items) => items.iter().map(|v| v.as_usize()).collect::<Option<Vec<_>>>()?,
            None => Vec::new(),
        };

        Some(CascadeStats {
            workload: j.get("workload")?.as_str()?.to_string(),
            machine: j.get("machine")?.as_str()?.to_string(),
            latency_cycles: f64_field("latency_cycles")?,
            energy_pj: f64_field("energy_pj")?,
            energy_by_level,
            mac_energy_pj: f64_field("mac_energy_pj")?,
            noc_energy_pj: f64_field("noc_energy_pj")?,
            offchip_energy_pj: f64_field("offchip_energy_pj")?,
            onchip_energy_by_role: role_map("onchip_energy_by_role")?,
            buffer_energy_by_role: role_map("buffer_energy_by_role")?,
            macs: f64_field("macs")?,
            busy_fraction: arr_field("busy_fraction")?,
            utilization_timeline: arr_field("utilization_timeline")?,
            energy_by_phase,
            node_contention,
            alloc_policy,
            assignment,
        })
    }
}

/// The role names [`Role::name`] can produce. Kept as a const so JSON
/// field order is fixed; `role_phase_name_lists_are_exhaustive` fails
/// the build's tests if `Role`/[`Phase`] ever drift from these lists
/// (drift would silently drop entries from reports and the disk cache).
const ROLE_NAMES: [&str; 3] = ["high-reuse", "low-reuse", "unified"];

/// The phase names [`phase_name`] can produce (same drift guard).
const PHASE_NAMES: [&str; 3] = ["encoder", "prefill", "decode"];

fn phase_name(p: Phase) -> &'static str {
    match p {
        Phase::Encoder => "encoder",
        Phase::Prefill => "prefill",
        Phase::Decode => "decode",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::partition::HardwareParams;
    use crate::arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
    use crate::hhp::scheduler::{schedule, ScheduleOptions};
    use crate::mapper::blackbox::BlackboxMapper;
    use crate::mapper::search::SearchBudget;
    use crate::workload::intensity::Classifier;
    use crate::workload::transformer;

    #[test]
    fn aggregates_bert_on_cross_node() {
        let machine = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let classifier = Classifier::new(machine.params.tipping_ai());
        let assign = crate::hhp::allocator::allocate(&g, &machine, &classifier);
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 40, seed: 1 });
        let mapped = mapper.map_cascade(&g, &machine, &assign);
        let sched = schedule(&g, &machine, &mapped, &ScheduleOptions::default());
        let stats = CascadeStats::aggregate(&g, &machine, &mapped, &sched, AllocPolicy::Greedy);

        assert!(stats.latency_cycles > 0.0);
        assert!(stats.energy_pj > 0.0);
        assert_eq!(stats.macs, g.total_macs() as f64);
        // Both roles consumed on-chip energy.
        assert!(stats.onchip_energy_by_role["high-reuse"] > 0.0);
        assert!(stats.onchip_energy_by_role["low-reuse"] > 0.0);
        // Level energies sum (with MAC + NoC) to the total.
        let level_sum: f64 = stats.energy_by_level.values().sum();
        let total = level_sum + stats.mac_energy_pj + stats.noc_energy_pj;
        assert!((total - stats.energy_pj).abs() < 1e-6 * stats.energy_pj);
        // JSON round-trips.
        let j = stats.to_json();
        assert!(j.get("mults_per_joule").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let machine = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let classifier = Classifier::new(machine.params.tipping_ai());
        let assign = crate::hhp::allocator::allocate(&g, &machine, &classifier);
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 20, seed: 1 });
        let mapped = mapper.map_cascade(&g, &machine, &assign);
        let sched = schedule(&g, &machine, &mapped, &ScheduleOptions::default());
        let stats = CascadeStats::aggregate(&g, &machine, &mapped, &sched, AllocPolicy::Greedy);

        let text = stats.to_json().to_string_pretty();
        let back = CascadeStats::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.workload, stats.workload);
        assert_eq!(back.machine, stats.machine);
        assert_eq!(back.latency_cycles, stats.latency_cycles);
        assert_eq!(back.energy_pj, stats.energy_pj);
        assert_eq!(back.mac_energy_pj, stats.mac_energy_pj);
        assert_eq!(back.noc_energy_pj, stats.noc_energy_pj);
        assert_eq!(back.offchip_energy_pj, stats.offchip_energy_pj);
        assert_eq!(back.macs, stats.macs);
        assert_eq!(back.energy_by_level, stats.energy_by_level);
        assert_eq!(back.onchip_energy_by_role, stats.onchip_energy_by_role);
        assert_eq!(back.buffer_energy_by_role, stats.buffer_energy_by_role);
        assert_eq!(back.energy_by_phase, stats.energy_by_phase);
        assert_eq!(back.busy_fraction, stats.busy_fraction);
        assert_eq!(back.utilization_timeline, stats.utilization_timeline);
        assert_eq!(back.node_contention, stats.node_contention);

        // Greedy documents carry NO allocation keys (pre-policy-engine
        // byte shape) and load back as the default policy.
        assert!(stats.to_json().get("alloc").is_none());
        assert!(stats.to_json().get("assignment").is_none());
        assert_eq!(back.alloc_policy, "greedy");
        assert!(back.assignment.is_empty());

        // Non-default policies serialize their name + assignment and
        // round-trip exactly.
        let mut searched = stats.clone();
        searched.alloc_policy = AllocPolicy::Search.name();
        let text2 = searched.to_json().to_string_pretty();
        let back2 = CascadeStats::from_json(&Json::parse(&text2).unwrap()).unwrap();
        assert_eq!(back2.alloc_policy, "search");
        assert_eq!(back2.assignment, searched.assignment);
        assert!(!back2.assignment.is_empty());

        // An unknown policy name is a malformed document (cache miss).
        let mut bad = searched.to_json();
        if let Json::Obj(pairs) = &mut bad {
            for (k, v) in pairs.iter_mut() {
                if k == "alloc" {
                    *v = Json::Str("optimal".into());
                }
            }
        }
        assert!(CascadeStats::from_json(&bad).is_none());

        // Malformed documents are a cache miss, not a panic.
        assert!(CascadeStats::from_json(&Json::parse("{}").unwrap()).is_none());

        // Pre-contention cache documents (no node_contention key) still
        // load — as an empty report, not a miss.
        let mut doc = stats.to_json();
        if let Json::Obj(pairs) = &mut doc {
            pairs.retain(|(k, _)| k != "node_contention");
        }
        let old = CascadeStats::from_json(&doc).expect("legacy document loads");
        assert!(old.node_contention.is_empty());
    }

    #[test]
    fn occupancy_sweep_counts_overlap_only() {
        // [0,10) and [5,15): occupied 15, contended 5, makespan 20.
        let (occ, cont) = occupancy_sweep(&[(0.0, 10.0), (5.0, 15.0)], 20.0);
        assert!((occ - 0.75).abs() < 1e-12);
        assert!((cont - 0.25).abs() < 1e-12);
        // Touching intervals do not contend.
        let (occ, cont) = occupancy_sweep(&[(0.0, 10.0), (10.0, 20.0)], 20.0);
        assert!((occ - 1.0).abs() < 1e-12);
        assert_eq!(cont, 0.0);
        assert_eq!(occupancy_sweep(&[], 20.0), (0.0, 0.0));
        assert_eq!(occupancy_sweep(&[(0.0, 1.0)], 0.0), (0.0, 0.0));
    }

    /// Every multi-unit machine shares at least the DRAM root: the
    /// report carries its occupancy, and overlap there matches the
    /// schedule's parallelism.
    #[test]
    fn shared_root_contention_reported() {
        let machine = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let classifier = Classifier::new(machine.params.tipping_ai());
        let assign = crate::hhp::allocator::allocate(&g, &machine, &classifier);
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 20, seed: 1 });
        let mapped = mapper.map_cascade(&g, &machine, &assign);
        let sched = schedule(&g, &machine, &mapped, &ScheduleOptions::default());
        let stats = CascadeStats::aggregate(&g, &machine, &mapped, &sched, AllocPolicy::Greedy);

        assert_eq!(stats.node_contention.len(), 1); // only the root is shared
        let root = &stats.node_contention[0];
        assert_eq!(root.users, 2);
        assert!(root.occupied_frac > 0.0 && root.occupied_frac <= 1.0 + 1e-9);
        assert!(root.contended_frac <= root.occupied_frac);
    }

    /// Drift guard: the hardcoded serialization key lists must cover
    /// exactly the names the enums can produce, or (de)serialization
    /// would silently drop entries.
    #[test]
    fn role_phase_name_lists_are_exhaustive() {
        let roles: Vec<&str> = Role::ALL.into_iter().map(|r| r.name()).collect();
        for r in roles.iter() {
            assert!(ROLE_NAMES.contains(r), "Role name '{r}' missing from ROLE_NAMES");
        }
        assert_eq!(roles.len(), ROLE_NAMES.len());

        let phases: Vec<&str> = Phase::ALL.into_iter().map(phase_name).collect();
        for p in phases.iter() {
            assert!(PHASE_NAMES.contains(p), "Phase name '{p}' missing from PHASE_NAMES");
        }
        assert_eq!(phases.len(), PHASE_NAMES.len());
    }

    fn real_stats() -> CascadeStats {
        let machine = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let classifier = Classifier::new(machine.params.tipping_ai());
        let assign = crate::hhp::allocator::allocate(&g, &machine, &classifier);
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 20, seed: 1 });
        let mapped = mapper.map_cascade(&g, &machine, &assign);
        let sched = schedule(&g, &machine, &mapped, &ScheduleOptions::default());
        CascadeStats::aggregate(&g, &machine, &mapped, &sched, AllocPolicy::Greedy)
    }

    /// The streaming emitter is byte-identical to the tree path in both
    /// styles, for both serialization shapes (greedy elides the
    /// allocation keys; non-default policies carry them).
    #[test]
    fn write_json_matches_to_json_bytes() {
        use crate::util::json::JsonStyle;
        let stats = real_stats();
        let mut searched = stats.clone();
        searched.alloc_policy = AllocPolicy::Search.name();
        for s in [&stats, &searched] {
            for style in [JsonStyle::Compact, JsonStyle::Pretty] {
                let mut w = JsonStreamWriter::new(Vec::new(), style);
                s.write_json(&mut w).unwrap();
                let streamed = w.finish().unwrap();
                let expect = match style {
                    JsonStyle::Compact => s.to_json().to_string_compact(),
                    JsonStyle::Pretty => s.to_json().to_string_pretty(),
                };
                assert_eq!(
                    String::from_utf8(streamed).unwrap(),
                    expect,
                    "{}/{style:?}: streamed stats drifted from the tree",
                    s.alloc_policy
                );
            }
        }
    }

    /// Binary codec round trip: read(write(stats)) serializes to the
    /// byte-identical JSON document (i.e. every f64 bit pattern, map
    /// entry, and vector survived), and the reader consumes every byte.
    #[test]
    fn binary_codec_round_trips_bit_exactly() {
        let stats = real_stats();
        let mut searched = stats.clone();
        searched.alloc_policy = AllocPolicy::Search.name();
        for s in [&stats, &searched] {
            let mut w = BinWriter::new(Vec::new());
            s.write_bin(&mut w).unwrap();
            let bytes = w.finish().unwrap();
            let mut r = BinReader::new(&bytes);
            let back = CascadeStats::read_bin(&mut r).unwrap();
            r.finish().unwrap();
            assert_eq!(
                back.to_json().to_string_pretty(),
                s.to_json().to_string_pretty(),
                "{}: binary round trip drifted",
                s.alloc_policy
            );
            assert_eq!(back.assignment, s.assignment);
            assert_eq!(back.alloc_policy, s.alloc_policy);
        }
    }
}
