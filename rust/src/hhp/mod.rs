//! The HHP wrapper — the paper's system contribution (§VI-A, Fig 5):
//! allocate operations to sub-accelerators by reuse, schedule the
//! cascade DAG with overlap across sub-accelerators, and aggregate
//! per-operation Timeloop statistics into cascade-level results.

pub mod allocator;
pub mod scheduler;
pub mod stats;

pub use allocator::{allocate, AllocPolicy};
pub use scheduler::{schedule, ScheduleOptions, ScheduleOracle, ScheduleResult};
pub use stats::CascadeStats;
