//! Reuse-based operation allocation (paper §III, §V-D).
//!
//! Operations are classified high/low reuse and assigned to a
//! sub-accelerator whose role accepts that class. When several
//! sub-accelerators share a role (clustered cross-node, compound), the
//! allocator balances accumulated MAC load greedily.

use crate::arch::partition::MachineConfig;
use crate::workload::cascade::Cascade;
use crate::workload::intensity::Classifier;

/// Assign each op of `cascade` to a sub-accelerator id.
pub fn allocate(cascade: &Cascade, machine: &MachineConfig, classifier: &Classifier) -> Vec<usize> {
    let mut load: Vec<f64> = vec![0.0; machine.sub_accels.len()];
    cascade
        .ops
        .iter()
        .map(|op| {
            let class = classifier.classify(op);
            let mut candidates = machine.accelerators_for(class);
            if candidates.is_empty() {
                // Homogeneous machine (or a role-less config): anything
                // that accepts the op — fall back to all units.
                candidates = (0..machine.sub_accels.len()).collect();
            }
            // Least-loaded candidate, weighted by its compute roof so a
            // half-size cluster fills at half the rate.
            let chosen = *candidates
                .iter()
                .min_by(|&&a, &&b| {
                    let la = load[a] / machine.sub_accels[a].spec.peak_macs() as f64;
                    let lb = load[b] / machine.sub_accels[b].spec.peak_macs() as f64;
                    la.partial_cmp(&lb).unwrap()
                })
                .unwrap();
            load[chosen] += op.total_macs() as f64;
            chosen
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::partition::{HardwareParams, MachineConfig};
    use crate::arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
    use crate::workload::einsum::{Phase, TensorOp};
    use crate::workload::transformer;

    fn classifier() -> Classifier {
        Classifier::new(HardwareParams::default().tipping_ai())
    }

    #[test]
    fn homogeneous_gets_everything() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let a = allocate(&g, &m, &classifier());
        assert!(a.iter().all(|&s| s == 0));
    }

    #[test]
    fn bert_split_matches_paper() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let a = allocate(&g, &m, &classifier());
        for (i, op) in g.ops.iter().enumerate() {
            let expect_low = matches!(op.name.as_str(), "logit" | "softmax" | "attend");
            assert_eq!(a[i] == 1, expect_low, "op {} on sub {}", op.name, a[i]);
        }
    }

    #[test]
    fn decoder_phases_split() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::decoder_cascade(&transformer::llama2());
        let a = allocate(&g, &m, &classifier());
        for (i, op) in g.ops.iter().enumerate() {
            match op.phase {
                Phase::Prefill => assert_eq!(a[i], 0, "{} should be high", op.name),
                Phase::Decode => assert_eq!(a[i], 1, "{} should be low", op.name),
                Phase::Encoder => unreachable!(),
            }
        }
    }

    #[test]
    fn multiple_low_units_balance() {
        let m = MachineConfig::build(
            &HarpClass::new(
                ComputePlacement::Hierarchical,
                HeterogeneityLoc::Compound(vec![
                    HeterogeneityLoc::cross_node(),
                    HeterogeneityLoc::CrossDepth,
                ]),
            ),
            &HardwareParams::default(),
        )
        .unwrap();
        let mut g = Cascade::new("lows");
        for i in 0..6 {
            g.push(TensorOp::gemm(&format!("v{i}"), Phase::Decode, 1, 512, 512));
        }
        let a = allocate(&g, &m, &classifier());
        // Both low units (ids 1, 2) receive work.
        assert!(a.contains(&1));
        assert!(a.contains(&2));
        assert!(!a.contains(&0));
    }
}
