//! Operation → sub-accelerator allocation policies (paper §III, §V-D).
//!
//! Operations are classified high/low reuse and assigned to a
//! sub-accelerator whose role accepts that class. *How* the ops spread
//! over the units that share a role is a first-class search space
//! (Herald, MOSAIC): [`AllocPolicy`] selects the policy, from the
//! byte-stable greedy default up to a schedule-aware local search that
//! replays the overlap scheduler as its cost oracle
//! ([`ScheduleOracle`]).
//!
//! Every policy preserves the same validity contract: each op lands on
//! a unit whose role accepts its reuse class, with the homogeneous
//! fallback (no unit accepts the class ⇒ every unit is eligible)
//! intact. `greedy` is bit-identical to the historical allocator, so
//! default evaluations — and the committed goldens — never move.

use crate::arch::partition::MachineConfig;
use crate::hhp::scheduler::{ScheduleOptions, ScheduleOracle};
use crate::mapper::blackbox::{BlackboxMapper, MappedOp, OpUnitCost};
use crate::model::stats::OpStats;
use crate::workload::cascade::Cascade;
use crate::workload::intensity::{Classifier, ReuseClass};

/// Allocation policy for the op → sub-accelerator assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllocPolicy {
    /// Reuse-class + least-loaded (load weighted by compute roof). The
    /// historical policy; byte-stable default.
    #[default]
    Greedy,
    /// Rotate eligible units per reuse class in op order.
    RoundRobin,
    /// Longest op first, onto the eligible unit that finishes it
    /// earliest under the load placed so far (LPT list scheduling on
    /// the compute roofs).
    CriticalPath,
    /// Start from `greedy`, then schedule-aware local search: replay
    /// the overlap scheduler per probe, repeatedly re-assigning the op
    /// with the worst queue-delay/latency ratio, keeping strict
    /// makespan improvements until a fixpoint or the move budget.
    Search,
}

impl AllocPolicy {
    pub const ALL: [AllocPolicy; 4] = [
        AllocPolicy::Greedy,
        AllocPolicy::RoundRobin,
        AllocPolicy::CriticalPath,
        AllocPolicy::Search,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AllocPolicy::Greedy => "greedy",
            AllocPolicy::RoundRobin => "round_robin",
            AllocPolicy::CriticalPath => "critical_path",
            AllocPolicy::Search => "search",
        }
    }

    /// Parse a CLI/config policy name. Unknown names error with the
    /// full valid set — never a silent default.
    pub fn parse(s: &str) -> Result<AllocPolicy, String> {
        match s.to_ascii_lowercase().as_str() {
            "greedy" => Ok(AllocPolicy::Greedy),
            "round_robin" | "round-robin" | "rr" => Ok(AllocPolicy::RoundRobin),
            "critical_path" | "critical-path" | "cp" => Ok(AllocPolicy::CriticalPath),
            "search" => Ok(AllocPolicy::Search),
            other => Err(format!(
                "unknown allocation policy '{other}' (valid: greedy, round_robin, \
                 critical_path, search)"
            )),
        }
    }
}

/// Accepted-move budget for [`AllocPolicy::Search`], as a function of
/// cascade size: generous enough that the fixpoint, not the budget, is
/// what normally terminates the search; the budget only bounds
/// pathological move chains on huge cascades.
pub fn search_move_budget(n_ops: usize) -> usize {
    (4 * n_ops).max(16)
}

/// Units eligible for `class` on `machine`: the role-accepting set, or
/// every unit when none accepts (homogeneous / role-less machines).
pub fn eligible_units(machine: &MachineConfig, class: ReuseClass) -> Vec<usize> {
    let candidates = machine.accelerators_for(class);
    if candidates.is_empty() {
        (0..machine.sub_accels.len()).collect()
    } else {
        candidates
    }
}

/// Order `units` by observed pressure (ascending, unit id as the
/// deterministic tie-break), dropping units whose pressure exceeds
/// twice the minimum — a serving-side feedback loop (MOSAIC-style) that
/// steers placement toward the units the scheduler reports as least
/// congested. With uniform pressure (e.g. all zero at start-up) every
/// unit survives in id order, which degrades exactly to round-robin.
/// Never returns an empty set: the minimum-pressure unit always passes
/// its own gate.
pub fn pressure_ordered(units: &[usize], pressure: &[f64]) -> Vec<usize> {
    let mut ranked: Vec<usize> = units.to_vec();
    ranked.sort_by(|&a, &b| pressure[a].total_cmp(&pressure[b]).then(a.cmp(&b)));
    let floor = pressure[ranked[0]];
    let gate = 2.0 * floor + 1e-12;
    let kept: Vec<usize> =
        ranked.iter().copied().filter(|&u| pressure[u] <= gate).collect();
    if kept.is_empty() { ranked } else { kept }
}

/// Assign each op of `cascade` to a sub-accelerator id (the historical
/// greedy policy — [`AllocPolicy::Greedy`]).
pub fn allocate(cascade: &Cascade, machine: &MachineConfig, classifier: &Classifier) -> Vec<usize> {
    let mut load: Vec<f64> = vec![0.0; machine.sub_accels.len()];
    cascade
        .ops
        .iter()
        .map(|op| {
            let class = classifier.classify(op);
            let candidates = eligible_units(machine, class);
            // Least-loaded candidate, weighted by its compute roof so a
            // half-size cluster fills at half the rate. Ratios are
            // finite non-negative (MachineConfig construction rejects
            // zero-PE units), and `total_cmp` keeps the ordering total
            // even if that invariant is ever violated upstream.
            let chosen = *candidates
                .iter()
                .min_by(|&&a, &&b| {
                    let la = load[a] / machine.sub_accels[a].spec.peak_macs() as f64;
                    let lb = load[b] / machine.sub_accels[b].spec.peak_macs() as f64;
                    la.total_cmp(&lb)
                })
                .unwrap();
            load[chosen] += op.total_macs() as f64;
            chosen
        })
        .collect()
}

/// Round-robin policy: eligible units for each reuse class are cycled
/// in op order, one counter per class.
fn allocate_round_robin(
    cascade: &Cascade,
    machine: &MachineConfig,
    classifier: &Classifier,
) -> Vec<usize> {
    let mut counters = [0usize; 2]; // [High, Low]
    cascade
        .ops
        .iter()
        .map(|op| {
            let class = classifier.classify(op);
            let candidates = eligible_units(machine, class);
            let c = match class {
                ReuseClass::High => &mut counters[0],
                ReuseClass::Low => &mut counters[1],
            };
            let chosen = candidates[*c % candidates.len()];
            *c += 1;
            chosen
        })
        .collect()
}

/// Critical-path (LPT) policy: ops in descending MAC count, each onto
/// the eligible unit that finishes it earliest given the compute-roof
/// load placed so far — the longest ops get first pick of the fastest
/// units. Ties break on op index and unit id, so the assignment is
/// deterministic.
fn allocate_critical_path(
    cascade: &Cascade,
    machine: &MachineConfig,
    classifier: &Classifier,
) -> Vec<usize> {
    let n = cascade.ops.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        cascade.ops[b]
            .total_macs()
            .cmp(&cascade.ops[a].total_macs())
            .then(a.cmp(&b))
    });
    let mut finish = vec![0.0f64; machine.sub_accels.len()];
    let mut assignment = vec![0usize; n];
    for &i in &order {
        let op = &cascade.ops[i];
        let class = classifier.classify(op);
        let candidates = eligible_units(machine, class);
        let work = op.total_macs() as f64;
        // min_by keeps the FIRST minimum; candidates are in ascending
        // unit-id order, so equal finish times pick the lower id.
        let chosen = *candidates
            .iter()
            .min_by(|&&a, &&b| {
                let fa = finish[a] + work / machine.sub_accels[a].spec.peak_macs() as f64;
                let fb = finish[b] + work / machine.sub_accels[b].spec.peak_macs() as f64;
                fa.total_cmp(&fb)
            })
            .unwrap();
        finish[chosen] += work / machine.sub_accels[chosen].spec.peak_macs() as f64;
        assignment[i] = chosen;
    }
    assignment
}

/// Dispatch the closed-form policies. [`AllocPolicy::Search`] needs the
/// mapper and scheduler as its cost oracle — use
/// [`search_allocation`] for it (this function falls back to its greedy
/// starting point, which the search only ever improves on).
pub fn allocate_policy(
    policy: AllocPolicy,
    cascade: &Cascade,
    machine: &MachineConfig,
    classifier: &Classifier,
) -> Vec<usize> {
    match policy {
        AllocPolicy::Greedy | AllocPolicy::Search => allocate(cascade, machine, classifier),
        AllocPolicy::RoundRobin => allocate_round_robin(cascade, machine, classifier),
        AllocPolicy::CriticalPath => allocate_critical_path(cascade, machine, classifier),
    }
}

/// Relative tolerance for "strictly better makespan": mirrors the
/// mapper's latency tie-break so float noise can never drive an
/// accept/oscillate loop.
pub(crate) fn strictly_better(candidate: f64, incumbent: f64) -> bool {
    candidate < incumbent - 1e-9 * incumbent.max(1.0)
}

/// One cell of the cost matrix as the `&OpStats` the oracle replays.
fn cost_at<'c>(costs: &'c [Vec<Option<OpUnitCost>>], i: usize, u: usize) -> &'c OpStats {
    &costs[i][u].as_ref().expect("cost searched for every eligible unit").stats
}

/// The per-op `&OpStats` view of `assign` over the cost matrix — what
/// [`ScheduleOracle::replay`] consumes per probe.
fn cost_view<'c>(
    costs: &'c [Vec<Option<OpUnitCost>>],
    assign: &[usize],
) -> Vec<&'c OpStats> {
    assign.iter().enumerate().map(|(i, &u)| cost_at(costs, i, u)).collect()
}

/// [`AllocPolicy::Search`]: greedy start, then schedule-aware local
/// search. Each round replays the scheduler on the current assignment,
/// ranks ops by queue-delay/latency ratio (the ops losing the most time
/// waiting for their unit), and tries moving the worst-queued op to
/// each alternative eligible unit; the first strict makespan
/// improvement is kept and the round restarts. Terminates at a fixpoint
/// (no op improves) or after [`search_move_budget`] accepted moves.
///
/// Returns the assignment AND the per-op mapping results for it (drawn
/// from the same cost matrix the oracle replayed), so the caller's
/// final [`schedule`](crate::hhp::scheduler::schedule) reproduces the
/// searched makespan exactly instead of re-searching the map space.
pub fn search_allocation(
    cascade: &Cascade,
    machine: &MachineConfig,
    classifier: &Classifier,
    mapper: &BlackboxMapper,
    sched_opts: &ScheduleOptions,
) -> (Vec<usize>, Vec<MappedOp>) {
    search_allocation_core(cascade, machine, classifier, mapper, sched_opts, true, None)
}

/// [`search_allocation`] with the replay mode exposed: `incremental`
/// probes use [`ScheduleOracle::replay_delta`], `false` forces the
/// historical full [`ScheduleOracle::replay`] on every probe. Both
/// trajectories are bit-identical (each probe's makespan is, so every
/// accept/reject decision is) — the switch exists so the regression
/// suite can pin that equivalence; callers want [`search_allocation`].
#[doc(hidden)]
pub fn search_allocation_impl(
    cascade: &Cascade,
    machine: &MachineConfig,
    classifier: &Classifier,
    mapper: &BlackboxMapper,
    sched_opts: &ScheduleOptions,
    incremental: bool,
) -> (Vec<usize>, Vec<MappedOp>) {
    search_allocation_core(cascade, machine, classifier, mapper, sched_opts, incremental, None)
}

/// [`search_allocation`] reweighted by a measured serving-pressure
/// signal (the per-unit export of a `harp serve` run —
/// [`ServeResult::unit_pressure`](crate::runtime::serve::ServeResult)):
/// after the static search reaches its fixpoint, a second probe round
/// tries to move ops *off* the units the serving engine reported as
/// congested, hottest-home ops first and coldest target units first.
/// Every move is still accepted only on a strict replayed-makespan
/// improvement, so the pressured result is never worse than the static
/// search's — and with `None` (or an all-zero signal) the function is
/// bit-identical to [`search_allocation`].
pub fn search_allocation_pressured(
    cascade: &Cascade,
    machine: &MachineConfig,
    classifier: &Classifier,
    mapper: &BlackboxMapper,
    sched_opts: &ScheduleOptions,
    pressure: Option<&[f64]>,
) -> (Vec<usize>, Vec<MappedOp>) {
    search_allocation_core(cascade, machine, classifier, mapper, sched_opts, true, pressure)
}

fn search_allocation_core(
    cascade: &Cascade,
    machine: &MachineConfig,
    classifier: &Classifier,
    mapper: &BlackboxMapper,
    sched_opts: &ScheduleOptions,
    incremental: bool,
    pressure: Option<&[f64]>,
) -> (Vec<usize>, Vec<MappedOp>) {
    let n = cascade.ops.len();
    let mut assignment = allocate(cascade, machine, classifier);
    let eligible: Vec<Vec<usize>> = cascade
        .ops
        .iter()
        .map(|op| eligible_units(machine, classifier.classify(op)))
        .collect();
    let costs = mapper.map_units(cascade, machine, &eligible);

    let mut oracle = ScheduleOracle::new(cascade, machine, sched_opts);
    // One stats view kept in lockstep with `assignment`: probes swap a
    // single entry in and out instead of rebuilding the O(n) vector.
    let mut stats_view = cost_view(&costs, &assignment);
    let mut best = oracle.replay(&assignment, &stats_view);

    let budget = search_move_budget(n);
    let mut moves = 0usize;
    let mut ranked: Vec<usize> = (0..n).collect();
    // Ranking scratch, allocated once: probing must not allocate.
    let mut delays = vec![0.0f64; n];
    let mut lats = vec![0.0f64; n];
    while moves < budget {
        // Rank ops by queue-delay/latency ratio under the CURRENT
        // assignment (the replay above / the accepted probe left the
        // oracle's delay and latency buffers at exactly this state).
        delays.copy_from_slice(oracle.queue_delays());
        lats.copy_from_slice(oracle.latencies());
        ranked.sort_by(|&a, &b| {
            let ra = delays[a] / lats[a].max(1e-12);
            let rb = delays[b] / lats[b].max(1e-12);
            rb.total_cmp(&ra).then(a.cmp(&b))
        });
        let mut improved = false;
        'outer: for &i in &ranked {
            if eligible[i].len() < 2 {
                continue;
            }
            let home = assignment[i];
            for &u in &eligible[i] {
                if u == home {
                    continue;
                }
                assignment[i] = u;
                stats_view[i] = cost_at(&costs, i, u);
                // Probes differ from the oracle's last replay by at
                // most two moves (this op, plus the revert of the
                // previous rejected probe) — exactly the incremental
                // replay's sweet spot.
                let m = if incremental {
                    oracle.replay_delta(&assignment, &stats_view)
                } else {
                    oracle.replay(&assignment, &stats_view)
                };
                if strictly_better(m, best) {
                    best = m;
                    moves += 1;
                    improved = true;
                    break 'outer;
                }
                assignment[i] = home;
                stats_view[i] = cost_at(&costs, i, home);
            }
        }
        if !improved {
            break;
        }
        // An accepted probe was the oracle's LAST replay, so its
        // delay/latency buffers already describe the new assignment —
        // the next round ranks against fresh state without a re-replay.
    }

    // Pressure-fed refinement: starting from the static fixpoint above,
    // try to vacate the units a serving run measured as congested. Ops
    // are probed hottest-home-unit first and alternatives coldest
    // first, but acceptance is still the strict replayed-makespan test
    // against `best` — so this phase can only improve on (never
    // degrade) the static search, and a missing or all-zero signal
    // leaves the result bit-identical.
    if let Some(pr) = pressure {
        assert_eq!(
            pr.len(),
            machine.sub_accels.len(),
            "pressure signal length must match the machine's unit count"
        );
        if pr.iter().any(|&p| p != 0.0) {
            let budget = search_move_budget(n);
            let mut moves = 0usize;
            while moves < budget {
                ranked.sort_by(|&a, &b| {
                    let pa = pr[assignment[a]];
                    let pb = pr[assignment[b]];
                    pb.total_cmp(&pa).then(a.cmp(&b))
                });
                let mut improved = false;
                'outer: for &i in &ranked {
                    if eligible[i].len() < 2 {
                        continue;
                    }
                    let home = assignment[i];
                    let mut alts: Vec<usize> =
                        eligible[i].iter().copied().filter(|&u| u != home).collect();
                    alts.sort_by(|&a, &b| pr[a].total_cmp(&pr[b]).then(a.cmp(&b)));
                    for u in alts {
                        assignment[i] = u;
                        stats_view[i] = cost_at(&costs, i, u);
                        let m = if incremental {
                            oracle.replay_delta(&assignment, &stats_view)
                        } else {
                            oracle.replay(&assignment, &stats_view)
                        };
                        if strictly_better(m, best) {
                            best = m;
                            moves += 1;
                            improved = true;
                            break 'outer;
                        }
                        assignment[i] = home;
                        stats_view[i] = cost_at(&costs, i, home);
                    }
                }
                if !improved {
                    break;
                }
            }
        }
    }

    let mapped = (0..n)
        .map(|i| {
            let c = costs[i][assignment[i]]
                .as_ref()
                .expect("cost searched for every eligible unit");
            MappedOp {
                op_index: i,
                sub_accel: assignment[i],
                stats: c.stats.clone(),
                evaluated: c.evaluated,
            }
        })
        .collect();
    (assignment, mapped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::partition::{HardwareParams, MachineConfig};
    use crate::arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
    use crate::mapper::search::SearchBudget;
    use crate::workload::einsum::{Phase, TensorOp};
    use crate::workload::transformer;

    fn classifier() -> Classifier {
        Classifier::new(HardwareParams::default().tipping_ai())
    }

    #[test]
    fn homogeneous_gets_everything() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let a = allocate(&g, &m, &classifier());
        assert!(a.iter().all(|&s| s == 0));
    }

    #[test]
    fn bert_split_matches_paper() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let a = allocate(&g, &m, &classifier());
        for (i, op) in g.ops.iter().enumerate() {
            let expect_low = matches!(op.name.as_str(), "logit" | "softmax" | "attend");
            assert_eq!(a[i] == 1, expect_low, "op {} on sub {}", op.name, a[i]);
        }
    }

    #[test]
    fn decoder_phases_split() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::CrossDepth),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::decoder_cascade(&transformer::llama2());
        let a = allocate(&g, &m, &classifier());
        for (i, op) in g.ops.iter().enumerate() {
            match op.phase {
                Phase::Prefill => assert_eq!(a[i], 0, "{} should be high", op.name),
                Phase::Decode => assert_eq!(a[i], 1, "{} should be low", op.name),
                Phase::Encoder => unreachable!(),
            }
        }
    }

    #[test]
    fn multiple_low_units_balance() {
        let m = MachineConfig::build(
            &HarpClass::new(
                ComputePlacement::Hierarchical,
                HeterogeneityLoc::Compound(vec![
                    HeterogeneityLoc::cross_node(),
                    HeterogeneityLoc::CrossDepth,
                ]),
            ),
            &HardwareParams::default(),
        )
        .unwrap();
        let mut g = Cascade::new("lows");
        for i in 0..6 {
            g.push(TensorOp::gemm(&format!("v{i}"), Phase::Decode, 1, 512, 512));
        }
        let a = allocate(&g, &m, &classifier());
        // Both low units (ids 1, 2) receive work.
        assert!(a.contains(&1));
        assert!(a.contains(&2));
        assert!(!a.contains(&0));
    }

    #[test]
    fn policy_names_parse_and_round_trip() {
        for p in AllocPolicy::ALL {
            assert_eq!(AllocPolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(AllocPolicy::parse("round-robin").unwrap(), AllocPolicy::RoundRobin);
        assert_eq!(AllocPolicy::parse("CP").unwrap(), AllocPolicy::CriticalPath);
        let err = AllocPolicy::parse("optimal").unwrap_err();
        for name in ["greedy", "round_robin", "critical_path", "search"] {
            assert!(err.contains(name), "valid set missing '{name}': {err}");
        }
        assert_eq!(AllocPolicy::default(), AllocPolicy::Greedy);
    }

    #[test]
    fn round_robin_cycles_eligible_units() {
        let m = MachineConfig::build(
            &HarpClass::new(
                ComputePlacement::Hierarchical,
                HeterogeneityLoc::Compound(vec![
                    HeterogeneityLoc::cross_node(),
                    HeterogeneityLoc::CrossDepth,
                ]),
            ),
            &HardwareParams::default(),
        )
        .unwrap();
        // Two low units (1, 2): four decode ops must alternate 1,2,1,2.
        let mut g = Cascade::new("rr");
        for i in 0..4 {
            g.push(TensorOp::gemm(&format!("v{i}"), Phase::Decode, 1, 64, 64));
        }
        let a = allocate_policy(AllocPolicy::RoundRobin, &g, &m, &classifier());
        assert_eq!(a, vec![1, 2, 1, 2]);
    }

    #[test]
    fn critical_path_gives_longest_op_first_pick() {
        let m = MachineConfig::build(
            &HarpClass::new(
                ComputePlacement::Hierarchical,
                HeterogeneityLoc::Compound(vec![
                    HeterogeneityLoc::cross_node(),
                    HeterogeneityLoc::CrossDepth,
                ]),
            ),
            &HardwareParams::default(),
        )
        .unwrap();
        // One huge decode op and three tiny ones on two low units: LPT
        // takes the huge op first (it gets an empty unit) and the tiny
        // ops then pile onto the OTHER unit, whose finish time stays
        // below the huge op's.
        let mut g = Cascade::new("lpt");
        g.push(TensorOp::gemm("big", Phase::Decode, 4, 4096, 4096));
        for i in 0..3 {
            g.push(TensorOp::gemm(&format!("s{i}"), Phase::Decode, 1, 32, 32));
        }
        let a = allocate_policy(AllocPolicy::CriticalPath, &g, &m, &classifier());
        let low = eligible_units(&m, ReuseClass::Low);
        assert!(a.iter().all(|u| low.contains(u)), "decode ops stay on low units: {a:?}");
        assert!(
            a[1..].iter().all(|&u| u != a[0]),
            "the longest op should run alone on its unit: {a:?}"
        );
        // Deterministic: ties break on op index / unit id, never on
        // iteration order of a hash container.
        let b = allocate_policy(AllocPolicy::CriticalPath, &g, &m, &classifier());
        assert_eq!(a, b);
    }

    #[test]
    fn every_policy_is_valid_on_paper_workload() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::decoder_cascade(&transformer::llama2());
        let cl = classifier();
        for p in [AllocPolicy::Greedy, AllocPolicy::RoundRobin, AllocPolicy::CriticalPath] {
            let a = allocate_policy(p, &g, &m, &cl);
            assert_eq!(a.len(), g.ops.len());
            for (i, &u) in a.iter().enumerate() {
                let class = cl.classify(&g.ops[i]);
                assert!(
                    eligible_units(&m, class).contains(&u),
                    "{}: op {i} on ineligible unit {u}",
                    p.name()
                );
            }
        }
    }

    /// The schedule-aware search never ends up above its greedy start —
    /// the invariant the allocation-oracle suite extends to the
    /// enumerated optimum — and its mapped ops agree with its
    /// assignment.
    #[test]
    fn search_never_worse_than_greedy_start() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::decoder_cascade(&transformer::llama2());
        let cl = classifier();
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 10, seed: 3 });
        let opts = ScheduleOptions::default();

        let greedy = allocate(&g, &m, &cl);
        let greedy_mapped = mapper.map_cascade(&g, &m, &greedy);
        let greedy_makespan =
            crate::hhp::scheduler::schedule(&g, &m, &greedy_mapped, &opts).makespan;

        let (assignment, mapped) = search_allocation(&g, &m, &cl, &mapper, &opts);
        assert_eq!(assignment.len(), g.ops.len());
        for (i, mo) in mapped.iter().enumerate() {
            assert_eq!(mo.op_index, i);
            assert_eq!(mo.sub_accel, assignment[i]);
            let class = cl.classify(&g.ops[i]);
            assert!(eligible_units(&m, class).contains(&assignment[i]));
        }
        let searched = crate::hhp::scheduler::schedule(&g, &m, &mapped, &opts).makespan;
        assert!(
            searched <= greedy_makespan + 1e-9 * greedy_makespan,
            "search ({searched}) worse than greedy ({greedy_makespan})"
        );
    }

    /// On a single-unit machine the search is a no-op that returns the
    /// greedy assignment (every eligible set is a singleton).
    #[test]
    fn search_on_homogeneous_machine_is_greedy() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::Homogeneous),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::encoder_cascade(&transformer::bert_large());
        let cl = classifier();
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 8, seed: 1 });
        let (a, _) = search_allocation(&g, &m, &cl, &mapper, &ScheduleOptions::default());
        assert_eq!(a, allocate(&g, &m, &cl));
    }

    /// The never-worse acceptance contract of the pressured search: for
    /// any pressure signal — uniform, adversarially inverted, or
    /// hammering a single unit — the refined makespan stays at or below
    /// the static search's, because refinement starts from the static
    /// fixpoint and accepts only strict replayed improvements.
    #[test]
    fn pressured_search_never_worse_than_static() {
        let cl = classifier();
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 10, seed: 3 });
        let opts = ScheduleOptions::default();
        for het in [
            HeterogeneityLoc::cross_node(),
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::cross_node(),
                HeterogeneityLoc::CrossDepth,
            ]),
        ] {
            let m = MachineConfig::build(
                &HarpClass::new(ComputePlacement::Hierarchical, het),
                &HardwareParams::default(),
            )
            .unwrap();
            let g = transformer::decoder_cascade(&transformer::llama2());
            let (_, static_mapped) = search_allocation(&g, &m, &cl, &mapper, &opts);
            let static_makespan =
                crate::hhp::scheduler::schedule(&g, &m, &static_mapped, &opts).makespan;
            let n = m.sub_accels.len();
            let mut signals: Vec<Vec<f64>> = vec![
                vec![1.0; n],                                   // uniform heat
                (0..n).map(|u| u as f64 + 1.0).collect(),       // ascending
                (0..n).map(|u| (n - u) as f64).collect(),       // descending
            ];
            for hot in 0..n {
                let mut s = vec![0.0; n];
                s[hot] = 100.0; // hammer one unit
                signals.push(s);
            }
            for pr in &signals {
                let (assignment, mapped) =
                    search_allocation_pressured(&g, &m, &cl, &mapper, &opts, Some(pr));
                for (i, mo) in mapped.iter().enumerate() {
                    assert_eq!(mo.sub_accel, assignment[i]);
                    let class = cl.classify(&g.ops[i]);
                    assert!(eligible_units(&m, class).contains(&assignment[i]));
                }
                let pressured =
                    crate::hhp::scheduler::schedule(&g, &m, &mapped, &opts).makespan;
                assert!(
                    pressured <= static_makespan + 1e-9 * static_makespan,
                    "pressure {pr:?}: pressured ({pressured}) degraded static \
                     ({static_makespan})"
                );
            }
        }
    }

    /// `None` and an all-zero signal short-circuit the refinement: the
    /// pressured entry point is bit-identical to the static search.
    #[test]
    fn pressured_search_without_signal_is_bit_identical() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap();
        let g = transformer::decoder_cascade(&transformer::llama2());
        let cl = classifier();
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 10, seed: 3 });
        let opts = ScheduleOptions::default();
        let (a_static, m_static) = search_allocation(&g, &m, &cl, &mapper, &opts);
        let zeros = vec![0.0; m.sub_accels.len()];
        for pr in [None, Some(zeros.as_slice())] {
            let (a, mo) = search_allocation_pressured(&g, &m, &cl, &mapper, &opts, pr);
            assert_eq!(a, a_static);
            for (x, y) in mo.iter().zip(&m_static) {
                assert_eq!(x.sub_accel, y.sub_accel);
                assert_eq!(x.stats.cycles.to_bits(), y.stats.cycles.to_bits());
            }
        }
    }
}
