//! Overlap-aware cascade scheduler.
//!
//! Event-driven list scheduling of the cascade DAG over the machine's
//! sub-accelerators: each sub-accelerator runs one operation at a time;
//! ready operations are dispatched to their assigned unit by descending
//! critical-path priority. This is what realises the paper's headline
//! mechanism — hiding low-reuse operations behind high-reuse ones on
//! heterogeneous machines — and its absence on homogeneous ones, where
//! every op serialises on the single unit.
//!
//! DRAM bandwidth is statically partitioned by the resource partitioner
//! (the paper's policy) as per-edge shares of the machine tree. With
//! [`ScheduleOptions::dynamic_bw`], idle units' shares are re-granted
//! to the busy ones along the tree
//! ([`MachineConfig::dynamic_dram_bw`]) — an ablation the paper hints
//! at when discussing partitioning sensitivity. The scheduler is
//! N-unit: any number of sub-accelerators contend, not a 2-way split.
//!
//! When the machine was flattened under
//! [`ContentionMode::Booked`](crate::arch::topology::ContentionMode),
//! the dynamic re-grant generalises from the DRAM root to EVERY shared
//! node: each op's latency is recomputed against the full per-boundary
//! grant vector ([`MachineConfig::contended_boundary_bw`]), so a unit
//! sharing an LLB with an idle sibling temporarily inherits the whole
//! edge, exactly as it inherits idle DRAM shares. Under
//! `ContentionMode::Off` the historical DRAM-only path runs unchanged,
//! bit-identically.
//!
//! Dependency queries go through a [`CascadeAdj`] built once per
//! schedule — the naive `Cascade::predecessors`/`successors` accessors
//! are O(E) with a fresh `Vec` per call, which made `priorities()` and
//! the ready-set updates O(V·E) on large cascades (see the scheduler
//! section of `benches/perf_hotpath.rs` for the before/after).

use crate::arch::partition::MachineConfig;
use crate::mapper::blackbox::MappedOp;
use crate::model::stats::OpStats;
use crate::workload::cascade::{Cascade, CascadeAdj};

/// Scheduler knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScheduleOptions {
    /// Re-grant idle sub-accelerators' DRAM bandwidth to busy ones.
    pub dynamic_bw: bool,
}

/// One scheduled execution interval.
#[derive(Debug, Clone)]
pub struct Interval {
    pub op: usize,
    pub sub_accel: usize,
    pub start: f64,
    pub end: f64,
}

/// Scheduling outcome.
#[derive(Debug, Clone)]
pub struct ScheduleResult {
    /// Total cascade latency in cycles.
    pub makespan: f64,
    pub intervals: Vec<Interval>,
    /// Busy cycles per sub-accelerator.
    pub busy: Vec<f64>,
}

impl ScheduleResult {
    /// Fraction of time sub-accelerator `s` is busy.
    pub fn busy_fraction(&self, s: usize) -> f64 {
        if self.makespan == 0.0 {
            0.0
        } else {
            self.busy[s] / self.makespan
        }
    }

    /// PE-weighted utilisation timeline in `buckets` equal slices of the
    /// makespan: fraction of total machine PEs busy in each slice (the
    /// Fig 6 utilisation zoom).
    pub fn utilization_timeline(&self, machine: &MachineConfig, buckets: usize) -> Vec<f64> {
        let total_pes: f64 = machine.total_pes() as f64;
        let mut out = vec![0.0f64; buckets];
        // Zero buckets or a degenerate makespan (empty cascade, all
        // zero-cost ops, or a non-finite schedule) would make `width`
        // zero/inf/NaN and the bucket divisions below meaningless — the
        // all-idle timeline is the only sensible answer.
        if buckets == 0 || !self.makespan.is_finite() || self.makespan <= 0.0 {
            return out;
        }
        let width = self.makespan / buckets as f64;
        for iv in &self.intervals {
            let pes = machine.sub_accels[iv.sub_accel].spec.peak_macs() as f64;
            let first = (iv.start / width).floor() as usize;
            let last = ((iv.end / width).ceil() as usize).min(buckets);
            for (b, slot) in out.iter_mut().enumerate().take(last).skip(first) {
                let lo = (b as f64) * width;
                let hi = lo + width;
                let overlap = (iv.end.min(hi) - iv.start.max(lo)).max(0.0);
                *slot += overlap / width * pes / total_pes;
            }
        }
        out
    }
}

/// Critical-path priorities: longest downstream path including self.
fn priorities(cascade: &Cascade, adj: &CascadeAdj, latency: &[f64]) -> Vec<f64> {
    let order = cascade.topo_order_with(adj).expect("valid DAG");
    let mut prio = vec![0.0f64; cascade.ops.len()];
    for &i in order.iter().rev() {
        let down = adj.succs[i].iter().map(|&s| prio[s]).fold(0.0f64, f64::max);
        prio[i] = latency[i] + down;
    }
    prio
}

/// Schedule `cascade` with per-op mapping results on `machine`.
pub fn schedule(
    cascade: &Cascade,
    machine: &MachineConfig,
    mapped: &[MappedOp],
    opts: &ScheduleOptions,
) -> ScheduleResult {
    let n = cascade.ops.len();
    assert_eq!(mapped.len(), n);
    let nsub = machine.sub_accels.len();

    // Adjacency built once: every dependency query below indexes it.
    let adj = CascadeAdj::new(cascade);

    // Baseline latency per op under the static bandwidth partition.
    let static_latency: Vec<f64> = (0..n)
        .map(|i| mapped[i].stats.cycles * cascade.ops[i].count as f64)
        .collect();
    let prio = priorities(cascade, &adj, &static_latency);

    // Dependency bookkeeping.
    let mut remaining_preds: Vec<usize> = (0..n).map(|i| adj.preds[i].len()).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| remaining_preds[i] == 0).collect();
    let mut done = vec![false; n];
    let mut scheduled = vec![false; n];

    // Per-sub-accelerator state.
    let mut sub_free_at = vec![0.0f64; nsub];
    let mut running: Vec<Option<(usize, f64)>> = vec![None; nsub]; // (op, end)
    let mut busy_buf = vec![false; nsub]; // reused per dynamic-bw query
    // Shared-node lookup tables, built once (like the adjacency): the
    // per-dispatch grant queries must not rebuild them.
    let booked = machine.contention == crate::arch::topology::ContentionMode::Booked;
    let contention_ctx =
        if opts.dynamic_bw && booked { Some(machine.contention_ctx()) } else { None };
    let mut bw_buf: Vec<f64> = Vec::new(); // reused per contended grant query
    let mut now = 0.0f64;
    let mut intervals: Vec<Interval> = Vec::with_capacity(n);
    let mut busy = vec![0.0f64; nsub];
    let mut completed = 0usize;

    while completed < n {
        // Dispatch every idle sub-accelerator's best ready op.
        let mut dispatched_any = true;
        while dispatched_any {
            dispatched_any = false;
            // Number of busy units AFTER this dispatch round is unknown;
            // approximate dynamic bandwidth with the count of currently
            // busy units + 1 (self).
            for s in 0..nsub {
                if running[s].is_some() {
                    continue;
                }
                // Highest-priority ready op assigned to s.
                let pick = ready
                    .iter()
                    .copied()
                    .filter(|&i| !scheduled[i] && mapped[i].sub_accel == s)
                    // total_cmp: a degenerate (NaN) latency upstream must
                    // not panic the dispatch loop (mirrors the allocator's
                    // tie-break; identical ordering on non-NaN priorities).
                    .max_by(|&a, &b| prio[a].total_cmp(&prio[b]));
                if let Some(i) = pick {
                    let lat = if opts.dynamic_bw {
                        // Idle units' bandwidth is re-granted along the
                        // machine tree, proportionally to the busy
                        // units' static edge shares.
                        for (x, slot) in busy_buf.iter_mut().enumerate() {
                            *slot = running[x].is_some() || x == s;
                        }
                        let cycles = if let Some(ctx) = &contention_ctx {
                            // Booked machines arbitrate every shared
                            // node, not just DRAM: the grant vector
                            // covers all boundaries.
                            machine.contended_boundary_bw_into(
                                ctx, s, &busy_buf, &mut bw_buf,
                            );
                            mapped[i].stats.latency_with_boundary_bw(&bw_buf)
                        } else {
                            let my_bw = machine.dynamic_dram_bw(s, &busy_buf);
                            mapped[i].stats.latency_with_dram_bw(my_bw)
                        };
                        cycles * cascade.ops[i].count as f64
                    } else {
                        static_latency[i]
                    };
                    let start = now.max(sub_free_at[s]);
                    let end = start + lat;
                    running[s] = Some((i, end));
                    scheduled[i] = true;
                    intervals.push(Interval { op: i, sub_accel: s, start, end });
                    busy[s] += lat;
                    dispatched_any = true;
                }
            }
        }

        // Advance to the earliest completion.
        let next_end = running
            .iter()
            .flatten()
            .map(|&(_, end)| end)
            .fold(f64::INFINITY, f64::min);
        if !next_end.is_finite() {
            // Nothing running but not all complete → dependency deadlock
            // (cannot happen on a valid DAG with total assignment).
            panic!("scheduler stalled: no runnable op at t={now}");
        }
        now = next_end;
        for s in 0..nsub {
            if let Some((i, end)) = running[s] {
                if end <= now + 1e-9 {
                    running[s] = None;
                    sub_free_at[s] = end;
                    done[i] = true;
                    completed += 1;
                    for &succ in &adj.succs[i] {
                        remaining_preds[succ] -= 1;
                        if remaining_preds[succ] == 0 {
                            ready.push(succ);
                        }
                    }
                }
            }
        }
    }

    ScheduleResult { makespan: now, intervals, busy }
}

/// Reusable scheduling cost oracle for the allocation-policy search
/// ([`AllocPolicy::Search`](crate::hhp::allocator::AllocPolicy)).
///
/// [`schedule`] rebuilds the [`CascadeAdj`], the topological order, and
/// the shared-node contention tables on every call — fine once per
/// evaluation, wasteful when a local search probes hundreds of
/// assignments of the SAME cascade on the SAME machine. The oracle
/// builds those once and exposes [`ScheduleOracle::replay`], which runs
/// the identical event-driven list-scheduling loop over reused buffers
/// and returns the makespan. For any `(assignment, stats)` pair,
/// `replay` is **bit-identical** to `schedule(..).makespan` with the
/// same options (property-tested) — so a makespan the search accepted
/// is exactly the makespan the final evaluation reports.
///
/// After a replay the oracle also exposes per-op queue delays (time an
/// op sat ready but waiting for its assigned unit) and scheduled
/// latencies — the signal the local search ranks its moves by.
///
/// For probe sequences that differ by one (or few) op moves,
/// [`ScheduleOracle::replay_delta`] replays incrementally: the oracle
/// records the dispatch timeline of the last replay and mechanically
/// reuses the untouched prefix, re-deciding only from the first instant
/// a moved op could have influenced the schedule. The result is
/// bit-identical to a full replay (see the method's contract).
pub struct ScheduleOracle<'a> {
    cascade: &'a Cascade,
    machine: &'a MachineConfig,
    opts: ScheduleOptions,
    adj: CascadeAdj,
    order: Vec<usize>,
    contention_ctx: Option<crate::arch::partition::ContentionCtx>,
    // Reused per replay (SoA arenas — no per-probe allocation):
    lat: Vec<f64>,
    prio: Vec<f64>,
    remaining_preds: Vec<usize>,
    ready: Vec<usize>,
    scheduled: Vec<bool>,
    running: Vec<Option<(usize, f64)>>,
    sub_free_at: Vec<f64>,
    busy_buf: Vec<bool>,
    bw_buf: Vec<f64>,
    start: Vec<f64>,
    end: Vec<f64>,
    ready_at: Vec<f64>,
    delay: Vec<f64>,
    sched_lat: Vec<f64>,
    // Record of the LAST replay, consumed by `replay_delta`: the
    // assignment and priorities it ran under, plus the dispatch
    // timeline (op, dispatch-round time) in chronological order.
    // `start`/`end`/`ready_at` above double as the recorded per-op
    // times of that replay.
    prev_assignment: Vec<usize>,
    prev_prio: Vec<f64>,
    disp_op: Vec<usize>,
    disp_now: Vec<f64>,
    has_timeline: bool,
    prev_makespan: f64,
    full_replays: usize,
    fast_replays: usize,
}

impl<'a> ScheduleOracle<'a> {
    pub fn new(
        cascade: &'a Cascade,
        machine: &'a MachineConfig,
        opts: &ScheduleOptions,
    ) -> ScheduleOracle<'a> {
        let n = cascade.ops.len();
        let nsub = machine.sub_accels.len();
        let adj = CascadeAdj::new(cascade);
        let order = cascade.topo_order_with(&adj).expect("valid DAG");
        let booked = machine.contention == crate::arch::topology::ContentionMode::Booked;
        let contention_ctx =
            if opts.dynamic_bw && booked { Some(machine.contention_ctx()) } else { None };
        ScheduleOracle {
            cascade,
            machine,
            opts: *opts,
            adj,
            order,
            contention_ctx,
            lat: vec![0.0; n],
            prio: vec![0.0; n],
            remaining_preds: vec![0; n],
            ready: Vec::with_capacity(n),
            scheduled: vec![false; n],
            running: vec![None; nsub],
            sub_free_at: vec![0.0; nsub],
            busy_buf: vec![false; nsub],
            bw_buf: Vec::new(),
            start: vec![0.0; n],
            end: vec![0.0; n],
            ready_at: vec![0.0; n],
            delay: vec![0.0; n],
            sched_lat: vec![0.0; n],
            prev_assignment: Vec::with_capacity(n),
            prev_prio: vec![0.0; n],
            disp_op: Vec::with_capacity(n),
            disp_now: Vec::with_capacity(n),
            has_timeline: false,
            prev_makespan: 0.0,
            full_replays: 0,
            fast_replays: 0,
        }
    }

    /// Makespan of list-scheduling the cascade with op `i` on unit
    /// `assignment[i]` at per-repetition cost `stats[i]` — the same
    /// event loop as [`schedule`], over prebuilt adjacency/contention
    /// tables and reused buffers, recording no intervals.
    pub fn replay(&mut self, assignment: &[usize], stats: &[&OpStats]) -> f64 {
        let n = self.cascade.ops.len();
        assert_eq!(assignment.len(), n);
        assert_eq!(stats.len(), n);
        self.compute_lat_prio(stats);
        self.full_replay_from_scratch(assignment, stats)
    }

    /// Incremental replay: bit-identical to [`ScheduleOracle::replay`]
    /// (and thus to `schedule().makespan`), but reusing the untouched
    /// prefix of the LAST replay's timeline when only a few ops moved.
    ///
    /// # Caller contract
    ///
    /// Across consecutive calls on one oracle, `stats[i]` must be a
    /// pure function of `(i, assignment[i])`: moving an op to a unit
    /// and back must present bitwise-identical stats for it, and an op
    /// whose assignment is unchanged must keep bitwise-identical stats.
    /// The allocation search satisfies this by construction (its stats
    /// view indexes a fixed per-(op, unit) cost matrix). Under that
    /// contract the replay state before the first moved op becomes
    /// ready provably coincides with the previous replay, so the
    /// recorded prefix is replayed mechanically — no candidate scans,
    /// no bandwidth arbitration — and the event loop only *decides*
    /// from the first instant a changed op could participate. When a
    /// moved op's priority change propagates to a source (a move on the
    /// critical path), the dirty cone covers the cascade and the oracle
    /// falls back to a full replay.
    pub fn replay_delta(&mut self, assignment: &[usize], stats: &[&OpStats]) -> f64 {
        let n = self.cascade.ops.len();
        assert_eq!(assignment.len(), n);
        assert_eq!(stats.len(), n);
        self.compute_lat_prio(stats);
        if !self.has_timeline {
            return self.full_replay_from_scratch(assignment, stats);
        }

        // Dirty ops: moved, or priority changed (a moved op's latency
        // change propagates upward exactly along max-successor paths —
        // comparing recomputed priorities bitwise captures that cone
        // precisely, instead of pessimistically dirtying all ancestors).
        // The schedule provably coincides with the recorded one at
        // every round strictly before the earliest time a dirty op
        // became ready in the previous replay.
        let mut t_dirty = f64::INFINITY;
        let mut any_dirty = false;
        for i in 0..n {
            if assignment[i] != self.prev_assignment[i]
                || self.prio[i].to_bits() != self.prev_prio[i].to_bits()
            {
                any_dirty = true;
                if self.ready_at[i] < t_dirty {
                    t_dirty = self.ready_at[i];
                }
            }
        }
        if !any_dirty {
            self.fast_replays += 1;
            return self.prev_makespan;
        }
        if t_dirty <= 1e-9 {
            // A dirty op is ready at t=0 (source, or critical-path
            // propagation reached one): no reusable prefix.
            return self.full_replay_from_scratch(assignment, stats);
        }

        self.reset_sim();
        let nsub = self.machine.sub_accels.len();
        let mut now = 0.0f64;
        let mut completed = 0usize;
        let mut cursor = 0usize;
        // Mechanical prefix: consume the recorded dispatches round by
        // round (matched by bitwise round time), applying recorded
        // start/end times. The completion epsilon lets an op join a
        // round up to 1e-9 before its ready time, hence the guard.
        while completed < n && now < t_dirty - 1e-9 {
            while cursor < self.disp_op.len()
                && self.disp_now[cursor].to_bits() == now.to_bits()
            {
                let i = self.disp_op[cursor];
                let s = self.prev_assignment[i];
                if self.running[s].is_some() {
                    // Recorded later in this round's time but after a
                    // completion at the same instant — next iteration.
                    break;
                }
                self.running[s] = Some((i, self.end[i]));
                self.scheduled[i] = true;
                cursor += 1;
            }
            let next_end = self
                .running
                .iter()
                .flatten()
                .map(|&(_, end)| end)
                .fold(f64::INFINITY, f64::min);
            if !next_end.is_finite() {
                panic!(
                    "incremental replay diverged from recorded timeline at t={now} \
                     (stats not a pure function of (op, assignment)?)"
                );
            }
            now = next_end;
            for s in 0..nsub {
                if let Some((i, end)) = self.running[s] {
                    if end <= now + 1e-9 {
                        self.running[s] = None;
                        self.sub_free_at[s] = end;
                        completed += 1;
                        for &succ in &self.adj.succs[i] {
                            self.remaining_preds[succ] -= 1;
                            if self.remaining_preds[succ] == 0 {
                                self.ready.push(succ);
                                self.ready_at[succ] = end;
                            }
                        }
                    }
                }
            }
        }
        // Keep the consumed prefix of the record; the live loop appends
        // its own dispatches after it.
        self.disp_op.truncate(cursor);
        self.disp_now.truncate(cursor);
        let makespan = self.run_live(assignment, stats, now, completed);
        self.record_replay(assignment, makespan);
        self.fast_replays += 1;
        makespan
    }

    /// (full, incremental) replay counts — incremental includes the
    /// no-change fast path; full includes fallbacks taken by
    /// [`ScheduleOracle::replay_delta`].
    pub fn replay_counts(&self) -> (usize, usize) {
        (self.full_replays, self.fast_replays)
    }

    /// Per-op latency (`stats.cycles × count`) and critical-path
    /// priorities, identical to `priorities()` but over the stored
    /// topological order.
    fn compute_lat_prio(&mut self, stats: &[&OpStats]) {
        let n = self.cascade.ops.len();
        for i in 0..n {
            self.lat[i] = stats[i].cycles * self.cascade.ops[i].count as f64;
        }
        for &i in self.order.iter().rev() {
            let down =
                self.adj.succs[i].iter().map(|&s| self.prio[s]).fold(0.0f64, f64::max);
            self.prio[i] = self.lat[i] + down;
        }
    }

    fn reset_sim(&mut self) {
        let n = self.cascade.ops.len();
        let nsub = self.machine.sub_accels.len();
        self.ready.clear();
        for i in 0..n {
            self.remaining_preds[i] = self.adj.preds[i].len();
            if self.remaining_preds[i] == 0 {
                self.ready.push(i);
            }
            self.scheduled[i] = false;
            self.ready_at[i] = 0.0;
        }
        for s in 0..nsub {
            self.running[s] = None;
            self.sub_free_at[s] = 0.0;
        }
    }

    fn full_replay_from_scratch(&mut self, assignment: &[usize], stats: &[&OpStats]) -> f64 {
        self.reset_sim();
        self.disp_op.clear();
        self.disp_now.clear();
        let makespan = self.run_live(assignment, stats, 0.0, 0);
        self.record_replay(assignment, makespan);
        self.full_replays += 1;
        makespan
    }

    /// The deciding event loop, resumable from `(now, completed)` with
    /// the simulation buffers describing that instant. Records every
    /// dispatch into the timeline.
    fn run_live(
        &mut self,
        assignment: &[usize],
        stats: &[&OpStats],
        mut now: f64,
        mut completed: usize,
    ) -> f64 {
        let n = self.cascade.ops.len();
        let nsub = self.machine.sub_accels.len();
        while completed < n {
            let mut dispatched_any = true;
            while dispatched_any {
                dispatched_any = false;
                for s in 0..nsub {
                    if self.running[s].is_some() {
                        continue;
                    }
                    let pick = self
                        .ready
                        .iter()
                        .copied()
                        .filter(|&i| !self.scheduled[i] && assignment[i] == s)
                        // total_cmp, like `schedule()`: NaN-latency ops
                        // must not panic the replay loop either.
                        .max_by(|&a, &b| self.prio[a].total_cmp(&self.prio[b]));
                    if let Some(i) = pick {
                        let lat = if self.opts.dynamic_bw {
                            for (x, slot) in self.busy_buf.iter_mut().enumerate() {
                                *slot = self.running[x].is_some() || x == s;
                            }
                            let cycles = if let Some(ctx) = &self.contention_ctx {
                                self.machine.contended_boundary_bw_into(
                                    ctx,
                                    s,
                                    &self.busy_buf,
                                    &mut self.bw_buf,
                                );
                                stats[i].latency_with_boundary_bw(&self.bw_buf)
                            } else {
                                let my_bw = self.machine.dynamic_dram_bw(s, &self.busy_buf);
                                stats[i].latency_with_dram_bw(my_bw)
                            };
                            cycles * self.cascade.ops[i].count as f64
                        } else {
                            self.lat[i]
                        };
                        let start = now.max(self.sub_free_at[s]);
                        let end = start + lat;
                        self.running[s] = Some((i, end));
                        self.scheduled[i] = true;
                        self.start[i] = start;
                        self.end[i] = end;
                        self.disp_op.push(i);
                        self.disp_now.push(now);
                        dispatched_any = true;
                    }
                }
            }

            let next_end = self
                .running
                .iter()
                .flatten()
                .map(|&(_, end)| end)
                .fold(f64::INFINITY, f64::min);
            if !next_end.is_finite() {
                panic!("scheduler stalled: no runnable op at t={now}");
            }
            now = next_end;
            for s in 0..nsub {
                if let Some((i, end)) = self.running[s] {
                    if end <= now + 1e-9 {
                        self.running[s] = None;
                        self.sub_free_at[s] = end;
                        completed += 1;
                        for &succ in &self.adj.succs[i] {
                            self.remaining_preds[succ] -= 1;
                            if self.remaining_preds[succ] == 0 {
                                self.ready.push(succ);
                                self.ready_at[succ] = end;
                            }
                        }
                    }
                }
            }
        }
        now
    }

    /// Finalise a replay: derive queue delays / scheduled latencies and
    /// snapshot the assignment + priorities the timeline ran under.
    fn record_replay(&mut self, assignment: &[usize], makespan: f64) {
        let n = self.cascade.ops.len();
        for i in 0..n {
            self.delay[i] = self.start[i] - self.ready_at[i];
            self.sched_lat[i] = self.end[i] - self.start[i];
        }
        self.prev_assignment.clear();
        self.prev_assignment.extend_from_slice(assignment);
        self.prev_prio.copy_from_slice(&self.prio);
        self.prev_makespan = makespan;
        self.has_timeline = true;
    }

    /// Per-op queue delay of the LAST replay: how long each op sat with
    /// all dependencies met, waiting for its assigned unit.
    pub fn queue_delays(&self) -> &[f64] {
        &self.delay
    }

    /// Per-op scheduled latency of the LAST replay.
    pub fn latencies(&self) -> &[f64] {
        &self.sched_lat
    }

    /// Accumulate the LAST replay's queueing pressure onto a per-unit
    /// signal: each op adds its queue-delay/latency ratio to
    /// `pressure[assignment[i]]`, in op order — in-place, so repeated
    /// decay-then-accumulate loops stay bitwise deterministic.
    pub fn accumulate_pressure(&self, assignment: &[usize], pressure: &mut [f64]) {
        for (i, (&d, &l)) in self.delay.iter().zip(&self.sched_lat).enumerate() {
            pressure[assignment[i]] += d / l.max(1e-9);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::partition::{HardwareParams, MachineConfig};
    use crate::arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
    use crate::model::stats::OpStats;
    use crate::workload::einsum::{Phase, TensorOp};

    fn machine_het() -> MachineConfig {
        MachineConfig::build(
            &HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap()
    }

    fn mapped_op(i: usize, sub: usize, cycles: f64) -> MappedOp {
        let mut stats = OpStats::new_empty();
        stats.cycles = cycles;
        stats.compute_cycles = cycles;
        stats.onchip_bound_cycles = cycles;
        MappedOp { op_index: i, sub_accel: sub, stats, evaluated: 0 }
    }

    fn chain3() -> Cascade {
        let mut g = Cascade::new("chain");
        for i in 0..3 {
            g.push(TensorOp::gemm(&format!("o{i}"), Phase::Encoder, 4, 4, 4));
        }
        g.dep(0, 1);
        g.dep(1, 2);
        g
    }

    #[test]
    fn serial_chain_sums() {
        let g = chain3();
        let m = machine_het();
        let mapped = vec![mapped_op(0, 0, 10.0), mapped_op(1, 0, 20.0), mapped_op(2, 0, 30.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        assert_eq!(r.makespan, 60.0);
        assert_eq!(r.busy[0], 60.0);
        assert_eq!(r.busy[1], 0.0);
    }

    #[test]
    fn independent_ops_overlap_across_units() {
        let mut g = Cascade::new("par");
        g.push(TensorOp::gemm("a", Phase::Encoder, 4, 4, 4));
        g.push(TensorOp::gemm("b", Phase::Encoder, 4, 4, 4));
        let m = machine_het();
        let mapped = vec![mapped_op(0, 0, 100.0), mapped_op(1, 1, 80.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        assert_eq!(r.makespan, 100.0); // fully overlapped
        assert!(r.busy_fraction(1) < 1.0);
    }

    #[test]
    fn same_unit_serialises() {
        let mut g = Cascade::new("par-same");
        g.push(TensorOp::gemm("a", Phase::Encoder, 4, 4, 4));
        g.push(TensorOp::gemm("b", Phase::Encoder, 4, 4, 4));
        let m = machine_het();
        let mapped = vec![mapped_op(0, 0, 100.0), mapped_op(1, 0, 80.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        assert_eq!(r.makespan, 180.0);
    }

    #[test]
    fn respects_dependencies_across_units() {
        let mut g = Cascade::new("xdep");
        g.push(TensorOp::gemm("a", Phase::Encoder, 4, 4, 4));
        g.push(TensorOp::gemm("b", Phase::Encoder, 4, 4, 4));
        g.dep(0, 1);
        let m = machine_het();
        let mapped = vec![mapped_op(0, 0, 50.0), mapped_op(1, 1, 50.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        assert_eq!(r.makespan, 100.0);
        let b = r.intervals.iter().find(|iv| iv.op == 1).unwrap();
        assert_eq!(b.start, 50.0);
    }

    #[test]
    fn makespan_bounds() {
        // makespan ≥ critical path and ≤ serial sum.
        let g = chain3();
        let m = machine_het();
        let mapped = vec![mapped_op(0, 0, 7.0), mapped_op(1, 1, 11.0), mapped_op(2, 0, 13.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        let lats = [7.0, 11.0, 13.0];
        let cp = g.critical_path(|i| lats[i]);
        assert!(r.makespan >= cp - 1e-9);
        assert!(r.makespan <= lats.iter().sum::<f64>() + 1e-9);
    }

    #[test]
    fn count_repetitions_scale_latency() {
        let mut g = Cascade::new("rep");
        g.push(TensorOp::gemm("a", Phase::Decode, 4, 4, 4).repeated(10));
        let m = machine_het();
        let mapped = vec![mapped_op(0, 1, 5.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        assert_eq!(r.makespan, 50.0);
    }

    #[test]
    fn priority_prefers_critical_path() {
        // Two ready ops on the same unit; the one feeding a long chain
        // must run first.
        let mut g = Cascade::new("prio");
        let a = g.push(TensorOp::gemm("a", Phase::Encoder, 4, 4, 4));
        let b = g.push(TensorOp::gemm("b", Phase::Encoder, 4, 4, 4));
        let c = g.push(TensorOp::gemm("c", Phase::Encoder, 4, 4, 4));
        g.dep(a, c);
        let m = machine_het();
        // a feeds c (c on the other unit); b is standalone.
        let mapped =
            vec![mapped_op(a, 0, 10.0), mapped_op(b, 0, 10.0), mapped_op(c, 1, 100.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        let ia = r.intervals.iter().find(|iv| iv.op == a).unwrap();
        let ib = r.intervals.iter().find(|iv| iv.op == b).unwrap();
        assert!(ia.start < ib.start);
        assert_eq!(r.makespan, 110.0);
    }

    #[test]
    fn utilization_timeline_sums_to_busy_share() {
        let mut g = Cascade::new("tl");
        g.push(TensorOp::gemm("a", Phase::Encoder, 4, 4, 4));
        let m = machine_het();
        let mapped = vec![mapped_op(0, 0, 100.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        let tl = r.utilization_timeline(&m, 10);
        let frac_high = m.sub_accels[0].spec.peak_macs() as f64 / m.total_pes() as f64;
        for v in tl {
            assert!((v - frac_high).abs() < 1e-9);
        }
    }

    /// N-unit scheduling: a ≥3-sub-accelerator machine overlaps
    /// independent ops across every unit, and per-unit busy fractions
    /// stay consistent with the makespan (Σ busy == Σ op latencies).
    #[test]
    fn n_unit_machine_overlaps_and_busy_is_consistent() {
        let m = MachineConfig::build(
            &HarpClass::new(
                ComputePlacement::Hierarchical,
                HeterogeneityLoc::Compound(vec![
                    HeterogeneityLoc::cross_node(),
                    HeterogeneityLoc::CrossDepth,
                ]),
            ),
            &HardwareParams::default(),
        )
        .unwrap();
        assert!(m.sub_accels.len() >= 3);
        let mut g = Cascade::new("tri");
        for i in 0..3 {
            g.push(TensorOp::gemm(&format!("o{i}"), Phase::Encoder, 4, 4, 4));
        }
        let mapped =
            vec![mapped_op(0, 0, 100.0), mapped_op(1, 1, 70.0), mapped_op(2, 2, 40.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        assert_eq!(r.makespan, 100.0); // fully overlapped across 3 units
        let total_busy: f64 = r.busy.iter().sum();
        assert!((total_busy - 210.0).abs() < 1e-9);
        for s in 0..m.sub_accels.len() {
            assert!((r.busy_fraction(s) * r.makespan - r.busy[s]).abs() < 1e-9);
        }
    }

    /// Booked-contention machines re-grant SHARED-NODE bandwidth, not
    /// just DRAM: a unit whose op is bound by a shared intermediate edge
    /// runs at the full edge rate while its co-attached sibling idles.
    #[test]
    fn booked_contention_regrants_shared_edge_to_solo_unit() {
        use crate::arch::level::LevelKind;
        use crate::arch::topology::{AccelNode, ContentionMode, MachineTopology};
        use crate::arch::partition::Role;
        use crate::arch::spec::MappingConstraints;

        let mut t = MachineTopology::new("deep-shared", 256.0);
        let llb = t.add_node(0, LevelKind::LLB, "llb", 1 << 20, 256.0, None);
        let l2 = t.add_node(llb, LevelKind::named("L2"), "l2.shared", 65536, 96.0, None);
        let l1 = t.add_node(l2, LevelKind::L1, "l1.deep", 8192, 256.0, None);
        for (label, attach, share) in [("deep", l1, 64.0), ("near", l2, 192.0)] {
            t.add_accel(AccelNode {
                label: label.into(),
                ty: label.into(),
                role: Role::Unified,
                rows: 8,
                cols: 8,
                rf_bytes_per_pe: 64,
                attach,
                attach_bw: 128.0,
                dram_share: share,
                capacity_share: None,
                mac_energy_pj: 0.2,
                fsm_group: None,
                constraints: MappingConstraints::default(),
            });
        }
        let m = MachineConfig::from_topology(t)
            .unwrap()
            .with_contention(ContentionMode::Booked)
            .unwrap();

        // Op on the deep unit bound by the shared l2 uplink: 9600 words
        // over an edge whose static booked share is 96 · 64/256 = 24
        // w/cyc → 400 cycles; the whole edge serves it in 100.
        let mut g = Cascade::new("solo");
        g.push(TensorOp::gemm("a", Phase::Decode, 4, 4, 4));
        let mut stats = OpStats::new_empty();
        stats.compute_cycles = 1.0;
        stats.onchip_bound_cycles = 400.0;
        stats.cycles = 400.0;
        stats.boundary_words = vec![
            (LevelKind::L1, 1.0),
            (LevelKind::named("L2"), 1.0),
            (LevelKind::LLB, 9600.0),
            (LevelKind::DRAM, 64.0),
        ];
        stats.dram_words = 64.0;
        let mapped = vec![MappedOp { op_index: 0, sub_accel: 0, stats, evaluated: 0 }];

        let stat = schedule(&g, &m, &mapped, &ScheduleOptions { dynamic_bw: false });
        assert_eq!(stat.makespan, 400.0); // static booked partition
        let dyn_ = schedule(&g, &m, &mapped, &ScheduleOptions { dynamic_bw: true });
        assert!((dyn_.makespan - 100.0).abs() < 1e-9); // whole edge re-granted
    }

    /// The oracle's replay is bit-identical to `schedule().makespan`
    /// for random DAGs × random assignments, in both the static and the
    /// dynamic-bandwidth mode — the contract that lets the allocation
    /// search trust its probes. One oracle is reused across every
    /// replay, exercising the buffer reset paths.
    #[test]
    fn oracle_replay_matches_schedule_bit_exactly() {
        use crate::util::rng::Rng;
        let m = machine_het();
        for seed in [1u64, 7, 42, 99] {
            let mut rng = Rng::new(seed);
            let n = 3 + rng.next_below(8);
            let mut g = Cascade::new("r");
            for i in 0..n {
                g.push(TensorOp::gemm(&format!("o{i}"), Phase::Encoder, 8, 8, 8));
            }
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.next_f64() < 0.3 {
                        g.dep(i, j);
                    }
                }
            }
            let mapped: Vec<MappedOp> = (0..n)
                .map(|i| {
                    mapped_op(i, rng.next_below(2), 5.0 + rng.next_below(100) as f64)
                })
                .collect();
            let assignment: Vec<usize> = mapped.iter().map(|mo| mo.sub_accel).collect();
            let stats: Vec<&crate::model::stats::OpStats> =
                mapped.iter().map(|mo| &mo.stats).collect();
            for dynamic_bw in [false, true] {
                let opts = ScheduleOptions { dynamic_bw };
                let full = schedule(&g, &m, &mapped, &opts);
                let mut oracle = ScheduleOracle::new(&g, &m, &opts);
                // Twice on the same oracle: the second replay runs over
                // reused (dirty) buffers and must agree.
                assert_eq!(oracle.replay(&assignment, &stats), full.makespan);
                assert_eq!(oracle.replay(&assignment, &stats), full.makespan);
            }
        }
    }

    /// Replay equivalence holds on booked-contention machines too (the
    /// per-boundary grant path).
    #[test]
    fn oracle_replay_matches_schedule_on_booked_machine() {
        let m = MachineConfig::build(
            &HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::cross_node()),
            &HardwareParams::default(),
        )
        .unwrap()
        .with_contention(crate::arch::topology::ContentionMode::Booked)
        .unwrap();
        let mut g = Cascade::new("bk");
        for i in 0..4 {
            g.push(TensorOp::gemm(&format!("o{i}"), Phase::Decode, 4, 64, 64));
        }
        g.dep(0, 2);
        let mut mapped = Vec::new();
        for (i, sub) in [(0usize, 1usize), (1, 2), (2, 1), (3, 2)] {
            let mut stats = OpStats::new_empty();
            stats.compute_cycles = 10.0;
            stats.onchip_bound_cycles = 10.0;
            stats.cycles = 40.0;
            stats.boundary_words = vec![
                (crate::arch::level::LevelKind::LLB, 200.0),
                (crate::arch::level::LevelKind::DRAM, 400.0),
            ];
            stats.dram_words = 400.0;
            mapped.push(MappedOp { op_index: i, sub_accel: sub, stats, evaluated: 0 });
        }
        let assignment: Vec<usize> = mapped.iter().map(|mo| mo.sub_accel).collect();
        let stats: Vec<&OpStats> = mapped.iter().map(|mo| &mo.stats).collect();
        for dynamic_bw in [false, true] {
            let opts = ScheduleOptions { dynamic_bw };
            let full = schedule(&g, &m, &mapped, &opts);
            let mut oracle = ScheduleOracle::new(&g, &m, &opts);
            assert_eq!(oracle.replay(&assignment, &stats), full.makespan);
        }
    }

    /// Queue delays: two independent ops forced onto one unit — the
    /// second waits exactly the first's latency; the op on the idle
    /// unit waits nothing.
    #[test]
    fn oracle_queue_delays_measure_unit_waiting() {
        let m = machine_het();
        let mut g = Cascade::new("qd");
        for name in ["a", "b", "c"] {
            g.push(TensorOp::gemm(name, Phase::Encoder, 4, 4, 4));
        }
        let mapped =
            vec![mapped_op(0, 0, 100.0), mapped_op(1, 0, 50.0), mapped_op(2, 1, 30.0)];
        let assignment = vec![0, 0, 1];
        let stats: Vec<&OpStats> = mapped.iter().map(|mo| &mo.stats).collect();
        let mut oracle = ScheduleOracle::new(&g, &m, &ScheduleOptions::default());
        let makespan = oracle.replay(&assignment, &stats);
        assert_eq!(makespan, 150.0);
        let d = oracle.queue_delays();
        assert_eq!(d[0], 0.0); // dispatched at t=0 (higher priority)
        assert_eq!(d[1], 100.0); // waited for unit 0
        assert_eq!(d[2], 0.0); // alone on unit 1
        assert_eq!(oracle.latencies(), &[100.0, 50.0, 30.0]);
    }

    /// Regression: a degenerate (NaN) latency op must not panic the
    /// dispatch loop — the old `partial_cmp(..).unwrap()` tie-break blew
    /// up the moment two ops with a NaN priority contended for a unit.
    /// Both the one-shot `schedule()` path and the oracle replay must
    /// survive; the resulting makespan is garbage (NaN-poisoned), which
    /// is fine — loud garbage beats a panic deep in a sweep.
    #[test]
    fn nan_latency_op_does_not_panic_dispatch() {
        let m = machine_het();
        let mut g = Cascade::new("nan");
        for name in ["a", "b", "c"] {
            g.push(TensorOp::gemm(name, Phase::Encoder, 4, 4, 4));
        }
        // Two NaN-priority ops contend for unit 0 (the max_by comparison
        // actually sees NaN on both sides), plus one sane op on unit 1.
        let mapped =
            vec![mapped_op(0, 0, f64::NAN), mapped_op(1, 0, f64::NAN), mapped_op(2, 1, 5.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        assert_eq!(r.intervals.len(), 3); // every op was dispatched
        let assignment = vec![0, 0, 1];
        let stats: Vec<&OpStats> = mapped.iter().map(|mo| &mo.stats).collect();
        let mut oracle = ScheduleOracle::new(&g, &m, &ScheduleOptions::default());
        let _ = oracle.replay(&assignment, &stats); // must not panic
    }

    /// Regression: utilisation bucketing on degenerate schedules. An
    /// empty cascade and a single zero-cost op both have makespan 0 —
    /// the old `makespan == 0.0` guard covered those, but `buckets == 0`
    /// divided by zero (width = inf) and a NaN makespan sailed past the
    /// equality check. All must yield an all-idle timeline, no panic.
    #[test]
    fn utilization_timeline_degenerate_schedules() {
        let m = machine_het();
        // Empty cascade: no intervals, makespan 0.
        let empty = ScheduleResult { makespan: 0.0, intervals: Vec::new(), busy: vec![0.0; 2] };
        assert_eq!(empty.utilization_timeline(&m, 8), vec![0.0; 8]);
        // Single zero-cost op: an interval of zero width at t=0.
        let mut g = Cascade::new("z");
        g.push(TensorOp::gemm("a", Phase::Encoder, 4, 4, 4));
        let mapped = vec![mapped_op(0, 0, 0.0)];
        let r = schedule(&g, &m, &mapped, &ScheduleOptions::default());
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.utilization_timeline(&m, 8), vec![0.0; 8]);
        // Zero buckets: empty timeline, never a division by zero.
        let busy_one = ScheduleResult {
            makespan: 100.0,
            intervals: vec![Interval { op: 0, sub_accel: 0, start: 0.0, end: 100.0 }],
            busy: vec![100.0, 0.0],
        };
        assert_eq!(busy_one.utilization_timeline(&m, 0), Vec::<f64>::new());
        // NaN-poisoned makespan (degenerate latency upstream): all idle.
        let poisoned = ScheduleResult {
            makespan: f64::NAN,
            intervals: vec![Interval { op: 0, sub_accel: 0, start: 0.0, end: f64::NAN }],
            busy: vec![0.0; 2],
        };
        assert_eq!(poisoned.utilization_timeline(&m, 4), vec![0.0; 4]);
    }

    #[test]
    fn dynamic_bw_helps_memory_bound_solo_op() {
        let mut g = Cascade::new("dyn");
        g.push(TensorOp::gemm("a", Phase::Decode, 4, 4, 4));
        let m = machine_het();
        // Memory-bound op: 1000 DRAM words, compute floor 1 cycle.
        let mut stats = OpStats::new_empty();
        stats.compute_cycles = 1.0;
        stats.onchip_bound_cycles = 1.0;
        stats.boundary_words =
            vec![(crate::arch::level::LevelKind::DRAM, 1000.0)];
        let low_bw = m.sub_accels[1].spec.dram().bw_words_per_cycle;
        stats.cycles = 1000.0 / low_bw;
        stats.dram_words = 1000.0;
        let mapped = vec![MappedOp { op_index: 0, sub_accel: 1, stats, evaluated: 0 }];
        let stat = schedule(&g, &m, &mapped, &ScheduleOptions { dynamic_bw: false });
        let dyn_ = schedule(&g, &m, &mapped, &ScheduleOptions { dynamic_bw: true });
        assert!(dyn_.makespan < stat.makespan);
    }
}
