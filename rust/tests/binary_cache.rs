//! Robustness + differential suite for the `harp_bin` binary cache
//! spills, covering BOTH persistence layers: the mapping cache
//! (`mapper/mapcache.rs`) and the evaluation cache
//! (`coordinator/figures.rs`).
//!
//! Contract under test:
//! - spill → load → re-evaluate is **bitwise** the fresh evaluation,
//!   for both layers;
//! - a truncation at ANY 97-byte step is a loud, cut-specific error —
//!   never a panic, never a quiet partial load;
//! - doctored magic/version/budget bytes reject with DISTINCT messages;
//! - the same cache contents behind JSON and binary spills serve
//!   byte-identical results (the formats are interchangeable encodings,
//!   not different caches).

use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::coordinator::figures::{EvalCacheError, Evaluator};
use harp::hhp::allocator::AllocPolicy;
use harp::mapper::MapCache;
use harp::util::binio::CacheFormat;
use harp::workload::cascade::Cascade;
use harp::workload::einsum::{Phase, TensorOp};
use harp::workload::registry::WorkloadSpec;
use harp::workload::transformer;
use std::collections::HashSet;
use std::path::{Path, PathBuf};

fn small_cascade() -> Cascade {
    let mut g = Cascade::new("bincache");
    g.push(TensorOp::gemm("a", Phase::Encoder, 64, 128, 64));
    g.push(TensorOp::gemm("b", Phase::Encoder, 64, 128, 64));
    g.push(TensorOp::bmm("c", Phase::Decode, 4, 64, 32, 64));
    g.dep(0, 2);
    g
}

/// Search-policy options (the policy that routes both mapper entry
/// points through the mapping cache), optionally bound to a cache file.
fn opts(cache: Option<&Path>) -> EvalOptions {
    let mut o = EvalOptions { samples: 8, ..EvalOptions::default() };
    o.alloc = AllocPolicy::Search;
    o.threads = 2;
    if let Some(p) = cache {
        o.attach_mapping_cache(p).expect("cache attach must succeed");
    }
    o
}

fn eval_doc(o: &EvalOptions) -> String {
    let g = small_cascade();
    let r = evaluate_cascade_on_config(
        &HarpClass::from_id("hier+xnode").unwrap(),
        &HardwareParams::default(),
        &g,
        o,
    )
    .unwrap();
    r.stats.to_json().to_string_pretty()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("harp-bincache-it-{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Mapping cache, binary spill: cold seeds the file, a fresh attach
/// loads it, and the warm evaluation is byte-identical to both the
/// cache-less baseline and the cold run.
#[test]
fn mapcache_binary_spill_serves_bitwise_results() {
    let dir = temp_dir("mapcache-roundtrip");
    let path = dir.join("mappings.bin");
    std::fs::remove_file(&path).ok();

    let plain = eval_doc(&opts(None));
    let cold_opts = opts(Some(&path));
    assert_eq!(
        cold_opts.map_cache.as_ref().unwrap().format(),
        CacheFormat::Binary,
        ".bin must select the binary spill"
    );
    let cold = eval_doc(&cold_opts);
    assert_eq!(plain, cold, "cold binary cache changed the stats document");
    cold_opts.map_cache.as_ref().unwrap().persist().unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert!(bytes.starts_with(b"harp_bin"), "binary spill must carry the magic");

    let warm_opts = opts(Some(&path));
    assert_eq!(
        warm_opts.map_cache.as_ref().unwrap().len(),
        cold_opts.map_cache.as_ref().unwrap().len(),
        "spill → load must preserve every entry"
    );
    let warm = eval_doc(&warm_opts);
    assert_eq!(plain, warm, "warm binary cache changed the stats document");
    // A pure-hit run computes nothing new; re-persisting must not move
    // the file.
    warm_opts.map_cache.as_ref().unwrap().persist().unwrap();
    assert_eq!(bytes, std::fs::read(&path).unwrap());
    std::fs::remove_file(&path).ok();
}

/// Evaluation cache, binary spill: same contract one layer up.
#[test]
fn evalcache_binary_spill_serves_bitwise_results() {
    let dir = temp_dir("evalcache-roundtrip");
    let path = dir.join("evals.bin");
    std::fs::remove_file(&path).ok();

    let o = EvalOptions { samples: 10, ..EvalOptions::default() };
    let wl = WorkloadSpec::Transformer(transformer::bert_large());
    let class = HarpClass::eval_points()[0].1.clone();

    let ev = Evaluator::with_spill(o.clone(), &path, CacheFormat::Binary).unwrap();
    let fresh = ev.eval(&wl, &class, 2048.0, None);
    ev.persist().unwrap();
    assert!(std::fs::read(&path).unwrap().starts_with(b"harp_bin"));

    let ev2 = Evaluator::with_spill(o, &path, CacheFormat::Binary).unwrap();
    assert_eq!(ev2.len(), 1, "spill → load must preserve the entry");
    let cached = ev2.eval(&wl, &class, 2048.0, None);
    assert_eq!(
        cached.to_json().to_string_pretty(),
        fresh.to_json().to_string_pretty(),
        "binary eval-cache round trip must be bitwise"
    );
    std::fs::remove_file(&path).ok();
}

/// Every 97-byte-step truncation of a valid binary spill is rejected
/// with a distinct, non-empty message — never a panic — for both cache
/// layers.
#[test]
fn binary_truncations_error_distinctly_at_every_cut() {
    // Mapping-cache layer.
    let dir = temp_dir("truncate");
    let map_path = dir.join("mappings.bin");
    std::fs::remove_file(&map_path).ok();
    let seed = opts(Some(&map_path));
    let _ = eval_doc(&seed);
    seed.map_cache.as_ref().unwrap().persist().unwrap();
    let full = std::fs::read(&map_path).unwrap();
    assert!(full.len() > 97, "spill too small to sweep");
    let cut_path = dir.join("truncated.bin");
    let mut seen = HashSet::new();
    for cut in (0..full.len()).step_by(97) {
        std::fs::write(&cut_path, &full[..cut]).unwrap();
        // Honourable header values: a cut past the header must fail on
        // the truncated PAYLOAD (a cut-specific offset), not collapse
        // into one shared fingerprint-mismatch message.
        let err = match MapCache::with_file(&cut_path, 1, seed.mapping_search_fingerprint()) {
            Ok(_) => panic!("mapcache truncation at {cut} bytes must be rejected"),
            Err(e) => e.to_string(),
        };
        assert!(!err.is_empty());
        assert!(seen.insert(err.clone()), "cut {cut}: duplicate message {err}");
    }

    // Eval-cache layer.
    let eval_path = dir.join("evals.bin");
    std::fs::remove_file(&eval_path).ok();
    let o = EvalOptions { samples: 10, ..EvalOptions::default() };
    let wl = WorkloadSpec::Transformer(transformer::bert_large());
    let class = HarpClass::eval_points()[0].1.clone();
    let ev = Evaluator::with_spill(o.clone(), &eval_path, CacheFormat::Binary).unwrap();
    ev.eval(&wl, &class, 2048.0, None);
    ev.persist().unwrap();
    let full = std::fs::read(&eval_path).unwrap();
    assert!(full.len() > 97, "spill too small to sweep");
    let cut_path = dir.join("truncated_eval.bin");
    let mut seen = HashSet::new();
    for cut in (0..full.len()).step_by(97) {
        std::fs::write(&cut_path, &full[..cut]).unwrap();
        let err = match Evaluator::with_spill(o.clone(), &cut_path, CacheFormat::Binary) {
            Ok(_) => panic!("evalcache truncation at {cut} bytes must be rejected"),
            Err(e) => e.to_string(),
        };
        assert!(!err.is_empty());
        assert!(seen.insert(err.clone()), "cut {cut}: duplicate message {err}");
    }
    std::fs::remove_file(&map_path).ok();
    std::fs::remove_file(&eval_path).ok();
}

/// Doctored magic bytes, a foreign model version, and a foreign budget
/// fingerprint reject with three DISTINCT messages on each layer.
#[test]
fn doctored_binary_headers_reject_distinctly() {
    // Mapping-cache layer.
    let dir = temp_dir("doctored");
    let map_path = dir.join("mappings.bin");
    std::fs::remove_file(&map_path).ok();
    let seed = opts(Some(&map_path));
    let _ = eval_doc(&seed);
    seed.map_cache.as_ref().unwrap().persist().unwrap();
    let clean = std::fs::read(&map_path).unwrap();

    let version_err = MapCache::with_file(&map_path, 4242, seed.mapping_search_fingerprint())
        .unwrap_err()
        .to_string();
    assert!(version_err.contains("version mismatch"), "{version_err}");

    let budget_err = MapCache::with_file(&map_path, 1, "s999|r0xsomething")
        .unwrap_err()
        .to_string();
    assert!(budget_err.contains("stale mapping cache"), "{budget_err}");

    let mut doctored = clean.clone();
    doctored[0] ^= 0xff;
    std::fs::write(&map_path, &doctored).unwrap();
    let magic_err = MapCache::with_file(&map_path, 1, seed.mapping_search_fingerprint())
        .unwrap_err()
        .to_string();
    assert!(magic_err.contains("magic"), "{magic_err}");

    let distinct: HashSet<&str> =
        [version_err.as_str(), budget_err.as_str(), magic_err.as_str()].into_iter().collect();
    assert_eq!(distinct.len(), 3, "mapcache causes must be distinguishable");

    // Eval-cache layer.
    let eval_path = dir.join("evals.bin");
    std::fs::remove_file(&eval_path).ok();
    let o = EvalOptions { samples: 10, ..EvalOptions::default() };
    let wl = WorkloadSpec::Transformer(transformer::bert_large());
    let class = HarpClass::eval_points()[0].1.clone();
    let ev = Evaluator::with_spill(o.clone(), &eval_path, CacheFormat::Binary).unwrap();
    ev.eval(&wl, &class, 2048.0, None);
    ev.persist().unwrap();
    let clean = std::fs::read(&eval_path).unwrap();

    // The model-version field sits right after the container header:
    // magic (8) + kind ("evalcache": 4 + 9) + format u32 (4) = 25.
    let version_off = 8 + 4 + "evalcache".len() + 4;
    let mut doctored = clean.clone();
    doctored[version_off] ^= 0xff;
    std::fs::write(&eval_path, &doctored).unwrap();
    let version_err = Evaluator::with_spill(o.clone(), &eval_path, CacheFormat::Binary)
        .unwrap_err();
    assert!(matches!(version_err, EvalCacheError::VersionMismatch { .. }), "{version_err}");
    let version_err = version_err.to_string();

    std::fs::write(&eval_path, &clean).unwrap();
    let stale = EvalOptions { samples: 11, ..EvalOptions::default() };
    let budget_err = Evaluator::with_spill(stale, &eval_path, CacheFormat::Binary)
        .unwrap_err();
    assert!(matches!(budget_err, EvalCacheError::StaleFingerprint { .. }), "{budget_err}");
    let budget_err = budget_err.to_string();

    let mut doctored = clean.clone();
    doctored[0] ^= 0xff;
    std::fs::write(&eval_path, &doctored).unwrap();
    let magic_err =
        Evaluator::with_spill(o.clone(), &eval_path, CacheFormat::Binary).unwrap_err();
    assert!(matches!(magic_err, EvalCacheError::Malformed(_)), "{magic_err}");
    let magic_err = magic_err.to_string();
    assert!(magic_err.contains("magic"), "{magic_err}");

    let distinct: HashSet<&str> =
        [version_err.as_str(), budget_err.as_str(), magic_err.as_str()].into_iter().collect();
    assert_eq!(distinct.len(), 3, "evalcache causes must be distinguishable");

    // The untouched spill still loads.
    std::fs::write(&eval_path, &clean).unwrap();
    let ok = Evaluator::with_spill(o, &eval_path, CacheFormat::Binary).unwrap();
    assert_eq!(ok.len(), 1);
    std::fs::remove_file(&map_path).ok();
    std::fs::remove_file(&eval_path).ok();
}

/// JSON↔binary differential: the same cache contents behind either
/// spill format serve byte-identical evaluation documents, for both
/// layers.
#[test]
fn json_and_binary_spills_serve_identical_results() {
    // Mapping-cache layer: seed a JSON and a binary spill from the same
    // evaluation, then warm-run from each.
    let dir = temp_dir("differential");
    let json_path = dir.join("mappings.json");
    let bin_path = dir.join("mappings.bin");
    std::fs::remove_file(&json_path).ok();
    std::fs::remove_file(&bin_path).ok();

    let plain = eval_doc(&opts(None));
    let seed_json = opts(Some(&json_path));
    let _ = eval_doc(&seed_json);
    seed_json.map_cache.as_ref().unwrap().persist().unwrap();
    let seed_bin = opts(Some(&bin_path));
    let _ = eval_doc(&seed_bin);
    seed_bin.map_cache.as_ref().unwrap().persist().unwrap();
    assert_eq!(
        seed_json.map_cache.as_ref().unwrap().len(),
        seed_bin.map_cache.as_ref().unwrap().len(),
        "both formats must capture the same entry set"
    );

    let warm_json = eval_doc(&opts(Some(&json_path)));
    let warm_bin = eval_doc(&opts(Some(&bin_path)));
    assert_eq!(warm_json, plain, "JSON-cached eval drifted from fresh");
    assert_eq!(warm_bin, plain, "binary-cached eval drifted from fresh");
    assert_eq!(warm_json, warm_bin);

    // Eval-cache layer: same point spilled both ways, reloaded, served.
    let o = EvalOptions { samples: 10, ..EvalOptions::default() };
    let wl = WorkloadSpec::Transformer(transformer::bert_large());
    let class = HarpClass::eval_points()[0].1.clone();
    let ev_json_path = dir.join("evals.json");
    let ev_bin_path = dir.join("evals.bin");
    std::fs::remove_file(&ev_json_path).ok();
    std::fs::remove_file(&ev_bin_path).ok();

    let a = Evaluator::with_spill(o.clone(), &ev_json_path, CacheFormat::Json).unwrap();
    let fresh = a.eval(&wl, &class, 2048.0, None).to_json().to_string_pretty();
    a.persist().unwrap();
    let b = Evaluator::with_spill(o.clone(), &ev_bin_path, CacheFormat::Binary).unwrap();
    b.eval(&wl, &class, 2048.0, None);
    b.persist().unwrap();

    let from_json = Evaluator::with_spill(o.clone(), &ev_json_path, CacheFormat::Json).unwrap();
    let from_bin = Evaluator::with_spill(o, &ev_bin_path, CacheFormat::Binary).unwrap();
    assert_eq!(from_json.len(), 1);
    assert_eq!(from_bin.len(), 1);
    let doc_json = from_json.eval(&wl, &class, 2048.0, None).to_json().to_string_pretty();
    let doc_bin = from_bin.eval(&wl, &class, 2048.0, None).to_json().to_string_pretty();
    assert_eq!(doc_json, fresh, "JSON eval-cache drifted");
    assert_eq!(doc_bin, fresh, "binary eval-cache drifted");
    assert_eq!(doc_json, doc_bin);

    for p in [&json_path, &bin_path, &ev_json_path, &ev_bin_path] {
        std::fs::remove_file(p).ok();
    }
}
