//! Property and robustness suite for the persistent mapping cache.
//!
//! The cache's contract is cache-hit-equals-fresh, **bitwise**: an
//! evaluation served from a spilled `(shape, unit) → mapping` cache
//! must produce the byte-identical `CascadeStats` document a fresh
//! search produces — across processes (spill → load) and worker
//! counts. Anything the cache cannot honour must be rejected loudly
//! with a cause-specific error, never served quietly and never a
//! panic: the robustness half truncates a valid spill at every 97-byte
//! step and doctors its version/budget headers.

use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::hhp::allocator::AllocPolicy;
use harp::mapper::MapCache;
use harp::workload::cascade::Cascade;
use harp::workload::einsum::{Phase, TensorOp};
use std::path::{Path, PathBuf};

fn small_cascade() -> Cascade {
    let mut g = Cascade::new("mapcache");
    g.push(TensorOp::gemm("a", Phase::Encoder, 64, 128, 64));
    g.push(TensorOp::gemm("b", Phase::Encoder, 64, 128, 64)); // same shape as a
    g.push(TensorOp::bmm("c", Phase::Decode, 4, 64, 32, 64));
    g.push(TensorOp::gemm("d", Phase::Prefill, 128, 64, 32));
    g.dep(0, 2);
    g.dep(1, 3);
    g
}

/// Options for a quick search-policy evaluation (the policy that routes
/// BOTH mapper entry points — the cost matrix and the final mapping —
/// through the cache), optionally attached to a cache file.
fn opts(threads: usize, cache: Option<&Path>) -> EvalOptions {
    let mut o = EvalOptions { samples: 8, ..EvalOptions::default() };
    o.alloc = AllocPolicy::Search;
    o.threads = threads;
    if let Some(p) = cache {
        o.attach_mapping_cache(p).expect("cache attach must succeed");
    }
    o
}

fn eval_doc(o: &EvalOptions) -> String {
    let g = small_cascade();
    let r = evaluate_cascade_on_config(
        &HarpClass::from_id("hier+xnode").unwrap(),
        &HardwareParams::default(),
        &g,
        o,
    )
    .unwrap();
    r.stats.to_json().to_string_pretty()
}

fn temp_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("harp-mapcache-it-{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("mappings.json")
}

/// Cold-cache, warm-in-process, and warm-across-"processes" (a fresh
/// cache loaded from the spill) evaluations all emit the byte-identical
/// stats document a cache-less evaluation emits — and a warm run adds
/// no entries, so re-persisting is a no-op on the file bytes.
#[test]
fn cached_evaluations_are_byte_identical_to_fresh() {
    let path = temp_path("identity");
    std::fs::remove_file(&path).ok();

    let plain = eval_doc(&opts(2, None));

    let cold_opts = opts(2, Some(&path));
    let cold = eval_doc(&cold_opts);
    assert_eq!(plain, cold, "cold cache changed the stats document");
    let mc = cold_opts.map_cache.as_ref().unwrap();
    assert!(mc.len() > 0, "search-policy eval must populate the cache");
    mc.persist().unwrap();
    let spilled = std::fs::read(&path).unwrap();
    assert!(!spilled.is_empty());

    // A second attach = a new process loading the spill.
    let warm_opts = opts(2, Some(&path));
    let loaded = warm_opts.map_cache.as_ref().unwrap().len();
    assert_eq!(loaded, mc.len(), "spill → load must preserve every entry");
    let warm = eval_doc(&warm_opts);
    assert_eq!(plain, warm, "warm cache changed the stats document");
    assert_eq!(
        warm_opts.map_cache.as_ref().unwrap().len(),
        loaded,
        "a warm run must hit, not grow the cache"
    );
    warm_opts.map_cache.as_ref().unwrap().persist().unwrap();
    assert_eq!(
        spilled,
        std::fs::read(&path).unwrap(),
        "re-persisting a clean cache must not move the file"
    );
    std::fs::remove_file(&path).ok();
}

/// Cache hits are worker-count invariant: serial and parallel
/// evaluations over the same warm cache emit identical documents
/// (and match the cache-less baseline at each count).
#[test]
fn warm_cache_is_bitwise_across_thread_counts() {
    let path = temp_path("threads");
    std::fs::remove_file(&path).ok();

    let seed_opts = opts(2, Some(&path));
    let baseline = eval_doc(&seed_opts);
    seed_opts.map_cache.as_ref().unwrap().persist().unwrap();

    for threads in [1usize, 4] {
        let fresh = eval_doc(&opts(threads, None));
        let cached = eval_doc(&opts(threads, Some(&path)));
        assert_eq!(fresh, baseline, "threads={threads}: fresh eval drifted");
        assert_eq!(cached, baseline, "threads={threads}: cached eval drifted");
    }
    std::fs::remove_file(&path).ok();
}

/// Every strict prefix of a valid spill (stepped at 97 bytes so the
/// cuts land everywhere: mid-number, mid-key, mid-structure) is
/// rejected with an error — never a panic, never a quiet partial load.
#[test]
fn truncated_spills_error_at_every_cut() {
    let path = temp_path("truncate");
    std::fs::remove_file(&path).ok();

    let seed_opts = opts(2, Some(&path));
    let _ = eval_doc(&seed_opts);
    seed_opts.map_cache.as_ref().unwrap().persist().unwrap();
    let full = std::fs::read(&path).unwrap();
    assert!(full.len() > 97, "spill too small to sweep");

    let cut_path = path.with_file_name("truncated.json");
    for cut in (0..full.len()).step_by(97) {
        std::fs::write(&cut_path, &full[..cut]).unwrap();
        let err = match MapCache::with_file(&cut_path, 1, "anything") {
            Ok(_) => panic!("truncation at {cut} bytes must be rejected"),
            Err(e) => e,
        };
        // Rejection must be loud AND descriptive.
        assert!(!err.to_string().is_empty());
    }
    // The untruncated file still loads (with the real header values).
    let mut reopen = opts(2, Some(&path));
    assert!(reopen.map_cache.take().unwrap().len() > 0);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cut_path).ok();
}

/// A spill from another model version and a spill from another search
/// budget are both rejected loudly, with DISTINCT messages naming what
/// they found vs expected — a user can tell the two apart from stderr.
#[test]
fn version_and_budget_mismatches_reject_with_distinct_errors() {
    let path = temp_path("mismatch");
    std::fs::remove_file(&path).ok();

    let seed_opts = opts(2, Some(&path));
    let _ = eval_doc(&seed_opts);
    seed_opts.map_cache.as_ref().unwrap().persist().unwrap();
    let doc = std::fs::read_to_string(&path).unwrap();

    // Doctor the model version.
    let versioned = doc.replace("\"model_version\":1", "\"model_version\":4242");
    assert_ne!(doc, versioned, "spill layout changed — update this test");
    std::fs::write(&path, &versioned).unwrap();
    let mut o = EvalOptions { samples: 8, ..EvalOptions::default() };
    let version_err = o.attach_mapping_cache(&path).unwrap_err();
    assert!(
        version_err.contains("version mismatch") && version_err.contains("4242"),
        "unhelpful version error: {version_err}"
    );

    // Restore, then attach under a different search budget.
    std::fs::write(&path, &doc).unwrap();
    let mut stale_o = EvalOptions { samples: 9, ..EvalOptions::default() };
    let stale_err = stale_o.attach_mapping_cache(&path).unwrap_err();
    assert!(
        stale_err.contains("stale mapping cache"),
        "unhelpful stale-budget error: {stale_err}"
    );
    assert_ne!(version_err, stale_err, "causes must be distinguishable");

    // The untouched file still attaches fine under the original budget.
    let mut ok = EvalOptions { samples: 8, ..EvalOptions::default() };
    ok.attach_mapping_cache(&path).unwrap();
    assert!(ok.map_cache.unwrap().len() > 0);
    std::fs::remove_file(&path).ok();
}
